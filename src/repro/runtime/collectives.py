"""Distributed decode attention: sequence-parallel KV with the appendix's
significand-exponent combine.

The paper's appendix defines the safe combination of exponentiated partial
sums:   (S1,t1) + (S2,t2) = (S1 e^{t1-z} + S2 e^{t2-z}, z),  z = max(t1,t2)

That identity IS the flash-decoding partial-softmax merge: each device
holds a slice of the KV cache along the sequence axis, computes its local
(numerator, denominator, max) triple with the on-chip fused kernel, and
the cross-chip reduction applies the pair algebra with psum/pmax over the
ICI — turning long-context decode from one chip's memory-bound scan into
a parallel scan over ``data``-axis shards.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

NEG_INF = -1e30


def _local_partial(q, k, v, scale, kv_valid):
    """Per-shard attention partials: (numerator, denominator, rowmax).

    q: (B,H,1,Dh); k,v: (B,Hkv,S_shard,Dh); kv_valid: how many of this
    shard's positions are filled (mask beyond)."""
    b, h, _, dh = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, dh)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32)) * scale
    cols = jnp.arange(k.shape[2])[None, None, None, :]
    s = jnp.where(cols < kv_valid, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)                     # (b,hkv,g,1)
    p = jnp.exp(s - m)
    num = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    den = p.sum(axis=-1, keepdims=True)
    return num, den, m


def distributed_decode_attention(q, k_cache, v_cache, pos, mesh, *,
                                 scale: Optional[float] = None,
                                 seq_axis: str = "data"):
    """One-token attention against a KV cache sharded along its sequence
    dim over ``seq_axis``.  q: (B,H,1,Dh); caches: (B,Hkv,S,Dh) with S
    sharded.  ``pos``: number of valid cache entries (global)."""
    b, h, _, dh = q.shape
    hkv, s_total = k_cache.shape[1], k_cache.shape[2]
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    n_shards = mesh.shape[seq_axis]
    s_shard = s_total // n_shards

    def body(q, k, v, pos):
        idx = jax.lax.axis_index(seq_axis)
        start = idx * s_shard
        kv_valid = jnp.clip(pos + 1 - start, 0, s_shard)
        num, den, m = _local_partial(q, k, v, scale, kv_valid)
        # appendix pair algebra across shards: z = max(t_i)
        z = jax.lax.pmax(m, seq_axis)
        alpha = jnp.exp(m - z)                     # e^{t_i - z}
        num = jax.lax.psum(num * alpha, seq_axis)  # sum of S_i e^{t_i - z}
        den = jax.lax.psum(den * alpha, seq_axis)
        out = num / den
        g = h // hkv
        return out.reshape(b, h, 1, dh)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(None, None, seq_axis, None),
                  P(None, None, seq_axis, None), P()),
        out_specs=P(),
    )
    return fn(q, k_cache, v_cache, jnp.asarray(pos, jnp.int32)).astype(
        q.dtype)
