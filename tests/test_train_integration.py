"""End-to-end training integration: loss goes down; crash/restart resumes
bitwise-identically (fault tolerance drill)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import train as T

pytestmark = pytest.mark.slow  # multi-step train loops: not tier-1


def test_train_loss_decreases(tmp_path):
    out = T.main(["--arch", "smollm-135m", "--reduced", "--steps", "60",
                  "--batch", "8", "--seq", "32", "--lr", "1e-2",
                  "--log-every", "5", "--ckpt-every", "0"])
    losses = dict(out["losses"])
    assert losses[55] < 0.8 * losses[0], losses


def test_crash_resume_is_bitwise_identical(tmp_path):
    common = ["--arch", "smollm-135m", "--reduced", "--batch", "4",
              "--seq", "16", "--lr", "1e-3", "--log-every", "1"]
    ck1 = str(tmp_path / "run_crash")
    ck2 = str(tmp_path / "run_clean")

    # run A: checkpoint at 10, crash at 14, restart to 20
    with pytest.raises(SystemExit):
        T.main(common + ["--steps", "20", "--ckpt-dir", ck1,
                         "--ckpt-every", "10", "--fail-at", "13"])
    out_resumed = T.main(common + ["--steps", "20", "--ckpt-dir", ck1,
                                   "--ckpt-every", "10"])

    # run B: uninterrupted
    out_clean = T.main(common + ["--steps", "20", "--ckpt-dir", ck2,
                                 "--ckpt-every", "10"])

    pa = jax.tree.leaves(out_resumed["params"])
    pb = jax.tree.leaves(out_clean["params"])
    for a, b in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
