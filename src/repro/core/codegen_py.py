"""Emit paper-style listings (``forall``/``for``/``load``/``store``) for any
block program.  Display-oriented: this is the notation used throughout the
paper's worked examples; execution is the interpreter's job.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core import ops as O
from repro.core.graph import (FuncNode, Graph, InputNode, MapNode, MiscNode,
                              OutputNode, ReduceNode)


@dataclass
class _Val:
    """Either a local temp (name) or a view into global memory
    (buffer name + accumulated indices, remaining dims)."""

    name: str
    idx: Tuple[str, ...] = ()
    is_global: bool = False
    n_dims: int = 0  # remaining list depth

    def subscript(self) -> str:
        if not self.idx:
            return self.name
        return f"{self.name}[{','.join(self.idx)}]"


class _Emitter:
    def __init__(self):
        self.lines: List[str] = []
        self.tmp = 0
        self.buf = 0
        self.used_idx: Dict[str, int] = {}

    def temp(self) -> str:
        self.tmp += 1
        return f"t{self.tmp}"

    def buffer(self) -> str:
        self.buf += 1
        return f"I{self.buf}"

    def index(self, dim: str) -> str:
        base = dim.lower()
        k = self.used_idx.get(base, 0)
        self.used_idx[base] = k + 1
        return base if k == 0 else f"{base}{k+1}"

    def release_index(self, dim: str) -> None:
        base = dim.lower()
        self.used_idx[base] -= 1

    def emit(self, indent: int, text: str) -> None:
        self.lines.append("    " * indent + text)


def _localize(em: _Emitter, v: _Val, indent: int) -> str:
    """Return a local temp holding v, emitting a load if it is global."""
    if not v.is_global:
        return v.name
    t = em.temp()
    em.emit(indent, f"{t} = load({v.subscript()})")
    return t


def _emit_graph(em: _Emitter, g: Graph, bindings: List[_Val],
                indent: int) -> List[_Val]:
    env: Dict[Tuple[int, int], _Val] = {}
    local_cache: Dict[Tuple[int, int], str] = {}
    for nid, b in zip(g.input_ids, bindings):
        env[(nid, 0)] = b

    def resolve(nid: int, port: int) -> str:
        key = (nid, port)
        if key in local_cache:
            return local_cache[key]
        t = _localize(em, env[key], indent_now[0])
        local_cache[key] = t
        return t

    indent_now = [indent]
    outs: Dict[int, _Val] = {}
    for nid in g.topo():
        node = g.nodes[nid]
        if isinstance(node, InputNode):
            continue
        if isinstance(node, OutputNode):
            e = g.in_edge(nid, 0)
            outs[nid] = env[(e.src, e.sp)]
        elif isinstance(node, FuncNode):
            args = [resolve(e.src, e.sp) for e in g.in_edges(nid)]
            t = em.temp()
            em.emit(indent, f"{t} = {node.op.render(tuple(args))}")
            env[(nid, 0)] = _Val(t)
        elif isinstance(node, MiscNode):
            args = [resolve(e.src, e.sp) for e in g.in_edges(nid)]
            t = em.temp()
            em.emit(indent, f"{t} = {node.name}({', '.join(args)})")
            for p in range(node.n_out()):
                env[(nid, p)] = _Val(t if node.n_out() == 1 else f"{t}[{p}]")
        elif isinstance(node, ReduceNode):
            e = g.in_edge(nid, 0)
            src = env[(e.src, e.sp)]
            acc = em.temp()
            # reduce iterates the outermost remaining dim of a global list
            dim = _dim_of(g, e)
            ix = em.index(dim)
            em.emit(indent, f"for {ix} in range({dim}):")
            item = _Val(src.name, src.idx + (ix,), src.is_global,
                        src.n_dims - 1)
            t = _localize(em, item, indent + 1)
            em.emit(indent + 1, f"{acc} += {t}")
            em.release_index(dim)
            env[(nid, 0)] = _Val(acc)
        elif isinstance(node, MapNode):
            ix = em.index(node.dim)
            kw = "for" if node.serial else "forall"
            em.emit(indent, f"{kw} {ix} in range({node.dim}):")
            inner_b: List[_Val] = []
            for p in range(node.n_in()):
                e = g.in_edge(nid, p)
                src = env[(e.src, e.sp)]
                if node.mapped[p]:
                    inner_b.append(_Val(src.name, src.idx + (ix,),
                                        src.is_global, src.n_dims - 1))
                else:
                    inner_b.append(src)
            # pre-allocate out-port values
            port_vals: List[_Val] = []
            accs: Dict[int, str] = {}
            for p, r in enumerate(node.reduced):
                if r is None:
                    name = em.buffer()
                    outer_idx = _outer_indices(env, g, nid)
                    port_vals.append(_Val(name, outer_idx + (ix,),
                                          is_global=True))
                else:
                    accs[p] = em.temp()
                    port_vals.append(_Val(accs[p]))
            inner_out = _emit_graph(em, node.inner, inner_b, indent + 1)
            for p, r in enumerate(node.reduced):
                ov = inner_out[p]
                if r is None:
                    if ov.is_global:
                        # the inner value is already materialized; the port
                        # is a view of that buffer (no extra store)
                        env[(nid, p)] = _Val(ov.name, (), True,
                                             max(ov.n_dims, 0) + 1)
                        continue
                    em.emit(indent + 1,
                            f"store({ov.name}, {port_vals[p].subscript()})")
                    pv = port_vals[p]
                    env[(nid, p)] = _Val(pv.name, pv.idx[:-1], True, 1)
                else:
                    t = ov.name if not ov.is_global else _localize(
                        em, ov, indent + 1)
                    em.emit(indent + 1, f"{accs[p]} += {t}")
                    env[(nid, p)] = _Val(accs[p])
            em.release_index(node.dim)
        else:
            raise TypeError(node)
    return [outs[oid] for oid in g.output_ids]


def _dim_of(g: Graph, e) -> str:
    types = getattr(g, "_cached_types", None)
    if types is None:
        try:
            types = g.infer_types()
        except Exception:
            return "?"
        g._cached_types = types
    t = types.get((e.src, e.sp))
    return t.dims[0] if t is not None and t.dims else "?"


def _outer_indices(env, g, nid) -> Tuple[str, ...]:
    return ()


def compile_py(g: Graph, dims: Dict[str, int]):
    """Executable form of the listing semantics: a plain-python callable
    ``fn({name: nested_block_lists}) -> {name: nested_block_lists}`` backed
    by the reference interpreter.  This is the pipeline's ``py`` backend —
    the slow, obviously-correct end of the differential harness."""
    from repro.core.interpreter import run

    def fn(inputs: Dict[str, object]) -> Dict[str, object]:
        return run(g, inputs, dims)

    return fn


def render(g: Graph) -> str:
    """Render a top-level block program as a paper-style listing."""
    em = _Emitter()
    bindings = [
        _Val(g.nodes[nid].name, (), True, len(g.nodes[nid].vtype.dims))
        for nid in g.input_ids
    ]
    out_vals = _emit_graph(em, g, bindings, 0)
    for oid, v in zip(g.output_ids, out_vals):
        name = g.nodes[oid].name
        if v.is_global:
            em.emit(0, f"# output {name} aliases {v.subscript()}")
        else:
            em.emit(0, f"store({v.name}, {name})")
    return "\n".join(em.lines)
