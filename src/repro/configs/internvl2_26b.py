"""internvl2-26b [vlm]: InternViT + InternLM2 backbone.  The vision
frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (n_vision_tokens x d_model) that are prefixed
to the token embeddings.  [arXiv:2404.16821; hf]"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab=92553,
    rope_theta=1e6,
    n_vision_tokens=256,
)
