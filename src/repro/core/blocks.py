"""Utilities for splitting arrays into blocks and merging them back.

The paper stores each matrix as a list of lists-of-blocks (row-major).
Also home to the *merged dense* layout math (``merged_shape`` /
``item_shape``) shared by ``pipeline/packing.py`` and the Pallas
backend — pure functions of a VType, so they live in core and both
layers import downward.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.graph import VType


def split(arr, n_row_blocks: int, n_col_blocks: int) -> List[List[Any]]:
    """Split a matrix into an ``n_row_blocks x n_col_blocks`` nested list."""
    rows = np.array_split(arr, n_row_blocks, axis=0)
    return [list(np.array_split(r, n_col_blocks, axis=1)) for r in rows]


def split_rows(arr, n_row_blocks: int) -> List[Any]:
    return list(np.array_split(arr, n_row_blocks, axis=0))


def merge(blocks) -> np.ndarray:
    """Merge a nested list (or flat list) of blocks back into one array."""
    if isinstance(blocks[0], list):
        return np.concatenate([np.concatenate(row, axis=1) for row in blocks],
                              axis=0)
    if getattr(blocks[0], "ndim", 0) == 2:
        return np.concatenate(blocks, axis=0)
    return np.concatenate(blocks, axis=0)


def merge_vectors(vectors) -> np.ndarray:
    return np.concatenate(vectors, axis=0)


def merged_shape(vt: VType, item_shape: Sequence[int],
                 dims: Dict[str, int]) -> Tuple[int, ...]:
    """Shape of the merged dense array holding a value of type ``vt``
    whose items have shape ``item_shape``.  Leading list dims beyond the
    item rank are stack axes of extent ``dims[d]``; the next dims scale
    the item's axes; trailing item axes pass through.  This is the
    layout contract every region kernel reads and writes, so it is also
    how the Pallas backend sizes the intermediate arrays it threads
    between regions."""
    lead = max(len(vt.dims) - len(item_shape), 0)
    k = len(vt.dims) - lead
    shape = [dims[d] for d in vt.dims[:lead]]
    shape += [item_shape[j] * dims[vt.dims[lead + j]] for j in range(k)]
    shape += [item_shape[j] for j in range(k, len(item_shape))]
    return tuple(shape)


def item_shape(merged: Sequence[int], vt: VType,
               dims: Dict[str, int]) -> Tuple[int, ...]:
    """Inverse of :func:`merged_shape`: per-axis item extents of a value
    stored as a merged array of the given shape.  This does not assume
    the i-th list dim splits the i-th axis with a uniform per-dim block
    size — intermediates (e.g. matmul partials ``block[M,N,K]``) are
    covered too."""
    lead = vt.lead_dims
    out = [merged[lead + i] // dims[d]
           for i, d in enumerate(vt.dims[lead:])]
    out += list(merged[len(vt.dims):])
    return tuple(out)
