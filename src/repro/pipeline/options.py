"""``CompileOptions`` — the consolidated, hashable compile configuration.

``pipeline.compile`` grew ~15 keyword arguments across PRs 1-7
(``backend``, ``blocks``, ``autotune``, ``group``, ``stabilize``,
``profile``, ``top_k``, ...).  This module folds every option that
shapes the *emitted kernel* into one frozen dataclass that

* normalizes dict-valued fields (``blocks``, ``item_bytes``) into
  sorted tuples at construction, so two equal option sets compare and
  hash equal regardless of dict insertion order;
* is hashable — model layers key their per-shape kernel lru_caches on
  it, and serving engines key persistent per-(arch, shape-bucket)
  kernels on it;
* **hashes directly into the kernel-cache key**: ``cache_opts()``
  produces the canonical opts tuple ``CacheKey`` embeds, the single
  source of truth for "which options make two compiles distinct".

``pipeline.compile(graph, dims, options=CompileOptions(...))`` is the
primary API; the flat-kwargs form (``pipeline.compile(graph, dims,
backend=..., blocks=...)``) is kept as a back-compat shim — it builds a
``CompileOptions`` internally and is **deprecated**: new call sites
should construct options explicitly.

Problem *shape* stays out of the options on purpose: ``dims`` /
``dim_candidates`` describe what is being compiled, ``CompileOptions``
describes how.  The ``cache`` handle (a runtime resource, not a compile
decision) also stays a separate argument.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional, Tuple

_MAP_FIELDS = ("blocks", "item_bytes")


def _norm_map(value) -> Optional[Tuple[Tuple[str, Any], ...]]:
    """dict | tuple-of-pairs | None -> canonical sorted tuple of pairs."""
    if value is None:
        return None
    if isinstance(value, Mapping):
        return tuple(sorted(value.items()))
    return tuple(sorted(tuple(value)))


@dataclass(frozen=True)
class CompileOptions:
    """Everything that decides *how* a block program compiles.

    Fields mirror the historical ``pipeline.compile`` keywords; see the
    driver docstring for full semantics.  ``blocks`` / ``item_bytes``
    accept plain dicts and are canonicalized to sorted tuples, so the
    instance is hashable and order-insensitive.
    """

    backend: str = "jax"
    # per-dim block sizes (pallas backend) — dict accepted, stored as a
    # sorted tuple of (dim, size) pairs
    blocks: Optional[Tuple[Tuple[str, int], ...]] = None
    # cost-model per-item-kind byte overrides
    item_bytes: Optional[Tuple[Tuple[str, int], ...]] = None
    fused: bool = True
    interpret: Optional[bool] = None   # pallas: None = resolve per device
    jit: Any = True                    # True | False | "per-op" (jax)
    stabilize: Optional[bool] = None   # None = auto (softmax-bearing)
    autotune: str = "analytic"         # analytic | measured
    top_k: int = 3
    measure_repeats: int = 3
    group: bool = True                 # pallas region-group megakernels
    # calibration profile override (CalibrationProfile); participates in
    # hashing/equality via its digest, not object identity
    profile: Optional[Any] = None
    # degradation-ladder policy (resilience.ResiliencePolicy): how far a
    # failing compile may demote (grouped -> ungrouped -> jax ->
    # interpreter), per-attempt timeout, retry budget, and the health-
    # ledger breaker knobs (breaker_threshold / breaker_cooldown_s /
    # breaker_cooldown_max_s governing when a repeatedly-failing rung is
    # skipped outright and when it is probed again).  None = the default
    # policy (full ladder, no timeout, no retries, threshold-3 breaker),
    # which keeps cache keys byte-identical to pre-resilience builds
    resilience: Optional[Any] = None

    def __post_init__(self):
        for name in _MAP_FIELDS:
            object.__setattr__(self, name, _norm_map(getattr(self, name)))

    # -- dict views ---------------------------------------------------------
    @property
    def blocks_dict(self) -> Optional[Dict[str, int]]:
        return dict(self.blocks) if self.blocks is not None else None

    @property
    def item_bytes_dict(self) -> Optional[Dict[str, int]]:
        return dict(self.item_bytes) if self.item_bytes is not None else None

    # -- identity -----------------------------------------------------------
    def _profile_digest(self) -> Optional[str]:
        return self.profile.digest() if self.profile is not None else None

    def _policy(self):
        """The effective ResiliencePolicy (``None`` -> the default)."""
        from repro import resilience as RZ
        return (self.resilience if self.resilience is not None
                else RZ.DEFAULT_POLICY)

    def key(self) -> Tuple:
        """Canonical value tuple: what equality and hashing mean."""
        return (self.backend, self.blocks, self.item_bytes, self.fused,
                self.interpret,
                self.jit if self.jit == "per-op" else bool(self.jit),
                self.stabilize, self.autotune, int(self.top_k),
                int(self.measure_repeats), bool(self.group),
                self._profile_digest(), self._policy().key())

    def __hash__(self) -> int:
        return hash(self.key())

    def __eq__(self, other) -> bool:
        if not isinstance(other, CompileOptions):
            return NotImplemented
        return self.key() == other.key()

    def replace(self, **changes) -> "CompileOptions":
        """``dataclasses.replace`` that re-normalizes dict fields."""
        return dataclasses.replace(self, **changes)

    # -- the cache-key contribution -----------------------------------------
    def cache_opts(self, *, stabilized: bool, autotuned: bool,
                   profile=None, vmem_budget: Optional[int] = None
                   ) -> Tuple:
        """The opts tuple ``CacheKey`` embeds — every option that changes
        the emitted kernel or the selection plan, nothing that doesn't.

        ``stabilized`` is the *resolved* stabilization decision (the
        ``None`` auto-detect already applied), ``autotuned`` says whether
        a dim_candidates sweep is in play (the autotune mode only matters
        then), ``profile`` is the *effective* calibration profile (the
        driver may have auto-loaded one), and ``vmem_budget`` must be the
        resolved budget when grouping shapes a pallas plan.  For the
        pallas backend ``interpret`` must already be resolved to a bool.
        """
        from repro.core import calibrate as CAL
        opts: Tuple = ()
        if stabilized:
            opts += (("stabilize", True),)
        if self.backend == "jax":
            opts += (("jit", self.jit if self.jit == "per-op"
                      else bool(self.jit)),)
        if self.backend == "pallas":
            opts += (("interpret", self.interpret), ("jit", bool(self.jit)))
            if not self.group:
                opts += (("group", False),)
            else:
                # the VMEM budget shapes the grouping, so a plan cached
                # under one budget must never serve another (its
                # kernel_ids/launches would describe kernels that no
                # longer exist)
                opts += (("vmem_budget", vmem_budget),)
        if self.item_bytes:
            opts += (("item_bytes", self.item_bytes),)
        if autotuned and self.autotune != "analytic":
            opts += (("autotune", self.autotune),)
        if (profile is not None
                and profile.digest() != CAL.DEFAULT_PROFILE.digest()):
            # a different calibration profile can select a different
            # snapshot/dims: never serve its plan under the default's key
            opts += (("profile", profile.digest()),)
        from repro import resilience as RZ
        policy = self._policy()
        if policy != RZ.DEFAULT_POLICY:
            # a bounded ladder (max_rung above interpreter) or a timeout
            # can change which rung's kernel gets cached in-process;
            # keyed only when non-default so existing keys stay
            # byte-identical
            opts += (("resilience", policy.key()),)
        return opts


#: the defaults, shared: ``CompileOptions()`` allocates nothing new
DEFAULT_OPTIONS = CompileOptions()
