"""Reference executor for block programs.

Executes the hierarchical graph exactly per its semantics: maps iterate,
reduced out-ports accumulate, reduces sum lists.  Values are numpy (or jnp)
arrays for items and nested python lists for list types.

This is the *logic-preservation oracle*: every snapshot produced by the
fusion algorithm must interpret to the same outputs as the original program
(the substitution rules are logic-preserving, paper §3).
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core import ops as O
from repro.core.graph import (FuncNode, Graph, InputNode, MapNode, MiscNode,
                              OutputNode, ReduceNode)


@dataclass
class RunStats:
    func_applications: Counter = field(default_factory=Counter)


def _map_length(node: MapNode, in_values: Sequence[Any],
                dims: Dict[str, int]) -> int:
    for p, m in enumerate(node.mapped):
        if m:
            return len(in_values[p])
    if node.dim in dims:
        return dims[node.dim]
    raise ValueError(f"cannot determine length of map dim {node.dim}")


def _accum(acc, val, op: str, xp):
    if acc is None:
        return val
    if op == O.REDUCE_ADD:
        return acc + val
    if op == O.REDUCE_MAX:
        return xp.maximum(acc, val)
    raise NotImplementedError(op)


def _apply(op, xp, *args):
    return op.apply(xp, *args)


def eval_graph(g: Graph, in_values: Sequence[Any], dims: Dict[str, int],
               xp=np, stats: Optional[RunStats] = None,
               apply_fn=_apply, accum_fn=_accum) -> List[Any]:
    env: Dict = {}
    for nid, v in zip(g.input_ids, in_values):
        env[(nid, 0)] = v
    outs: Dict[int, Any] = {}
    for nid in g.topo():
        node = g.nodes[nid]
        if isinstance(node, InputNode):
            continue
        ins = [env[(e.src, e.sp)] for e in g.in_edges(nid)]
        if isinstance(node, OutputNode):
            outs[nid] = ins[0]
        elif isinstance(node, FuncNode):
            env[(nid, 0)] = apply_fn(node.op, xp, *ins)
            if stats is not None:
                stats.func_applications[node.op.name] += 1
        elif isinstance(node, ReduceNode):
            acc = None
            for item in ins[0]:
                acc = accum_fn(acc, item, node.op, xp)
            env[(nid, 0)] = acc
        elif isinstance(node, MiscNode):
            res = node.fn(xp, *ins)
            if node.n_out() == 1:
                env[(nid, 0)] = res
            else:
                for p, r in enumerate(res):
                    env[(nid, p)] = r
        elif isinstance(node, MapNode):
            length = _map_length(node, ins, dims)
            collected: List[Any] = [None] * node.n_out()
            for p, r in enumerate(node.reduced):
                if r is None:
                    collected[p] = []
            plain = O.plain_serial_tags(node.reduced)
            for i in range(length):
                inner_in = [v[i] if node.mapped[p] else v
                            for p, v in enumerate(ins)]
                inner_out = eval_graph(node.inner, inner_in, dims, xp, stats,
                                       apply_fn, accum_fn)
                if plain:
                    # legacy path: pluggable accum_fn (run_stabilized
                    # threads SEPair accumulation through it)
                    for p, r in enumerate(node.reduced):
                        if r is None:
                            collected[p].append(inner_out[p])
                        else:
                            collected[p] = accum_fn(collected[p],
                                                    inner_out[p], r, xp)
                else:
                    # stabilized graphs: coupled "max"/"+@k" carries
                    O.serial_accum_step(collected, inner_out,
                                        node.reduced, xp)
            for p in range(node.n_out()):
                env[(nid, p)] = collected[p]
        else:
            raise TypeError(node)
    return [outs[oid] for oid in g.output_ids]


def run(g: Graph, inputs: Dict[str, Any], dims: Dict[str, int], xp=np,
        stats: Optional[RunStats] = None, apply_fn=_apply,
        accum_fn=_accum) -> Dict[str, Any]:
    in_values = [inputs[g.nodes[nid].name] for nid in g.input_ids]
    out_values = eval_graph(g, in_values, dims, xp, stats, apply_fn, accum_fn)
    return {g.nodes[oid].name: v
            for oid, v in zip(g.output_ids, out_values)}
