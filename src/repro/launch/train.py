"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features exercised at full scale by the launcher (and at CPU scale by the
integration tests):
  * auto-resume from the latest checkpoint (``--resume auto``), with the
    deterministic step-keyed data pipeline replaying identically;
  * async checkpointing every ``--ckpt-every`` steps with atomic publish;
  * optional failure injection (``--fail-at N``) to drill the
    crash/restart path;
  * XLA latency-hiding scheduler flags for compute/collective overlap
    (set on TPU; harmless on CPU).
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

TPU_PERF_FLAGS = (
    "--xla_tpu_enable_data_parallel_all_reduce_opt=true "
    "--xla_tpu_data_parallel_opt_different_sized_ops=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true "
)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-scale reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a crash after this step (fault drill)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "debug", "single", "multi"])
    args = ap.parse_args(argv)

    if "libtpu" in os.environ.get("TPU_LIBRARY_PATH", ""):
        os.environ.setdefault("XLA_FLAGS", TPU_PERF_FLAGS)

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, get_reduced_config
    from repro.data import SyntheticLMData
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.launch.steps import make_train_step, sanitize_shardings
    from repro.models import build_model
    from repro.optim import AdamW, cosine_schedule
    from repro.runtime import sharding as SH
    from repro.runtime.sharding import tree_shardings

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    mesh = {"none": None, "debug": make_debug_mesh(),
            "single": None, "multi": None}[args.mesh]
    if args.mesh == "single":
        mesh = make_production_mesh()
    elif args.mesh == "multi":
        mesh = make_production_mesh(multi_pod=True)

    model = build_model(cfg)
    optimizer = AdamW(lr=cosine_schedule(args.lr, 20, args.steps))
    data = SyntheticLMData(cfg.vocab, args.seq, args.batch, seed=args.seed)

    with SH.use_mesh(mesh):
        params, specs = model.init_params(jax.random.key(args.seed))
        opt_state = optimizer.init(params)
        start_step = 0

        mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
        if mgr and args.resume == "auto" and mgr.latest_step() is not None:
            shardings = None
            if mesh is not None:
                shardings = {
                    "params": tree_shardings(specs, mesh),
                    "opt": tree_shardings(optimizer.state_specs(specs),
                                          mesh),
                    "step": None,
                }
            state = mgr.restore(shardings=shardings)
            params, opt_state = state["params"], state["opt"]
            start_step = int(state["step"])
            print(f"resumed from step {start_step}")

        step_fn = make_train_step(model, optimizer)
        jit_kwargs = {}
        if mesh is not None:
            from repro.launch.steps import batch_shardings  # noqa: F401
            pass
        train_step = jax.jit(step_fn, donate_argnums=(0, 1))

        losses = []
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = data.batch(step)
            params, opt_state, metrics = train_step(params, opt_state,
                                                    batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                rate = (step - start_step + 1) / (time.time() - t0)
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({rate:.2f} it/s)", flush=True)
            if mgr and args.ckpt_every > 0 and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt": opt_state,
                                    "step": jnp.asarray(step + 1)})
            if args.fail_at >= 0 and step == args.fail_at:
                print("injected failure!", flush=True)
                sys.exit(42)
        if mgr:
            mgr.save(args.steps, {"params": params, "opt": opt_state,
                                  "step": jnp.asarray(args.steps)},
                     blocking=True)
    return {"final_loss": losses[-1][1] if losses else None,
            "losses": losses, "params": params}


if __name__ == "__main__":
    main()
