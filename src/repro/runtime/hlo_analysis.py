"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``collective_bytes`` parses the *compiled* (partitioned) HLO text and sums
the operand/result sizes of every cross-device collective.  Conventions
(bytes that actually cross links, per device):

  all-reduce         2 x size   (ring: reduce-scatter + all-gather)
  all-gather         1 x result size
  reduce-scatter     1 x operand size
  all-to-all         1 x size
  collective-permute 1 x size

``cost_analysis()`` gives per-device HLO flops/bytes (the module is the
per-partition program after GSPMD).  Roofline terms per §Roofline:

  compute    = flops / peak_flops          (per chip)
  memory     = hbm_bytes / hbm_bw          (per chip)
  collective = coll_bytes / link_bw        (per chip link)
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# TPU v5e hardware constants (assignment):
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "e4m3": 1, "e5m2": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SCALE = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
          "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum collective traffic by op kind from partitioned HLO text."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result, kind = m.group(1), m.group(2)
        if "-done" in line:
            continue  # async pair: count the -start only
        size = _shape_bytes(result)
        out[kind] = out.get(kind, 0.0) + size * _SCALE[kind]
    return out


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: Dict[str, float] = field(default_factory=dict)

    @property
    def total_coll(self) -> float:
        return sum(self.coll_bytes.values())

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.total_coll / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def fraction_of_roofline(self, model_flops_per_chip: float) -> float:
        """useful-FLOPs time / bound time: how close the *model* math runs
        to the hardware bound if perfectly overlapped."""
        if self.t_bound == 0:
            return 0.0
        return (model_flops_per_chip / PEAK_FLOPS) / self.t_bound

    def summary(self) -> Dict[str, float]:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.total_coll,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def roofline_from_compiled(compiled, hlo_text: Optional[str] = None
                           ) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    return Roofline(flops=flops, hbm_bytes=hbm,
                    coll_bytes=collective_bytes(text))


def model_flops(n_params_active: float, tokens: float,
                kind: str = "train") -> float:
    """6·N·D for training; 2·N·D for a forward/serve step (per global
    batch)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
