"""Continuous-batching serving loop: oracle differential (ragged mixed
prefill+decode tokens == per-sequence sequential decode), scheduler
invariants, and the zero-recompile cache-stats pin."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, pipeline
from repro.launch import serve as S
from repro.launch.engine import Engine, Request, synth_trace


@pytest.fixture()
def fresh_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_CACHE", str(tmp_path))
    pipeline.reset_default_cache()
    yield
    pipeline.reset_default_cache()


def _tiny_cfg(backend="jax", **overrides):
    mc = configs.get_reduced_config(
        "smollm-135m", n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        d_head=32, d_ff=128, vocab=128, **overrides)
    return configs.with_pipeline(
        mc, options=pipeline.CompileOptions(backend=backend))


_ORACLE_DECODE = {}


def _oracle_decode(engine):
    # one jitted single-sequence decode step per engine (sharing it
    # across requests keeps the oracle loop out of retrace purgatory)
    fn = _ORACLE_DECODE.get(id(engine))
    if fn is None:
        fn = _ORACLE_DECODE[id(engine)] = jax.jit(engine.model.decode_step)
    return fn


def _oracle(engine, req):
    """Per-sequence sequential greedy decode — no batching, no padding."""
    m, params = engine.model, engine.params
    decode = _oracle_decode(engine)
    prompt = jnp.asarray(req.prompt)[None, :]
    lg, cache = m.prefill(params, prompt, max_len=engine.max_len)
    tok = int(jnp.argmax(lg[0, -1]))
    toks = [tok]
    pos = len(req.prompt)
    for _ in range(req.max_new_tokens - 1):
        lg, cache = decode(params, cache, jnp.asarray([[tok]]),
                           jnp.asarray(pos))
        tok = int(jnp.argmax(lg[0, -1]))
        toks.append(tok)
        pos += 1
    return toks


def test_ragged_trace_matches_sequential_oracle(fresh_cache):
    """The acceptance differential: a ragged mixed prefill+decode trace
    (varying per-sequence positions and occupancy) must emit tokens
    IDENTICAL to decoding each sequence alone."""
    engine = Engine(_tiny_cfg("jax"), max_batch=3, max_len=48,
                    prompt_buckets=(8, 16), sampling="greedy", seed=0)
    trace = synth_trace(7, seed=3, arrival_rate=1.5, prompt_lens=(3, 14),
                        gen_lens=(2, 6), vocab=engine.cfg.vocab)
    report = engine.run(trace)
    assert report.n_completed == len(trace)
    assert report.n_rejected == 0 and report.n_evicted_stalled == 0
    for req in trace:
        assert report.tokens[req.rid] == _oracle(engine, req), (
            f"request {req.rid} diverged from the sequential oracle")


def test_admission_eviction_invariants(fresh_cache):
    """Occupancy never exceeds the slot count, the queue builds under
    overload and drains, every request is accounted for exactly once,
    and oversized requests are rejected, not wedged."""
    engine = Engine(_tiny_cfg("jax"), max_batch=2, max_len=32,
                    prompt_buckets=(8,), sampling="greedy", seed=0)
    trace = [Request(rid=i, prompt=tuple(range(1, 7)), max_new_tokens=4,
                     arrival_step=0) for i in range(5)]
    # prompt longer than every bucket -> must be rejected
    trace.append(Request(rid=5, prompt=tuple(range(1, 15)),
                         max_new_tokens=4, arrival_step=0))
    # prompt + generation overflowing the cache slot -> rejected
    trace.append(Request(rid=6, prompt=tuple(range(1, 7)),
                         max_new_tokens=30, arrival_step=0))
    report = engine.run(trace)
    assert report.n_rejected == 2
    assert report.n_completed == 5
    assert report.n_completed + report.n_rejected == len(trace)
    assert all(r.occupancy <= 2 for r in report.per_step)
    # 5 single-step-arrival requests over 2 slots: the queue must build
    assert report.max_queue_depth >= 3
    # and drain: the engine ran to quiescence with every slot free
    assert all(s is None for s in engine.slots)
    assert report.per_step[-1].queue_depth == 0
    # each completed request produced exactly max_new_tokens tokens
    for rid in range(5):
        assert len(report.tokens[rid]) == 4


def test_zero_recompiles_after_warmup_pallas(fresh_cache):
    """The tentpole pin: a ragged trace through the grouped pallas
    megakernels compiles everything in warmup and NOTHING after —
    cache-stats growth in the steady state is zero, and no region fell
    back off the megakernel path."""
    engine = Engine(_tiny_cfg("pallas"), max_batch=2, max_len=24,
                    prompt_buckets=(4, 8), sampling="greedy", seed=0)
    compiles = engine.warmup()
    assert compiles > 0, "warmup compiled nothing"
    assert engine.pallas_fallbacks == 0
    trace = synth_trace(4, seed=1, arrival_rate=1.0, prompt_lens=(2, 8),
                        gen_lens=(2, 4), vocab=engine.cfg.vocab)
    report = engine.run(trace)  # strict_no_recompile raises on any growth
    assert report.decode_recompiles == 0
    assert report.warmup_compiles == compiles
    assert report.n_completed == len(trace)


def test_serve_config_run_api(fresh_cache):
    """ServeConfig + run(cfg) -> ServeReport, JSON-serializable."""
    import json

    cfg = S.ServeConfig(arch="smollm-135m", backend="jax", max_batch=2,
                        max_len=48, prompt_buckets=(8,), n_requests=3,
                        prompt_lens=(3, 8), gen_lens=(2, 4),
                        sampling="categorical", temperature=0.8, seed=0)
    report = S.run(cfg)
    assert report.n_completed + report.n_rejected == 3
    d = json.loads(json.dumps(report.to_json()))
    assert d["decode_recompiles"] == 0
    assert d["steps"] == len(d["per_step"])
    assert d["tokens_per_s"] > 0


def test_engine_rejects_ssm_families():
    with pytest.raises(ValueError, match="attention-family"):
        Engine(configs.get_reduced_config("mamba2-2.7b"))
