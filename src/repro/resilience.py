"""``repro.resilience`` — the degradation ladder, fault isolation, and
deterministic fault injection for the compile pipeline and the serving
engine.

The paper's framework targets "any multiprocessor architecture", which
in production terms means lowering WILL fail on some backend/shape
combinations, on-disk state WILL corrupt, and a request WILL produce
non-finite logits.  This module is the shared vocabulary for surviving
all three:

* **The ladder** — :data:`LADDER` orders the compile strategies from
  fastest to most conservative::

      grouped      one multi-stage megakernel pallas_call per region group
      ungrouped    one pallas_call per region (no VMEM residency)
      jax          codegen_jax under jax.jit (runs everywhere)
      interpreter  the numpy reference interpreter (always correct)

  ``pipeline.compile`` starts at the rung its options ask for and, when
  an attempt raises or times out, *demotes* one rung at a time until
  :class:`ResiliencePolicy.max_rung`, recording every attempt in a
  :class:`ResilienceReport` on the returned kernel.  The default policy
  adds **zero happy-path overhead**: no timeout thread, no retry sleep —
  one ``try`` around the lowering call that already existed.

* **Fault injection** — :class:`FaultPlan` fires deterministic faults
  (exceptions, slow compiles, cache corruption, NaN logits) at chosen
  per-site call indices.  Sites are string names checked by the
  production code paths (``compile:<rung>``, ``cache:get_plan``,
  ``serve:logits``, ``serve:decode``); an inactive plan costs one
  ``None`` check.  Activate programmatically (:func:`install` /
  :func:`faults`) or via ``$REPRO_FAULT_PLAN`` (inline JSON or a path
  to a JSON file), so CI chaos jobs can drive every rung reproducibly.

* **The health ledger** — :class:`HealthLedger` is a per-(key, rung)
  circuit breaker: ``closed`` (healthy) → ``open`` after
  ``breaker_threshold`` consecutive failures (cool-down doubles per
  trip) → ``half_open`` after the cool-down, admitting exactly one
  *probe*; a passing probe closes the breaker, a failing one re-opens
  it at doubled cool-down.  Entries persist as checksummed JSON
  envelopes under ``<cache>/health/`` so rung health survives process
  restarts and is shared cross-process.  ``pipeline.compile`` consults
  it to skip known-open rungs instantly (no re-burning the
  retry/timeout budget per compile) and the serving engine uses it to
  *re-promote* a demoted decode rung after N clean ticks.  The happy
  path does zero ledger I/O: no entries exist until a rung fails.

* **Metrics** — :data:`METRICS` counts ladder demotions process-wide
  (the serving engine reports the delta per run), mirroring how
  ``pipeline.CacheStats`` counts quarantines.
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
import time
import warnings
from contextlib import contextmanager
from dataclasses import asdict, dataclass, field, fields, replace
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# fastest first; each entry is strictly more conservative than the one
# before it.  ``pipeline.compile`` maps its options to a starting rung
# (pallas+group -> grouped, pallas -> ungrouped, jax -> jax, py ->
# interpreter) and only ever moves DOWN the list.
LADDER = ("grouped", "ungrouped", "jax", "interpreter")

FAULT_KINDS = ("raise", "sleep", "nan", "corrupt")


class InjectedFault(RuntimeError):
    """Raised by :func:`check` at a site a :class:`FaultPlan` targets."""


class AttemptTimeout(RuntimeError):
    """A ladder attempt exceeded ``ResiliencePolicy.attempt_timeout_s``.
    The underlying work keeps running in its worker thread (python
    cannot kill it); the ladder moves on without waiting."""


class LadderError(RuntimeError):
    """Every allowed rung failed.  ``.report`` carries the full
    per-attempt record (rung, elapsed, error) for triage."""

    def __init__(self, msg: str, report: "ResilienceReport"):
        super().__init__(msg)
        self.report = report


def rung_index(rung: str) -> int:
    if rung not in LADDER:
        raise ValueError(f"unknown ladder rung {rung!r}; one of {LADDER}")
    return LADDER.index(rung)


def start_rung(backend: str, group: bool) -> str:
    """The rung ``pipeline.compile`` starts at for a backend/group pair."""
    if backend == "pallas":
        return "grouped" if group else "ungrouped"
    if backend == "jax":
        return "jax"
    return "interpreter"


def rungs_from(start: str, max_rung: str) -> Tuple[str, ...]:
    """The rungs a compile may attempt, in order: ``start`` down to
    ``max_rung`` inclusive.  A ``max_rung`` *above* the start permits no
    demotion at all — only the starting rung is attempted."""
    s, m = rung_index(start), rung_index(max_rung)
    if m < s:
        return (start,)
    return LADDER[s:m + 1]


@dataclass(frozen=True)
class ResiliencePolicy:
    """How far, how patiently, and how often a compile may retry before
    demoting.  Frozen and hashable: lives on ``CompileOptions`` and
    participates in the kernel-cache key (non-default policies only, so
    default cache keys stay byte-identical to pre-resilience builds).

    * ``max_rung`` — the deepest ladder rung a compile may demote to;
      exhausting it raises :class:`LadderError`.
    * ``attempt_timeout_s`` — wall-clock budget per attempt; ``None``
      (default) runs inline with no watchdog thread.
    * ``retries`` — extra same-rung attempts for transient failures
      (including timeouts) before demoting, with exponential backoff
      ``backoff_s * 2**retry`` between them.
    * ``breaker_threshold`` — consecutive failures of a (fingerprint,
      rung) pair before its :class:`HealthLedger` breaker opens and the
      rung is skipped without an attempt; ``0`` disables the breaker.
    * ``breaker_cooldown_s`` / ``breaker_cooldown_max_s`` — how long an
      open breaker waits before admitting a half-open probe; doubles
      per trip, capped at the max.
    """

    max_rung: str = "interpreter"
    attempt_timeout_s: Optional[float] = None
    retries: int = 0
    backoff_s: float = 0.05
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 60.0
    breaker_cooldown_max_s: float = 3600.0

    def __post_init__(self):
        rung_index(self.max_rung)  # validate
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.breaker_threshold < 0:
            raise ValueError(
                f"breaker_threshold must be >= 0, got {self.breaker_threshold}")

    def key(self) -> Tuple:
        """Canonical value tuple (hashing / cache-key embedding)."""
        return (self.max_rung, self.attempt_timeout_s, int(self.retries),
                float(self.backoff_s), int(self.breaker_threshold),
                float(self.breaker_cooldown_s),
                float(self.breaker_cooldown_max_s))


DEFAULT_POLICY = ResiliencePolicy()


@dataclass
class Attempt:
    """One ladder attempt: a (rung, retry) pair and how it went."""

    rung: str
    ok: bool
    elapsed_s: float              # wall time of this attempt (calibration
                                  # input for attempt_timeout_s)
    error: Optional[str] = None   # "ExcType: message" when not ok
    retry: int = 0                # 0 = first try at this rung
    timed_out: bool = False
    skipped_open: bool = False    # breaker open: rung skipped, not run
    probe: bool = False           # half-open probe after cool-down


@dataclass
class ResilienceReport:
    """The compile's fault provenance: which rung was requested, which
    rung actually served it, and every attempt in between.  Attached to
    ``CompiledKernel.resilience_report`` on every compile (the happy
    path is one ok attempt at the requested rung, zero demotions)."""

    requested: str = "grouped"
    rung: Optional[str] = None        # the rung that served the compile
    attempts: List[Attempt] = field(default_factory=list)
    # RegionError from the driver's region partitioning, when the
    # partitioner could not split the selected snapshot (the lowering
    # then took emit_program's whole-program fallback)
    plan_error: Optional[str] = None

    @property
    def demotions(self) -> int:
        """Rungs descended from the requested one (0 on the happy path)."""
        if self.rung is None:
            return 0
        return max(rung_index(self.rung) - rung_index(self.requested), 0)

    @property
    def errors(self) -> List[str]:
        return [a.error for a in self.attempts if a.error]

    @property
    def skipped_open(self) -> int:
        """Rungs skipped because their health-ledger breaker was open."""
        return sum(1 for a in self.attempts if a.skipped_open)

    @property
    def probes(self) -> int:
        """Half-open probe attempts admitted after a cool-down."""
        return sum(1 for a in self.attempts if a.probe)

    def wall_by_rung(self) -> Dict[str, List[float]]:
        """Wall times of every *executed* attempt, grouped by rung — the
        raw material for calibrating ``attempt_timeout_s`` from real
        measurements instead of guesses."""
        out: Dict[str, List[float]] = {}
        for a in self.attempts:
            if not a.skipped_open:
                out.setdefault(a.rung, []).append(a.elapsed_s)
        return out

    def suggest_timeout_s(self, margin: float = 4.0) -> Optional[float]:
        """A candidate ``attempt_timeout_s``: the slowest *successful*
        attempt times ``margin``.  ``None`` when nothing succeeded."""
        oks = [a.elapsed_s for a in self.attempts if a.ok]
        return max(oks) * float(margin) if oks else None

    def to_json(self) -> Dict[str, Any]:
        d = asdict(self)
        d["demotions"] = self.demotions
        d["skipped_open"] = self.skipped_open
        d["probes"] = self.probes
        return d

    def summary(self) -> str:
        steps = ", ".join(
            f"{a.rung}{'#%d' % a.retry if a.retry else ''}:"
            f"{'skip-open' if a.skipped_open else ('ok' if a.ok else ('timeout' if a.timed_out else 'fail'))}"
            for a in self.attempts)
        return (f"requested={self.requested} served={self.rung} "
                f"demotions={self.demotions} [{steps}]")


# ---------------------------------------------------------------------------
# process-wide resilience metrics (mirrors pipeline.CacheStats)
# ---------------------------------------------------------------------------

@dataclass
class ResilienceMetrics:
    demotions: int = 0         # ladder rungs descended (compile pipeline)
    ladder_failures: int = 0   # compiles that exhausted every rung
    faults_fired: int = 0      # injected faults that actually fired
    abandoned_workers: int = 0  # timeout workers left running (daemonic)
    skipped_open: int = 0      # ladder rungs skipped on an open breaker
    probes: int = 0            # half-open probe attempts (compile ladder)
    probe_failures: int = 0    # probes that failed (breaker re-opened)

    def snapshot(self) -> "ResilienceMetrics":
        return replace(self)

    def delta(self, since: "ResilienceMetrics") -> "ResilienceMetrics":
        return ResilienceMetrics(**{
            f.name: getattr(self, f.name) - getattr(since, f.name)
            for f in fields(self)})


METRICS = ResilienceMetrics()


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultSpec:
    """Fire ``kind`` at ``site`` on the listed 0-based call indices.

    Kinds: ``raise`` (an :class:`InjectedFault` from :func:`check`),
    ``sleep`` (stall ``sleep_s`` — drives the attempt-timeout path),
    ``nan`` / ``corrupt`` (returned to the caller, which applies the
    mutation itself: the engine NaNs one logits row, the kernel cache
    garbles the on-disk entry so the REAL integrity machinery detects
    it)."""

    site: str
    indices: Tuple[int, ...] = (0,)
    kind: str = "raise"
    message: str = "injected fault"
    sleep_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        object.__setattr__(self, "indices",
                           tuple(int(i) for i in self.indices))

    def to_json(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "FaultSpec":
        return cls(site=str(d["site"]),
                   indices=tuple(d.get("indices", (0,))),
                   kind=str(d.get("kind", "raise")),
                   message=str(d.get("message", "injected fault")),
                   sleep_s=float(d.get("sleep_s", 0.0)))


class FaultPlan:
    """A deterministic schedule of faults.  Each production site calls
    :func:`fire`; the plan counts the call (per site) and fires the
    matching :class:`FaultSpec` when the count hits one of its indices.
    Everything is index-based, so the same plan against the same code
    path fires identically every run — that is what lets the chaos CI
    job pin quarantine/demotion counters *exactly*.

    ``seed`` is provenance (recorded in reports) and the randomness
    source for :meth:`seeded` helpers; the plan itself is deterministic
    by construction."""

    def __init__(self, specs: Sequence[FaultSpec] = (), *, seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._by_site: Dict[str, List[FaultSpec]] = {}
        for s in self.specs:
            self._by_site.setdefault(s.site, []).append(s)
        self._calls: Dict[str, int] = {}
        self.fired: List[Tuple[str, int, str]] = []  # (site, index, kind)
        self._lock = threading.Lock()

    def fire(self, site: str) -> Optional[FaultSpec]:
        """Count one call at ``site``; return the spec that fires at
        this index, if any (thread-safe: ladder attempts may run in
        timeout worker threads)."""
        with self._lock:
            idx = self._calls.get(site, 0)
            self._calls[site] = idx + 1
            for spec in self._by_site.get(site, ()):
                if idx in spec.indices:
                    self.fired.append((site, idx, spec.kind))
                    METRICS.faults_fired += 1
                    return spec
        return None

    def calls(self, site: str) -> int:
        return self._calls.get(site, 0)

    def fired_count(self, site: Optional[str] = None) -> int:
        if site is None:
            return len(self.fired)
        return sum(1 for s, _, _ in self.fired if s == site)

    def expected_count(self, site_prefix: str = "") -> int:
        """How many faults this plan schedules at sites matching the
        prefix — what the chaos gate pins counters against."""
        return sum(len(s.indices) for s in self.specs
                   if s.site.startswith(site_prefix))

    def reset(self) -> None:
        with self._lock:
            self._calls.clear()
            self.fired.clear()

    def to_json(self) -> Dict[str, Any]:
        return {"seed": self.seed,
                "faults": [s.to_json() for s in self.specs]}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "FaultPlan":
        return cls([FaultSpec.from_json(s) for s in d.get("faults", ())],
                   seed=int(d.get("seed", 0)))


_ACTIVE: Optional[FaultPlan] = None
# lazily-parsed $REPRO_FAULT_PLAN, cached per env value so per-site call
# counters survive across active() calls
_ENV_PLAN: Tuple[Optional[str], Optional[FaultPlan]] = (None, None)


def install(plan: Optional[FaultPlan]) -> None:
    """Set (or clear, with ``None``) the process-wide fault plan."""
    global _ACTIVE
    _ACTIVE = plan


@contextmanager
def faults(plan: FaultPlan):
    """Scope a fault plan: ``with resilience.faults(plan): ...``."""
    prev = _ACTIVE
    install(plan)
    try:
        yield plan
    finally:
        install(prev)


def active() -> Optional[FaultPlan]:
    """The installed plan, else one parsed from ``$REPRO_FAULT_PLAN``
    (inline JSON or a path to a JSON file), else ``None``."""
    if _ACTIVE is not None:
        return _ACTIVE
    global _ENV_PLAN
    raw = os.environ.get("REPRO_FAULT_PLAN")
    if not raw:
        return None
    if _ENV_PLAN[0] == raw:
        return _ENV_PLAN[1]
    text = raw
    if not raw.lstrip().startswith("{"):
        with open(raw) as f:
            text = f.read()
    plan = FaultPlan.from_json(json.loads(text))
    _ENV_PLAN = (raw, plan)
    return plan


def fire(site: str) -> Optional[FaultSpec]:
    """Consult the active plan at ``site``.  No plan -> ``None`` (one
    global read: the cost injection adds to the happy path)."""
    plan = active()
    return plan.fire(site) if plan is not None else None


def check(site: str) -> None:
    """The compile-site hook: raise on ``raise`` faults, stall on
    ``sleep`` faults (so an ``attempt_timeout_s`` watchdog can catch the
    slow compile), ignore kinds the site does not implement."""
    spec = fire(site)
    if spec is None:
        return
    if spec.kind == "sleep":
        time.sleep(spec.sleep_s)
        return
    if spec.kind == "raise":
        raise InjectedFault(f"{site}[{spec.message}]")


# ---------------------------------------------------------------------------
# timeout runner
# ---------------------------------------------------------------------------

def run_with_timeout(fn, timeout_s: float):
    """Run ``fn()`` in a **daemon** worker thread and wait at most
    ``timeout_s``.  On timeout the worker keeps running (python offers
    no preemption) but the caller gets :class:`AttemptTimeout`
    immediately and the ladder moves on — a hung Pallas lowering must
    not hang the server.  The worker is daemonic so an abandoned
    attempt can never block process exit (``ThreadPoolExecutor``
    workers are non-daemon and join at interpreter shutdown, which
    turned one hung compile into a hung process); every abandonment is
    counted in ``METRICS.abandoned_workers``."""
    done = threading.Event()
    box: List[Any] = [None, None]  # [result, exception]

    def _worker():
        try:
            box[0] = fn()
        except BaseException as e:  # propagate *any* failure to the caller
            box[1] = e
        finally:
            done.set()

    t = threading.Thread(target=_worker, name="repro-ladder-worker",
                         daemon=True)
    t.start()
    if not done.wait(timeout_s):
        METRICS.abandoned_workers += 1
        raise AttemptTimeout(
            f"attempt exceeded {timeout_s:g}s (daemon worker left running)")
    if box[1] is not None:
        raise box[1]
    return box[0]


# ---------------------------------------------------------------------------
# the health ledger: a persistent per-(key, rung) circuit breaker
# ---------------------------------------------------------------------------

BREAKER_STATES = ("closed", "open", "half_open")
_LEDGER_SCHEMA = 1


@dataclass
class BreakerEntry:
    """Health of one (key, rung) pair.  ``key`` is a graph fingerprint
    for compile-side breakers or ``serve:<model>:decode`` for the
    engine's decode breaker."""

    key: str
    rung: str
    state: str = "closed"
    failures: int = 0        # consecutive failures while closed
    trips: int = 0           # closed/half_open -> open transitions
    cooldown_s: float = 0.0  # cool-down used at the last trip
    open_until: float = 0.0  # ledger-clock time the breaker half-opens
    last_error: Optional[str] = None
    updated_at: float = 0.0

    def to_json(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "BreakerEntry":
        known = {f.name for f in fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclass
class HealthStats:
    """Ledger instrumentation.  ``reads``/``writes`` count *entry file*
    I/O — the zero-overhead acceptance pin: a healthy process never
    reads or writes a ledger entry."""

    reads: int = 0          # entry envelopes read from disk
    writes: int = 0         # entry envelopes written or removed
    skipped_open: int = 0   # decisions that returned "open"
    probes: int = 0         # decisions that admitted a half-open probe
    trips: int = 0          # breakers opened (incl. re-opens)
    resets: int = 0         # breakers closed again (recovery)
    corrupt: int = 0        # unreadable envelopes discarded

    def snapshot(self) -> "HealthStats":
        return replace(self)

    def delta(self, since: "HealthStats") -> "HealthStats":
        return HealthStats(**{
            f.name: getattr(self, f.name) - getattr(since, f.name)
            for f in fields(self)})


class HealthLedger:
    """A per-(key, rung) circuit breaker with optional on-disk
    persistence.

    States: ``closed`` (attempt normally) → ``open`` after
    ``breaker_threshold`` consecutive :meth:`record_failure` calls
    (skip the rung until the cool-down elapses; cool-down is
    ``breaker_cooldown_s * 2**(trips-1)`` capped at
    ``breaker_cooldown_max_s``) → ``half_open`` (one probe admitted by
    :meth:`decision`) → ``closed`` on :meth:`record_success`, or back
    to ``open`` at doubled cool-down on another failure.

    ``root=None`` keeps the ledger memory-only (``disk=False`` caches,
    unit tests).  With a root, every entry persists as a checksummed
    JSON envelope ``{"schema", "sha256", "entry"}`` written atomically
    (tmp + rename), so breaker state survives crashes and is shared by
    sibling processes pointed at the same kernel cache.  The directory
    is only created on the first write — a healthy install never even
    makes it, which is what keeps the happy path at zero ledger I/O.

    ``clock`` is injectable for determinism: the serving engine passes
    its tick counter, tests pass a fake; default is wall time.
    """

    def __init__(self, root: Optional[os.PathLike] = None, *,
                 clock: Callable[[], float] = time.time):
        self.root = Path(root) if root is not None else None
        self.clock = clock
        self.stats = HealthStats()
        self._entries: Dict[Tuple[str, str], BreakerEntry] = {}
        self._lock = threading.Lock()
        self._dir_seen = False  # latched True once <root> is known to exist

    # -- persistence ------------------------------------------------------

    def _path(self, key: str, rung: str) -> Optional[Path]:
        if self.root is None:
            return None
        h = hashlib.sha256(f"{key}|{rung}".encode()).hexdigest()[:32]
        return self.root / f"{h}.json"

    def _have_dir(self) -> bool:
        if self.root is None:
            return False
        if not self._dir_seen:
            self._dir_seen = self.root.is_dir()
        return self._dir_seen

    def _load(self, key: str, rung: str) -> Optional[BreakerEntry]:
        """The entry for (key, rung): in-memory first, then disk.  A
        missing or corrupt envelope is ``closed`` (fail open: a broken
        ledger must never take a healthy rung out of service)."""
        ck = (key, rung)
        if ck in self._entries:
            return self._entries[ck]
        path = self._path(key, rung)
        if path is None or not self._have_dir():
            return None
        try:
            raw = path.read_bytes()
        except OSError:
            return None
        self.stats.reads += 1
        try:
            env = json.loads(raw)
            if env.get("schema") != _LEDGER_SCHEMA:
                raise ValueError(f"schema {env.get('schema')!r}")
            body = json.dumps(env["entry"], sort_keys=True).encode()
            if hashlib.sha256(body).hexdigest() != env.get("sha256"):
                raise ValueError("sha256 mismatch")
            entry = BreakerEntry.from_json(env["entry"])
            if entry.state not in BREAKER_STATES:
                raise ValueError(f"state {entry.state!r}")
        except Exception as e:
            self.stats.corrupt += 1
            warnings.warn(
                f"health ledger: discarding corrupt entry {path} "
                f"({type(e).__name__}: {e})", RuntimeWarning, stacklevel=3)
            try:
                path.unlink()
            except OSError:
                pass
            return None
        self._entries[ck] = entry
        return entry

    def _store(self, entry: BreakerEntry) -> None:
        self._entries[(entry.key, entry.rung)] = entry
        path = self._path(entry.key, entry.rung)
        if path is None:
            return
        body = json.dumps(entry.to_json(), sort_keys=True)
        env = {"schema": _LEDGER_SCHEMA,
               "sha256": hashlib.sha256(body.encode()).hexdigest(),
               "entry": entry.to_json()}
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            self._dir_seen = True
            tmp = path.parent / f"{path.name}.{os.getpid()}.tmp"
            tmp.write_text(json.dumps(env, sort_keys=True))
            os.replace(tmp, path)
            self.stats.writes += 1
        except OSError as e:
            warnings.warn(f"health ledger: could not persist {path} ({e})",
                          RuntimeWarning, stacklevel=3)

    def _remove(self, key: str, rung: str) -> None:
        self._entries.pop((key, rung), None)
        path = self._path(key, rung)
        if path is not None and self._have_dir():
            try:
                path.unlink()
                self.stats.writes += 1
            except OSError:
                pass

    # -- breaker protocol -------------------------------------------------

    def state(self, key: str, rung: str) -> str:
        """The current breaker state, with no side effects."""
        with self._lock:
            e = self._load(key, rung)
            return e.state if e is not None else "closed"

    def entry(self, key: str, rung: str) -> Optional[BreakerEntry]:
        with self._lock:
            e = self._load(key, rung)
            return replace(e) if e is not None else None

    def decision(self, key: str, rung: str) -> str:
        """What the caller should do with this rung right now:

        * ``"closed"`` — attempt normally.
        * ``"open"``   — skip instantly, cool-down not yet elapsed.
        * ``"probe"``  — cool-down elapsed; the breaker has moved to
          ``half_open`` and this caller owns the single probe.  Follow
          up with :meth:`record_success` or :meth:`record_failure`.
        """
        with self._lock:
            e = self._load(key, rung)
            if e is None or e.state == "closed":
                return "closed"
            now = float(self.clock())
            if e.state == "open":
                if now < e.open_until:
                    self.stats.skipped_open += 1
                    return "open"
                e.state = "half_open"
                e.updated_at = now
                self.stats.probes += 1
                self._store(e)
                return "probe"
            # half_open: a probe is already in flight.  If its owner
            # crashed, admit another once a full cool-down has passed.
            if now >= e.updated_at + max(e.cooldown_s, 0.0):
                e.updated_at = now
                self.stats.probes += 1
                self._store(e)
                return "probe"
            self.stats.skipped_open += 1
            return "open"

    def record_failure(self, key: str, rung: str, error: Any = None, *,
                       policy: Optional[ResiliencePolicy] = None) -> str:
        """Count one failure; returns the resulting state.  A failed
        half-open probe re-opens at doubled cool-down; ``closed``
        failures accumulate and trip at ``breaker_threshold``."""
        policy = policy or DEFAULT_POLICY
        if policy.breaker_threshold <= 0:
            return "disabled"
        with self._lock:
            e = self._load(key, rung) or BreakerEntry(key=key, rung=rung)
            now = float(self.clock())
            e.failures += 1
            e.last_error = (f"{type(error).__name__}: {error}"
                            if isinstance(error, BaseException)
                            else (str(error) if error is not None else None))
            e.updated_at = now
            if e.state == "half_open":
                # the probe failed: back to open, cool-down doubled
                e.trips += 1
                e.cooldown_s = min(max(e.cooldown_s, policy.breaker_cooldown_s) * 2,
                                   policy.breaker_cooldown_max_s)
                e.state = "open"
                e.open_until = now + e.cooldown_s
                self.stats.trips += 1
            elif e.state == "closed" and e.failures >= policy.breaker_threshold:
                e.trips += 1
                e.cooldown_s = min(
                    policy.breaker_cooldown_s * (2 ** (e.trips - 1)),
                    policy.breaker_cooldown_max_s)
                e.state = "open"
                e.open_until = now + e.cooldown_s
                self.stats.trips += 1
            self._store(e)
            return e.state

    def record_success(self, key: str, rung: str) -> None:
        """The rung worked: close the breaker and drop its entry (the
        ledger returns to its pristine, zero-I/O shape).  A success on a
        pair the ledger has never seen is a no-op — no entry is created,
        so the happy path stays write-free."""
        with self._lock:
            if (key, rung) not in self._entries:
                return  # never seen unhealthy -> nothing to reset
            if self._entries[(key, rung)].state != "closed" \
                    or self._entries[(key, rung)].failures:
                self.stats.resets += 1
            self._remove(key, rung)

    def reopen(self, key: str, rung: str, cooldown_s: float,
               error: Any = None) -> None:
        """Force the breaker open for ``cooldown_s`` from *this*
        ledger's clock — used when a fresh process adopts persisted
        breaker state whose ``open_until`` was written by a different
        clock (the engine's tick clock restarts at 0 every process)."""
        with self._lock:
            e = self._load(key, rung) or BreakerEntry(key=key, rung=rung)
            now = float(self.clock())
            e.state = "open"
            e.trips = max(e.trips, 1)
            e.cooldown_s = float(cooldown_s)
            e.open_until = now + float(cooldown_s)
            e.updated_at = now
            if error is not None:
                e.last_error = str(error)
            self._store(e)

    def entries(self) -> List[BreakerEntry]:
        """Every known entry (memory + disk) — the triage view."""
        with self._lock:
            if self._have_dir():
                for p in sorted(self.root.glob("*.json")):
                    try:
                        env = json.loads(p.read_text())
                        ent = BreakerEntry.from_json(env["entry"])
                    except Exception:
                        continue
                    self._entries.setdefault((ent.key, ent.rung), ent)
            return [replace(e) for e in self._entries.values()]
