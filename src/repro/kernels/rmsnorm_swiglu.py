"""Pallas TPU kernel: Flash-RMSNorm+FFN-SwiGLU (paper Example 3).

The paper fuses three matmuls, a Hadamard product, the RMS reduction, and
elementwise ops into one mega-kernel, and notes the block-count parameters
N and K trade replication against local-memory pressure (its autotuner
would pick N=1 and/or K=1).  The TPU-native realization here *is* the
paper's N=1 choice rethought for VMEM/MXU:

  grid = (M_blocks, K_blocks); the K grid dim is the paper's serial K-map.
  Per m-block the whole X row panel (block_m, D) sits in VMEM (so the RMS
  statistic is computed once — no replication), each K step computes one
  h-tile = swish(xn @ W_k) * (xn @ V_k) entirely in registers/VMEM and
  immediately accumulates h_tile @ U_k into the (block_m, N) output
  accumulator, exactly the paper's final listing with its buffered edges
  erased.

VMEM budget (bf16 in, f32 acc), block_m=128, block_k=256, D=N=4096:
  x 1MB + w,v 2x2MB + u 2MB + acc 2MB + out 1MB  ~= 10MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _swiglu_kernel(x_ref, w_ref, v_ref, u_ref, g_ref, o_ref,
                   acc_ref, irms_ref, *, eps: float, d_dim: int, n_k: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        x = x_ref[...].astype(jnp.float32)
        ss = (x * x).sum(axis=1, keepdims=True)          # paper: t3 += row_sum(x*x)
        irms_ref[...] = jax.lax.rsqrt(ss / d_dim + eps)  # paper: t4 = 1/sqrt(...)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    gamma = g_ref[...].astype(jnp.float32)               # (1, D)
    xn = x * gamma * irms_ref[...]                       # row_scale (Rule 4 target)
    w = w_ref[...].astype(jnp.float32)                   # (D, bk)
    v = v_ref[...].astype(jnp.float32)                   # (D, bk)
    a = jax.lax.dot(xn, w, preferred_element_type=jnp.float32)
    b = jax.lax.dot(xn, v, preferred_element_type=jnp.float32)
    h = (a * jax.nn.sigmoid(a)) * b                      # swish + Hadamard
    u = u_ref[...].astype(jnp.float32)                   # (bk, N)
    acc_ref[...] += jax.lax.dot(h, u, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def rmsnorm_swiglu_pallas(x: jax.Array, w: jax.Array, v: jax.Array,
                          u: jax.Array, gamma: jax.Array, *,
                          eps: float = 1e-6, block_m: int = 128,
                          block_k: int = 512,
                          interpret: bool = False) -> jax.Array:
    """x: (M, D); w, v: (D, K); u: (K, N); gamma: (D,).  Returns (M, N).

    O = (swish(RMSNorm_g(x) @ w) * (RMSNorm_g(x) @ v)) @ u in ONE pass over
    x/w/v/u with no materialized intermediate."""
    m_dim, d_dim = x.shape
    _, k_dim = w.shape
    _, n_dim = u.shape
    block_m = min(block_m, m_dim)
    block_k = min(block_k, k_dim)
    pad_m = (-m_dim) % block_m
    pad_k = (-k_dim) % block_k
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    if pad_k:
        # padded K columns produce swish(0)*0 = 0 contributions
        w = jnp.pad(w, ((0, 0), (0, pad_k)))
        v = jnp.pad(v, ((0, 0), (0, pad_k)))
        u = jnp.pad(u, ((0, pad_k), (0, 0)))
    mp, kp = m_dim + pad_m, k_dim + pad_k
    n_k = kp // block_k
    g2 = gamma.reshape(1, d_dim)

    kernel = functools.partial(_swiglu_kernel, eps=eps, d_dim=d_dim, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(mp // block_m, n_k),
        in_specs=[
            pl.BlockSpec((block_m, d_dim), lambda i, k: (i, 0)),
            pl.BlockSpec((d_dim, block_k), lambda i, k: (0, k)),
            pl.BlockSpec((d_dim, block_k), lambda i, k: (0, k)),
            pl.BlockSpec((block_k, n_dim), lambda i, k: (k, 0)),
            pl.BlockSpec((1, d_dim), lambda i, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n_dim), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n_dim), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, n_dim), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, v, u, g2)
    return out[:m_dim, :]
