import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimbing driver (§Perf): measure one (arch x shape) cell's
roofline terms under a named variant — a (config override, sharding-rule
override, jit-option) tuple — so each hypothesis -> change -> measure cycle
is one command:

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch qwen2-7b --shape train_4k --variant out_shardings
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402

import jax           # noqa: E402

from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.dryrun import extrapolated_roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_step  # noqa: E402
from repro.runtime import sharding as SH  # noqa: E402
from repro.runtime.hlo_analysis import roofline_from_compiled  # noqa: E402

# ---------------------------------------------------------------------------
# variant registry: name -> dict(cfg=..., rules=..., out_shardings=bool)
# ---------------------------------------------------------------------------

DP_ONLY_RULES = {
    # small models: replicate params, shard batch over ALL 256/512 chips
    "batch": ("pod", "data", "model"),
    "capacity": ("pod", "data"),
    "expert": (),
    "tensor": (),
    "fsdp": (),
    "kv_seq": (),
}

FSDP_DP_RULES = {
    # batch over everything, params sharded over data (storage only)
    "batch": ("pod", "data", "model"),
    "fsdp": ("data",),
    "capacity": ("pod", "data"),
    "tensor": (),
    "expert": ("model",),
    "kv_seq": (),
}

SEQ_TENSOR_RULES = {
    # inference prefill: shard sequence over data instead of batch-only
    "batch": ("pod",),
    "seq": ("data",),
    "tensor": ("model",),
    "expert": ("model",),
    "capacity": ("data",),
    "fsdp": (),
    "kv_seq": ("data",),
}

VARIANTS = {
    "baseline": {},
    "out_shardings": {"out_shardings": True},
    "dp_only": {"rules": DP_ONLY_RULES},
    "dp_only_out": {"rules": DP_ONLY_RULES, "out_shardings": True},
    "fsdp_dp": {"rules": FSDP_DP_RULES, "out_shardings": True},
    "remat_dots": {"cfg": {"remat_policy": "dots"}},
    "no_remat": {"cfg": {"remat": False}},
    "cap_1_0": {"cfg": {"capacity_factor": 1.0}},
    "remat_dots_out": {"cfg": {"remat_policy": "dots"},
                       "out_shardings": True},
    "p_half": {"cfg": {"attn_p_half": True}},
    "p_half_out": {"cfg": {"attn_p_half": True}, "out_shardings": True},
    "dp_p_half_out": {"cfg": {"attn_p_half": True}, "rules": DP_ONLY_RULES,
                      "out_shardings": True},
    "moe_shard_map": {"cfg": {"moe_impl": "shard_map"}},
    "moe_sm_out": {"cfg": {"moe_impl": "shard_map"}, "out_shardings": True},
    "moe_sm_dots_out": {"cfg": {"moe_impl": "shard_map",
                                "remat_policy": "dots"},
                        "out_shardings": True},
}


def measure(arch: str, shape_name: str, variant: str,
            multi_pod: bool = False) -> dict:
    spec = VARIANTS[variant]
    cfg = get_config(arch)
    if spec.get("cfg"):
        cfg = dataclasses.replace(cfg, **spec["cfg"])
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = spec.get("rules")
    roof = extrapolated_roofline(cfg, shape, mesh, rules=rules,
                                 out_shardings=spec.get("out_shardings",
                                                        False))
    from repro.launch.dryrun import active_params
    from repro.runtime.hlo_analysis import model_flops
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = model_flops(active_params(cfg), tokens,
                     "train" if shape.kind == "train" else "serve")
    n_chips = mesh.devices.size
    return {
        "arch": arch, "shape": shape_name, "variant": variant,
        "flops_per_chip": roof.flops,
        "hbm_bytes_per_chip": roof.hbm_bytes,
        "coll_bytes_per_chip": roof.coll_bytes,
        "t_compute_s": roof.t_compute,
        "t_memory_s": roof.t_memory,
        "t_collective_s": roof.t_collective,
        "bottleneck": roof.bottleneck,
        "useful_flops_ratio": (mf / n_chips) / roof.flops if roof.flops
        else 0,
        "roofline_fraction": roof.fraction_of_roofline(mf / n_chips),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(VARIANTS))
    ap.add_argument("--multi", action="store_true")
    args = ap.parse_args()
    out = measure(args.arch, args.shape, args.variant, args.multi)
    print(json.dumps(out, indent=2, default=str))


if __name__ == "__main__":
    main()
