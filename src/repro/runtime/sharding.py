"""Logical-axis sharding rules (MaxText-style).

Model code annotates values and parameters with *logical* axis names;
the rules below map them to mesh axes.  The same model code then runs on
the single-pod (data, model) mesh, the multi-pod (pod, data, model) mesh,
or a single CPU device (no mesh: every annotation is a no-op).

  batch   -> (pod, data)   data parallelism (pod axis folds into DP)
  fsdp    -> data           parameter/optimizer storage sharding (ZeRO-ish;
                            gathered per layer inside the scan body by SPMD)
  tensor  -> model           TP: heads / ffn-hidden / vocab
  expert  -> model           EP: MoE experts
  kv_seq  -> data            sequence-parallel KV cache for long-ctx decode
  (anything unlisted)        replicated
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "tensor": ("model",),
    "expert": ("model",),
    "capacity": ("pod", "data"),   # MoE expert-buffer capacity dim
    "kv_seq": ("data",),
}

_state = threading.local()


def _ctx():
    if not hasattr(_state, "mesh"):
        _state.mesh = None
        _state.rules = dict(DEFAULT_RULES)
    return _state


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict] = None):
    st = _ctx()
    prev = (st.mesh, st.rules)
    st.mesh = mesh
    if rules is not None:
        st.rules = dict(rules)
    try:
        yield
    finally:
        st.mesh, st.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _ctx().mesh


def logical_to_spec(axes: Sequence[Optional[str]],
                    mesh: Optional[Mesh] = None) -> P:
    """Map logical axis names to a PartitionSpec under the active rules,
    dropping mesh axes that don't exist on the given mesh."""
    st = _ctx()
    mesh = mesh or st.mesh
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    spec = []
    used = set()
    for ax in axes:
        if ax is None:
            spec.append(None)
            continue
        targets = tuple(a for a in st.rules.get(ax, ())
                        if a in mesh_axes and a not in used)
        used.update(targets)
        if len(targets) == 0:
            spec.append(None)
        elif len(targets) == 1:
            spec.append(targets[0])
        else:
            spec.append(targets)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active mesh (no-op without one)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(axes: Sequence[Optional[str]],
                   mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or active_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(axes, mesh))


def tree_shardings(spec_tree, mesh: Optional[Mesh] = None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda axes: named_sharding(axes, mesh),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
