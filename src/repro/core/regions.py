"""Region partitioning: any fusion snapshot -> a DAG of spine regions.

The Pallas backend lowers one ``pallas_call`` per *region*: a nest of
parallel maps (grid dimensions) around at most one accumulating node (a
serial map or a reduce — the trailing sequential grid dimension), with
functional operators at any level of the nest.  Fusion snapshots are not
born that way: partially fused programs have sibling maps at a level,
serial maps next to parallel nests, and reduces consuming materialized
lists.  ``partition`` rewrites such a snapshot — by *loop fission*, the
inverse of the paper's Rule 1/2 merges — into an equivalent program whose
top-level operator nodes are each a valid region, introducing top-level
edges for every value that crosses a region boundary.  Those edges are
exactly the global-memory materializations the snapshot's traffic cost
model already charged for (a list edge inside a map is buffered, paper
§2), so lowering the partitioned program is an honest execution of the
*selected* snapshot, not a silently more- or less-fused one.

``plan_program`` then extracts each region as a standalone ``Graph`` with
its own input/output boundary plus the wiring (which top-level values
feed it, which it produces) that the executor threads between kernels.

``group_plan`` is the region-group scheduler on top: it greedily merges
regions whose parallel-map spines are compatible — a producer→consumer
chain may shrink the shared grid to the intersection of the members'
parallel dims (the off-grid dims of each member then evaluate in-kernel
over whole-VMEM-resident data), independent siblings merge only at
set-equal grids — subject to a VMEM budget.  Every cross-region value
whose producer and consumers share a group becomes a VMEM-resident
carry instead of a merged global array, and the Pallas backend emits
one multi-stage ``pallas_call`` per *group*: fewer launches, less HBM
traffic, with spills to global memory only where the budget or grid
compatibility forces them.

Everything here is pure graph surgery — no jax imports — so the
selection layer can reuse it for per-kernel traffic attribution.
"""

from __future__ import annotations

import copy
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import (FuncNode, Graph, InputNode, MapNode, MiscNode,
                              Node, OutputNode, Ref, ReduceNode)


class RegionError(ValueError):
    """A nest that cannot be expressed as a single spine region (and that
    ``partition`` cannot split, e.g. around a ``MiscNode``)."""


# ---------------------------------------------------------------------------
# Region validity: the exact shape codegen_pallas can emit as one kernel
# ---------------------------------------------------------------------------

def _misc_free(g: Graph) -> bool:
    for node in g.nodes.values():
        if isinstance(node, MiscNode):
            return False
        if isinstance(node, MapNode) and not _misc_free(node.inner):
            return False
    return True


def _level_split(g: Graph):
    """Classify one level's op nodes: (parallel maps, accumulating nodes,
    funcs, miscs)."""
    pars, accs, funcs, miscs = [], [], [], []
    for nid in sorted(g.op_nodes()):
        node = g.nodes[nid]
        if isinstance(node, MapNode):
            (accs if node.serial else pars).append(nid)
        elif isinstance(node, ReduceNode):
            accs.append(nid)
        elif isinstance(node, FuncNode):
            funcs.append(nid)
        else:
            miscs.append(nid)
    return pars, accs, funcs, miscs


def spine(node: Node) -> Optional[Tuple[List[str], Optional[str]]]:
    """``(grid_dims, red_dim)`` if the nest rooted at ``node`` is a valid
    region, else ``None``.

    A valid region is a chain of parallel maps (each level holding the
    next spine map plus only functional operators), ending in a level with
    at most one accumulating node — a serial map (its inner evaluates
    whole-resident in-kernel) or a reduce fed straight from a level input
    (its list dim becomes the trailing serial grid dim).
    """
    if isinstance(node, FuncNode):
        return [], None
    if isinstance(node, ReduceNode):
        return [], None  # red_dim resolved from the input type at emit time
    if not isinstance(node, MapNode):
        return None
    grid: List[str] = []
    while True:
        if node.serial:
            return (grid, node.dim) if _misc_free(node.inner) else None
        grid.append(node.dim)
        gi = node.inner
        pars, accs, funcs, miscs = _level_split(gi)
        if miscs:
            return None
        if len(pars) == 1 and not accs:
            node = gi.nodes[pars[0]]
            continue
        if pars:
            return None
        if not accs:
            return grid, None  # pure parallel nest
        if len(accs) > 1:
            return None
        acc = gi.nodes[accs[0]]
        if isinstance(acc, MapNode):
            return (grid, acc.dim) if _misc_free(acc.inner) else None
        # ReduceNode: its list input must be sliceable by the grid, i.e.
        # come straight from a level input
        e = gi.in_edge(accs[0], 0)
        src = gi.nodes[e.src]
        if not isinstance(src, InputNode) or not src.vtype.dims:
            return None
        return grid, src.vtype.dims[0]


def region_ok(node: Node) -> bool:
    return spine(node) is not None


# ---------------------------------------------------------------------------
# Fission: split an invalid parallel map into one map per region group
# ---------------------------------------------------------------------------

def _group_ops(gi: Graph) -> Tuple[Dict[int, int], int]:
    """Partition a level's op nodes into region groups.

    Every non-func node seeds its own group (it is the group's single
    map/reduce); funcs ride along — with a producing group when one
    exists (epilogue), else with their first consuming group (prologue) —
    so fission never manufactures single-elementwise kernels it can
    avoid.  Group indices respect topological order, keeping the
    resulting top-level DAG acyclic.
    """
    topo_ops = [n for n in gi.topo()
                if not isinstance(gi.nodes[n], (InputNode, OutputNode))]
    group_of: Dict[int, int] = {}
    n_groups = 0
    for nid in topo_ops:
        if not isinstance(gi.nodes[nid], FuncNode):
            group_of[nid] = n_groups
            n_groups += 1
    for nid in topo_ops:  # funcs joining a producer's group (epilogue)
        if nid in group_of:
            continue
        srcs = [group_of[e.src] for e in gi.in_edges(nid)
                if e.src in group_of]
        if srcs:
            group_of[nid] = max(srcs)
    for nid in reversed(topo_ops):  # remaining funcs join a consumer
        if nid in group_of:
            continue
        dsts = [group_of[e.dst] for e in gi.out_edges(nid)
                if e.dst in group_of]
        if dsts:
            group_of[nid] = min(dsts)
    for nid in topo_ops:  # isolated func chains: own group
        if nid not in group_of:
            group_of[nid] = n_groups
            n_groups += 1
    return group_of, n_groups


def _split_map(gc: Graph, nid: int) -> List[int]:
    """Replace parallel map ``nid`` of ``gc`` with one map per region
    group of its inner graph, threading cross-group values as new list
    edges at the ``gc`` level.  Returns the replacement node ids."""
    m: MapNode = gc.nodes[nid]
    assert isinstance(m, MapNode) and not m.serial
    gi = m.inner
    types = gi.infer_types()
    group_of, n_groups = _group_ops(gi)
    if n_groups < 2:
        raise RegionError(
            f"cannot split map[{m.dim}]: single group but not a region")

    out_src: List[Optional[Ref]] = []  # gi ref feeding each m out port
    for oid in gi.output_ids:
        e = gi.in_edge(oid, 0)
        out_src.append((e.src, e.sp))

    # per group: inputs (level-input ports + cross refs) and outputs
    g_in_ports: List[List[int]] = [[] for _ in range(n_groups)]
    g_in_cross: List[List[Ref]] = [[] for _ in range(n_groups)]
    g_out_refs: List[List[Ref]] = [[] for _ in range(n_groups)]
    in_port_of = {iid: p for p, iid in enumerate(gi.input_ids)}

    topo_ops = [n for n in gi.topo() if n in group_of]

    for gid in range(n_groups):
        members = [n for n in topo_ops if group_of[n] == gid]
        for n in members:
            for e in gi.in_edges(n):
                if e.src in group_of and group_of[e.src] == gid:
                    continue
                if e.src in in_port_of:
                    p = in_port_of[e.src]
                    if p not in g_in_ports[gid]:
                        g_in_ports[gid].append(p)
                elif (e.src, e.sp) not in g_in_cross[gid]:
                    g_in_cross[gid].append((e.src, e.sp))
        # outputs: values consumed by other groups or feeding m's out ports
        for n in members:
            node = gi.nodes[n]
            for p in range(node.n_out()):
                ref = (n, p)
                cross = any(group_of.get(e.dst) not in (None, gid)
                            for e in gi.out_edges(n, p))
                feeds_out = ref in out_src
                if (cross or feeds_out) and ref not in g_out_refs[gid]:
                    g_out_refs[gid].append(ref)
        g_in_ports[gid].sort()
        g_in_cross[gid].sort()
        g_out_refs[gid].sort()

    for p, ref in enumerate(out_src):  # pass-through outputs unsupported
        if ref[0] in in_port_of and gc.out_edges(nid, p):
            raise RegionError(
                f"map[{m.dim}] passes input straight to output")

    # build one new map per group
    new_ids: List[int] = []
    port_at: Dict[Ref, Tuple[int, int]] = {}  # gi ref -> (new map id, port)
    for gid in range(n_groups):
        members = [n for n in topo_ops if group_of[n] == gid]
        sub = Graph()
        sub.causal_dims = dict(gi.causal_dims)
        ref_map: Dict[Ref, Ref] = {}
        mapped_flags: List[bool] = []
        outer_srcs: List[Ref] = []
        for p in g_in_ports[gid]:
            src_node: InputNode = gi.nodes[gi.input_ids[p]]
            iid = sub.add(InputNode(src_node.name, src_node.vtype))
            ref_map[(gi.input_ids[p], 0)] = (iid, 0)
            mapped_flags.append(m.mapped[p])
            oe = gc.in_edge(nid, p)
            outer_srcs.append((oe.src, oe.sp))
        for ref in g_in_cross[gid]:
            vt = types[ref]
            iid = sub.add(InputNode(f"t{ref[0]}_{ref[1]}", vt))
            ref_map[ref] = (iid, 0)
            mapped_flags.append(True)  # cross values vary per iteration
            outer_srcs.append(port_at[ref])  # producer group built earlier
        for n in members:  # topo-sorted member ids keep construction stable
            clone = copy.deepcopy(gi.nodes[n])
            if isinstance(clone, MapNode):
                clone.inner.causal_dims = dict(gi.causal_dims)
            cid = sub.add(clone)
            for e in gi.in_edges(n):
                ref_map_src = ref_map[(e.src, e.sp)]
                sub.connect(ref_map_src, (cid, e.dp))
            for p in range(clone.n_out()):
                ref_map[(n, p)] = (cid, p)
        for k, ref in enumerate(g_out_refs[gid]):
            oid = sub.add(OutputNode(f"t{ref[0]}_{ref[1]}"))
            sub.connect(ref_map[ref], (oid, 0))

        new_node = MapNode(m.dim, sub,
                           mapped_flags, [None] * len(g_out_refs[gid]))
        new_id = gc.add(new_node)
        for p, src in enumerate(outer_srcs):
            gc.connect(src, (new_id, p))
        for k, ref in enumerate(g_out_refs[gid]):
            port_at[ref] = (new_id, k)
        new_ids.append(new_id)

    # rewire consumers of the old map's out ports, then drop it
    for p, ref in enumerate(out_src):
        if gc.out_edges(nid, p):
            gc.rewire_consumers((nid, p), port_at[ref])
    gc.remove_node(nid)
    return new_ids


def _make_valid(gc: Graph, nid: int) -> None:
    if nid not in gc.nodes:
        return
    node = gc.nodes[nid]
    if region_ok(node):
        return
    if not isinstance(node, MapNode):
        raise RegionError(f"unsupported region root {node.label()}")
    if node.serial:
        raise RegionError(
            f"serial map[{node.dim}] region contains unsupported nodes")
    gi = node.inner
    for inner_id in list(sorted(gi.op_nodes())):
        _make_valid(gi, inner_id)
    if region_ok(node):
        return
    for new_id in _split_map(gc, nid):
        _make_valid(gc, new_id)


def partition(g: Graph) -> Graph:
    """Equivalent program whose every top-level op node is a valid region
    (``region_ok``).  Raises :class:`RegionError` for nests it cannot
    split (MiscNode / exotic pass-throughs)."""
    g = g.clone()
    for nid in list(sorted(g.op_nodes())):
        _make_valid(g, nid)
    g.validate()
    return g


# ---------------------------------------------------------------------------
# Region extraction: one standalone Graph per top-level op node
# ---------------------------------------------------------------------------

@dataclass
class RegionSpec:
    """One kernel's worth of program: a standalone single-op graph plus
    the top-level wiring the executor threads between kernels."""

    node: int                 # top-level op node id in the partitioned graph
    label: str
    grid_dims: Tuple[str, ...]
    red_dim: Optional[str]
    graph: Graph              # inputs -> the op node -> outputs
    in_refs: List[Ref]        # top-level (node, port) feeding each input
    out_refs: List[Ref]       # top-level (node, port) each output defines


@dataclass
class ProgramPlan:
    """The partitioned program and its regions in topological order."""

    graph: Graph
    regions: List[RegionSpec] = field(default_factory=list)

    @property
    def n_regions(self) -> int:
        return len(self.regions)


def plan_program(g: Graph) -> ProgramPlan:
    """Partition ``g`` and extract every region.  Regions come back in
    topological order, so executing them in sequence (threading the
    ``in_refs``/``out_refs`` values) evaluates the program."""
    part = partition(g)
    types = part.infer_types()
    regions: List[RegionSpec] = []
    for nid in part.topo():
        node = part.nodes[nid]
        if isinstance(node, (InputNode, OutputNode)):
            continue
        sp = spine(node)
        if sp is None:  # partition() guarantees this cannot happen
            raise RegionError(f"unlowerable region {node.label()}")
        grid_dims, red_dim = sp
        if isinstance(node, ReduceNode):
            e = part.in_edge(nid, 0)
            red_dim = types[(e.src, e.sp)].dims[0]

        rg = Graph()
        rg.causal_dims = dict(part.causal_dims)
        in_refs: List[Ref] = []
        srcs: List[Ref] = []
        for p, e in enumerate(part.in_edges(nid)):
            src = part.nodes[e.src]
            name = (src.name if isinstance(src, InputNode)
                    else f"t{e.src}_{e.sp}")
            rg.add(InputNode(name, types[(e.src, e.sp)]))
            in_refs.append((e.src, e.sp))
            srcs.append((e.src, e.sp))
        clone = copy.deepcopy(node)
        cid = rg.add(clone)
        for p in range(len(srcs)):
            rg.connect((rg.input_ids[p], 0), (cid, p))
        out_refs: List[Ref] = []
        for p in range(node.n_out()):
            if not part.out_edges(nid, p):
                continue  # dead port: nothing downstream wants it
            names = [part.nodes[e.dst].name
                     for e in part.out_edges(nid, p)
                     if isinstance(part.nodes[e.dst], OutputNode)]
            oid = rg.add(OutputNode(names[0] if names else f"o{p}"))
            rg.connect((cid, p), (oid, 0))
            out_refs.append((nid, p))
        if not out_refs:
            continue  # fully dead region
        rg.validate()
        regions.append(RegionSpec(nid, node.label(), tuple(grid_dims),
                                  red_dim, rg, in_refs, out_refs))
    return ProgramPlan(part, regions)


# ---------------------------------------------------------------------------
# Region grouping: pack compatible regions into megakernels
# ---------------------------------------------------------------------------

# half a TPU core's ~16 MiB VMEM: room for double-buffered input windows
# next to the resident carries
DEFAULT_VMEM_BUDGET = 8 << 20
VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET_BYTES"


@dataclass
class RegionGroup:
    """One megakernel's worth of regions.

    ``members`` run in sequence inside a single kernel whose grid is
    ``grid_dims`` (a subset of every member's parallel spine — members'
    off-grid dims evaluate in-kernel over whole-resident data).
    ``resident`` lists the cross-region values that never leave VMEM:
    produced by one member, consumed only by later members.  ``out_refs``
    are the values spilled to global memory (consumed by other groups or
    program outputs)."""

    gid: str
    members: List[RegionSpec]
    grid_dims: Tuple[str, ...]
    in_refs: List[Ref]
    out_refs: List[Ref]
    resident: List[Ref]

    @property
    def label(self) -> str:
        return "+".join(m.label for m in self.members)


@dataclass
class GroupedPlan:
    """The region DAG packed into kernel-sized groups (topological
    order): launching the groups in sequence, threading the spilled
    ``out_refs`` between them, evaluates the program."""

    plan: ProgramPlan
    groups: List[RegionGroup] = field(default_factory=list)
    budget_bytes: int = DEFAULT_VMEM_BUDGET

    @property
    def n_launches(self) -> int:
        return len(self.groups)

    @property
    def n_resident_edges(self) -> int:
        return sum(len(g.resident) for g in self.groups)


def vmem_budget(budget_bytes: Optional[int] = None) -> int:
    """The grouping VMEM budget: explicit argument, else
    ``$REPRO_VMEM_BUDGET_BYTES``, else :data:`DEFAULT_VMEM_BUDGET`."""
    if budget_bytes is not None:
        return int(budget_bytes)
    return int(os.environ.get(VMEM_BUDGET_ENV, DEFAULT_VMEM_BUDGET))


def _est_value_bytes(vt, dims: Dict[str, int],
                     blocks: Optional[Dict[str, int]],
                     grid: frozenset) -> int:
    """Estimated in-kernel VMEM footprint (f32) of one value: grid dims
    contribute one block, off-grid dims are whole-resident.  Intermediate
    item extents are approximated by the per-dim block sizes — a budget
    estimate, not the emitted shapes."""
    blocks = blocks or {}
    default_b = max([int(b) for b in blocks.values()] or [8])
    lead = vt.lead_dims
    split = vt.dims[lead:]
    n = 1
    for d in vt.dims[:lead]:
        n *= 1 if d in grid else dims.get(d, 1)
    for d in split:
        b = int(blocks.get(d, default_b))
        n *= b if d in grid else b * dims.get(d, 1)
    for _ in range(vt.item_ndim - len(split)):
        n *= default_b
    return 4 * n


def _group_bytes(regions: Sequence[RegionSpec], member_ids: Sequence[int],
                 types, dims, blocks, grid: frozenset) -> int:
    refs = set()
    for i in member_ids:
        refs.update(regions[i].in_refs)
        refs.update(regions[i].out_refs)
    return sum(_est_value_bytes(types[r], dims, blocks, grid)
               for r in refs)


def _finish_groups(plan: ProgramPlan, member_sets: List[List[int]],
                   grids: List[Tuple[str, ...]], budget: int) -> GroupedPlan:
    """Materialize ``RegionGroup``s in a deterministic topological order
    of the group-level DAG and classify each cross-region value as
    resident (in-VMEM carry) or spilled (global array)."""
    regions = plan.regions
    prod_group: Dict[Ref, int] = {}
    for gi, members in enumerate(member_sets):
        for i in members:
            for r in regions[i].out_refs:
                prod_group[r] = gi
    deps: List[set] = [set() for _ in member_sets]
    for gi, members in enumerate(member_sets):
        for i in members:
            for r in regions[i].in_refs:
                pg = prod_group.get(r)
                if pg is not None and pg != gi:
                    deps[gi].add(pg)
    order: List[int] = []
    done: set = set()
    ready = sorted(gi for gi in range(len(member_sets)) if not deps[gi])
    while ready:
        gi = ready.pop(0)
        order.append(gi)
        done.add(gi)
        newly = sorted(gj for gj in range(len(member_sets))
                       if gj not in done and gj not in ready
                       and deps[gj] <= done)
        ready = sorted(ready + newly)
    if len(order) != len(member_sets):
        raise RegionError("cycle in region-group DAG")  # join checks failed

    program_outs = {(e.src, e.sp) for oid in plan.graph.output_ids
                    for e in [plan.graph.in_edge(oid, 0)]}
    consumers: Dict[Ref, set] = {}
    for gi, members in enumerate(member_sets):
        for i in members:
            for r in regions[i].in_refs:
                consumers.setdefault(r, set()).add(gi)

    groups: List[RegionGroup] = []
    for k, gi in enumerate(order):
        members = [regions[i] for i in member_sets[gi]]
        produced = {r for m in members for r in m.out_refs}
        in_refs: List[Ref] = []
        for m in members:
            for r in m.in_refs:
                if r not in produced and r not in in_refs:
                    in_refs.append(r)
        out_refs: List[Ref] = []
        resident: List[Ref] = []
        for m in members:
            for r in m.out_refs:
                spill = (r in program_outs
                         or consumers.get(r, set()) - {gi})
                if spill:
                    out_refs.append(r)
                elif r in consumers:
                    resident.append(r)
                else:  # produced but consumed nowhere: keep as output
                    out_refs.append(r)
        gid = f"g{k}:" + "+".join(str(m.node) for m in members)
        groups.append(RegionGroup(gid, members, grids[gi], in_refs,
                                  out_refs, resident))
    return GroupedPlan(plan, groups, budget)


def ungrouped_plan(plan: ProgramPlan) -> GroupedPlan:
    """Every region in its own group — the pre-grouping one-kernel-per-
    region lowering, as a ``GroupedPlan`` so both paths share one
    executor shape."""
    return _finish_groups(plan, [[i] for i in range(len(plan.regions))],
                          [spec.grid_dims for spec in plan.regions],
                          budget=0)


def group_plan(plan: ProgramPlan, dims: Dict[str, int],
               blocks: Optional[Dict[str, int]] = None, *,
               budget_bytes: Optional[int] = None) -> GroupedPlan:
    """Greedily pack the region DAG into megakernel groups.

    Regions are visited in topological order; each joins the first
    existing group it is compatible with, preferring groups that produce
    one of its inputs (the join turns that edge into a VMEM-resident
    carry).  Compatibility:

    * **chained** (the candidate consumes a group output): the shared
      grid shrinks to the intersection of the group grid and the
      candidate's parallel dims — non-empty, and never containing the
      candidate's serial dim;
    * **siblings** (no edge): grids must be set-equal — shrinking a grid
      for an unrelated region buys no traffic, only VMEM;
    * joining must not create a kernel-level cycle through a region
      outside the group;
    * the group's estimated VMEM footprint (every boundary and resident
      value at the — possibly shrunk — grid) must fit ``budget_bytes``
      (default ``$REPRO_VMEM_BUDGET_BYTES`` or 8 MiB).

    The result is deterministic for a given (plan, dims, blocks,
    budget): selection's per-kernel costing and the Pallas emitter
    re-derive identical groupings.
    """
    budget = vmem_budget(budget_bytes)
    regions = plan.regions
    types = plan.graph.infer_types()
    prod_of: Dict[Ref, int] = {}
    for i, spec in enumerate(regions):
        for r in spec.out_refs:
            prod_of[r] = i
    deps = [sorted({prod_of[r] for r in spec.in_refs if r in prod_of})
            for spec in regions]
    anc: List[set] = [set() for _ in regions]
    for i in range(len(regions)):
        for p in deps[i]:
            anc[i] |= anc[p] | {p}

    member_sets: List[List[int]] = []
    grids: List[Tuple[str, ...]] = []
    gidx: Dict[int, int] = {}
    for i, spec in enumerate(regions):
        sdims = set(spec.grid_dims)
        connected = sorted({gidx[p] for p in deps[i]})
        placed = None
        for gi in connected + [g for g in range(len(member_sets))
                               if g not in connected]:
            newgrid = tuple(d for d in grids[gi] if d in sdims)
            if not newgrid:
                continue
            if gi not in connected and (set(grids[gi]) != sdims):
                continue  # sibling joins never shrink the group's grid
            if spec.red_dim is not None and spec.red_dim in newgrid:
                continue
            gset = set(member_sets[gi])
            if any(anc[k] & gset for k in (anc[i] - gset)):
                continue  # would order-cycle through an outside region
            if _group_bytes(regions, member_sets[gi] + [i], types, dims,
                            blocks, frozenset(newgrid)) > budget:
                continue
            member_sets[gi].append(i)
            grids[gi] = newgrid
            placed = gi
            break
        if placed is None:
            gidx[i] = len(member_sets)
            member_sets.append([i])
            grids.append(tuple(spec.grid_dims))
        else:
            gidx[i] = placed
    return _finish_groups(plan, member_sets, grids, budget)
