"""Functional-operator vocabulary for block programs (paper Table 1).

Each functional operator is a stateless function on *items* that live in
local memory: blocks (2-D arrays), vectors (1-D), or scalars.

NOTE on ``row_sum``: Table 1's printed numpy definition (``sum(a, axis=0)``)
contradicts both its own prose ("sums the values in each row") and every use
in the paper's worked examples (the softmax denominator, LayerNorm row
statistics, and the ``row_scale`` constraint ``c.size == a.shape[0]`` all
need per-row sums).  We use ``axis=1`` with ``r.size == a.shape[0]``, which
makes all three examples type-check and validate numerically.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

# Item kinds
BLOCK = "block"
VECTOR = "vector"
SCALAR = "scalar"

_SAFE_FNS = ("exp", "log", "sqrt", "maximum", "minimum", "abs", "tanh",
             "where", "sign")


def _env(xp) -> Dict[str, Any]:
    env = {name: getattr(xp, name) for name in _SAFE_FNS if hasattr(xp, name)}
    env["pi"] = math.pi
    return env


class Op:
    """Base functional operator."""

    name: str = "op"
    n_in: int = 1

    def result_kind(self, kinds: Tuple[str, ...]) -> str:
        raise NotImplementedError

    def apply(self, xp, *args):
        raise NotImplementedError

    def render(self, args: Tuple[str, ...]) -> str:
        return f"{self.name}({', '.join(args)})"

    def clone(self) -> "Op":
        return self  # stateless ops are shared

    # Structural equality for tests / dedup.
    def signature(self) -> Tuple:
        return (self.name,)

    def __repr__(self):
        return f"<{self.name}>"


class Dot(Op):
    """r = a @ b.T  (contraction over the shared last axis)."""

    name = "dot"
    n_in = 2

    def result_kind(self, kinds):
        assert kinds == (BLOCK, BLOCK), kinds
        return BLOCK

    def apply(self, xp, a, b):
        return a @ b.T


class Outer(Op):
    """r = outer(a, b) for vectors a, b."""

    name = "outer"
    n_in = 2

    def result_kind(self, kinds):
        assert kinds == (VECTOR, VECTOR), kinds
        return BLOCK

    def apply(self, xp, a, b):
        return xp.outer(a, b)


class RowScale(Op):
    """r = a * c[:, None] — scale each row of a block."""

    name = "row_scale"
    n_in = 2

    def result_kind(self, kinds):
        assert kinds[0] == BLOCK and kinds[1] in (VECTOR, SCALAR), kinds
        return BLOCK

    def apply(self, xp, a, c):
        c = xp.asarray(c)
        if c.ndim == 0:
            return a * c
        return a * c[:, None]


class RowShift(Op):
    """r = a + c[:, None] — add c_i to row i of a block."""

    name = "row_shift"
    n_in = 2

    def result_kind(self, kinds):
        assert kinds[0] == BLOCK and kinds[1] in (VECTOR, SCALAR), kinds
        return BLOCK

    def apply(self, xp, a, c):
        c = xp.asarray(c)
        if c.ndim == 0:
            return a + c
        return a + c[:, None]


class RowSum(Op):
    """r = a.sum(axis=1) — per-row sums (see module docstring)."""

    name = "row_sum"
    n_in = 1

    def result_kind(self, kinds):
        assert kinds == (BLOCK,), kinds
        return VECTOR

    def apply(self, xp, a):
        return a.sum(axis=1)


class RowMax(Op):
    """r = a.max(axis=1) — per-row maxima.

    Introduced by the numerical-safety pass (``numerics.stabilize``):
    the shared row-wise exponent of a significand–exponent pair is the
    row max of the exponentiation argument."""

    name = "row_max"
    n_in = 1

    def result_kind(self, kinds):
        assert kinds == (BLOCK,), kinds
        return VECTOR

    def apply(self, xp, a):
        return a.max(axis=1)


# Large-negative fill for masked attention scores: survives a subsequent
# scale multiply (scale * NEG_MASK is still << float32 min for exp) and
# exp() maps it to exactly 0.0 in float32.
NEG_MASK = -1e30


class CausalMask(Op):
    """r[i,j] = a[i,j] if rows[i] >= cols[j] else NEG_MASK.

    ``rows`` / ``cols`` are per-row and per-column *global position*
    vectors (they arrive as ordinary blocked program inputs, so the
    query-block index reaches the masked score computation as data —
    no special index plumbing in any backend).  A decode step is the
    same op with a single row position equal to the cache write
    position."""

    name = "causal_mask"
    n_in = 3

    def result_kind(self, kinds):
        assert kinds == (BLOCK, VECTOR, VECTOR), kinds
        return BLOCK

    def apply(self, xp, a, rows, cols):
        rows = xp.asarray(rows)
        cols = xp.asarray(cols)
        return xp.where(rows[:, None] >= cols[None, :], a, NEG_MASK)


_ARG_RE = re.compile(r"\ba(\d+)\b")


@dataclass
class Elementwise(Op):
    """An n-ary elementwise operator defined by an expression over a0..a{n-1}.

    ``consts`` are named scalar constants usable in the expression.  Two
    consecutive Elementwise nodes compose into one (paper Rule 9).
    """

    expr: str = "a0"
    n_in: int = 1
    consts: Dict[str, float] = field(default_factory=dict)
    name: str = "ew"

    def result_kind(self, kinds):
        order = {SCALAR: 0, VECTOR: 1, BLOCK: 2}
        return max(kinds, key=lambda k: order[k])

    def apply(self, xp, *args):
        env = _env(xp)
        env.update(self.consts)
        for i, a in enumerate(args):
            env[f"a{i}"] = a
        # __import__ must be reachable: numpy's overflow-warning machinery
        # imports lazily inside ufuncs; everything else stays sandboxed.
        return eval(self.expr,  # noqa: S307
                    {"__builtins__": {"__import__": __import__}}, env)

    def render(self, args):
        out = _ARG_RE.sub(lambda m: args[int(m.group(1))], self.expr)
        for k, v in self.consts.items():
            out = re.sub(rf"\b{k}\b", repr(v), out)
        return out

    def signature(self):
        # normalize const scalar types (np.float64 is a float subclass with
        # a different repr) so structurally-equal ops fingerprint equally
        return ("ew", self.expr, self.n_in,
                tuple(sorted((k, float(v))
                             for k, v in self.consts.items())))

    def clone(self):
        return Elementwise(self.expr, self.n_in, dict(self.consts))

    def __repr__(self):
        return f"<ew:{self.expr}>"


def compose_elementwise(u: Elementwise, v: Elementwise, dport: int) -> Elementwise:
    """Compose v after u, where u's output feeds v's input ``dport``.

    New op args = u's args followed by v's remaining args (paper Rule 9).
    """
    consts = dict(u.consts)
    v_expr = v.expr
    # Rename v's consts on collision.
    for k, val in v.consts.items():
        nk = k
        while nk in consts and consts[nk] != val:
            nk = nk + "_"
        if nk != k:
            v_expr = re.sub(rf"\b{k}\b", nk, v_expr)
        consts[nk] = val

    n_new = u.n_in + v.n_in - 1

    # Map v's argument indices into the composed argument list.
    def v_arg(m):
        i = int(m.group(1))
        if i == dport:
            return f"({u.expr})"
        j = i if i < dport else i - 1
        return f"a{u.n_in + j}__NEW"

    expr = _ARG_RE.sub(v_arg, v_expr)
    expr = expr.replace("__NEW", "")
    return Elementwise(expr, n_new, consts)


# ---------------------------------------------------------------------------
# Serial-map reduction tags (MapNode.reduced vocabulary)
# ---------------------------------------------------------------------------
# Historically the only accumulating tag was "+".  The numerical-safety
# pass (numerics.stabilize) adds two more, lowered by every backend:
#
#   "max"   — running elementwise maximum (init -inf): the shared
#             exponent carry of a significand–exponent pair.
#   "+@k"   — a rescaled additive carry *coupled* to the "max" port k of
#             the same map: on each step, with z_old the max carry
#             before the step, m the step's port-k value and
#             z_new = max(z_old, m),
#
#                 acc' = acc * exp(z_old - z_new) + step * exp(m - z_new)
#
#             — exactly Flash Attention's rescale-on-new-max recurrence.
#
# Tags participate in Graph.canonical(), so stabilized programs
# fingerprint (and therefore cache) differently from raw ones.

REDUCE_ADD = "+"
REDUCE_MAX = "max"

_RESCALED_RE = re.compile(r"^\+@(\d+)$")


def rescaled_add(port: int) -> str:
    """The reduced tag of an additive carry rescaled against the "max"
    out-port ``port`` of the same map."""
    return f"+@{port}"


def rescaled_ref(tag) -> "int | None":
    """The coupled max-port index of a ``"+@k"`` tag, else ``None``."""
    if not isinstance(tag, str):
        return None
    m = _RESCALED_RE.match(tag)
    return int(m.group(1)) if m else None


def bcast_to(xp, f, like):
    """Broadcast a row-wise factor against a higher-rank significand by
    appending trailing singleton axes (uniform rank rule: the leading
    axis is the row axis at every rank)."""
    f = xp.asarray(f)
    extra = xp.asarray(like).ndim - f.ndim
    if extra > 0:
        return f.reshape(f.shape + (1,) * extra)
    return f


def serial_accum_step(collected, vals, tags, xp):
    """Advance one step of a serial map's (possibly coupled) carries.

    ``collected[p]`` is the carry for out-port ``p`` (``None`` before the
    first step; a python list for non-reduced ports), ``vals[p]`` the
    step's port value, ``tags[p]`` the reduced tag.  Mutates and returns
    ``collected``.  Shared by the interpreter and the Pallas grouped
    lowering so the "max"/"+@k" semantics exist in exactly one place.
    """
    z_old: Dict[int, Any] = {}
    z_new: Dict[int, Any] = {}
    for p, r in enumerate(tags):
        if r == REDUCE_MAX:
            z_old[p] = collected[p]
            z_new[p] = (vals[p] if collected[p] is None
                        else xp.maximum(collected[p], vals[p]))
    for p, r in enumerate(tags):
        if r is None:
            collected[p].append(vals[p])
        elif r == REDUCE_ADD:
            collected[p] = (vals[p] if collected[p] is None
                            else collected[p] + vals[p])
        elif r == REDUCE_MAX:
            collected[p] = z_new[p]
        else:
            k = rescaled_ref(r)
            if k is None:
                raise NotImplementedError(f"reduced tag {r!r}")
            step = vals[p] * bcast_to(xp, xp.exp(vals[k] - z_new[k]),
                                      vals[p])
            if collected[p] is None:
                collected[p] = step
            else:
                collected[p] = (
                    collected[p]
                    * bcast_to(xp, xp.exp(z_old[k] - z_new[k]),
                               collected[p])
                    + step)
    return collected


def plain_serial_tags(tags) -> bool:
    """True when every accumulating tag is the legacy "+" (the fast
    uncoupled path every backend had before stabilization)."""
    return all(r is None or r == REDUCE_ADD for r in tags)


# ---------------------------------------------------------------------------
# Shared instances / convenience constructors
# ---------------------------------------------------------------------------

DOT = Dot()
OUTER = Outer()
ROW_SCALE = RowScale()
ROW_SHIFT = RowShift()
ROW_SUM = RowSum()
ROW_MAX = RowMax()
CAUSAL_MASK = CausalMask()


def ew(expr: str, n_in: int = 1, **consts) -> Elementwise:
    return Elementwise(expr, n_in, consts)


EW_ADD = ew("a0+a1", 2)
EW_MUL = ew("a0*a1", 2)


def is_elementwise(op: Op) -> bool:
    return isinstance(op, Elementwise)
