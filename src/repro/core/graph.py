"""Block-program IR (paper §2).

A block program is a hierarchical DAG.  Nodes:

* ``InputNode`` / ``OutputNode`` — the program (or inner-graph) boundary.
* ``FuncNode`` — a functional operator on items in local memory (Table 1).
* ``MapNode`` — an embarrassingly-parallel loop over one dimension, holding
  an inner ``Graph``.  Each in-port is either *mapped* (consumes one item of
  a list per iteration) or *broadcast* (the whole value is visible to every
  iteration).  Each out-port is either a plain list output or *reduced*
  (paper Rule 3 moved a reduction inside: the port yields a single item and
  the map lowers to a serial loop with an accumulator).
* ``ReduceNode`` — reduces a list to a single item (circled ``+``).
* ``MiscNode`` — escape hatch for operators outside the vocabulary.

Value types (``VType``) record the list-nesting dims (outer first) and the
item kind.  Edge *bufferedness* is derived, matching the paper: an edge is
buffered iff it carries a list (which cannot fit in local memory) or is
incident to program inputs/outputs (which live in global memory).
"""

from __future__ import annotations

import copy
import hashlib
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core import ops as O


_ITEM_NDIM = {O.BLOCK: 2, O.VECTOR: 1, O.SCALAR: 0}


@dataclass(frozen=True)
class VType:
    dims: Tuple[str, ...] = ()
    item: str = O.BLOCK

    @property
    def is_list(self) -> bool:
        return len(self.dims) > 0

    @property
    def item_ndim(self) -> int:
        """Array rank of one item of this kind (block 2, vector 1,
        scalar 0)."""
        return _ITEM_NDIM[self.item]

    @property
    def lead_dims(self) -> int:
        """Leading list dims beyond the item rank.  In the merged dense
        layout (pipeline/packing.py) and the Pallas lowering these are
        plain stack axes of extent ``dims[d]`` with block size 1 — e.g.
        the GQA head-group dim of ``block[H,M,D]``."""
        return max(len(self.dims) - _ITEM_NDIM[self.item], 0)

    def strip(self) -> "VType":
        return VType(self.dims[1:], self.item)

    def wrap(self, dim: str) -> "VType":
        return VType((dim,) + self.dims, self.item)

    def __repr__(self):
        if not self.dims:
            return self.item
        return f"{self.item}[{','.join(self.dims)}]"


Ref = Tuple[int, int]  # (node_id, port)


@dataclass(frozen=True)
class Edge:
    src: int
    sp: int
    dst: int
    dp: int


class Node:
    id: int = -1

    def n_in(self) -> int:
        raise NotImplementedError

    def n_out(self) -> int:
        raise NotImplementedError

    def label(self) -> str:
        return type(self).__name__


class InputNode(Node):
    def __init__(self, name: str, vtype: VType):
        self.name = name
        self.vtype = vtype

    def n_in(self):
        return 0

    def n_out(self):
        return 1

    def label(self):
        return f"in:{self.name}:{self.vtype!r}"


class OutputNode(Node):
    def __init__(self, name: str):
        self.name = name

    def n_in(self):
        return 1

    def n_out(self):
        return 0

    def label(self):
        return f"out:{self.name}"


class FuncNode(Node):
    def __init__(self, op: O.Op):
        self.op = op

    def n_in(self):
        return self.op.n_in

    def n_out(self):
        return 1

    def label(self):
        return self.op.name if not isinstance(self.op, O.Elementwise) else f"ew[{self.op.expr}]"


class ReduceNode(Node):
    def __init__(self, op: str = "+"):
        self.op = op

    def n_in(self):
        return 1

    def n_out(self):
        return 1

    def label(self):
        return f"reduce[{self.op}]"


class MiscNode(Node):
    """Anything outside the vocabulary; blocks all fusion around it.

    ``type_fn`` optionally maps input VTypes to output VTypes (defaults to
    one block item per out-port)."""

    def __init__(self, name: str, n_in: int, n_out: int, fn=None,
                 type_fn=None):
        self.name = name
        self._n_in = n_in
        self._n_out = n_out
        self.fn = fn
        self.type_fn = type_fn

    def n_in(self):
        return self._n_in

    def n_out(self):
        return self._n_out

    def label(self):
        return f"misc:{self.name}"


class MapNode(Node):
    def __init__(self, dim: str, inner: "Graph", mapped: List[bool],
                 reduced: List[Optional[str]]):
        self.dim = dim
        self.inner = inner
        self.mapped = list(mapped)
        self.reduced = list(reduced)
        assert len(self.mapped) == len(inner.input_ids)
        assert len(self.reduced) == len(inner.output_ids)

    def n_in(self):
        return len(self.mapped)

    def n_out(self):
        return len(self.reduced)

    @property
    def serial(self) -> bool:
        """A map with an accumulated out-port lowers to a serial loop."""
        return any(r is not None for r in self.reduced)

    def label(self):
        return f"map[{self.dim}]"


class Graph:
    """A flat graph; hierarchy comes from MapNode.inner."""

    def __init__(self):
        self.nodes: Dict[int, Node] = {}
        self.edges: Set[Edge] = set()
        self.input_ids: List[int] = []
        self.output_ids: List[int] = []
        # masking structure: {key_block_dim: query_block_dim} for every
        # causal_mask in the program; the traffic cost model uses it to
        # skip fully-masked tiles (they cost no loads, stores, or work).
        # Survives fuse() (snapshots are deep clones of this graph).
        self.causal_dims: Dict[str, str] = {}
        self._next = 0

    # -- construction -------------------------------------------------------
    def add(self, node: Node) -> int:
        nid = self._next
        self._next += 1
        node.id = nid
        self.nodes[nid] = node
        if isinstance(node, InputNode):
            self.input_ids.append(nid)
        elif isinstance(node, OutputNode):
            self.output_ids.append(nid)
        return nid

    def connect(self, src: Ref, dst: Ref) -> None:
        e = Edge(src[0], src[1], dst[0], dst[1])
        assert e.src in self.nodes and e.dst in self.nodes
        assert self.in_edge(e.dst, e.dp) is None, (
            f"in-port {(e.dst, e.dp)} already connected")
        self.edges.add(e)

    # -- queries -------------------------------------------------------------
    def in_edge(self, nid: int, port: int) -> Optional[Edge]:
        for e in self.edges:
            if e.dst == nid and e.dp == port:
                return e
        return None

    def in_edges(self, nid: int) -> List[Edge]:
        return sorted((e for e in self.edges if e.dst == nid),
                      key=lambda e: e.dp)

    def out_edges(self, nid: int, port: Optional[int] = None) -> List[Edge]:
        return sorted((e for e in self.edges
                       if e.src == nid and (port is None or e.sp == port)),
                      key=lambda e: (e.sp, e.dst, e.dp))

    def op_nodes(self) -> List[int]:
        return [nid for nid, n in self.nodes.items()
                if not isinstance(n, (InputNode, OutputNode))]

    def topo(self) -> List[int]:
        indeg = {nid: 0 for nid in self.nodes}
        for e in self.edges:
            indeg[e.dst] += 1
        ready = sorted(nid for nid, d in indeg.items() if d == 0)
        order: List[int] = []
        while ready:
            nid = ready.pop(0)
            order.append(nid)
            for e in sorted(self.out_edges(nid), key=lambda e: e.dst):
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    ready.append(e.dst)
        if len(order) != len(self.nodes):
            raise ValueError("cycle in block program graph")
        return order

    def reachable(self, a: int, b: int, skip_direct: bool = False) -> bool:
        """Is b reachable from a?  skip_direct ignores direct a->b edges."""
        frontier = [a]
        seen = set()
        while frontier:
            n = frontier.pop()
            for e in self.out_edges(n):
                if skip_direct and n == a and e.dst == b:
                    continue
                if e.dst == b:
                    return True
                if e.dst not in seen:
                    seen.add(e.dst)
                    frontier.append(e.dst)
        return False

    # -- mutation -------------------------------------------------------------
    def remove_node(self, nid: int) -> None:
        self.edges = {e for e in self.edges if e.src != nid and e.dst != nid}
        node = self.nodes.pop(nid)
        if isinstance(node, InputNode):
            self.input_ids.remove(nid)
        elif isinstance(node, OutputNode):
            self.output_ids.remove(nid)

    def disconnect(self, e: Edge) -> None:
        self.edges.discard(e)

    def rewire_consumers(self, old: Ref, new: Ref) -> None:
        """Make every consumer of old (src,port) read from new instead."""
        moved = [e for e in self.edges if (e.src, e.sp) == old]
        for e in moved:
            self.edges.discard(e)
            self.edges.add(Edge(new[0], new[1], e.dst, e.dp))

    def clone(self) -> "Graph":
        return copy.deepcopy(self)

    # -- identity ---------------------------------------------------------------
    def canonical(self) -> str:
        """A canonical serialization of the whole hierarchy.

        Node ids are renumbered by topological order, so a program built
        by the same deterministic construction sequence (e.g. the
        ``array_program`` builders) serializes identically in every
        process.  This is *not* full graph-isomorphism canonicalization:
        two equal programs whose independent nodes were inserted in
        different orders may serialize differently — that costs a
        spurious cache miss, never a wrong hit.  Functional operators
        contribute their full ``Op.signature()`` (expression and
        constants included) and ``MiscNode`` functions hash their
        bytecode+consts, so programs differing only in baked-in behavior
        do not collide."""
        order = self.topo()
        renum = {nid: i for i, nid in enumerate(order)}
        parts: List[str] = []
        for nid in order:
            node = self.nodes[nid]
            if isinstance(node, InputNode):
                lbl = f"in:{node.name}:{node.vtype!r}"
            elif isinstance(node, OutputNode):
                lbl = f"out:{node.name}"
            elif isinstance(node, FuncNode):
                lbl = f"func:{node.op.signature()!r}"
            elif isinstance(node, ReduceNode):
                lbl = f"reduce:{node.op}"
            elif isinstance(node, MiscNode):
                fn_tag = ""
                if node.fn is not None:
                    code = getattr(node.fn, "__code__", None)
                    if code is not None:
                        fn_tag = ":" + hashlib.sha256(
                            code.co_code
                            + repr(code.co_consts).encode()
                        ).hexdigest()[:12]
                    else:
                        fn_tag = ":" + getattr(node.fn, "__qualname__",
                                               "fn")
                lbl = f"misc:{node.name}:{node.n_in()}:{node.n_out()}{fn_tag}"
            elif isinstance(node, MapNode):
                m = "".join("1" if x else "0" for x in node.mapped)
                r = ",".join("-" if x is None else x for x in node.reduced)
                lbl = (f"map:{node.dim}:m={m}:r={r}"
                       f":inner={{{node.inner.canonical()}}}")
            else:
                raise TypeError(node)
            ins = ",".join(f"{renum[e.src]}.{e.sp}"
                           for e in self.in_edges(nid))
            parts.append(f"{renum[nid]}={lbl}<[{ins}]")
        io = ("I:" + ",".join(str(renum[i]) for i in self.input_ids)
              + ";O:" + ",".join(str(renum[o]) for o in self.output_ids))
        if self.causal_dims:
            io += ";C:" + ",".join(
                f"{k}<{q}" for k, q in sorted(self.causal_dims.items()))
        return io + "|" + ";".join(parts)

    def fingerprint(self) -> str:
        """Stable content hash of the program (hex).  Equal for
        structurally identical programs regardless of process or node-id
        allocation order; used as the kernel-cache key component."""
        return hashlib.sha256(self.canonical().encode()).hexdigest()[:32]

    # -- typing ----------------------------------------------------------------
    def infer_types(self, in_types: Optional[Sequence[VType]] = None
                    ) -> Dict[Ref, VType]:
        """Return {(node, out_port): VType}; validates the whole hierarchy."""
        types: Dict[Ref, VType] = {}
        if in_types is None:
            in_types = [self.nodes[i].vtype for i in self.input_ids]  # type: ignore[attr-defined]
        for nid, t in zip(self.input_ids, in_types):
            types[(nid, 0)] = t

        for nid in self.topo():
            node = self.nodes[nid]
            if isinstance(node, InputNode):
                continue
            ins: List[VType] = []
            for p in range(node.n_in()):
                e = self.in_edge(nid, p)
                if e is None:
                    raise ValueError(f"unconnected in-port {p} of {node.label()}")
                ins.append(types[(e.src, e.sp)])
            if isinstance(node, OutputNode):
                continue
            if isinstance(node, FuncNode):
                for t in ins:
                    if t.is_list:
                        raise TypeError(
                            f"func {node.label()} fed a list {t!r}")
                kind = node.op.result_kind(tuple(t.item for t in ins))
                types[(nid, 0)] = VType((), kind)
            elif isinstance(node, ReduceNode):
                t = ins[0]
                if not t.is_list:
                    raise TypeError("reduce needs a list input")
                types[(nid, 0)] = t.strip()
            elif isinstance(node, MiscNode):
                if node.type_fn is not None:
                    outs = node.type_fn(ins)
                    for p, t in enumerate(outs):
                        types[(nid, p)] = t
                else:
                    for p in range(node.n_out()):
                        types[(nid, p)] = VType((), O.BLOCK)
            elif isinstance(node, MapNode):
                inner_in: List[VType] = []
                for p, t in enumerate(ins):
                    if node.mapped[p]:
                        if not t.is_list or t.dims[0] != node.dim:
                            raise TypeError(
                                f"map[{node.dim}] mapped port {p} got {t!r}")
                        inner_in.append(t.strip())
                    else:
                        inner_in.append(t)
                inner_types = node.inner.infer_types(inner_in)
                for p, oid in enumerate(node.inner.output_ids):
                    e = node.inner.in_edge(oid, 0)
                    t = inner_types[(e.src, e.sp)]
                    if node.reduced[p] is not None:
                        types[(nid, p)] = t
                    else:
                        types[(nid, p)] = t.wrap(node.dim)
            else:
                raise TypeError(node)
        return types

    def validate(self, in_types: Optional[Sequence[VType]] = None) -> None:
        self.infer_types(in_types)
        # every in-port connected exactly once is enforced by connect();
        # check out-ports of Outputs exist etc. via topo() (acyclicity).
        self.topo()

    # -- display -----------------------------------------------------------------
    def describe(self, indent: int = 0) -> str:
        pad = "  " * indent
        lines = []
        for nid in self.topo():
            node = self.nodes[nid]
            srcs = ", ".join(
                f"{e.src}.{e.sp}" for e in self.in_edges(nid))
            lines.append(f"{pad}{nid}: {node.label()}  <- [{srcs}]")
            if isinstance(node, MapNode):
                flags = "".join("m" if m else "b" for m in node.mapped)
                reds = "".join("r" if r else "." for r in node.reduced)
                lines.append(f"{pad}   ports in={flags} out={reds}")
                lines.append(node.inner.describe(indent + 2))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------

class GB:
    """Small fluent builder for block-program graphs."""

    def __init__(self):
        self.g = Graph()

    def inp(self, name: str, vtype: VType) -> Ref:
        return (self.g.add(InputNode(name, vtype)), 0)

    def out(self, name: str, src: Ref) -> int:
        nid = self.g.add(OutputNode(name))
        self.g.connect(src, (nid, 0))
        return nid

    def func(self, op: O.Op, *srcs: Ref) -> Ref:
        nid = self.g.add(FuncNode(op))
        for p, s in enumerate(srcs):
            self.g.connect(s, (nid, p))
        return (nid, 0)

    def reduce(self, src: Ref, op: str = "+") -> Ref:
        nid = self.g.add(ReduceNode(op))
        self.g.connect(src, (nid, 0))
        return (nid, 0)

    def map(self, dim: str, inner: Graph, inputs: Sequence[Tuple[Ref, bool]],
            reduced: Optional[Sequence[Optional[str]]] = None) -> List[Ref]:
        if reduced is None:
            reduced = [None] * len(inner.output_ids)
        node = MapNode(dim, inner, [m for _, m in inputs], list(reduced))
        nid = self.g.add(node)
        for p, (src, _) in enumerate(inputs):
            self.g.connect(src, (nid, p))
        return [(nid, p) for p in range(node.n_out())]


def buffered(types: Dict[Ref, VType], e: Edge, g: Graph) -> bool:
    """Paper definition: buffered iff it carries a list, or touches program
    inputs/outputs (which live in global memory)."""
    t = types[(e.src, e.sp)]
    if t.is_list:
        return True
    return isinstance(g.nodes[e.src], InputNode) or isinstance(
        g.nodes[e.dst], OutputNode)


def internal_buffered_edges(g: Graph,
                            types: Optional[Dict[Ref, VType]] = None,
                            ) -> List[Tuple[Graph, Edge]]:
    """All buffered edges not incident to *program* inputs/outputs, across
    the whole hierarchy.  An empty result == fully fused (paper's epilogues).

    Edges inside a map that read from an inner InputNode whose data
    ultimately comes from a program input are *loads from inputs* — they are
    unavoidable and not counted here.  What we count is intermediate
    materialization: a list-typed edge produced by an operator node.
    """
    if types is None:
        types = g.infer_types()
    found: List[Tuple[Graph, Edge]] = []
    for e in g.edges:
        t = types[(e.src, e.sp)]
        src, dst = g.nodes[e.src], g.nodes[e.dst]
        if t.is_list and not isinstance(src, InputNode) and not isinstance(
                dst, OutputNode):
            found.append((g, e))
    for nid, node in g.nodes.items():
        if isinstance(node, MapNode):
            # recompute inner types
            ins = []
            for p in range(node.n_in()):
                e = g.in_edge(nid, p)
                t = types[(e.src, e.sp)]
                ins.append(t.strip() if node.mapped[p] else t)
            inner_types = node.inner.infer_types(ins)
            for sub in internal_buffered_edges(node.inner, inner_types):
                found.append(sub)
    return found
