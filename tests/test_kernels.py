"""Per-kernel allclose sweeps: Pallas body (interpret=True) vs ref.py oracle,
across shapes and dtypes, plus gradient checks through the custom_vjp path.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K
from repro.kernels import ref as R

TOL = {jnp.float32: 2e-4, jnp.bfloat16: 5e-2}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,dh,causal",
    [
        (2, 4, 2, 64, 64, 32, False),     # GQA
        (1, 8, 8, 96, 96, 64, True),      # MHA causal
        (2, 4, 1, 48, 80, 32, False),     # MQA, padded kv
        (1, 2, 2, 1, 100, 64, True),      # decode: one query vs cache
        (1, 2, 2, 33, 33, 128, True),     # odd lengths, lane-wide head
    ],
)
def test_flash_attention_sweep(rng, b, hq, hkv, sq, skv, dh, causal, dtype):
    q = _rand(rng, (b, hq, sq, dh), dtype)
    k = _rand(rng, (b, hkv, skv, dh), dtype)
    v = _rand(rng, (b, hkv, skv, dh), dtype)
    qoff = skv - sq if causal else 0
    out = K.flash_attention(q, k, v, causal=causal, q_offset=qoff,
                            impl="interpret", block_q=32, block_kv=32)
    ref = R.attention_ref(q, k, v, causal=causal, q_offset=qoff)
    assert out.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(64, 128, 96), (100, 256, 64),
                                   (32, 64, 32), (8, 128, 8)])
def test_layernorm_matmul_sweep(rng, m, k, n, dtype):
    x = _rand(rng, (m, k), dtype)
    y = _rand(rng, (k, n), dtype)
    gamma = _rand(rng, (k,), jnp.float32) * 0.1 + 1.0
    beta = _rand(rng, (k,), jnp.float32) * 0.1
    out = K.layernorm_matmul(x, y, gamma, beta, impl="interpret",
                             block_m=32, block_n=32, block_k=64)
    ref = R.layernorm_matmul_ref(x, y, gamma, beta)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype] * k ** 0.5,
                               rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d,k,n", [(64, 128, 96, 64), (40, 64, 256, 64),
                                     (16, 128, 64, 128)])
def test_rmsnorm_swiglu_sweep(rng, m, d, k, n, dtype):
    x = _rand(rng, (m, d), dtype)
    w = _rand(rng, (d, k), dtype) / np.sqrt(d)
    v = _rand(rng, (d, k), dtype) / np.sqrt(d)
    u = _rand(rng, (k, n), dtype) / np.sqrt(k)
    gamma = _rand(rng, (d,), jnp.float32) * 0.1 + 1.0
    out = K.rmsnorm_swiglu(x, w, v, u, gamma, impl="interpret",
                           block_m=32, block_k=32)
    ref = R.rmsnorm_swiglu_ref(x, w, v, u, gamma)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype] * 2, rtol=TOL[dtype] * 2)


def test_flash_attention_matches_online_softmax_invariance(rng):
    """Block-size independence: the online-softmax carry must make the
    result invariant to the kv block decomposition (appendix claim)."""
    q = _rand(rng, (1, 2, 32, 32), jnp.float32)
    k = _rand(rng, (1, 2, 96, 32), jnp.float32)
    v = _rand(rng, (1, 2, 96, 32), jnp.float32)
    outs = [
        np.asarray(K.flash_attention(q, k, v, impl="interpret",
                                     block_q=16, block_kv=bk))
        for bk in (16, 32, 96)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


def test_gradients_flow_through_fused_ops(rng):
    """custom_vjp: fused forward + reference backward == reference grads."""
    x = _rand(rng, (16, 64), jnp.float32)
    w = _rand(rng, (64, 32), jnp.float32) / 8
    v = _rand(rng, (64, 32), jnp.float32) / 8
    u = _rand(rng, (32, 64), jnp.float32) / 8
    gamma = jnp.ones((64,), jnp.float32)

    def loss_fused(x):
        return K.rmsnorm_swiglu(x, w, v, u, gamma, impl="interpret",
                                block_m=16, block_k=16).sum()

    def loss_ref(x):
        return R.rmsnorm_swiglu_ref(x, w, v, u, gamma).sum()

    g1 = jax.grad(loss_fused)(x)
    g2 = jax.grad(loss_ref)(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-4, rtol=1e-4)


def test_attention_grads(rng):
    q = _rand(rng, (1, 2, 16, 32), jnp.float32)
    k = _rand(rng, (1, 2, 16, 32), jnp.float32)
    v = _rand(rng, (1, 2, 16, 32), jnp.float32)

    def loss(fn):
        return lambda q: fn(q).sum()

    fused = lambda q: K.flash_attention(q, k, v, causal=True,
                                        impl="interpret", block_q=8,
                                        block_kv=8)
    ref = lambda q: R.attention_ref(q, k, v, causal=True)
    g1 = jax.grad(loss(fused))(q)
    g2 = jax.grad(loss(ref))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-4, rtol=1e-4)


def test_fused_kernels_match_fusion_algorithm_output(rng, attention_case):
    """Cross-layer consistency: the Pallas kernel computes the same function
    as the block program the fusion algorithm derived (Example 1)."""
    from repro.core.blocks import merge
    from repro.core.fusion import fuse
    from repro.core.numerics import run_stabilized

    snaps = fuse(attention_case.graph)
    ir_out = merge(run_stabilized(snaps[-1], attention_case.inputs,
                                  attention_case.dims)["O"])
    # reconstruct dense inputs from the blocked ones
    Q = merge(attention_case.inputs["Q"])
    KT = merge(attention_case.inputs["KT"])
    VT = merge(attention_case.inputs["VT"])
    q = jnp.asarray(Q, jnp.float32)[None, None]
    k = jnp.asarray(KT, jnp.float32)[None, None]
    v = jnp.asarray(VT.T, jnp.float32)[None, None]
    scale = 1.0 / np.sqrt(Q.shape[1])
    out = K.flash_attention(q, k, v, scale=scale, impl="interpret",
                            block_q=8, block_kv=8)[0, 0]
    np.testing.assert_allclose(np.asarray(out), ir_out, atol=1e-5, rtol=1e-5)
