"""The graph-level safety rewrite (``numerics.stabilize``).

Unlike ``run_stabilized`` (interpreter-only pair semantics), the rewrite
must produce an ordinary block program — explicit significand/exponent
edges, ``row_max``/``row_shift`` producers, and ``"max"``/``"+@k"``
serial carries — that the interpreter and every codegen execute without
any pair representation at runtime.
"""

import numpy as np
import pytest

from repro.core import array_program as AP
from repro.core import ops as O
from repro.core.blocks import merge
from repro.core.fusion import fuse
from repro.core.graph import MapNode
from repro.core.interpreter import run
from repro.core.numerics import (needs_stabilization, run_stabilized,
                                 stabilize)
from conftest import make_attention_case, make_layernorm_case, \
    make_swiglu_case


def test_needs_stabilization_detects_softmax_programs():
    assert needs_stabilization(AP.attention_program(0.125))
    assert needs_stabilization(AP.causal_attention_program(0.125))
    assert needs_stabilization(
        AP.gqa_attention_program(0.125, causal=True))
    # fused snapshots still contain the (nested) exp producer
    for s in fuse(AP.attention_program(0.125)):
        assert needs_stabilization(s)


def test_needs_stabilization_skips_exp_free_programs():
    assert not needs_stabilization(AP.layernorm_matmul_program(64.0))
    # swiglu's exp lives inside sigmoid (not top-level): raw exp there
    # never overflows because its argument is bounded by the gate input
    assert not needs_stabilization(AP.rmsnorm_ffn_swiglu_program(64.0))


def test_stabilize_is_identity_on_exp_free_graphs(rng):
    for case in (make_layernorm_case(rng), make_swiglu_case(rng)):
        assert stabilize(case.graph) is case.graph


def test_stabilize_changes_fingerprint_and_validates(rng):
    g = make_attention_case(rng).graph
    g2 = stabilize(g)
    assert g2 is not g
    assert g2.fingerprint() != g.fingerprint()
    g2.validate()
    # the original is untouched (stabilize clones)
    assert not any(
        r is not None and O.rescaled_ref(r) is not None
        for nid, n in g.nodes.items() if isinstance(n, MapNode)
        for r in n.reduced)


def _serial_tags(g):
    tags = []
    for n in g.nodes.values():
        if isinstance(n, MapNode):
            if n.serial:
                tags.extend(r for r in n.reduced if r is not None)
            tags.extend(_serial_tags(n.inner))
    return tags


def test_fused_attention_grows_online_softmax_carries(rng):
    """The fully-fused snapshot's serial spine gains a running-max carry
    with its additive ports retagged to rescale against it."""
    snap = fuse(make_attention_case(rng).graph)[-1]
    tags = _serial_tags(stabilize(snap))
    assert O.REDUCE_MAX in tags
    rescaled = [t for t in tags if O.rescaled_ref(t) is not None]
    assert rescaled, tags
    k = O.rescaled_ref(rescaled[0])
    assert all(O.rescaled_ref(t) == k for t in rescaled)


@pytest.mark.parametrize("snap_i", [0, -1])
def test_stabilized_graph_interprets_to_oracle_at_huge_logits(snap_i,
                                                              rng):
    """Every fusion level of the rewritten program, run by the PLAIN
    interpreter, matches the pair-semantics oracle where the raw
    program overflows."""
    case = make_attention_case(rng, logit_scale=40.0)
    snap = fuse(case.graph)[snap_i]
    oracle = merge(run_stabilized(snap, case.inputs, case.dims)["O"])
    got = merge(run(stabilize(snap), case.inputs, case.dims)["O"])
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, oracle, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(got, case.ref, rtol=1e-9, atol=1e-9)


def test_stabilized_graph_safe_range_exact(rng):
    """In the safe range the rewrite is numerically equivalent to the
    raw program (same sums, only max-shifted)."""
    case = make_attention_case(rng)
    for snap in fuse(case.graph):
        raw = merge(run(snap, case.inputs, case.dims)["O"])
        got = merge(run(stabilize(snap), case.inputs, case.dims)["O"])
        np.testing.assert_allclose(got, raw, rtol=1e-12, atol=1e-13)
