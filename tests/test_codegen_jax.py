"""The executable JAX backend: fused block programs compile to jitted
functions that match the interpreter oracle (array program -> Table 2 ->
fusion -> executable, the full compiler pipeline)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import merge
from repro.core.codegen_jax import run_jax, stack_blocks
from repro.core.fusion import fuse


def _merge_out(v):
    v = np.asarray(v)
    if v.ndim == 4:  # (R, C, br, bc) stacked blocks
        return np.concatenate(np.concatenate(v, axis=1), axis=1)
    if v.ndim == 3:
        return np.concatenate(v, axis=0)
    return v


@pytest.mark.parametrize("case_name", ["attention", "layernorm", "swiglu"])
def test_fused_programs_execute_under_jit(case_name, rng, attention_case,
                                          layernorm_case, swiglu_case):
    case = {"attention": attention_case, "layernorm": layernorm_case,
            "swiglu": swiglu_case}[case_name]
    snaps = fuse(case.graph)
    out = run_jax(snaps[-1], case.inputs)
    got = _merge_out(out[case.out_name])
    np.testing.assert_allclose(got, case.ref, rtol=2e-4, atol=2e-4)


def test_initial_program_also_compiles(attention_case):
    """Not just the fused form: any block program lowers (the unfused
    Table-2 expansion too)."""
    out = run_jax(attention_case.graph, attention_case.inputs)
    got = _merge_out(out[attention_case.out_name])
    np.testing.assert_allclose(got, attention_case.ref, rtol=2e-4,
                               atol=2e-4)


def test_compiled_program_is_differentiable(layernorm_case):
    """The compiled function is ordinary JAX: grads flow through the fused
    kernel structure."""
    from repro.core.codegen_jax import compile_program
    snaps = fuse(layernorm_case.graph)
    fn = compile_program(snaps[-1])
    xs = stack_blocks(layernorm_case.inputs["X"])
    ys = stack_blocks(layernorm_case.inputs["YT"])

    def loss(xs):
        return jnp.sum(fn(xs, ys)[0] ** 2)

    g = jax.grad(loss)(xs)
    assert g.shape == xs.shape
    assert bool(jnp.isfinite(g).all())
