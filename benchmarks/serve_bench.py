"""Serving-loop benchmark: replay a fixed synthetic open-loop trace
through the continuous-batching engine (``launch/engine.py``) and emit
the gated numbers — tokens/sec, p50/p99 per-token latency, occupancy,
and the zero-recompile / zero-fallback / zero-degradation pins.

    PYTHONPATH=src:. python benchmarks/serve_bench.py --preset ci \
        --json SERVE_ci.json --report serve_report.json

Row format matches ``benchmarks/run.py`` (``name,us_per_call,derived``)
so ``check_regression.py`` gates ``serve_*`` rows the same way it gates
``pipeline_*`` rows: tokens/sec may not collapse >1.5x below the pinned
baseline, and any steady-state decode recompile or Pallas fallback
fails outright.  Deterministic keys (completed/rejected counts, compile
counts, and the resilience counters ``degradations``/``quarantined``,
which must be zero on the clean path) are pinned exactly.

Chaos mode (``--faults chaos``, the CI ``chaos`` job) runs the preset
twice against a throwaway kernel-cache dir — once clean, once under a
seeded ``resilience.FaultPlan`` injecting a Pallas compile failure at
the grouped AND ungrouped rungs (so the ladder is exercised down to the
jax rung), one corrupted on-disk plan, and one NaN decode step — and
gates internally:

* every non-poisoned request completes, tokens byte-identical to the
  clean run;
* ``degradations`` equals the number of compile faults in the plan,
  ``quarantined``/``corrupt_plans`` match the cache faults exactly, and
  ``n_poisoned`` matches the NaN faults;
* chaos tokens/sec stays within the same 1.5x collapse gate, measured
  against this runner's own clean pass.

Heal mode (``--faults heal``, the CI ``chaos`` job's second step) drives
the self-healing loop end-to-end: a transient decode fault fires once
and stops, the watchdog demotes decode to the jax rung, and the health
ledger's half-open probe must re-promote back to the grouped pallas
rung mid-run — with the first probe itself faulted, so the breaker
re-opens at doubled cool-down before the second probe heals.  Gates pin
``repromotions`` / ``probes`` / ``probe_failures`` EXACTLY against the
plan and require tokens byte-identical to the clean pass.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import tempfile


PRESET_ARGS = {
    # tiny fixed trace for CI runners: small slot count, short prompts
    "ci": dict(arch="smollm-135m", backend="pallas", max_batch=2,
               max_len=64, prompt_buckets=(8, 16), n_requests=8,
               arrival_rate=1.0, prompt_lens=(4, 14),
               gen_lens=(3, 8), seed=0, keep_per_step=False),
    # the trajectory pin at repo root (BENCH_serve.json)
    "full": dict(arch="smollm-135m", backend="pallas", max_batch=4,
                 max_len=96, prompt_buckets=(8, 16, 32),
                 n_requests=32, arrival_rate=1.0,
                 prompt_lens=(4, 30), gen_lens=(6, 16), seed=0,
                 keep_per_step=False),
}


def _presets():
    from repro.launch.serve import ServeConfig
    return {k: ServeConfig(**v) for k, v in PRESET_ARGS.items()}


# the seeded chaos plan: one compile failure at the grouped AND the
# ungrouped rung (first compile of warmup -> ladder lands on jax), the
# first on-disk plan read corrupted, one NaN decode step mid-run
def _chaos_plan():
    from repro import resilience as RZ
    return RZ.FaultPlan([
        RZ.FaultSpec(site="compile:grouped", indices=(0,), kind="raise",
                     message="chaos: grouped lowering down"),
        RZ.FaultSpec(site="compile:ungrouped", indices=(0,), kind="raise",
                     message="chaos: ungrouped lowering down"),
        RZ.FaultSpec(site="cache:get_plan", indices=(0,), kind="corrupt"),
        RZ.FaultSpec(site="serve:logits", indices=(2,), kind="nan"),
    ], seed=0)


# the seeded heal plan: one transient decode fault (fires once, then
# the rung is healthy again) plus one faulted re-promotion probe, so
# the breaker re-opens at doubled cool-down before the second probe
# swaps the pallas rung back in
def _heal_plan():
    from repro import resilience as RZ
    return RZ.FaultPlan([
        RZ.FaultSpec(site="serve:decode", indices=(2,), kind="raise",
                     message="heal: transient decode fault"),
        RZ.FaultSpec(site="serve:probe", indices=(0,), kind="raise",
                     message="heal: probe still cold"),
    ], seed=0)


def _row(preset: str, cfg, report) -> dict:
    total_tokens = report.prefill_tokens + report.decode_tokens
    us_per_token = (report.wall_s * 1e6 / max(report.decode_tokens, 1))
    derived = ";".join([
        f"tokens_per_s={report.tokens_per_s:.1f}",
        f"decode_tokens_per_s={report.decode_tokens_per_s:.1f}",
        f"p50_ms={report.p50_token_ms:.2f}",
        f"p99_ms={report.p99_token_ms:.2f}",
        f"mean_occupancy={report.mean_occupancy:.2f}",
        f"max_queue_depth={report.max_queue_depth}",
        f"steps={report.steps}",
        f"total_tokens={total_tokens}",
        f"completed={report.n_completed}",
        f"rejected={report.n_rejected}",
        f"stalled={report.n_evicted_stalled}",
        f"warmup_compiles={report.warmup_compiles}",
        f"decode_recompiles={report.decode_recompiles}",
        f"pallas_fallbacks={report.pallas_fallbacks}",
        f"degradations={report.degradations}",
        f"quarantined={report.quarantined}",
        f"poisoned={report.n_poisoned}",
        f"repromotions={report.repromotions}",
        f"probes={report.probes}",
        f"probe_failures={report.probe_failures}",
        f"cache_hit_rate={report.cache_hit_rate:.3f}",
    ])
    return {"name": f"serve_{cfg.arch}_{preset}",
            "us_per_call": us_per_token, "derived": derived}


def bench(preset: str) -> dict:
    from repro.launch.serve import run
    cfg = _presets()[preset]
    report = run(cfg)
    return {"row": _row(preset, cfg, report), "report": report}


def chaos(preset: str) -> dict:
    """The chaos harness: clean pass, then the same preset under the
    seeded fault plan, gated against the clean pass.  Returns
    ``{"row", "report", "failures": [...]}`` — empty failures = pass."""
    from repro import pipeline, resilience as RZ
    from repro.launch.serve import run

    cfg = _presets()[preset]
    cache_dir = tempfile.mkdtemp(prefix="repro-chaos-cache-")
    os.environ["REPRO_KERNEL_CACHE"] = cache_dir
    pipeline.reset_default_cache()

    clean = run(cfg)
    # drop every in-process kernel (the pipeline cache AND the model
    # layers' per-shape lru caches) but keep the on-disk plans, so the
    # faulted pass re-reads (and the plan corrupts) the disk entries and
    # re-runs every compile under the injected ladder faults
    from repro.models import layers
    layers._attention_kernel.cache_clear()
    layers._swiglu_kernel.cache_clear()
    pipeline.reset_default_cache()
    plan = _chaos_plan()
    with RZ.faults(plan):
        faulted = run(cfg)
    stats = pipeline.default_cache().stats

    failures = []

    def gate(ok: bool, what: str):
        if not ok:
            failures.append(what)

    poisoned = {f["rid"] for f in faulted.failures
                if f["reason"] in ("nonfinite_logits",
                                   "nonfinite_prefill")}
    n_nan = plan.expected_count("serve:logits")
    n_compile = plan.expected_count("compile:")
    n_cache = plan.expected_count("cache:")

    gate(plan.fired_count() == len(plan.specs),
         f"every planned fault fires (fired {plan.fired_count()}/"
         f"{len(plan.specs)}: {plan.fired})")
    gate(faulted.n_poisoned == n_nan,
         f"poisoned evictions match the plan "
         f"({faulted.n_poisoned} != {n_nan})")
    gate(faulted.n_completed == clean.n_completed - len(poisoned),
         f"all non-poisoned requests complete "
         f"({faulted.n_completed} != {clean.n_completed}-{len(poisoned)})")
    mismatched = [r for r in clean.tokens
                  if int(r) not in poisoned
                  and clean.tokens[r] != faulted.tokens.get(r)]
    gate(not mismatched,
         f"non-poisoned tokens byte-identical to the clean run "
         f"(mismatched rids {mismatched})")
    gate(faulted.degradations == n_compile,
         f"ladder demotions match the plan "
         f"({faulted.degradations} != {n_compile})")
    served_rungs = [s for s, _, _ in plan.fired if s.startswith("compile:")]
    gate({"compile:grouped", "compile:ungrouped"} <= set(served_rungs),
         f"ladder exercised down to the jax rung (fired {served_rungs})")
    gate(stats.corrupt_plans == n_cache,
         f"corrupt plans match the plan "
         f"({stats.corrupt_plans} != {n_cache})")
    qdir = pathlib.Path(cache_dir) / "quarantine"
    n_qfiles = len(list(qdir.iterdir())) if qdir.is_dir() else 0
    gate(faulted.quarantined == n_qfiles and faulted.quarantined >= n_cache,
         f"quarantine counter matches the quarantine dir "
         f"({faulted.quarantined} != {n_qfiles} files, >= {n_cache})")
    gate(faulted.tokens_per_s >= clean.tokens_per_s / 1.5,
         f"chaos tokens/sec within the 1.5x serve gate "
         f"({faulted.tokens_per_s:.1f} vs clean {clean.tokens_per_s:.1f})")
    gate(clean.degradations == 0 and clean.quarantined == 0
         and clean.n_poisoned == 0,
         f"clean pass has zero resilience counters (degradations="
         f"{clean.degradations} quarantined={clean.quarantined} "
         f"poisoned={clean.n_poisoned})")

    row = _row(f"{preset}_chaos", cfg, faulted)
    return {"row": row, "report": faulted, "clean": clean,
            "failures": failures, "plan": plan.to_json()}


def heal(preset: str) -> dict:
    """The self-healing harness: clean pass, then the same preset under
    a transient decode fault plus a faulted first probe, with a short
    re-promotion window.  Gates pin the full breaker lifecycle —
    demote -> failed probe (doubled cool-down) -> successful probe ->
    re-promotion to the grouped pallas rung — EXACTLY against the plan."""
    import dataclasses

    from repro import pipeline, resilience as RZ
    from repro.launch.serve import run

    # a short probe window so the lifecycle completes inside the preset
    # trace: demote ~tick 2, failed probe 3 ticks later, breaker doubles
    # to 6, healing probe ~tick 12
    cfg = dataclasses.replace(_presets()[preset], repromote_after=3)
    cache_dir = tempfile.mkdtemp(prefix="repro-heal-cache-")
    os.environ["REPRO_KERNEL_CACHE"] = cache_dir
    pipeline.reset_default_cache()

    clean = run(cfg)
    pipeline.reset_default_cache()
    plan = _heal_plan()
    with RZ.faults(plan):
        faulted = run(cfg)

    failures = []

    def gate(ok: bool, what: str):
        if not ok:
            failures.append(what)

    n_decode = plan.expected_count("serve:decode")
    n_probe_faults = plan.expected_count("serve:probe")

    gate(plan.fired_count() == len(plan.specs),
         f"every planned fault fires (fired {plan.fired_count()}/"
         f"{len(plan.specs)}: {plan.fired})")
    gate(faulted.degradations == n_decode,
         f"watchdog demotions match the plan "
         f"({faulted.degradations} != {n_decode})")
    gate(faulted.repromotions == n_decode,
         f"every demotion healed: re-promotions match the plan "
         f"({faulted.repromotions} != {n_decode})")
    gate(faulted.probe_failures == n_probe_faults,
         f"probe failures match the plan "
         f"({faulted.probe_failures} != {n_probe_faults})")
    gate(faulted.probes == n_decode + n_probe_faults,
         f"probe count matches the plan: one per planned probe fault "
         f"plus one healing probe ({faulted.probes} != "
         f"{n_decode + n_probe_faults})")
    gate(faulted.decode_backend == "pipeline-pallas",
         f"decode ended the run back on the grouped pallas rung "
         f"(ended on {faulted.decode_backend!r})")
    gate(faulted.n_completed == clean.n_completed,
         f"a transient fault poisons nothing: all requests complete "
         f"({faulted.n_completed} != {clean.n_completed})")
    mismatched = [r for r in clean.tokens
                  if clean.tokens[r] != faulted.tokens.get(r)]
    gate(not mismatched,
         f"tokens byte-identical to the clean run across demote AND "
         f"re-promote (mismatched rids {mismatched})")
    gate(faulted.decode_recompiles == 0,
         f"demotion/probe compiles stay off the strict-no-recompile "
         f"books ({faulted.decode_recompiles} != 0)")
    gate(faulted.quarantined == 0 and faulted.n_poisoned == 0,
         f"no cache or numeric casualties (quarantined="
         f"{faulted.quarantined} poisoned={faulted.n_poisoned})")
    # no 1.5x throughput gate here: the heal pass pays two mid-run jit
    # rebuilds (the demotion build and the probe re-compile) inside a
    # deliberately tiny CI trace, so wall time is compile-dominated by
    # design.  A 20x collapse guard still catches hangs and pathological
    # probe loops
    gate(faulted.tokens_per_s >= clean.tokens_per_s / 20.0,
         f"heal tokens/sec within the 20x hang guard "
         f"({faulted.tokens_per_s:.1f} vs clean {clean.tokens_per_s:.1f})")
    gate(clean.repromotions == 0 and clean.probes == 0
         and clean.probe_failures == 0 and clean.degradations == 0,
         f"clean pass has zero self-healing counters (repromotions="
         f"{clean.repromotions} probes={clean.probes} probe_failures="
         f"{clean.probe_failures} degradations={clean.degradations})")

    row = _row(f"{preset}_heal", cfg, faulted)
    return {"row": row, "report": faulted, "clean": clean,
            "failures": failures, "plan": plan.to_json()}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=sorted(PRESET_ARGS))
    ap.add_argument("--faults", default=None, choices=("chaos", "heal"),
                    help="run a seeded fault harness instead of the "
                         "clean bench (gates internally, exit 1 on any "
                         "gate failure)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the gate-format rows file")
    ap.add_argument("--report", default=None,
                    help="write the full ServeReport JSON")
    args = ap.parse_args(argv)

    if args.faults in ("chaos", "heal"):
        out = (chaos if args.faults == "chaos" else heal)(args.preset)
        row, report = out["row"], out["report"]
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        for f in out["failures"]:
            print(f"{args.faults.upper()} GATE FAILED: {f}")
        if not out["failures"]:
            print(f"{args.faults} gates passed: "
                  f"{len(out['plan']['faults'])} faults injected, every "
                  "counter matched the plan")
        if args.report:
            with open(args.report, "w") as fh:
                json.dump({args.faults: report.to_json(),
                           "clean": out["clean"].to_json(),
                           "plan": out["plan"],
                           "failures": out["failures"]}, fh, indent=1)
        return 1 if out["failures"] else 0

    out = bench(args.preset)
    row, report = out["row"], out["report"]
    print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"preset": args.preset, "rows": [row]}, f, indent=2)
            f.write("\n")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report.to_json(), f, indent=1)
    return 1 if (report.decode_recompiles or report.pallas_fallbacks
                 or report.degradations or report.quarantined) else 0


if __name__ == "__main__":
    sys.exit(main())
