"""The paper's two novel kernels, derived automatically and executed:

  * Flash-LayerNorm+Matmul          (paper Example 2)
  * Flash-RMSNorm+FFN-SwiGLU        (paper Example 3)

then the same computations through the hand-written Pallas TPU kernels
(interpret mode on CPU), demonstrating IR-derived == kernel == numpy.

    PYTHONPATH=src python examples/fusion_megakernels.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import array_program as AP
from repro.core import blocks as B
from repro.core import cost as C
from repro.core.codegen_py import render
from repro.core.fusion import fuse
from repro.core.interpreter import run
from repro.kernels import ops as K

rng = np.random.default_rng(0)

# --- Example 2: LayerNorm + Matmul -----------------------------------------
M, Kd, N = 3, 4, 2
KK = Kd * 16
X = rng.normal(size=(M * 8, KK))
Y = rng.normal(size=(KK, N * 16))
g2 = AP.layernorm_matmul_program(float(KK))
snaps = fuse(g2)
print("=" * 72)
print("Flash-LayerNorm+Matmul (derived by the fusion algorithm):")
print("=" * 72)
print(render(snaps[-1]))
dims = {"M": M, "K": Kd, "N": N}
out = B.merge(run(snaps[-1],
                  {"X": B.split(X, M, Kd), "YT": B.split(Y.T, N, Kd)},
                  dims)["Z"])
mu = X.mean(1, keepdims=True)
sd = np.sqrt((X ** 2).mean(1, keepdims=True) - mu ** 2)
ref = ((X - mu) / sd) @ Y
print(f"IR-derived vs numpy: {np.abs(out - ref).max():.2e}")

kout = K.layernorm_matmul(jnp.asarray(X, jnp.float32),
                          jnp.asarray(Y, jnp.float32),
                          jnp.ones((KK,), jnp.float32),
                          jnp.zeros((KK,), jnp.float32),
                          eps=0.0, impl="interpret", block_m=8,
                          block_n=16, block_k=16)
print(f"Pallas kernel vs numpy: {np.abs(np.asarray(kout) - ref).max():.2e}")

# --- Example 3: RMSNorm + FFN-SwiGLU ----------------------------------------
Mr, Dr, Kr, Nr = 2, 3, 4, 2
DD = Dr * 16
X3 = rng.normal(size=(Mr * 8, DD))
W = rng.normal(size=(DD, Kr * 8)) / np.sqrt(DD)
V = rng.normal(size=(DD, Kr * 8)) / np.sqrt(DD)
U = rng.normal(size=(Kr * 8, Nr * 8)) / np.sqrt(Kr * 8)
g3 = AP.rmsnorm_ffn_swiglu_program(float(DD))
snaps3 = fuse(g3)
print()
print("=" * 72)
print("Flash-RMSNorm+FFN-SwiGLU mega-kernel (three matmuls, a Hadamard,")
print("a reduction and elementwise ops in ONE kernel; paper Example 3):")
print("=" * 72)
print(render(snaps3[-1]))

xn = X3 / np.sqrt((X3 ** 2).mean(1, keepdims=True))
gsw = xn @ W
ref3 = ((gsw / (1 + np.exp(-gsw))) * (xn @ V)) @ U
out3 = B.merge(run(snaps3[-1],
                   {"X": B.split(X3, Mr, Dr), "WT": B.split(W.T, Kr, Dr),
                    "VT": B.split(V.T, Kr, Dr), "UT": B.split(U.T, Nr, Kr)},
                   {"M": Mr, "D": Dr, "K": Kr, "N": Nr})["O"])
print(f"IR-derived vs numpy: {np.abs(out3 - ref3).max():.2e}")

kout3 = K.rmsnorm_swiglu(jnp.asarray(X3, jnp.float32),
                         jnp.asarray(W, jnp.float32),
                         jnp.asarray(V, jnp.float32),
                         jnp.asarray(U, jnp.float32),
                         jnp.ones((DD,), jnp.float32),
                         eps=0.0, impl="interpret", block_m=8, block_k=8)
print(f"Pallas kernel vs numpy: {np.abs(np.asarray(kout3) - ref3).max():.2e}")

# snapshots: the paper's replication-vs-buffering trade for the selector
print()
print("snapshots returned to the candidate-selection algorithm:")
dims3 = {"M": Mr, "D": Dr, "K": Kr, "N": Nr}
for i, s in enumerate(snaps3):
    t = C.traffic(s, dims3)
    print(f"  snap{i}: stores={sum(t.stores.values()):4d} "
          f"loads={sum(t.loads.values()):5d} "
          f"work={sum(t.work.values()):5d}")
