"""Golden fusion-trace regressions.

The paper's two flagship results — Flash Attention rediscovered
(Example 1) and the RMSNorm+FFN-SwiGLU mega-kernel (Example 3) — are
pinned as *exact ordered rule sequences*, not just counts: a rule-priority
regression that still converges to a fused program (but via a different,
possibly costlier route) fails loudly here instead of silently producing
worse snapshots downstream of ``pipeline.compile``.
"""

from collections import Counter

from repro.core import array_program as AP
from repro.core.fusion import FusionTrace, fuse

# Example 1: the paper's 17-step Flash Attention derivation.
GOLDEN_ATTENTION_TRACE = [
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule4_swap_scale_dot",
    "rule3_fuse_map_reduction",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule3_fuse_map_reduction",
    "rule9_fuse_consecutive_elementwise",
    "rule3_fuse_map_reduction",
    "rule6_extend_map",
    "rule1_fuse_consecutive_maps",
]

# Example 3: the SwiGLU mega-kernel (27 steps: Rule-8 duplication, two
# linearity swaps, two sibling fusions, two map extensions).
GOLDEN_SWIGLU_TRACE = [
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule8_duplicate_mapped_scale",
    "rule4_swap_scale_dot",
    "rule4_swap_scale_dot",
    "rule3_fuse_map_reduction",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule3_fuse_map_reduction",
    "rule9_fuse_consecutive_elementwise",
    "rule3_fuse_map_reduction",
    "rule3_fuse_map_reduction",
    "rule2_fuse_sibling_maps",
    "rule6_extend_map",
    "rule1_fuse_consecutive_maps",
    "rule6_extend_map",
    "rule2_fuse_sibling_maps",
]


def _trace(graph):
    t = FusionTrace()
    fuse(graph, t)
    return [r for r, _ in t.steps]


def test_flash_attention_golden_trace():
    got = _trace(AP.attention_program(0.125))
    assert len(got) == 17, got  # the paper's step count
    assert got == GOLDEN_ATTENTION_TRACE, got


def test_swiglu_megakernel_golden_trace():
    got = _trace(AP.rmsnorm_ffn_swiglu_program(512.0))
    assert got == GOLDEN_SWIGLU_TRACE, got


def test_golden_rule_counts():
    """Counts, separately from order, for a friendlier failure signal."""
    att = Counter(_trace(AP.attention_program(0.125)))
    assert att == Counter({"rule1_fuse_consecutive_maps": 11,
                           "rule4_swap_scale_dot": 1,
                           "rule3_fuse_map_reduction": 3,
                           "rule9_fuse_consecutive_elementwise": 1,
                           "rule6_extend_map": 1})
    swi = Counter(_trace(AP.rmsnorm_ffn_swiglu_program(512.0)))
    assert swi == Counter({"rule1_fuse_consecutive_maps": 15,
                           "rule8_duplicate_mapped_scale": 1,
                           "rule4_swap_scale_dot": 2,
                           "rule3_fuse_map_reduction": 4,
                           "rule9_fuse_consecutive_elementwise": 1,
                           "rule2_fuse_sibling_maps": 2,
                           "rule6_extend_map": 2})


def test_golden_trace_independent_of_constants():
    """The trace depends on program *structure* only, never on the baked
    scale constants (selection owns shapes; fusion owns structure)."""
    assert _trace(AP.attention_program(0.125)) == \
        _trace(AP.attention_program(0.99))
    assert _trace(AP.rmsnorm_ffn_swiglu_program(512.0)) == \
        _trace(AP.rmsnorm_ffn_swiglu_program(64.0, eps=1e-6))
