"""End-to-end training driver: train an LM for a few hundred steps with
checkpointing + auto-resume, on the synthetic pipeline.

Default is a CPU-friendly reduced smollm (so the example finishes in
minutes); pass ``--full`` on real hardware to train the full 135M
smollm-135m config (a ~100M-class model), or any other --arch.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""

import argparse
import sys

from repro.launch import train as T

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--lr", "3e-3",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--log-every", "10"]
    if not args.full:
        argv.append("--reduced")
    out = T.main(argv)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"loss: {first:.3f} -> {last:.3f} over {args.steps} steps")
    assert last < first, "training should reduce loss"
