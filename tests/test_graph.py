"""Unit tests for the block-program IR: typing, validation, bufferedness."""

import numpy as np
import pytest

from repro.core import ops as O
from repro.core.graph import (GB, Graph, InputNode, MapNode, VType,
                              internal_buffered_edges)
from repro.core.interpreter import eval_graph


def _ew_map(dim, expr="a0*2.0"):
    gb = GB()
    x = gb.inp("x", VType((), O.BLOCK))
    gb.out("o", gb.func(O.ew(expr), x))
    top = GB()
    xs = top.inp("X", VType((dim,), O.BLOCK))
    outs = top.map(dim, gb.g, [(xs, True)])
    top.out("O", outs[0])
    return top.g


def test_types_simple_map():
    g = _ew_map("N")
    types = g.infer_types()
    mid = [n for n in g.op_nodes()][0]
    assert types[(mid, 0)] == VType(("N",), O.BLOCK)


def test_type_error_on_func_fed_list():
    gb = GB()
    x = gb.inp("X", VType(("N",), O.BLOCK))
    gb.out("O", gb.func(O.ew("a0"), x))
    with pytest.raises(TypeError):
        gb.g.infer_types()


def test_map_dim_mismatch_rejected():
    gb = GB()
    inner = GB()
    a = inner.inp("a", VType((), O.BLOCK))
    inner.out("o", inner.func(O.ew("a0"), a))
    x = gb.inp("X", VType(("N",), O.BLOCK))
    outs = gb.map("M", inner.g, [(x, True)])  # wrong dim
    gb.out("O", outs[0])
    with pytest.raises(TypeError):
        gb.g.infer_types()


def test_cycle_detection():
    gb = GB()
    x = gb.inp("x", VType((), O.BLOCK))
    f1 = gb.func(O.ew("a0+a1", 2), x, x)
    g = gb.g
    f2 = gb.func(O.ew("a0"), f1)
    # manually create a cycle
    g.edges = {e for e in g.edges if not (e.dst == f1[0] and e.dp == 1)}
    g.connect(f2, (f1[0], 1))
    with pytest.raises(ValueError):
        g.topo()


def test_reachability():
    gb = GB()
    x = gb.inp("x", VType((), O.BLOCK))
    a = gb.func(O.ew("a0"), x)
    b = gb.func(O.ew("a0"), a)
    c = gb.func(O.ew("a0"), b)
    gb.out("o", c)
    g = gb.g
    assert g.reachable(a[0], c[0])
    assert not g.reachable(c[0], a[0])
    assert g.reachable(a[0], b[0], skip_direct=True) is False


def test_internal_buffered_edges_counts_intermediates_only():
    # X -> map(ew) -> map(ew) -> O : one internal buffered edge
    gb = GB()
    inner1 = GB()
    a = inner1.inp("a", VType((), O.BLOCK))
    inner1.out("o", inner1.func(O.ew("a0*2.0"), a))
    inner2 = GB()
    b = inner2.inp("b", VType((), O.BLOCK))
    inner2.out("o", inner2.func(O.ew("a0+1.0"), b))
    x = gb.inp("X", VType(("N",), O.BLOCK))
    m1 = gb.map("N", inner1.g, [(x, True)])
    m2 = gb.map("N", inner2.g, [(m1[0], True)])
    gb.out("O", m2[0])
    assert len(internal_buffered_edges(gb.g)) == 1


def test_reduced_port_yields_item():
    gb = GB()
    inner = GB()
    a = inner.inp("a", VType((), O.BLOCK))
    inner.out("o", inner.func(O.ROW_SUM, a))
    x = gb.inp("X", VType(("N",), O.BLOCK))
    outs = gb.map("N", inner.g, [(x, True)], reduced=["+"])
    gb.out("O", outs[0])
    types = gb.g.infer_types()
    mid = gb.g.op_nodes()[0]
    assert types[(mid, 0)] == VType((), O.VECTOR)
    xs = [np.ones((4, 8)) * i for i in range(3)]
    out = eval_graph(gb.g, [xs], {"N": 3})
    np.testing.assert_allclose(out[0], np.sum([x.sum(1) for x in xs], axis=0))


def test_elementwise_compose():
    u = O.ew("a0*C0", 1, C0=0.5)
    v = O.ew("exp(a0)+a1", 2)
    c = O.compose_elementwise(u, v, 0)
    assert c.n_in == 2
    x, y = np.array([1.0, 2.0]), np.array([3.0, 4.0])
    np.testing.assert_allclose(c.apply(np, x, y), np.exp(x * 0.5) + y)


def test_elementwise_compose_const_collision():
    u = O.ew("a0*C0", 1, C0=2.0)
    v = O.ew("a0+C0", 1, C0=5.0)
    c = O.compose_elementwise(u, v, 0)
    np.testing.assert_allclose(c.apply(np, np.array([1.0])), 1.0 * 2.0 + 5.0)
