"""Kernel micro-benchmarks: fused (XLA-level flash semantics) vs naive
reference, jitted, wall time per call on the host backend.

On CPU the absolute numbers are only indicative; the structural payoff
(no quadratic materialization) still shows up as both time and the ability
to run shapes the naive path cannot.  On TPU the same entry points
dispatch to the Pallas kernels.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as K
from repro.kernels import ref as R


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # flash attention: naive (materializes S x S) vs chunked-flash
    b, h, s, dh = 1, 4, 2048, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, dh)), jnp.float32)
    naive = jax.jit(lambda q, k, v: R.attention_ref(q, k, v, causal=True))
    flash = jax.jit(lambda q, k, v: K.flash_attention(
        q, k, v, causal=True, impl="xla", block_kv=512))
    t_naive = _time(naive, q, k, v)
    t_flash = _time(flash, q, k, v)
    rows.append({"name": "kernel_attention_naive", "us_per_call": t_naive,
                 "derived": f"b{b}_h{h}_s{s}_d{dh}"})
    rows.append({"name": "kernel_attention_flash_xla", "us_per_call": t_flash,
                 "derived": f"speedup={t_naive / t_flash:.2f}x"})

    # rmsnorm+swiglu: unfused (4 HBM round trips) vs single jitted region
    m, d, f = 512, 1024, 2048
    x = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, f)) / np.sqrt(d), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(d, f)) / np.sqrt(d), jnp.float32)
    u = jnp.asarray(rng.normal(size=(f, d)) / np.sqrt(f), jnp.float32)
    g = jnp.ones((d,), jnp.float32)

    def unfused(x):
        xn = R.rmsnorm_ref(x, g)
        a = jax.block_until_ready(xn @ w)  # forced materialization
        bb = jax.block_until_ready(xn @ vv)
        hh = jax.block_until_ready(R.swish(a) * bb)
        return hh @ u

    fused = jax.jit(lambda x: K.rmsnorm_swiglu(x, w, vv, u, g, impl="ref"))
    t_unf = _time(unfused, x)
    t_fus = _time(fused, x)
    rows.append({"name": "kernel_rmsnorm_swiglu_unfused",
                 "us_per_call": t_unf, "derived": f"m{m}_d{d}_f{f}"})
    rows.append({"name": "kernel_rmsnorm_swiglu_fused",
                 "us_per_call": t_fus,
                 "derived": f"speedup={t_unf / t_fus:.2f}x"})

    # layernorm+matmul
    mk, kk, nk = 512, 1024, 1024
    x2 = jnp.asarray(rng.normal(size=(mk, kk)), jnp.float32)
    y2 = jnp.asarray(rng.normal(size=(kk, nk)), jnp.float32)
    g2 = jnp.ones((kk,), jnp.float32)
    b2 = jnp.zeros((kk,), jnp.float32)

    def ln_unfused(x):
        ln = jax.block_until_ready(R.layernorm_ref(x, g2, b2))
        return ln @ y2

    ln_fused = jax.jit(lambda x: K.layernorm_matmul(x, y2, g2, b2,
                                                    impl="ref"))
    t_unf2 = _time(ln_unfused, x2)
    t_fus2 = _time(ln_fused, x2)
    rows.append({"name": "kernel_layernorm_matmul_unfused",
                 "us_per_call": t_unf2, "derived": f"m{mk}_k{kk}_n{nk}"})
    rows.append({"name": "kernel_layernorm_matmul_fused",
                 "us_per_call": t_fus2,
                 "derived": f"speedup={t_unf2 / t_fus2:.2f}x"})
    return rows
