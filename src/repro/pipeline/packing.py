"""Layout conversion between the three value representations the pipeline
backends speak:

* **merged**  — one dense array per program value; the i-th blocked dim of
  its VType splits the i-th array axis (``block[M,D]`` of shape
  ``(M*bm, D*bd)``).  When a value has more list dims than its item has
  axes (e.g. the GQA head-group dim: ``block[H,M,D]``), the *leading*
  extra dims are plain stack axes of extent ``dims[d]`` — the merged
  array is ``(H, M*bm, D*bd)``.  This is the public calling convention
  of every compiled kernel and the layout the Pallas backend consumes
  directly.
* **stacked** — one leading axis per list level (``(M, D, bm, bd)``), the
  layout ``codegen_jax`` lowers to (vmap/scan axes).
* **nested**  — nested python lists of item arrays, the interpreter's
  native layout (``codegen_py`` backend).

All merged<->stacked conversions are pure reshape/transpose, so they are
jnp-traceable and fuse away under jit.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

# the merged-layout math lives in core (shared with the Pallas backend,
# which threads inter-region intermediates in this layout); re-exported
# here because packing is the pipeline's layout-conversion surface
from repro.core.blocks import item_shape, merged_shape  # noqa: F401
from repro.core.graph import Graph, VType


def block_shape(merged_shape: Sequence[int], vt: VType,
                dims: Dict[str, int]) -> Dict[str, int]:
    """Infer per-dim block sizes from a merged array's shape."""
    out = {}
    for i, d in enumerate(vt.dims):
        n = dims[d]
        if merged_shape[i] % n:
            raise ValueError(
                f"axis {i} of size {merged_shape[i]} not divisible by "
                f"{n} blocks of dim {d}")
        out[d] = merged_shape[i] // n
    return out


def to_stacked(arr, vt: VType, dims: Dict[str, int]):
    """merged -> stacked: split the blocked axes into (count, block)
    pairs and hoist the counts to the front.  Leading stack axes (list
    depth beyond the item rank) are already per-dim counts and pass
    through unchanged."""
    n = len(vt.dims)
    if n == 0:
        return arr
    lead = vt.lead_dims
    k = n - lead
    for i, d in enumerate(vt.dims[:lead]):
        if arr.shape[i] != dims[d]:
            raise ValueError(
                f"stack axis {i} of {vt!r} has size {arr.shape[i]}, "
                f"expected {dims[d]} (dim {d})")
    shape: List[int] = list(arr.shape[:lead])
    for i, d in enumerate(vt.dims[lead:]):
        c = dims[d]
        ax = lead + i
        if arr.shape[ax] % c:
            raise ValueError(
                f"cannot split axis {ax} (size {arr.shape[ax]}) of {vt!r} "
                f"into {c} blocks")
        shape += [c, arr.shape[ax] // c]
    shape += list(arr.shape[lead + k:])
    r = arr.reshape(shape)
    perm = (list(range(lead))
            + [lead + 2 * i for i in range(k)]
            + [lead + 2 * i + 1 for i in range(k)]
            + list(range(lead + 2 * k, r.ndim)))
    return r.transpose(perm)


def from_stacked(arr, vt: VType, dims: Dict[str, int]):
    """stacked -> merged (inverse of ``to_stacked``)."""
    n = len(vt.dims)
    if n == 0:
        return arr
    lead = vt.lead_dims
    k = n - lead
    # axes: [lead..., c0..c{k-1}, b0..b{k-1}, rest] -> interleave counts
    # with their blocks, then merge each pair
    perm: List[int] = list(range(lead))
    for i in range(k):
        perm += [lead + i, lead + k + i]
    perm += list(range(lead + 2 * k, arr.ndim))
    r = arr.transpose(perm)
    shape = list(r.shape[:lead])
    shape += [r.shape[lead + 2 * i] * r.shape[lead + 2 * i + 1]
              for i in range(k)]
    shape += list(r.shape[lead + 2 * k:])
    return r.reshape(shape)


def to_nested(arr, vt: VType, dims: Dict[str, int]) -> Any:
    """merged -> nested python lists of numpy item arrays."""
    st = np.asarray(to_stacked(np.asarray(arr), vt, dims))

    def rec(a, depth):
        if depth == 0:
            return a
        return [rec(a[i], depth - 1) for i in range(a.shape[0])]

    return rec(st, len(vt.dims))


def from_nested(val, vt: VType, dims: Dict[str, int]):
    """nested python lists -> merged numpy array."""
    def rec(v, depth):
        if depth == 0:
            return np.asarray(v)
        return np.stack([rec(x, depth - 1) for x in v], axis=0)

    return from_stacked(rec(val, len(vt.dims)), vt, dims)


def output_types(g: Graph) -> List[VType]:
    """VType of each program output (the type at its feeding edge)."""
    types = g.infer_types()
    out = []
    for oid in g.output_ids:
        e = g.in_edge(oid, 0)
        out.append(types[(e.src, e.sp)])
    return out
