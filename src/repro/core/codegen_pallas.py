"""Emit a Pallas TPU kernel directly from a fused block program.

Scope: the program class the fusion algorithm produces for the paper's
Example 1 — a spine of parallel maps (-> pallas grid dimensions) around
one serial accumulator map (-> the trailing sequential grid dimension
with f32 VMEM scratch carries), functional operators in the epilogue, and
deeper serial maps evaluated in-kernel over whole-resident dims.

`emit(fuse(attention_program(s))[-1], ...)` produces — automatically —
the same kernel structure as the hand-written
``kernels/flash_attention.py`` (modulo the online-softmax rescale, which
is the appendix's separate numerics pass, exactly as in the paper).

Layout convention: an IR input typed ``block[A,B]`` is one merged array
of shape (A*bA, B*bB); dims on the grid are tiled by BlockSpecs, other
dims are whole-resident in VMEM and in-kernel loops slice them.  A value
with more list dims than item axes (``block[H,M,D]`` — the GQA
head-group dim) carries the *leading* extra dims as plain stack axes of
extent ``dims[d]`` (block size 1): on the grid they are selected by the
BlockSpec and squeezed in-kernel; off the grid they unroll to an
in-kernel list.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.graph import (FuncNode, Graph, InputNode, MapNode,
                              OutputNode, ReduceNode, VType)


@dataclass
class KernelPlan:
    grid_dims: List[str]
    red_dim: str
    spine: List[int]  # map node ids, top level -> the serial map


def plan(g: Graph) -> KernelPlan:
    grid: List[str] = []
    spine: List[int] = []
    cur = g
    while True:
        maps = [n for n in cur.op_nodes()
                if isinstance(cur.nodes[n], MapNode)]
        if len(maps) != 1:
            raise ValueError("expected a single-map spine (fused program)")
        node: MapNode = cur.nodes[maps[0]]
        spine.append(maps[0])
        if node.serial:
            return KernelPlan(grid, node.dim, spine)
        grid.append(node.dim)
        cur = node.inner


def _split_whole(arr, vt_dims, dims, grid_axes, axis=0):
    """Split non-grid list dims of a kernel block into nested python
    lists (the IR's value layout)."""
    if not vt_dims:
        return arr
    d = vt_dims[0]
    if d in grid_axes:
        return _split_whole(arr, vt_dims[1:], dims, grid_axes, axis + 1)
    n = dims[d]
    size = arr.shape[axis] // n
    parts = []
    for i in range(n):
        idx = [slice(None)] * arr.ndim
        idx[axis] = slice(i * size, (i + 1) * size)
        parts.append(_split_whole(arr[tuple(idx)], vt_dims[1:], dims,
                                  grid_axes, axis))
    return parts


def _split_input(arr, vt: VType, dims, grid_axes):
    """Lead-aware version of :func:`_split_whole` for a kernel input: the
    leading stack axes (``VType.lead_dims``) are squeezed when
    grid-selected, or unrolled into in-kernel lists otherwise."""
    def rec(a, vt_dims, lead):
        if lead:
            d = vt_dims[0]
            if d in grid_axes:
                return rec(a[0], vt_dims[1:], lead - 1)
            return [rec(a[i], vt_dims[1:], lead - 1)
                    for i in range(dims[d])]
        return _split_whole(a, list(vt_dims), dims, grid_axes)

    return rec(arr, vt.dims, vt.lead_dims)


def _eval_inner(g: Graph, env: Dict, dims: Dict[str, int]) -> List[Any]:
    """In-kernel evaluation; list values are python lists of VMEM slices,
    serial maps unroll statically."""
    out: Dict[int, Any] = {}
    for nid in g.topo():
        node = g.nodes[nid]
        if isinstance(node, InputNode):
            continue
        ins = [env[(e.src, e.sp)] for e in g.in_edges(nid)]
        if isinstance(node, OutputNode):
            out[nid] = ins[0]
        elif isinstance(node, FuncNode):
            env[(nid, 0)] = node.op.apply(jnp, *ins)
        elif isinstance(node, ReduceNode):
            acc = ins[0][0]
            for item in ins[0][1:]:
                acc = acc + item
            env[(nid, 0)] = acc
        elif isinstance(node, MapNode):
            n = dims[node.dim]
            accs: List[Any] = [None] * node.n_out()
            lists: List[List[Any]] = [[] for _ in range(node.n_out())]
            for i in range(n):
                ienv: Dict = {}
                for p, e in enumerate(g.in_edges(nid)):
                    v = env[(e.src, e.sp)]
                    if node.mapped[p]:
                        v = v[i]
                    ienv[(node.inner.input_ids[p], 0)] = v
                res = _eval_inner(node.inner, ienv, dims)
                for pp, r in enumerate(node.reduced):
                    if r is None:
                        lists[pp].append(res[pp])
                    else:
                        accs[pp] = res[pp] if accs[pp] is None else \
                            accs[pp] + res[pp]
            for pp, r in enumerate(node.reduced):
                env[(nid, pp)] = lists[pp] if r is None else accs[pp]
        else:
            raise TypeError(node)
    return [out[oid] for oid in g.output_ids]


def resolve_interpret(interpret) -> bool:
    """``"auto"``/``None`` -> interpret everywhere except a real TPU
    backend.  Single source of the policy for emit and pipeline.compile."""
    if interpret in (None, "auto"):
        return jax.default_backend() != "tpu"
    return bool(interpret)


def emit(g: Graph, dims: Dict[str, int], blocks: Dict[str, int],
         interpret="auto") -> Callable[..., jax.Array]:
    """``interpret`` may be a bool, ``None``, or ``"auto"`` (see
    :func:`resolve_interpret`)."""
    interpret = resolve_interpret(interpret)
    kp = plan(g)
    grid_axes = kp.grid_dims + [kp.red_dim]
    in_names = [g.nodes[i].name for i in g.input_ids]
    in_types = [g.nodes[i].vtype for i in g.input_ids]
    n_red = dims[kp.red_dim]

    out_types = g.infer_types()
    oe = g.in_edge(g.output_ids[0], 0)
    out_vt = out_types[(oe.src, oe.sp)]
    out_lead = out_vt.lead_dims
    for vt in in_types + [out_vt]:
        for d in vt.dims[:vt.lead_dims]:
            if blocks.get(d, 1) != 1:
                raise ValueError(
                    f"stack dim {d} of {vt!r} needs block size 1, got "
                    f"{blocks[d]}")

    # locate the serial map and its containing level
    level = g
    for nid in kp.spine[:-1]:
        level = level.nodes[nid].inner
    smid = kp.spine[-1]
    smap: MapNode = level.nodes[smid]
    n_acc = sum(r is not None for r in smap.reduced)

    def spec_for(vt: VType) -> pl.BlockSpec:
        shape = tuple(blocks[d] if d in grid_axes else blocks[d] * dims[d]
                      for d in vt.dims)
        tiled = tuple(d if d in grid_axes else None for d in vt.dims)

        def index_map(*gids, tiled=tiled):
            pos = dict(zip(grid_axes, gids))
            return tuple(pos[d] if d is not None else 0 for d in tiled)

        return pl.BlockSpec(shape, index_map)

    def bind_spine(values_by_id: Dict[int, Any]):
        """Walk parallel levels (grid-selected: ports pass through) and
        return (serial-level graph, env keyed by input node id)."""
        cur_g, cur_env = g, values_by_id
        for nid in kp.spine[:-1]:
            node: MapNode = cur_g.nodes[nid]
            nxt = {}
            for p, e in enumerate(cur_g.in_edges(nid)):
                assert isinstance(cur_g.nodes[e.src], InputNode), \
                    "spine ports must come from inputs (fused program)"
                nxt[node.inner.input_ids[p]] = cur_env[e.src]
            cur_g, cur_env = node.inner, nxt
        return cur_g, cur_env

    def serial_step(values_by_id: Dict[int, Any]) -> List[Any]:
        lvl_g, lvl_env = bind_spine(values_by_id)
        senv: Dict = {}
        for p, e in enumerate(lvl_g.in_edges(smid)):
            senv[(smap.inner.input_ids[p], 0)] = lvl_env[e.src]
        res = _eval_inner(smap.inner, senv, dims)
        return [res[pp] for pp, r in enumerate(smap.reduced)
                if r is not None]

    def epilogue(values_by_id: Dict[int, Any], acc_vals: List[Any]):
        lvl_g, lvl_env = bind_spine(values_by_id)
        env: Dict = {}
        for iid in lvl_g.input_ids:
            env[(iid, 0)] = lvl_env[iid]
        ai = 0
        for pp, r in enumerate(smap.reduced):
            if r is not None:
                env[(smid, pp)] = acc_vals[ai]
                ai += 1
        outs = {}
        for nid in lvl_g.topo():
            node = lvl_g.nodes[nid]
            if isinstance(node, InputNode) or nid == smid:
                continue
            if isinstance(node, OutputNode):
                e = lvl_g.in_edge(nid, 0)
                outs[nid] = env[(e.src, e.sp)]
            elif isinstance(node, FuncNode):
                ins = [env[(e.src, e.sp)] for e in lvl_g.in_edges(nid)]
                env[(nid, 0)] = node.op.apply(jnp, *ins)
            else:
                raise TypeError(f"epilogue: {node.label()}")
        return outs[lvl_g.output_ids[0]]

    def kernel(*refs):
        in_refs = refs[:len(in_names)]
        o_ref = refs[len(in_names)]
        acc_refs = refs[len(in_names) + 1:]
        ri = pl.program_id(len(grid_axes) - 1)

        @pl.when(ri == 0)
        def _init():
            for a in acc_refs:
                a[...] = jnp.zeros_like(a)

        values = {iid: _split_input(r[...], vt, dims, grid_axes)
                  for iid, r, vt in zip(g.input_ids, in_refs, in_types)}
        partials = serial_step(values)
        for a, p_val in zip(acc_refs, partials):
            a[...] += p_val.astype(jnp.float32)

        @pl.when(ri == n_red - 1)
        def _done():
            res = epilogue(values, [a[...] for a in acc_refs])
            o_ref[...] = res.reshape(o_ref.shape).astype(o_ref.dtype)

    # accumulator shapes via abstract evaluation of one serial step
    abstract_ins = [
        jax.ShapeDtypeStruct(
            tuple(blocks[d] if d in grid_axes else blocks[d] * dims[d]
                  for d in vt.dims), jnp.float32)
        for vt in in_types]

    def one_step(*arrs):
        values = {iid: _split_input(a, vt, dims, grid_axes)
                  for iid, a, vt in zip(g.input_ids, arrs, in_types)}
        return serial_step(values)

    acc_shapes = jax.eval_shape(one_step, *abstract_ins)
    scratch = [pltpu.VMEM(a.shape, jnp.float32) for a in acc_shapes]
    assert len(acc_shapes) == n_acc

    out_block = jax.eval_shape(
        lambda arrs, accs: epilogue(
            {iid: _split_input(a, vt, dims, grid_axes)
             for iid, a, vt in zip(g.input_ids, arrs, in_types)},
            list(accs)), tuple(abstract_ins), tuple(acc_shapes))

    # leading stack dims of the output (head-group H) prepend size-1 axes
    # to the epilogue's item block
    out_block_shape = (1,) * out_lead + tuple(out_block.shape)
    grid = tuple(dims[d] for d in grid_axes)
    out_spec = pl.BlockSpec(
        out_block_shape,
        lambda *gids: tuple(gids[:len(kp.grid_dims)])
        + (0,) * (len(out_block_shape) - len(kp.grid_dims)))
    out_full = tuple(
        s * (dims[d] if i < len(kp.grid_dims) else 1)
        for i, (s, d) in enumerate(
            zip(out_block_shape,
                kp.grid_dims + [kp.red_dim] * 8)))

    def wrapper(*merged_inputs):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[spec_for(vt) for vt in in_types],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct(out_full,
                                           merged_inputs[0].dtype),
            scratch_shapes=scratch,
            interpret=interpret,
        )(*merged_inputs)

    return wrapper
