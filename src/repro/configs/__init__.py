"""Architecture registry + assigned input-shape sets.

Every assigned architecture is selectable via ``--arch <id>``; each arch is
paired with the LM shape set.  ``decode_*`` / ``long_*`` lower serve steps
(one token against a filled KV cache), not train steps.  ``long_500k``
requires sub-quadratic attention and is run only for the SSM/hybrid archs
(skips recorded in EXPERIMENTS.md per the assignment note).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.models.common import ModelConfig, reduced_config

from repro.configs import (deepseek_v3_671b, internvl2_26b,
                           jamba_1_5_large_398b, llama3_2_1b, mamba2_2_7b,
                           qwen2_7b, qwen3_32b, qwen3_moe_30b_a3b,
                           smollm_135m, whisper_tiny)

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in [
        qwen2_7b.CONFIG,
        smollm_135m.CONFIG,
        llama3_2_1b.CONFIG,
        qwen3_32b.CONFIG,
        internvl2_26b.CONFIG,
        whisper_tiny.CONFIG,
        mamba2_2_7b.CONFIG,
        deepseek_v3_671b.CONFIG,
        qwen3_moe_30b_a3b.CONFIG,
        jamba_1_5_large_398b.CONFIG,
    ]
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs whose attention is sub-quadratic in sequence length (SSM / hybrid):
SUBQUADRATIC = {"mamba2-2.7b", "jamba-1.5-large-398b"}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_reduced_config(arch: str, **overrides) -> ModelConfig:
    return reduced_config(get_config(arch), **overrides)


def with_pipeline(cfg: ModelConfig, backend: str = "jax",
                  attn: bool = True, mlp: bool = True,
                  options=None) -> ModelConfig:
    """Route the config's attention / gated-MLP blocks through the
    ``repro.pipeline`` fusion driver (fuse -> select -> codegen -> cached
    kernel) instead of the hand-written kernels.  ``backend`` is the
    pipeline codegen backend (``jax`` everywhere; ``pallas`` on TPU).

    ``options`` (a ``pipeline.CompileOptions``) overrides the full
    compile configuration — stabilize/group/autotune and the backend
    (its ``backend`` field wins over the ``backend`` argument)."""
    return dataclasses.replace(
        cfg,
        attn_impl="pipeline" if attn else cfg.attn_impl,
        mlp_impl="pipeline" if mlp else cfg.mlp_impl,
        pipeline_backend=options.backend if options is not None else backend,
        pipeline_options=options)


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    """Is the (arch x shape) cell runnable?  Returns (ok, reason)."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, ("pure full-attention arch: 512k dense-attention "
                       "decode skipped per assignment (sub-quadratic "
                       "attention required)")
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]
