from repro.data.pipeline import SyntheticLMData
