"""The end-to-end compile driver: array/block program -> fusion ->
snapshot + block-shape selection -> backend codegen -> cached callable.

    kern = pipeline.compile(AP.attention_program(0.125),
                            dims={"M": 2, "D": 2, "N": 4, "L": 2},
                            backend="jax")
    out = kern({"Q": Q, "KT": K, "VT": V.T})["O"]

Backends:

* ``"py"``     — the reference interpreter (``codegen_py.compile_py``);
                 slow, numpy-level, the differential oracle.
* ``"jax"``    — ``codegen_jax.compile_program`` under ``jax.jit``
                 (vmap/scan lowering; runs everywhere, differentiable).
* ``"pallas"`` — ``codegen_pallas.emit_program``: the selected snapshot
                 is partitioned into spine regions, the regions are
                 packed into megakernel *groups* (compatible parallel
                 spines share one kernel, cross-region values stay
                 VMEM-resident, under a VMEM budget), and each group
                 lowers to one real multi-stage ``pallas_call``
                 (interpret-mode off-TPU); the chained schedule runs
                 under ``jax.jit`` with dying intermediates donated via
                 ``input_output_aliases``.  Requires ``blocks`` (per-dim
                 block sizes).  ``CompiledKernel.lowering_report``
                 records the regions emitted, kernels launched,
                 resident edges, and fallbacks taken (zero for every
                 in-repo program — there is no walk-back to a
                 differently-fused snapshot: what selection picked is
                 what runs).

Every compiled kernel takes and returns **merged dense arrays** keyed by
program input/output names, so all three backends are drop-in
interchangeable — that is what the differential test harness exploits.

Results are memoized in a two-level :class:`KernelCache` keyed by
``(Graph.fingerprint(), dims, backend, blocks, fused)`` plus the
``cache.CODEGEN_VERSION`` salt (on-disk plans written by an older
fusion/selection/codegen build are never loaded): in-process hits return
the existing jitted callable; on-disk hits skip fusion + selection and
only re-lower.
"""

from __future__ import annotations

import functools
import time
import warnings
from dataclasses import dataclass, replace
from math import lcm
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import resilience as RZ
from repro.core import calibrate as CAL
from repro.core import numerics as NU
from repro.core import selection as SEL
from repro.core.fusion import FusionTrace, fuse
from repro.core.graph import Graph
from repro.pipeline import packing as P
from repro.pipeline.cache import (CacheKey, CachePlan, KernelCache,
                                  default_cache)
from repro.pipeline.options import DEFAULT_OPTIONS, CompileOptions

BACKENDS = ("py", "jax", "pallas")
AUTOTUNE_OBJECTIVES = ("analytic", "measured")


@dataclass
class CompiledKernel:
    """A ready-to-run fused kernel plus its compilation provenance."""

    key: CacheKey
    backend: str
    graph: Graph                      # the selected snapshot
    dims: Dict[str, int]
    blocks: Optional[Dict[str, int]]
    snapshot_index: int
    cost: float                       # predicted traffic cost (selected)
    initial_cost: float               # same model on the unfused program
    cache_hit: Optional[str]          # None | "memory" | "disk"
    # True when numerics.stabilize rewrote the snapshots before
    # selection/lowering (online-softmax-safe exp handling)
    stabilized: bool
    in_names: List[str]
    out_names: List[str]
    _fn: Callable[[Dict[str, Any]], Dict[str, Any]] = None  # type: ignore
    # pallas backend only: regions emitted / fallbacks taken / kernels
    # launched (see codegen_pallas.LoweringReport) and the cost model's
    # residency-aware per-kernel traffic attribution of the selected
    # snapshot, with the kernel ids the timing harness pairs against
    lowering_report: Optional[Any] = None
    region_costs: Optional[Tuple[float, ...]] = None
    kernel_ids: Optional[Tuple[str, ...]] = None
    # autotune="measured" only: the winner's wall seconds and every
    # (dims, seconds) candidate the autotuner timed (the analytic choice
    # is always among them)
    measured_s: Optional[float] = None
    autotune_timings: Optional[Tuple] = None
    # fault provenance (resilience.ResilienceReport): the rung requested,
    # the rung that actually served the compile, and every ladder attempt
    # in between — present on every compile (the happy path is a single
    # ok attempt at the requested rung, zero demotions)
    resilience_report: Optional[Any] = None

    def __call__(self, inputs: Dict[str, Any]) -> Dict[str, Any]:
        missing = [n for n in self.in_names if n not in inputs]
        if missing:
            raise KeyError(f"missing kernel inputs {missing}; "
                           f"expected {self.in_names}")
        return self._fn(inputs)

    @property
    def predicted_traffic_reduction(self) -> float:
        return self.initial_cost / max(self.cost, 1e-30)

    @property
    def launches(self) -> Optional[int]:
        """Kernels launched per call (pallas: groups emitted)."""
        return (self.lowering_report.launches
                if self.lowering_report is not None else None)

    @property
    def resident_edges(self) -> Optional[int]:
        """Cross-region values kept VMEM-resident instead of
        round-tripping through global memory (pallas grouped lowering)."""
        return (self.lowering_report.resident_edges
                if self.lowering_report is not None else None)

    @property
    def rung(self) -> Optional[str]:
        """The degradation-ladder rung that served this compile
        (``"grouped"``/``"ungrouped"``/``"jax"``/``"interpreter"``)."""
        return (self.resilience_report.rung
                if self.resilience_report is not None else None)

    @property
    def grouped_cost(self) -> Optional[float]:
        """Residency-aware predicted cost of what actually runs: the sum
        of the per-kernel attributions (``cost`` is the paper model's
        snapshot cost, which charges every cross-region edge)."""
        return (sum(self.region_costs)
                if self.region_costs is not None else None)


def _io_info(g: Graph):
    in_info = [(g.nodes[i].name, g.nodes[i].vtype) for i in g.input_ids]
    out_info = [(g.nodes[o].name, vt)
                for o, vt in zip(g.output_ids, P.output_types(g))]
    return in_info, out_info


def _lower_py(g: Graph, dims: Dict[str, int]):
    from repro.core.codegen_py import compile_py
    in_info, out_info = _io_info(g)
    prog = compile_py(g, dims)

    def call(inputs: Dict[str, Any]) -> Dict[str, Any]:
        nested = {nm: P.to_nested(np.asarray(inputs[nm]), vt, dims)
                  for nm, vt in in_info}
        outs = prog(nested)
        return {nm: P.from_nested(outs[nm], vt, dims)
                for nm, vt in out_info}

    return call


def _lower_jax(g: Graph, dims: Dict[str, int], jit):
    """``jit`` is ``True`` (whole-program ``jax.jit``), ``False`` (eager),
    or ``"per-op"``: every top-level operator jitted separately and
    dispatched from python — the honest launch-per-operator unfused
    baseline (whole-program jit would let XLA fuse the graph itself)."""
    import jax
    from repro.core.codegen_jax import compile_program
    in_info, out_info = _io_info(g)
    per_op = jit == "per-op"
    prog = compile_program(g, per_op_jit=per_op)

    def fn(*merged):
        stacked = [P.to_stacked(a, vt, dims)
                   for (_, vt), a in zip(in_info, merged)]
        outs = prog(*stacked)
        return tuple(P.from_stacked(o, vt, dims)
                     for (_, vt), o in zip(out_info, outs))

    if jit and not per_op:
        fn = jax.jit(fn)

    def call(inputs: Dict[str, Any]) -> Dict[str, Any]:
        outs = fn(*[inputs[nm] for nm, _ in in_info])
        return {nm: o for (nm, _), o in zip(out_info, outs)}

    return call


def _region_plan(g: Graph):
    """Partition the selected snapshot once; the plan is shared between
    per-kernel cost attribution and the Pallas lowering.  Returns
    ``(plan, error)``: when the partitioner cannot split, ``plan`` is
    ``None`` and ``error`` carries the ``RegionError`` text — recorded in
    ``LoweringReport.plan_error`` / ``ResilienceReport.plan_error`` so
    the demotion to emit_program's whole-program fallback is visible to
    ``check_regression.py`` and the serve warmup checks instead of being
    silently swallowed here."""
    from repro.core import regions as REG
    try:
        return REG.plan_program(g), None
    except REG.RegionError as err:
        return None, str(err)


def _grouped_plan(pplan, dims: Dict[str, int],
                  blocks: Optional[Dict[str, int]], group: bool):
    """Pack the region DAG into megakernel groups (or one-region groups
    when ``group=False``) — shared between costing and lowering."""
    from repro.core import regions as REG
    if pplan is None:
        return None
    return (REG.group_plan(pplan, dims, blocks) if group
            else REG.ungrouped_plan(pplan))


def _lower_pallas(g: Graph, dims: Dict[str, int],
                  blocks: Optional[Dict[str, int]], interpret: bool,
                  program_plan=None, grouped_plan=None,
                  group: bool = True, jit: bool = True):
    """Lower the selected snapshot itself — no walking back to a
    differently-fused candidate.  Returns (call, LoweringReport).  The
    chained kernel schedule runs under ``jax.jit`` (when ``jit``) so
    XLA plans the spilled intermediate buffers once and the per-kernel
    ``input_output_aliases`` donations actually reuse them."""
    import jax
    from repro.core.codegen_pallas import emit_program
    if blocks is None:
        raise ValueError(
            "backend='pallas' needs per-dim block sizes: pass blocks=")
    missing = [d for d in dims if d not in blocks]
    if missing:
        raise ValueError(f"blocks missing sizes for dims {missing}")
    f, report = emit_program(g, dims, blocks, interpret=interpret,
                             program_plan=program_plan,
                             grouped_plan=grouped_plan, group=group)
    if report.fallbacks:
        warnings.warn(
            "pallas lowering fallback: "
            f"{report.fallbacks}/{report.n_regions} regions ran on the "
            f"jax backend ({report.summary()})", RuntimeWarning,
            stacklevel=3)
    in_info, out_info = _io_info(g)
    exec_f = jax.jit(f) if jit else f

    def call(inputs: Dict[str, Any]) -> Dict[str, Any]:
        outs = exec_f(*[inputs[nm] for nm, _ in in_info])
        return {nm: o for (nm, _), o in zip(out_info, outs)}

    # the raw (un-jitted) emit_program callable carries the per-kernel
    # runners the timing harness (core/timing.region_times) needs
    call.raw_program = f
    return call, report


def _rung_thunk(rung: str, g: Graph, dims: Dict[str, int], *,
                blocks: Optional[Dict[str, int]], interpret, jit,
                pplan, gplan, group: bool) -> Callable[[], Tuple]:
    """The lowering a ladder rung runs; every thunk returns
    ``(call, LoweringReport-or-None)``.  ``gplan`` is only reusable at
    the rung it was packed for — a demoted rung recomputes its own."""
    if rung == "grouped":
        return lambda: _lower_pallas(
            g, dims, blocks, interpret, program_plan=pplan,
            grouped_plan=gplan if group else None, group=True,
            jit=bool(jit))
    if rung == "ungrouped":
        return lambda: _lower_pallas(
            g, dims, blocks, interpret, program_plan=pplan,
            grouped_plan=None if group else gplan, group=False,
            jit=bool(jit))
    if rung == "jax":
        return lambda: (_lower_jax(g, dims, jit), None)
    return lambda: (_lower_py(g, dims), None)


def _ladder_lower(rungs: Tuple[str, ...], make_thunk: Callable,
                  policy, rr, *, ledger=None,
                  health_key: Optional[str] = None) -> Tuple:
    """Attempt each allowed rung in order — ``policy.retries`` extra
    same-rung tries with exponential backoff, each attempt optionally
    under ``policy.attempt_timeout_s`` — recording every attempt in the
    :class:`resilience.ResilienceReport` ``rr``.  Returns the first
    successful rung's ``(call, report)``; raises
    :class:`resilience.LadderError` when every rung is exhausted.

    When a :class:`resilience.HealthLedger` is given, each rung's
    breaker is consulted first: an **open** breaker skips the rung
    instantly (a zero-cost ``skipped_open`` attempt — no retry sleeps,
    no timeout worker, no re-burning the budget a known-bad rung
    already wasted), a cool-down-elapsed breaker admits the attempt as
    a half-open **probe**, and every executed attempt's outcome feeds
    back into the ledger.

    The default policy costs the happy path nothing: no timeout means no
    worker thread, zero retries means no sleep, and the ledger holds no
    entry for a rung that never failed — one ``try`` around the
    lowering call that already existed."""
    last: Optional[BaseException] = None
    for ri, rung in enumerate(rungs):
        probe = False
        if ledger is not None and health_key is not None:
            verdict = ledger.decision(health_key, rung)
            if verdict == "open":
                rr.attempts.append(RZ.Attempt(
                    rung, False, 0.0, error="breaker open (skipped)",
                    skipped_open=True))
                RZ.METRICS.skipped_open += 1
                if ri + 1 < len(rungs):
                    warnings.warn(
                        f"compile ladder: rung {rung!r} breaker open; "
                        f"skipping to {rungs[ri + 1]!r}", RuntimeWarning,
                        stacklevel=3)
                continue
            probe = verdict == "probe"
            if probe:
                RZ.METRICS.probes += 1
        thunk = make_thunk(rung)

        def attempt(rung=rung, thunk=thunk):
            RZ.check(f"compile:{rung}")
            return thunk()

        for retry in range(policy.retries + 1):
            if retry:
                time.sleep(policy.backoff_s * (2 ** (retry - 1)))
            t0 = time.perf_counter()
            try:
                res = (RZ.run_with_timeout(attempt,
                                           policy.attempt_timeout_s)
                       if policy.attempt_timeout_s is not None
                       else attempt())
            except Exception as e:  # any lowering failure demotes
                last = e
                rr.attempts.append(RZ.Attempt(
                    rung, False, time.perf_counter() - t0,
                    error=f"{type(e).__name__}: {e}", retry=retry,
                    timed_out=isinstance(e, RZ.AttemptTimeout),
                    probe=probe))
                if ledger is not None and health_key is not None:
                    ledger.record_failure(health_key, rung, e,
                                          policy=policy)
                    if probe:
                        RZ.METRICS.probe_failures += 1
                        probe = False  # retries are ordinary attempts
                continue
            rr.attempts.append(RZ.Attempt(
                rung, True, time.perf_counter() - t0, retry=retry,
                probe=probe))
            rr.rung = rung
            if ledger is not None and health_key is not None:
                ledger.record_success(health_key, rung)
            return res
        if ri + 1 < len(rungs):
            RZ.METRICS.demotions += 1
            warnings.warn(
                f"compile ladder: rung {rung!r} failed "
                f"({rr.attempts[-1].error}); demoting to "
                f"{rungs[ri + 1]!r}", RuntimeWarning, stacklevel=3)
    RZ.METRICS.ladder_failures += 1
    raise RZ.LadderError(
        f"every allowed ladder rung failed ({rr.summary()}); "
        f"last error: {last}", rr)


def _measure_harness(graph: Graph,
                     dim_candidates: Dict[str, Sequence[int]], *,
                     options: CompileOptions, profile,
                     cache: KernelCache,
                     stabilize: bool = False) -> Callable:
    """The ``measure`` callback ``selection.autotune(objective=
    "measured")`` calls for each top-K survivor: compile the candidate
    through this same driver (so the in-process kernel cache absorbs
    repeats) and time it end-to-end on synthetic inputs.

    Every candidate runs the SAME total problem: per dim the total
    extent is a base block extent (the caller's ``blocks``, else 8;
    1 for stack dims) times the lcm of the candidate counts, and each
    candidate's block extent is ``total // count`` — varying the block
    *count* at fixed problem size, which is the choice the paper's
    selector owns.  Measurements are memoized process-wide
    (``timing.measured``) keyed by (fingerprint, dims, backend, device,
    totals), so re-sweeps never re-time a configuration."""
    from repro.core import timing as T
    o = options
    repeats = o.measure_repeats
    blocks = o.blocks_dict
    sd = T.stack_dims(graph)
    base = {d: (1 if d in sd else (blocks or {}).get(d, 8))
            for d in dim_candidates}
    total = {d: base[d] * lcm(*{int(c) for c in dim_candidates[d]})
             for d in dim_candidates}
    dev = CAL.device_kind()
    fp = graph.fingerprint()
    kernels: Dict[Tuple, CompiledKernel] = {}

    def measure(sel) -> float:
        cand_blocks = {d: total[d] // sel.dims[d] for d in sel.dims}
        bad = [d for d in sd
               if d in cand_blocks and cand_blocks[d] != 1]
        if bad:
            raise ValueError(
                f"stack dims {bad} need equal candidate counts (block "
                "size is pinned to 1)")
        dkey = tuple(sorted(sel.dims.items()))
        # everything the wall time depends on is in the memo key —
        # notably interpret mode (orders of magnitude slower) and the
        # repeat count
        mkey = (fp, dkey, o.backend, dev, tuple(sorted(total.items())),
                o.jit, o.fused, o.interpret, repeats, o.group, stabilize)

        def thunk() -> float:
            cand = o.replace(
                blocks=(cand_blocks if o.backend == "pallas"
                        else o.blocks),
                stabilize=stabilize, autotune="analytic",
                profile=profile)
            kern = compile(graph, dict(sel.dims), options=cand,
                           cache=cache)
            kernels[dkey] = kern
            inputs = T.synth_inputs(graph, sel.dims, cand_blocks)
            return T.time_callable(kern, inputs, warmup=1,
                                   repeats=repeats).median_s

        return T.measured(mkey, thunk)

    measure.kernels = kernels
    return measure


def compile(graph: Graph, dims: Optional[Dict[str, int]] = None, *,
            options: Optional[CompileOptions] = None,
            dim_candidates: Optional[Dict[str, Sequence[int]]] = None,
            cache: Optional[KernelCache] = None,
            **kwargs) -> CompiledKernel:
    """Compile a block program into an executing, cached kernel.

    How the program compiles is described by ``options``, a frozen
    hashable :class:`CompileOptions` (``backend``, ``blocks``,
    ``stabilize``, ``autotune``, ``group``, ...).  The historical flat
    keyword form — ``compile(g, dims, backend="pallas", blocks=...)`` —
    is kept as a back-compat shim that builds a ``CompileOptions``
    internally; it is **deprecated** and new call sites should pass
    ``options=`` (passing both forms at once is a ``TypeError``).  The
    options hash directly into the kernel-cache key
    (``CompileOptions.cache_opts``), so equal options can never compile
    twice and unequal options can never alias.

    Either ``dims`` (fixed block counts -> ``selection.select``) or
    ``dim_candidates`` (a per-dim sweep -> ``selection.autotune``, which
    also picks the dims) must be given.  ``fused=False`` skips the fusion
    algorithm — the unfused Table-2 program compiles as-is; that is the
    benchmark baseline.  ``jit`` (jax backend) additionally accepts
    ``"per-op"``: each top-level operator is jitted separately and
    dispatched from python, the launch-per-operator unfused baseline.

    ``stabilize`` controls the graph-level numerical-safety rewrite
    (``numerics.stabilize``): top-level ``exp`` producers become
    significand/exponent pairs with running-max rescaled serial carries
    (online softmax), so attention stays finite at any logit magnitude.
    ``None`` (the default) auto-enables it exactly when the program
    contains a block-typed top-level ``exp``
    (``numerics.needs_stabilization``) — attention programs get it,
    exp-free programs compile unchanged.  The flag is part of the cache
    key: stabilized and raw kernels never alias.

    ``group`` (pallas backend) controls region-group megakernel
    lowering: by default compatible regions of the selected snapshot
    share one multi-stage ``pallas_call`` with cross-region values held
    in VMEM (``regions.group_plan``, gated by the
    ``$REPRO_VMEM_BUDGET_BYTES`` budget); ``group=False`` keeps the
    one-kernel-per-region lowering.  When grouping is on, snapshot
    selection also ranks by the grouped residency-aware objective
    (``selection.objective_cost(group=True)`` — resident edges free,
    one launch per group) instead of the paper's all-edges-global sum,
    so what is picked is what is cheapest to actually run.

    ``autotune="measured"`` (with ``dim_candidates``) closes the
    predict -> run -> measure loop: the calibrated analytic model prunes
    the sweep, the ``top_k`` cheapest distinct candidates are compiled
    and *timed* (median of ``measure_repeats`` fenced calls on synthetic
    inputs at a fixed total problem size), and the wall-clock winner is
    what lowers, caches, and re-loads.  ``profile`` overrides the
    calibration profile; by default the measured path loads the one
    fitted for this (backend, device) from the cache dir if a
    calibration run saved one — ``benchmarks/run.py --only pipeline``
    fits a ``backend="pallas"`` profile from per-region timings; other
    backends keep the default constants until calibrated (see
    ``core/calibrate.py``).  The analytic path always keeps the
    deterministic defaults.
    """
    if options is None:
        try:
            options = CompileOptions(**kwargs) if kwargs else DEFAULT_OPTIONS
        except TypeError as e:
            raise TypeError(f"pipeline.compile: {e}") from None
    elif kwargs:
        raise TypeError(
            "pipeline.compile: pass either options=CompileOptions(...) or "
            f"the flat keyword form, not both (extra: {sorted(kwargs)})")
    o = options
    backend, item_bytes, fused = o.backend, o.item_bytes_dict, o.fused
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if dims is None and dim_candidates is None:
        raise ValueError("pass dims= (fixed) or dim_candidates= (autotune)")
    if o.autotune not in AUTOTUNE_OBJECTIVES:
        raise ValueError(f"unknown autotune objective {o.autotune!r}; "
                         f"one of {AUTOTUNE_OBJECTIVES}")
    if o.autotune == "measured" and dim_candidates is None:
        raise ValueError("autotune='measured' needs dim_candidates=")
    cache = cache if cache is not None else default_cache()
    profile = o.profile
    if profile is None and o.autotune == "measured":
        # the measured path runs under the calibrated cost model fitted
        # for this backend+device (default constants if none saved)
        profile = CAL.load_or_default(cache.root, backend=backend,
                                      device_kind=CAL.device_kind())

    # default: stabilize exactly the programs that need it (block-typed
    # top-level exp, i.e. softmax-bearing programs like attention)
    stab = (NU.needs_stabilization(graph) if o.stabilize is None
            else bool(o.stabilize))

    vmem_budget = None
    if backend == "pallas":
        from repro.core import regions as REG
        from repro.core.codegen_pallas import resolve_interpret
        o = o.replace(interpret=resolve_interpret(o.interpret))
        if o.group:
            vmem_budget = REG.vmem_budget()
    blocks, interpret, jit, group = o.blocks_dict, o.interpret, o.jit, o.group

    # autotune keys embed the full candidate sweep, so two sweeps over the
    # same dim names but different candidate sets never collide
    key_dims = (dims if dims is not None
                else {k: tuple(v) for k, v in dim_candidates.items()})
    # every option that changes the emitted kernel or the selection plan
    # is part of the key, else a later compile is served a stale kernel
    # (CompileOptions.cache_opts is the single source of truth)
    opts = o.cache_opts(stabilized=stab,
                        autotuned=dim_candidates is not None,
                        profile=profile, vmem_budget=vmem_budget)
    key = CacheKey.make(graph.fingerprint(), backend, key_dims, blocks,
                        fused, opts)
    hit = cache.get_kernel(key)
    if hit is not None:
        return replace(hit, cache_hit="memory")

    plan, selected_graph = cache.get_plan(key)
    snaps: Optional[List[Graph]] = None
    pplan = None  # shared region partition (pallas cache-miss path)
    gplan = None  # shared region grouping (costing + lowering)
    plan_err = None  # RegionError text when the partitioner couldn't split
    timings = None
    measure = None
    # the pallas grouped lowering runs the grouped megakernel schedule,
    # so its snapshots are ranked by the residency-aware grouped
    # objective (sum of group costs); every other backend runs the
    # whole program as one unit and keeps the paper's global objective
    sel_group = bool(group) and backend == "pallas"
    if plan is None:
        # -- the full pipeline: fuse -> select/autotune --------------------
        if fused:
            trace = FusionTrace()
            snaps = fuse(graph, trace)
        else:
            snaps = [graph.clone()]
        # stabilization rewrites every snapshot (and the unfused base
        # used for init_cost) BEFORE selection, so the cost model ranks
        # the graphs that will actually lower — exponent-vector edges
        # and rescale work included
        base = graph
        if stab:
            snaps = [NU.stabilize(s) for s in snaps]
            base = NU.stabilize(graph)
        if dim_candidates is not None:
            if o.autotune == "measured":
                measure = _measure_harness(
                    graph, dim_candidates, options=o, profile=profile,
                    cache=cache, stabilize=stab)
                sel = SEL.autotune(base, dim_candidates, item_bytes,
                                   snapshots=snaps, objective="measured",
                                   profile=profile, measure=measure,
                                   top_k=o.top_k, group=sel_group,
                                   blocks=blocks)
                timings = sel.timings
            else:
                sel = SEL.autotune(base, dim_candidates, item_bytes,
                                   snapshots=snaps, profile=profile,
                                   group=sel_group, blocks=blocks)
        else:
            sel = SEL.select(base, dims, item_bytes, snapshots=snaps,
                             profile=profile, group=sel_group,
                             blocks=blocks)
        selected_graph = snaps[sel.snapshot_index]
        # residency-aware per-kernel traffic attribution of the snapshot
        # that will run (pallas packs its regions into megakernel
        # groups; the same grouping is reused by the lowering below)
        rcosts = kids = None
        launches = resident = None
        if backend == "pallas" and blocks is not None:
            pplan, plan_err = _region_plan(selected_graph)
            gplan = _grouped_plan(pplan, sel.dims, blocks, group)
            if gplan is not None:
                rcosts = SEL.region_costs(selected_graph, sel.dims,
                                          item_bytes, plan=gplan,
                                          profile=profile)
                kids = tuple(grp.gid for grp in gplan.groups)
                launches = gplan.n_launches
                resident = gplan.n_resident_edges
        # the unfused program priced under the SAME objective as the
        # winner, so predicted_traffic_reduction compares like with like
        init_cost = SEL.objective_cost(base, sel.dims, item_bytes,
                                       profile, group=sel_group,
                                       blocks=blocks)
        plan = CachePlan(sel.snapshot_index, sel.dims, sel.cost,
                         sel.costs, init_cost,
                         region_costs=rcosts, measured_s=sel.measured_s,
                         kernel_ids=kids, launches=launches,
                         resident_edges=resident, stabilized=stab)
        cache.put_plan(key, plan, selected_graph)
        cache_hit = None
    else:
        cache_hit = "disk"
        if selected_graph is None:
            # plan-only disk entry (un-picklable graph): re-fuse and
            # re-apply the same deterministic stabilization pass so
            # snapshot_index addresses the graph the plan described
            snaps = fuse(graph) if fused else [graph.clone()]
            if stab:
                snaps = [NU.stabilize(s) for s in snaps]
            selected_graph = snaps[plan.snapshot_index]

    use_dims = plan.dims

    # -- backend lowering: the selected snapshot, nothing else --------------
    # the measured sweep already compiled its candidates through this
    # driver; if the winner's kernel is lowering-identical to what we
    # would emit (same backend, and for pallas the same block extents),
    # reuse it instead of recompiling the same plan
    policy = o._policy()
    start = RZ.start_rung(backend, bool(group))
    rr = RZ.ResilienceReport(requested=start, plan_error=plan_err)
    fn = report = None
    if measure is not None:
        cand = measure.kernels.get(tuple(sorted(use_dims.items())))
        if cand is not None and (
                backend != "pallas"
                or cand.blocks == (dict(blocks) if blocks else None)):
            fn, report = cand._fn, cand.lowering_report
            # the sweep compiled it through this driver; adopt its
            # provenance instead of claiming a fresh zero-cost attempt
            rr = cand.resilience_report or rr
    if fn is None:
        # configuration errors are the caller's, not the ladder's: raise
        # before any rung runs instead of demoting past them
        if backend == "pallas":
            if blocks is None:
                raise ValueError(
                    "backend='pallas' needs per-dim block sizes: pass "
                    "blocks=")
            missing = [d for d in use_dims if d not in blocks]
            if missing:
                raise ValueError(
                    f"blocks missing sizes for dims {missing}")
        fn, report = _ladder_lower(
            RZ.rungs_from(start, policy.max_rung),
            functools.partial(_rung_thunk, g=selected_graph,
                              dims=use_dims, blocks=blocks,
                              interpret=interpret, jit=jit, pplan=pplan,
                              gplan=gplan, group=group),
            policy, rr,
            # the cache's health ledger shares breaker state with every
            # process pointed at the same cache dir; keyed by graph
            # fingerprint so one program's bad rung never taints another
            ledger=(cache.health if policy.breaker_threshold > 0
                    else None),
            health_key=graph.fingerprint())
    # thread the partitioner's RegionError (or emit_program's own
    # whole-program fallback, on the disk-hit path where the driver
    # never partitioned) through both provenance records
    if report is not None and report.plan_error is None and plan_err:
        report.plan_error = plan_err
    if report is not None and report.plan_error and not rr.plan_error:
        rr.plan_error = report.plan_error

    # emission may diverge from the planned grouping (a group the
    # emitter cannot express degrades to per-region kernels): the
    # per-kernel cost provenance must describe what actually runs, or
    # costs would claim residency savings the fallback never realized
    # and id-based time pairing would silently drop kernels
    if backend == "pallas" and report is not None:
        actual = getattr(getattr(fn, "raw_program", None),
                         "emitted_kernels", None)
        if (actual is not None and plan.kernel_ids is not None
                and tuple(gid for gid, _ in actual) != plan.kernel_ids):
            rcosts = []
            for gid, unit in actual:
                if hasattr(unit, "members"):  # a whole RegionGroup
                    rcosts.append(SEL.group_cost(unit, use_dims,
                                                 item_bytes, profile))
                else:  # a single RegionSpec (degraded / singleton)
                    rcosts.append(SEL.snapshot_cost(unit.graph, use_dims,
                                                    item_bytes, profile))
            plan = replace(plan, region_costs=tuple(rcosts),
                           kernel_ids=tuple(g for g, _ in actual),
                           launches=report.launches,
                           resident_edges=report.resident_edges)
            cache.put_plan(key, plan, selected_graph)

    in_info, out_info = _io_info(selected_graph)
    kern = CompiledKernel(
        key=key, backend=backend, graph=selected_graph, dims=dict(use_dims),
        blocks=dict(blocks) if blocks else None,
        snapshot_index=plan.snapshot_index, cost=plan.cost,
        initial_cost=plan.initial_cost, cache_hit=cache_hit,
        stabilized=stab,
        in_names=[n for n, _ in in_info],
        out_names=[n for n, _ in out_info], _fn=fn,
        lowering_report=report, region_costs=plan.region_costs,
        kernel_ids=plan.kernel_ids,
        measured_s=plan.measured_s, autotune_timings=timings,
        resilience_report=rr)
    cache.put_kernel(key, kern)
    return kern
