"""Self-healing rungs: the HealthLedger circuit breaker (state machine,
checksummed persistence, zero-overhead happy path), ladder integration
(skip known-open rungs, probe after cool-down), serving-engine
re-promotion (demote -> clean ticks -> half-open probe -> swap back),
the cache crash-recovery sweep, and cross-process cache contention."""

import json
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro import pipeline
from repro import resilience as RZ
from repro.pipeline import cache as C

from test_lowering_coverage import PROGRAMS
from test_resilience import _oracle, _tiny_cfg

SRC = Path(pipeline.__file__).resolve().parents[2]


@pytest.fixture()
def fresh_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_CACHE", str(tmp_path))
    pipeline.reset_default_cache()
    yield tmp_path
    pipeline.reset_default_cache()


@pytest.fixture(autouse=True)
def _no_env_plan(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    RZ.install(None)
    yield
    RZ.install(None)


# ---------------------------------------------------------------------------
# the breaker state machine (memory-only, injectable clock)
# ---------------------------------------------------------------------------

def test_breaker_closed_open_halfopen_cycle():
    clk = [0.0]
    led = RZ.HealthLedger(None, clock=lambda: clk[0])
    pol = RZ.ResiliencePolicy(breaker_threshold=2, breaker_cooldown_s=10.0)
    key = "fp-abc"
    assert led.decision(key, "grouped") == "closed"
    assert led.record_failure(key, "grouped", "boom", policy=pol) == "closed"
    assert led.record_failure(key, "grouped", "boom", policy=pol) == "open"
    assert led.decision(key, "grouped") == "open"
    assert led.stats.trips == 1 and led.stats.skipped_open == 1
    # cool-down elapses -> exactly one half-open probe is admitted
    clk[0] = 10.0
    assert led.decision(key, "grouped") == "probe"
    assert led.state(key, "grouped") == "half_open"
    # a failed probe re-opens at DOUBLED cool-down
    assert led.record_failure(key, "grouped", "still bad",
                              policy=pol) == "open"
    e = led.entry(key, "grouped")
    assert e.cooldown_s == 20.0 and e.open_until == 30.0
    assert led.decision(key, "grouped") == "open"
    clk[0] = 30.0
    assert led.decision(key, "grouped") == "probe"
    # a passing probe closes the breaker and drops the entry entirely
    led.record_success(key, "grouped")
    assert led.decision(key, "grouped") == "closed"
    assert led.entry(key, "grouped") is None
    assert led.stats.resets == 1


def test_breaker_cooldown_caps_and_threshold_zero_disables():
    clk = [0.0]
    led = RZ.HealthLedger(None, clock=lambda: clk[0])
    pol = RZ.ResiliencePolicy(breaker_threshold=1, breaker_cooldown_s=10.0,
                              breaker_cooldown_max_s=25.0)
    led.record_failure("k", "jax", "x", policy=pol)
    for expect in (20.0, 25.0, 25.0):  # doubles, then pins at the cap
        clk[0] = led.entry("k", "jax").open_until
        assert led.decision("k", "jax") == "probe"
        led.record_failure("k", "jax", "x", policy=pol)
        assert led.entry("k", "jax").cooldown_s == expect
    # threshold 0 disables the breaker: failures never open it
    off = RZ.HealthLedger(None)
    zero = RZ.ResiliencePolicy(breaker_threshold=0)
    for _ in range(5):
        assert off.record_failure("k", "jax", "x", policy=zero) == "disabled"
    assert off.decision("k", "jax") == "closed"
    with pytest.raises(ValueError, match="breaker_threshold"):
        RZ.ResiliencePolicy(breaker_threshold=-1)


def test_halfopen_probe_owner_crash_admits_another_after_cooldown():
    clk = [0.0]
    led = RZ.HealthLedger(None, clock=lambda: clk[0])
    pol = RZ.ResiliencePolicy(breaker_threshold=1, breaker_cooldown_s=10.0)
    led.record_failure("k", "grouped", "x", policy=pol)
    clk[0] = 10.0
    assert led.decision("k", "grouped") == "probe"
    # the probe's owner never reported back; concurrent callers wait...
    assert led.decision("k", "grouped") == "open"
    # ...until a full cool-down has passed, then another probe is allowed
    clk[0] = 20.0
    assert led.decision("k", "grouped") == "probe"


# ---------------------------------------------------------------------------
# persistence: checksummed envelopes, fresh-process round-trip, corruption
# ---------------------------------------------------------------------------

def test_ledger_roundtrips_across_a_fresh_process(tmp_path):
    """Breaker state written by a REAL separate process is read back
    here: rung health survives restarts and is shared cross-process."""
    hroot = tmp_path / "health"
    script = (
        "import sys\n"
        "sys.path.insert(0, sys.argv[2])\n"
        "from repro import resilience as RZ\n"
        "led = RZ.HealthLedger(sys.argv[1], clock=lambda: 100.0)\n"
        "pol = RZ.ResiliencePolicy(breaker_threshold=2,\n"
        "                          breaker_cooldown_s=50.0)\n"
        "led.record_failure('fp-x', 'grouped', 'boom', policy=pol)\n"
        "led.record_failure('fp-x', 'grouped', 'boom', policy=pol)\n"
    )
    subprocess.run([sys.executable, "-c", script, str(hroot), str(SRC)],
                   check=True, timeout=120)
    envs = list(hroot.glob("*.json"))
    assert len(envs) == 1
    env = json.loads(envs[0].read_text())
    assert set(env) == {"schema", "sha256", "entry"} and len(env["sha256"]) == 64
    assert not list(hroot.glob("*.tmp"))  # atomic write left no temp files

    clk = [120.0]
    led = RZ.HealthLedger(hroot, clock=lambda: clk[0])
    assert led.state("fp-x", "grouped") == "open"
    assert led.stats.reads == 1
    e = led.entry("fp-x", "grouped")
    assert (e.failures, e.trips, e.open_until) == (2, 1, 150.0)
    assert "boom" in e.last_error
    assert led.decision("fp-x", "grouped") == "open"
    clk[0] = 150.0
    assert led.decision("fp-x", "grouped") == "probe"
    # recovery unlinks the envelope: the dir is pristine again
    led.record_success("fp-x", "grouped")
    assert list(hroot.glob("*.json")) == []


def test_corrupt_envelope_fails_open_and_is_discarded(tmp_path):
    hroot = tmp_path / "health"
    led = RZ.HealthLedger(hroot)
    pol = RZ.ResiliencePolicy(breaker_threshold=1)
    led.record_failure("fp", "grouped", "x", policy=pol)
    path = next(hroot.glob("*.json"))
    path.write_text(path.read_text()[:40] + "garbage")
    fresh = RZ.HealthLedger(hroot)
    with pytest.warns(RuntimeWarning, match="corrupt entry"):
        # a broken ledger must never take a healthy rung out of service
        assert fresh.decision("fp", "grouped") == "closed"
    assert fresh.stats.corrupt == 1
    assert not path.exists()  # discarded, not re-read forever


def test_happy_path_is_zero_ledger_io(tmp_path):
    """The acceptance pin: a clean compile performs no ledger reads or
    writes and never even creates <cache>/health/."""
    cache = C.KernelCache(root=tmp_path)
    build, dims, _ = PROGRAMS["layernorm_matmul"]
    kern = pipeline.compile(build(), dims, backend="jax", cache=cache)
    assert kern.resilience_report.rung == "jax"
    assert not (tmp_path / "health").exists()
    st = cache.health.stats
    assert (st.reads, st.writes, st.skipped_open, st.probes) == (0, 0, 0, 0)


# ---------------------------------------------------------------------------
# ladder integration: skip open rungs instantly, probe after cool-down
# ---------------------------------------------------------------------------

def test_ladder_skips_open_rung_and_probes_after_cooldown(tmp_path):
    build, dims, _ = PROGRAMS["layernorm_matmul"]
    g = build()
    cache = C.KernelCache(root=tmp_path)
    clk = [0.0]
    cache.health.clock = lambda: clk[0]
    pol = RZ.ResiliencePolicy(breaker_threshold=2, breaker_cooldown_s=100.0,
                              retries=0)
    opts = pipeline.CompileOptions(backend="jax", resilience=pol)
    plan = RZ.FaultPlan([RZ.FaultSpec(site="compile:jax", indices=(0, 1))])
    with RZ.faults(plan), pytest.warns(RuntimeWarning,
                                       match="compile ladder"):
        k1 = pipeline.compile(g, {**dims, "M": 2}, options=opts,
                              cache=cache)
        k2 = pipeline.compile(g, {**dims, "M": 4}, options=opts,
                              cache=cache)
        assert k1.rung == k2.rung == "interpreter"
        # two consecutive jax failures tripped the breaker: the third
        # compile skips the rung INSTANTLY — compile:jax is never called
        before = RZ.METRICS.snapshot()
        with pytest.warns(RuntimeWarning, match="breaker open"):
            k3 = pipeline.compile(g, {**dims, "M": 8}, options=opts,
                                  cache=cache)
        assert plan.calls("compile:jax") == 2
        assert k3.rung == "interpreter"
        rr = k3.resilience_report
        assert rr.attempts[0].skipped_open and not rr.attempts[0].ok
        assert rr.skipped_open == 1
        assert RZ.METRICS.delta(before).skipped_open == 1
        # METRICS.demotions untouched by the skip (chaos gates pin it)
        assert RZ.METRICS.delta(before).demotions == 0

        # the open state is SHARED: a fresh cache on the same dir sees it
        assert C.KernelCache(root=tmp_path).health.state(
            g.fingerprint(), "jax") == "open"

        # cool-down elapses -> the next compile probes and recovers
        clk[0] = 100.0
        before = RZ.METRICS.snapshot()
        k4 = pipeline.compile(g, {**dims, "M": 16}, options=opts,
                              cache=cache)
        assert k4.rung == "jax"
        assert k4.resilience_report.attempts[0].probe
        assert k4.resilience_report.probes == 1
        assert RZ.METRICS.delta(before).probes == 1
    # recovery removed the entry: the health dir is pristine again
    assert list((tmp_path / "health").glob("*.json")) == []


def test_attempt_wall_times_recorded_for_timeout_calibration():
    rr = RZ.ResilienceReport(requested="grouped")
    rr.attempts = [
        RZ.Attempt("grouped", False, 0.0, skipped_open=True),
        RZ.Attempt("ungrouped", False, 0.8, error="X: y"),
        RZ.Attempt("ungrouped", True, 0.5, retry=1),
        RZ.Attempt("jax", True, 0.1),
    ]
    walls = rr.wall_by_rung()
    assert "grouped" not in walls  # skipped rungs never ran: no sample
    assert walls["ungrouped"] == [0.8, 0.5] and walls["jax"] == [0.1]
    assert rr.suggest_timeout_s(margin=4.0) == pytest.approx(2.0)
    assert RZ.ResilienceReport().suggest_timeout_s() is None
    js = json.loads(json.dumps(rr.to_json()))
    assert js["skipped_open"] == 1 and js["probes"] == 0


def test_run_with_timeout_daemon_worker_counted_and_transparent():
    before = RZ.METRICS.snapshot()
    started = threading.Event()

    def hang():
        started.set()
        time.sleep(30)

    with pytest.raises(RZ.AttemptTimeout):
        RZ.run_with_timeout(hang, 0.1)
    assert started.wait(5)
    workers = [t for t in threading.enumerate()
               if t.name.startswith("repro-ladder")]
    # the leaked worker is daemonic: it can never block process exit
    assert workers and all(t.daemon for t in workers)
    assert RZ.METRICS.delta(before).abandoned_workers == 1
    # the non-timeout paths stay transparent: values and exceptions
    assert RZ.run_with_timeout(lambda: 7, 5.0) == 7
    with pytest.raises(ZeroDivisionError):
        RZ.run_with_timeout(lambda: 1 // 0, 5.0)
    assert RZ.METRICS.delta(before).abandoned_workers == 1


# ---------------------------------------------------------------------------
# serving-engine re-promotion: the inverse of the PR-9 watchdog
# ---------------------------------------------------------------------------

def test_engine_self_heals_end_to_end(fresh_cache):
    """The acceptance path: a transient decode fault demotes decode to
    the jax rung; after `repromote_after` clean ticks a half-open probe
    re-compiles the pallas rung and swaps it back mid-run.  Tokens stay
    byte-identical to the sequential oracle and the ledger entry clears."""
    from repro.launch.engine import Engine, synth_trace
    engine = Engine(_tiny_cfg("pallas"), max_batch=2, max_len=32,
                    prompt_buckets=(8,), sampling="greedy", seed=0,
                    repromote_after=2)
    trace = synth_trace(4, seed=1, arrival_rate=1.0, prompt_lens=(2, 7),
                        gen_lens=(3, 5), vocab=engine.cfg.vocab)
    plan = RZ.FaultPlan([RZ.FaultSpec(site="serve:decode", indices=(1,),
                                      message="transient decode fault")])
    with RZ.faults(plan), pytest.warns(RuntimeWarning,
                                       match="re-promoted"):
        report = engine.run(trace)
    assert report.n_completed == len(trace)
    assert engine.watchdog_demotions == 1
    assert (report.repromotions, report.probes,
            report.probe_failures) == (1, 1, 0)
    # decode ended the run back on the grouped pallas rung
    assert report.decode_backend == "pipeline-pallas"
    demote = [f for f in report.failures
              if f["reason"] == "decode_demotion"]
    heal = [f for f in report.failures
            if f["reason"] == "decode_repromotion"]
    assert len(demote) == 1 and demote[0]["to"] == "pipeline-jax"
    assert len(heal) == 1 and heal[0]["to"] == "pipeline-pallas"
    # cool-down honored: the probe waited >= repromote_after clean ticks
    assert heal[0]["step"] - demote[0]["step"] >= 2
    # probe compiles are explained: strict_no_recompile stayed armed
    assert report.decode_recompiles == 0
    # non-poisoned tokens byte-identical to the sequential oracle (the
    # engine's model is the re-promoted pallas impl again)
    for req in trace:
        assert report.tokens[req.rid] == _oracle(engine, req)
    # recovery closed the breaker: the persisted entry is gone
    led = RZ.HealthLedger(pipeline.default_cache().root / "health")
    assert led.state(engine._hkey, "pipeline-pallas") == "closed"
    d = json.loads(json.dumps(report.to_json()))
    assert d["repromotions"] == 1 and d["decode_backend"] == "pipeline-pallas"


def test_engine_failed_probe_reopens_at_doubled_cooldown(fresh_cache):
    from repro.launch.engine import Engine, synth_trace
    engine = Engine(_tiny_cfg("pallas"), max_batch=2, max_len=48,
                    prompt_buckets=(8,), sampling="greedy", seed=0,
                    repromote_after=2)
    trace = synth_trace(6, seed=2, arrival_rate=1.0, prompt_lens=(2, 7),
                        gen_lens=(5, 7), vocab=engine.cfg.vocab)
    plan = RZ.FaultPlan([
        RZ.FaultSpec(site="serve:decode", indices=(1,)),
        RZ.FaultSpec(site="serve:probe", indices=(0,),
                     message="probe still cold"),
    ])
    with RZ.faults(plan), pytest.warns(RuntimeWarning,
                                       match="probe"):
        report = engine.run(trace)
    assert (report.repromotions, report.probes,
            report.probe_failures) == (1, 2, 1)
    assert report.decode_backend == "pipeline-pallas"
    failed = [f for f in report.failures if f["reason"] == "probe_failed"]
    healed = [f for f in report.failures
              if f["reason"] == "decode_repromotion"]
    assert len(failed) == 1 and len(healed) == 1
    # the failed probe doubled the cool-down: the second probe waited
    # at least 2 * repromote_after ticks after the first
    assert healed[0]["step"] - failed[0]["step"] >= 4
    assert report.n_completed == len(trace)
    assert report.decode_recompiles == 0


def test_engine_adopts_persisted_breaker_state_across_processes(fresh_cache):
    """A predecessor process crashed the pallas decode rung and died
    before healing: a new engine adopts the persisted open breaker,
    starts demoted, then probes and re-promotes — cross-process healing
    with zero watchdog demotions in THIS process."""
    from repro.launch.engine import Engine, synth_trace
    cfg = _tiny_cfg("pallas")
    hroot = pipeline.default_cache().root / "health"
    RZ.HealthLedger(hroot).reopen(
        f"serve:{cfg.name}:decode", "pipeline-pallas", 1000.0,
        error="predecessor decode crash")
    with pytest.warns(RuntimeWarning, match="starting demoted"):
        engine = Engine(cfg, max_batch=2, max_len=32, prompt_buckets=(8,),
                        sampling="greedy", seed=0, repromote_after=2)
    # the engine came up on the demoted rung without crashing first
    assert engine._demote_stack and engine.watchdog_demotions == 0
    trace = synth_trace(4, seed=1, arrival_rate=1.0, prompt_lens=(2, 7),
                        gen_lens=(3, 5), vocab=engine.cfg.vocab)
    with pytest.warns(RuntimeWarning, match="re-promoted"):
        report = engine.run(trace)
    assert report.repromotions == 1
    assert report.decode_backend == "pipeline-pallas"
    assert report.degradations == 0  # nothing demoted in THIS process
    assert report.n_completed == len(trace)


def test_clean_engine_run_zero_probe_counters_and_zero_ledger_io(fresh_cache):
    from repro.launch.engine import Engine, synth_trace
    engine = Engine(_tiny_cfg("jax"), max_batch=2, max_len=32,
                    prompt_buckets=(8,), sampling="greedy", seed=0)
    trace = synth_trace(3, seed=0, arrival_rate=1.0, prompt_lens=(2, 6),
                        gen_lens=(2, 4), vocab=engine.cfg.vocab)
    report = engine.run(trace)
    assert (report.repromotions, report.probes,
            report.probe_failures) == (0, 0, 0)
    assert report.decode_backend == "pipeline-jax"
    st = engine._ledger.stats
    assert (st.reads, st.writes, st.probes, st.skipped_open) == (0, 0, 0, 0)
    assert not (pipeline.default_cache().root / "health").exists()


# ---------------------------------------------------------------------------
# cache crash-recovery sweep
# ---------------------------------------------------------------------------

def test_recovery_sweep_removes_dead_writer_tmp_files(tmp_path):
    # a pid guaranteed dead: a subprocess that already exited
    p = subprocess.Popen([sys.executable, "-c", "pass"])
    p.wait()
    dead = tmp_path / f"abc.json.{p.pid}.tmp"
    dead.write_text("half-written plan from a crashed writer")
    live = tmp_path / f"def.json.{os.getpid()}.tmp"
    live.write_text("an in-flight write by a live process")
    foreign_old = tmp_path / "weird.tmp"  # no pid: age decides
    foreign_old.write_text("x")
    os.utime(foreign_old, (0, 0))
    with pytest.warns(RuntimeWarning, match="orphaned tmp"):
        kc = C.KernelCache(root=tmp_path)
    assert kc.stats.recovered_tmp == 2
    assert not dead.exists() and not foreign_old.exists()
    assert live.exists()  # never races a live writer


def test_recovery_sweep_removes_stale_unheld_lock(tmp_path):
    lock = tmp_path / ".lock"
    lock.write_text("")
    os.utime(lock, (0, 0))  # ancient and nobody holds it
    with pytest.warns(RuntimeWarning, match="stale lock"):
        kc = C.KernelCache(root=tmp_path)
    assert kc.stats.stale_locks == 1 and not lock.exists()


def test_recovery_sweep_spares_a_held_lock(tmp_path):
    import fcntl
    lock = tmp_path / ".lock"
    lock.write_text("")
    os.utime(lock, (0, 0))
    fd = os.open(str(lock), os.O_RDWR)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)  # a live writer holds it
        kc = C.KernelCache(root=tmp_path)
        assert kc.stats.stale_locks == 0 and lock.exists()
    finally:
        os.close(fd)


def test_quarantine_capped_at_byte_budget_oldest_first(tmp_path,
                                                       monkeypatch):
    monkeypatch.setenv("REPRO_QUARANTINE_MAX_BYTES", "100")
    qdir = tmp_path / "quarantine"
    qdir.mkdir(parents=True)
    for name, size, mtime in (("old.json", 60, 1000.0),
                              ("mid.json", 60, 2000.0),
                              ("new.json", 30, 3000.0)):
        f = qdir / name
        f.write_bytes(b"x" * size)
        os.utime(f, (mtime, mtime))
    with pytest.warns(RuntimeWarning, match="quarantine"):
        kc = C.KernelCache(root=tmp_path)
    assert kc.stats.quarantine_evicted == 1
    assert sorted(p.name for p in qdir.iterdir()) == ["mid.json",
                                                      "new.json"]


def test_recovery_sweep_is_silent_on_a_clean_cache(tmp_path, recwarn):
    kc = C.KernelCache(root=tmp_path)  # dir does not even exist yet
    st = kc.stats
    assert (st.recovered_tmp, st.stale_locks, st.quarantine_evicted) == \
        (0, 0, 0)
    mem = C.KernelCache(disk=False)  # memory-only caches never sweep
    assert mem.stats.recovered_tmp == 0
    assert not [w for w in recwarn.list
                if "kernel cache" in str(w.message)]


# ---------------------------------------------------------------------------
# cross-process cache contention
# ---------------------------------------------------------------------------

_CONTENTION_SCRIPT = """
import hashlib, json, sys
sys.path.insert(0, sys.argv[1])
from repro import pipeline
from repro.core import array_program as AP

g = AP.layernorm_matmul_program(32.0)
dims = {"M": 2, "K": 4, "N": 2}
kern = pipeline.compile(g, dims, backend="py")
cache = pipeline.default_cache()
key = pipeline.CacheKey.make(
    g.fingerprint(), "py", dims, None, True,
    pipeline.CompileOptions(backend="py").cache_opts(
        stabilized=False, autotuned=False))
plan_path = cache.root / (key.digest() + ".json")
print(json.dumps({
    "cost": kern.cost,
    "snapshot": kern.snapshot_index,
    "sha": hashlib.sha256(plan_path.read_bytes()).hexdigest(),
}))
"""


def test_cross_process_contention_same_key(tmp_path):
    """Two subprocesses compile the same (fingerprint, dims, options)
    key concurrently: both succeed, the surviving on-disk plan is
    byte-identical from both sides, and nothing is quarantined or left
    half-written."""
    env = dict(os.environ, REPRO_KERNEL_CACHE=str(tmp_path))
    env.pop("REPRO_FAULT_PLAN", None)
    procs = [subprocess.Popen(
        [sys.executable, "-c", _CONTENTION_SCRIPT, str(SRC)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env,
        text=True) for _ in range(2)]
    outs = []
    for p in procs:
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, err
        outs.append(json.loads(out.strip().splitlines()[-1]))
    assert outs[0] == outs[1]  # same plan, byte-identical envelope

    # zero corruption, zero leftovers, exactly one entry
    assert not list(tmp_path.glob("*.tmp"))
    assert not (tmp_path / "quarantine").exists()
    assert len(list(tmp_path.glob("*.json"))) == 1
    kc = C.KernelCache(root=tmp_path)
    assert (kc.stats.recovered_tmp, kc.stats.stale_locks) == (0, 0)
    # and the surviving entry reads back clean in this process
    from repro.core import array_program as AP
    g = AP.layernorm_matmul_program(32.0)
    kern = pipeline.compile(g, {"M": 2, "K": 4, "N": 2}, backend="py",
                            cache=kc)
    assert kern.cache_hit == "disk"
    assert kc.stats.corrupt_plans == 0 and kc.stats.quarantined == 0
