"""Layout conversion between the three value representations the pipeline
backends speak:

* **merged**  — one dense array per program value; the i-th blocked dim of
  its VType splits the i-th array axis (``block[M,D]`` of shape
  ``(M*bm, D*bd)``).  This is the public calling convention of every
  compiled kernel and the layout the Pallas backend consumes directly.
* **stacked** — one leading axis per list level (``(M, D, bm, bd)``), the
  layout ``codegen_jax`` lowers to (vmap/scan axes).
* **nested**  — nested python lists of item arrays, the interpreter's
  native layout (``codegen_py`` backend).

All merged<->stacked conversions are pure reshape/transpose, so they are
jnp-traceable and fuse away under jit.
"""

from __future__ import annotations

from typing import Any, Dict, List, Sequence

import numpy as np

from repro.core.graph import Graph, VType

_ITEM_NDIM = {"block": 2, "vector": 1, "scalar": 0}


def block_shape(merged_shape: Sequence[int], vt: VType,
                dims: Dict[str, int]) -> Dict[str, int]:
    """Infer per-dim block sizes from a merged array's shape."""
    out = {}
    for i, d in enumerate(vt.dims):
        n = dims[d]
        if merged_shape[i] % n:
            raise ValueError(
                f"axis {i} of size {merged_shape[i]} not divisible by "
                f"{n} blocks of dim {d}")
        out[d] = merged_shape[i] // n
    return out


def to_stacked(arr, vt: VType, dims: Dict[str, int]):
    """merged -> stacked: split the first len(dims) axes into
    (count, block) pairs and hoist the counts to the front."""
    n = len(vt.dims)
    if n == 0:
        return arr
    shape: List[int] = []
    for i, d in enumerate(vt.dims):
        c = dims[d]
        if arr.shape[i] % c:
            raise ValueError(
                f"cannot split axis {i} (size {arr.shape[i]}) of {vt!r} "
                f"into {c} blocks")
        shape += [c, arr.shape[i] // c]
    shape += list(arr.shape[n:])
    r = arr.reshape(shape)
    perm = ([2 * i for i in range(n)] + [2 * i + 1 for i in range(n)]
            + list(range(2 * n, r.ndim)))
    return r.transpose(perm)


def from_stacked(arr, vt: VType, dims: Dict[str, int]):
    """stacked -> merged (inverse of ``to_stacked``)."""
    n = len(vt.dims)
    if n == 0:
        return arr
    # axes: [c0..c{n-1}, b0..b{n-1}, rest] -> interleave then merge pairs
    perm: List[int] = []
    for i in range(n):
        perm += [i, n + i]
    perm += list(range(2 * n, arr.ndim))
    r = arr.transpose(perm)
    shape = [r.shape[2 * i] * r.shape[2 * i + 1] for i in range(n)]
    shape += list(r.shape[2 * n:])
    return r.reshape(shape)


def to_nested(arr, vt: VType, dims: Dict[str, int]) -> Any:
    """merged -> nested python lists of numpy item arrays."""
    st = np.asarray(to_stacked(np.asarray(arr), vt, dims))

    def rec(a, depth):
        if depth == 0:
            return a
        return [rec(a[i], depth - 1) for i in range(a.shape[0])]

    return rec(st, len(vt.dims))


def from_nested(val, vt: VType, dims: Dict[str, int]):
    """nested python lists -> merged numpy array."""
    def rec(v, depth):
        if depth == 0:
            return np.asarray(v)
        return np.stack([rec(x, depth - 1) for x in v], axis=0)

    return from_stacked(rec(val, len(vt.dims)), vt, dims)


def output_types(g: Graph) -> List[VType]:
    """VType of each program output (the type at its feeding edge)."""
    types = g.infer_types()
    out = []
    for oid in g.output_ids:
        e = g.in_edge(oid, 0)
        out.append(types[(e.src, e.sp)])
    return out
