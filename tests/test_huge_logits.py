"""Huge-logit differential matrix for the compiled backends.

The graph-level safety pass (``numerics.stabilize``, applied by default
in ``pipeline.compile``) must make every backend agree with the
stabilized interpreter oracle at |logit| ~ 1e4 — far past float32
``exp`` overflow (~88) — across {plain, causal, GQA} attention, with the
fused Pallas snapshot lowering fallback-free as a single launch.  On top
of the matrix: prefill/decode parity through the model layer at large
logits, where the unstabilized kernel would produce NaNs.
"""

import dataclasses

import numpy as np
import pytest

from repro import pipeline
from repro.core import array_program as AP
from repro.core import numerics as NU
from repro.pipeline import packing as P

BACKENDS = ["py", "jax", "pallas"]

H = 4                       # GQA group size
DIMS = {"M": 3, "D": 2, "N": 3, "L": 2}
BLOCKS = {"M": 8, "D": 8, "N": 8, "L": 8, "H": 1}
SCALE = 0.125
# Q entries ~N(0, 2000^2): logits Q@K^T * SCALE land around |1e4|,
# where raw exp overflows by thousands of orders of magnitude
QSCALE = 2000.0


@pytest.fixture()
def cache(tmp_path):
    return pipeline.KernelCache(tmp_path)


def _case(rng, grouped: bool, causal: bool):
    """(program, dims, merged inputs, float64 dense reference)."""
    s_q = DIMS["M"] * BLOCKS["M"]
    s_kv = DIMS["N"] * BLOCKS["N"]
    d = DIMS["D"] * BLOCKS["D"]
    dv = DIMS["L"] * BLOCKS["L"]
    lead = (H,) if grouped else ()
    Q = (rng.normal(size=lead + (s_q, d)) * QSCALE).astype(np.float32)
    K = rng.normal(size=(s_kv, d)).astype(np.float32)
    V = rng.normal(size=(s_kv, dv)).astype(np.float32)
    qp = np.arange(s_q, dtype=np.float32)
    kp = np.arange(s_kv, dtype=np.float32)

    s = Q.astype(np.float64) @ K.T.astype(np.float64)
    if causal:
        s = np.where(qp[:, None] >= kp[None, :], s, -1e30)
    s = s * SCALE
    p = np.exp(s - s.max(-1, keepdims=True))
    ref = (p / p.sum(-1, keepdims=True)) @ V.astype(np.float64)

    if grouped:
        g = AP.gqa_attention_program(SCALE, causal=causal)
    elif causal:
        g = AP.causal_attention_program(SCALE)
    else:
        g = AP.attention_program(SCALE)
    dims = dict(DIMS, **({"H": H} if grouped else {}))
    inputs = {"Q": Q, "KT": K, "VT": V.T}
    if causal:
        inputs.update(QP=qp, KP=kp)
    return g, dims, inputs, ref


def _oracle(g, dims, inputs):
    """Stabilized-interpreter run of the unfused program."""
    nested = {}
    for nid in g.input_ids:
        node = g.nodes[nid]
        nested[node.name] = P.to_nested(inputs[node.name], node.vtype,
                                        dims)
    out = NU.run_stabilized(g, nested, dims)["O"]
    return P.from_nested(out, P.output_types(g)[0], dims)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("variant", ["plain", "causal", "gqa"])
def test_huge_logit_matrix_differential(variant, backend, cache, rng):
    grouped = variant == "gqa"
    causal = variant != "plain"
    g, dims, inputs, ref = _case(rng, grouped, causal)
    kern = pipeline.compile(g, dims, backend=backend, blocks=BLOCKS,
                            cache=cache)
    assert kern.stabilized  # auto-detected, no explicit opt-in
    got = np.asarray(kern(inputs)[kern.out_names[0]])
    assert np.isfinite(got).all(), "stabilized kernel overflowed"
    oracle = _oracle(g, dims, inputs)
    np.testing.assert_allclose(got, oracle, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got, ref, rtol=5e-4, atol=5e-4)
    if backend == "pallas":
        rep = kern.lowering_report
        assert rep.fallbacks == 0, rep.summary()
        assert rep.launches == 1  # fused attention stays one kernel


@pytest.mark.parametrize("group", [True, False],
                         ids=["grouped", "ungrouped"])
def test_huge_logit_pallas_group_modes(group, cache, rng):
    """Both Pallas lowering modes (megakernel groups on/off) stay finite
    and agree with the oracle on the stabilized snapshot."""
    g, dims, inputs, _ = _case(rng, grouped=False, causal=False)
    kern = pipeline.compile(g, dims, backend="pallas", blocks=BLOCKS,
                            cache=cache, group=group)
    assert kern.stabilized
    assert kern.lowering_report.fallbacks == 0
    got = np.asarray(kern(inputs)[kern.out_names[0]])
    assert np.isfinite(got).all()
    oracle = _oracle(g, dims, inputs)
    np.testing.assert_allclose(got, oracle, rtol=2e-4, atol=2e-4)


def test_stabilize_off_overflows_stabilize_on_does_not(cache, rng):
    """The rewrite is what buys the safety: the same program compiled
    with ``stabilize=False`` produces non-finite output where the
    default stays finite."""
    import warnings
    g, dims, inputs, _ = _case(rng, grouped=False, causal=False)
    raw = pipeline.compile(g, dims, backend="jax", cache=cache,
                           stabilize=False)
    assert not raw.stabilized
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        out_raw = np.asarray(raw(inputs)[raw.out_names[0]])
    assert not np.isfinite(out_raw).all()
    stab = pipeline.compile(g, dims, backend="jax", cache=cache)
    assert stab.key != raw.key  # stabilization is part of the cache key
    out = np.asarray(stab(inputs)[stab.out_names[0]])
    assert np.isfinite(out).all()


def test_prefill_decode_parity_at_huge_logits(tmp_path, monkeypatch):
    """Causal prefill and token-by-token decode through the model layer
    agree position by position with inputs scaled so logits reach ~1e4
    (the pre-stabilization kernel NaN'd here)."""
    import jax
    import jax.numpy as jnp

    monkeypatch.setenv("REPRO_KERNEL_CACHE", str(tmp_path))
    pipeline.reset_default_cache()
    from repro.models import layers as L
    from repro.models.common import ModelConfig, ParamBuilder

    n_heads = 4
    cfg = ModelConfig(d_model=64, n_heads=n_heads, n_kv_heads=1,
                      d_head=16, d_ff=128, dtype=jnp.float32,
                      norm_eps=1e-6)
    cfg = dataclasses.replace(cfg, attn_impl="pipeline",
                              pipeline_backend="jax", rope_theta=0.0)
    pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    L.init_attention(pb, cfg)
    p = pb.params
    batch, seq = 2, 8
    # x ~ N(0, 100^2) drives q/k to ~1e2 each: logits ~ 1e4
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, 64),
                          jnp.float32) * 100.0

    prefill = L.attention_apply(p, x, cfg, causal=True)
    assert np.isfinite(np.asarray(prefill)).all()
    cache_kv = L.attention_init_cache(cfg, batch, seq, jnp.float32)
    for pos in range(seq):
        step, cache_kv = L.attention_decode(p, x[:, pos:pos + 1],
                                            cache_kv, pos, cfg)
        np.testing.assert_allclose(np.asarray(step[:, 0]),
                                   np.asarray(prefill[:, pos]),
                                   rtol=2e-3, atol=2e-3)
