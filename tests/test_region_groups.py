"""Region-group megakernels: the grouping pass, the grouped Pallas
lowering, the residency-aware costing, and buffer donation.

Acceptance for the grouped backend: on every in-repo program the
grouped lowering has zero fallbacks and launches *at most* as many
kernels as it has regions — strictly fewer for ``rmsnorm_ffn_swiglu``
and the attention programs, whose cross-region intermediates (exp
scores, softmax denominators, gate activations) stay VMEM-resident —
while remaining bit-comparable to the ungrouped lowering and the
interpreter oracle.
"""

import numpy as np
import pytest

from repro import pipeline
from repro.core import array_program as AP
from repro.core import calibrate as CAL
from repro.core import codegen_pallas as CP
from repro.core import cost as C
from repro.core import regions as R
from repro.core import selection as SEL
from repro.core import timing as T
from repro.core.fusion import fuse
from repro.core.graph import GB, VType
from repro.core.interpreter import run as interp_run
from repro.pipeline import packing as P

PROGRAMS = {
    "layernorm_matmul": (lambda: AP.layernorm_matmul_program(32.0),
                         {"M": 2, "K": 4, "N": 2},
                         {"M": 4, "K": 8, "N": 8}),
    "rmsnorm_ffn_swiglu": (lambda: AP.rmsnorm_ffn_swiglu_program(16.0),
                           {"M": 2, "D": 2, "K": 3, "N": 2},
                           {"M": 4, "D": 8, "K": 4, "N": 4}),
    "attention": (lambda: AP.attention_program(0.125),
                  {"M": 2, "D": 2, "N": 3, "L": 2},
                  {"M": 4, "D": 8, "N": 4, "L": 8}),
    "causal_attention": (lambda: AP.causal_attention_program(0.25),
                         {"M": 2, "D": 2, "N": 2, "L": 2},
                         {"M": 4, "D": 8, "N": 4, "L": 8}),
    "gqa_attention": (lambda: AP.gqa_attention_program(0.25, causal=True),
                      {"H": 2, "M": 2, "D": 2, "N": 2, "L": 2},
                      {"H": 1, "M": 4, "D": 8, "N": 4, "L": 8}),
}

# the programs whose selected snapshot must collapse to strictly fewer
# launches than regions (the tentpole's headline)
MUST_GROUP = ("rmsnorm_ffn_swiglu", "attention", "causal_attention",
              "gqa_attention")


def _merged_inputs(g, dims, blocks, rng):
    out = {}
    for nid in g.input_ids:
        node = g.nodes[nid]
        vt = node.vtype
        item = tuple(blocks[d] for d in vt.dims[vt.lead_dims:])
        shape = P.merged_shape(vt, item, dims)
        if node.name in ("QP", "KP"):
            out[node.name] = np.arange(shape[0], dtype=np.float32)
        else:
            out[node.name] = (rng.normal(size=shape)
                              / max(shape[-1], 1) ** 0.5).astype(np.float32)
    return out


def _selected_plan(name):
    build, dims, blocks = PROGRAMS[name]
    g = build()
    snaps = fuse(g)
    sel = SEL.select(g, dims, snapshots=snaps)
    return g, snaps[sel.snapshot_index], dims, blocks


# ---------------------------------------------------------------------------
# The grouping pass
# ---------------------------------------------------------------------------

def test_group_assignment_deterministic_on_fixed_dag():
    """Same plan, same dims/blocks/budget -> identical groups, member
    order, grids, and ids — selection's costing and the emitter rely on
    re-deriving the identical grouping."""
    _, snap, dims, blocks = _selected_plan("rmsnorm_ffn_swiglu")
    plan = R.plan_program(snap)
    a = R.group_plan(plan, dims, blocks)
    b = R.group_plan(R.plan_program(snap), dims, blocks)
    assert [g.gid for g in a.groups] == [g.gid for g in b.groups]
    assert [[m.node for m in g.members] for g in a.groups] == \
        [[m.node for m in g.members] for g in b.groups]
    assert [g.grid_dims for g in a.groups] == [g.grid_dims for g in b.groups]
    assert [g.resident for g in a.groups] == [g.resident for g in b.groups]


def test_rmsnorm_chain_collapses_to_one_megakernel():
    """The paper's mega-kernel claim on Example 3: three matmuls, a
    reduction, and elementwise stages — three regions with grids (M,),
    (M,K), (M,N) — share one kernel on the common M spine, with both
    intermediates (the inverse-RMS vector and the gated activations)
    VMEM-resident."""
    _, snap, dims, blocks = _selected_plan("rmsnorm_ffn_swiglu")
    plan = R.plan_program(snap)
    assert plan.n_regions == 3
    gp = R.group_plan(plan, dims, blocks)
    assert gp.n_launches == 1
    assert gp.n_resident_edges == 2
    (grp,) = gp.groups
    assert grp.grid_dims == ("M",)
    assert len(grp.members) == 3
    # the only spilled value is the program output
    assert len(grp.out_refs) == 1


def test_sibling_regions_merge_at_equal_grids():
    """Two independent elementwise stages over the same (M, K) grid are
    siblings: no connecting edge, but they still share one kernel."""
    ap = AP.ArrayProgramBuilder()
    a = ap.input("A", ("M", "K"))
    b = ap.input("B", ("M", "K"))
    ap.output("EA", ap.elementwise("exp(a0)", a))
    ap.output("SB", ap.elementwise("a0*a0", b))
    g = ap.build()
    dims = {"M": 2, "K": 2}
    plan = R.plan_program(g)
    assert plan.n_regions == 2
    gp = R.group_plan(plan, dims, {"M": 4, "K": 4})
    assert gp.n_launches == 1
    assert gp.groups[0].grid_dims == ("M", "K")
    # siblings share the launch but spill both outputs: nothing resident
    assert gp.n_resident_edges == 0


def test_sibling_with_smaller_grid_never_shrinks_a_group():
    """An unrelated sibling whose grid is a strict subset must NOT join
    (shrinking the group's grid for it buys no traffic, only VMEM)."""
    ap = AP.ArrayProgramBuilder()
    a = ap.input("A", ("M", "K"))
    b = ap.input("B", ("M", "K"))
    ap.output("EA", ap.elementwise("exp(a0)", a))      # grid (M, K)
    ap.output("RB", ap.reduce_rows(b, "a0"))           # grid (M,), red K
    g = ap.build()
    dims = {"M": 2, "K": 2}
    plan = R.plan_program(g)
    assert plan.n_regions == 2
    assert {spec.grid_dims for spec in plan.regions} == {("M", "K"),
                                                         ("M",)}
    gp = R.group_plan(plan, dims, {"M": 4, "K": 4})
    assert gp.n_launches == 2
    assert {grp.grid_dims for grp in gp.groups} == {("M", "K"), ("M",)}


def test_vmem_budget_gates_grouping():
    """A budget too small for any merge degenerates to one kernel per
    region; the env var steers the default."""
    _, snap, dims, blocks = _selected_plan("rmsnorm_ffn_swiglu")
    plan = R.plan_program(snap)
    gp = R.group_plan(plan, dims, blocks, budget_bytes=1)
    assert gp.n_launches == plan.n_regions
    assert gp.n_resident_edges == 0
    # ungrouped_plan is the same degenerate shape
    up = R.ungrouped_plan(plan)
    assert up.n_launches == plan.n_regions


# ---------------------------------------------------------------------------
# Residency-aware costing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_group_costs_drop_resident_traffic(name):
    """Per-kernel grouped costs: one launch per group, and the resident
    edges' stores/loads are uncharged — so whenever anything grouped,
    the grouped total is strictly below the per-region total."""
    _, snap, dims, blocks = _selected_plan(name)
    plan = R.plan_program(snap)
    gp = R.group_plan(plan, dims, blocks)
    per_region = SEL.region_costs(snap, dims, plan=plan)
    grouped = SEL.region_costs(snap, dims, plan=gp)
    assert grouped is not None and len(grouped) == gp.n_launches
    for grp in gp.groups:
        assert C.group_traffic(grp, dims).launches == 1
    if gp.n_launches < plan.n_regions:
        assert sum(grouped) < sum(per_region)
    else:
        assert sum(grouped) == pytest.approx(sum(per_region))
    # group features mirror group costs term by term, paired by id
    feats = CAL.group_features(snap, dims, blocks)
    assert feats is not None
    assert [gid for gid, _ in feats] == [grp.gid for grp in gp.groups]
    for (gid, f), cost in zip(feats, grouped):
        assert CAL.DEFAULT_PROFILE.predict(f) == pytest.approx(cost)


# ---------------------------------------------------------------------------
# Grouped lowering: differential oracle + launch accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_grouped_vs_ungrouped_differential(name, rng):
    """The grouped and ungrouped lowerings of the selected snapshot both
    match the interpreter oracle; grouped launches fewer kernels (and
    strictly fewer wherever the DAG has compatible regions), with zero
    fallbacks either way."""
    build, dims, blocks = PROGRAMS[name]
    g = build()
    inputs = _merged_inputs(g, dims, blocks, rng)
    nested = {g.nodes[i].name: P.to_nested(inputs[g.nodes[i].name],
                                           g.nodes[i].vtype, dims)
              for i in g.input_ids}
    oracle = interp_run(g, nested, dims)
    out_types = P.output_types(g)

    kerns = {}
    for grouped in (True, False):
        cache = pipeline.KernelCache(disk=False)
        # stabilize=False: stabilized attention selects the fully-fused
        # single-region snapshot (1 launch with or without grouping);
        # this test's subject is the multi-region group scheduler
        kern = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                                cache=cache, group=grouped,
                                stabilize=False)
        rep = kern.lowering_report
        assert rep.fallbacks == 0, rep.summary()
        out = kern(inputs)
        for oid, vt in zip(kern.graph.output_ids, out_types):
            nm = kern.graph.nodes[oid].name
            ref = P.from_nested(oracle[nm], vt, dims)
            np.testing.assert_allclose(
                np.asarray(out[nm]), ref, rtol=2e-4, atol=2e-4,
                err_msg=f"{name} grouped={grouped}")
        kerns[grouped] = kern
    grep, urep = (kerns[True].lowering_report,
                  kerns[False].lowering_report)
    assert urep.launches == urep.n_regions
    assert grep.launches <= urep.launches
    if name in MUST_GROUP:
        assert grep.launches < urep.launches
        assert grep.resident_edges > 0
    # the two lowerings agree with each other too
    for nm in kerns[True].out_names:
        np.testing.assert_allclose(np.asarray(kerns[True](inputs)[nm]),
                                   np.asarray(kerns[False](inputs)[nm]),
                                   rtol=2e-5, atol=2e-5)


def test_grouped_plan_survives_disk_reload(tmp_path):
    """kernel_ids / launches / resident provenance persist in the plan
    cache and re-pair after a fresh-process reload."""
    build, dims, blocks = PROGRAMS["attention"]
    g = build()
    cache = pipeline.KernelCache(root=tmp_path)
    # stabilize=False keeps the multi-region snapshot this test's
    # grouped-vs-ungrouped launch comparison depends on
    k1 = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          cache=cache, stabilize=False)
    assert k1.kernel_ids is not None and len(k1.kernel_ids) >= 1
    cache2 = pipeline.KernelCache(root=tmp_path)
    k2 = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          cache=cache2, stabilize=False)
    assert k2.cache_hit == "disk"
    assert k2.kernel_ids == k1.kernel_ids
    assert k2.region_costs == pytest.approx(k1.region_costs)
    assert k2.lowering_report.launches == k1.lowering_report.launches
    assert (k2.lowering_report.resident_edges
            == k1.lowering_report.resident_edges)
    # grouped vs ungrouped key separately: no stale cross-serving
    k3 = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          cache=cache2, group=False, stabilize=False)
    assert k3.key != k2.key
    assert k3.lowering_report.launches > k2.lowering_report.launches


# ---------------------------------------------------------------------------
# Buffer donation (input_output_aliases on dying intermediates)
# ---------------------------------------------------------------------------

def test_alias_map_donates_dying_intermediates():
    x = np.zeros((8, 8), np.float32)
    y = np.zeros((4, 4), np.float32)
    # only donatable inputs alias, first-fit by shape, each output once
    aliases = CP._alias_map([x, y, x], [(8, 8), (4, 4)], np.float32,
                            [True, True, True])
    assert aliases == {0: 0, 1: 1}
    assert CP._alias_map([x, y], [(8, 8)], np.float32, [False, True]) == {}
    assert CP._alias_map([x], [(8, 8)], np.float64, [True]) == {}
    # a shape match with a DIFFERENT block layout (=> different index
    # map) must not alias: earlier grid steps could clobber blocks a
    # later step still reads
    lin = [(("M", "K"), (4, 4))]
    lout_same, lout_diff = [(("M", "K"), (4, 4))], [(("M", "N"), (4, 4))]
    assert CP._alias_map([x], [(8, 8)], np.float32, [True],
                         lin, lout_same) == {0: 0}
    assert CP._alias_map([x], [(8, 8)], np.float32, [True],
                         lin, lout_diff) == {}


def test_degraded_group_reconciles_cost_provenance(monkeypatch, rng):
    """If emit_group cannot express a planned group, emission degrades
    to per-region kernels — and the recorded provenance (kernel_ids,
    region_costs, launches) must describe the kernels that actually
    run, not the planned megakernel."""
    build, dims, blocks = PROGRAMS["rmsnorm_ffn_swiglu"]
    g = build()

    def boom(*a, **k):
        raise CP.RegionError("forced for the degradation test")

    monkeypatch.setattr(CP, "emit_group", boom)
    cache = pipeline.KernelCache(disk=False)
    with pytest.warns(RuntimeWarning, match="fell back to per-region"):
        kern = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                                cache=cache)
    rep = kern.lowering_report
    assert rep.launches == 3 and rep.fallbacks == 0
    assert len(kern.kernel_ids) == 3
    assert len(kern.region_costs) == 3
    # degraded kernels pay the full per-region traffic: no phantom
    # residency savings in the recorded costs
    per_region = SEL.region_costs(kern.graph, dims)
    assert sum(kern.region_costs) == pytest.approx(sum(per_region))
    # id-based pairing covers every actually-emitted kernel
    inputs = _merged_inputs(g, dims, blocks, rng)
    rts = T.region_times(kern, inputs, warmup=1, repeats=2)
    assert len(T.pair_region_times(kern, rts)) == 3
    out = kern(inputs)
    assert set(out) == {"O"}


def test_vmem_budget_is_part_of_the_cache_key(tmp_path, monkeypatch):
    """A plan cached under one VMEM budget must not serve another: the
    grouping (kernel_ids, launches) would describe kernels the lowering
    no longer emits."""
    build, dims, blocks = PROGRAMS["rmsnorm_ffn_swiglu"]
    g = build()
    cache = pipeline.KernelCache(root=tmp_path)
    k1 = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          cache=cache)
    assert k1.lowering_report.launches == 1
    monkeypatch.setenv(R.VMEM_BUDGET_ENV, "1")
    cache2 = pipeline.KernelCache(root=tmp_path)
    k2 = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          cache=cache2)
    assert k2.cache_hit is None  # not served the stale grouped plan
    assert k2.lowering_report.launches == 3
    assert len(k2.kernel_ids) == 3
    assert len(k2.region_costs) == 3


def test_donated_spilled_edges_stay_correct(rng):
    """With grouping forced off, cross-kernel intermediates spill to
    global arrays whose last consumer donates them; repeated calls on
    the same inputs stay correct (XLA copies when a donated buffer is
    still live)."""
    build, dims, blocks = PROGRAMS["rmsnorm_ffn_swiglu"]
    g = build()
    cache = pipeline.KernelCache(disk=False)
    kern = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                            cache=cache, group=False)
    assert kern.lowering_report.launches == 3  # spilled edges exist
    inputs = _merged_inputs(g, dims, blocks, rng)
    first = np.asarray(kern(inputs)["O"]).copy()
    again = np.asarray(kern(inputs)["O"])
    np.testing.assert_array_equal(first, again)


# ---------------------------------------------------------------------------
# Slow tier: grouped lowering is never slower than per-region
# ---------------------------------------------------------------------------

# bench-scale shapes: at toy grids the per-cell interpret overhead, not
# the launch count, dominates — the launch/traffic win needs real tile
# counts to show (at these dims grouped wins 1.1-3.5x on CPU interpret)
PERF_DIMS = {
    "attention": {"M": 8, "D": 4, "N": 16, "L": 4},
    "causal_attention": {"M": 16, "D": 4, "N": 16, "L": 4},
    "gqa_attention": {"H": 2, "M": 8, "D": 4, "N": 8, "L": 4},
    "rmsnorm_ffn_swiglu": {"M": 8, "D": 8, "K": 16, "N": 8},
}


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(MUST_GROUP))
def test_grouped_not_slower_than_per_region(name):
    """Fewer launches must not cost wall time: at bench-scale shapes the
    grouped kernel's median call time stays within noise (1.15x) of the
    per-region schedule on every program that actually grouped."""
    build, _, _ = PROGRAMS[name]
    dims = PERF_DIMS[name]
    g = build()
    blocks = T.synth_blocks(g, dims, item=16)
    inputs = T.synth_inputs(g, dims, blocks, seed=0)
    cache = pipeline.KernelCache(disk=False)
    kg = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          cache=cache, group=True, stabilize=False)
    ku = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          cache=cache, group=False, stabilize=False)
    assert kg.lowering_report.launches < ku.lowering_report.launches
    tg = T.time_callable(kg, inputs, warmup=2, repeats=5).median_s
    tu = T.time_callable(ku, inputs, warmup=2, repeats=5).median_s
    assert tg <= tu * 1.15, (tg, tu)
