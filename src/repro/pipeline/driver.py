"""The end-to-end compile driver: array/block program -> fusion ->
snapshot + block-shape selection -> backend codegen -> cached callable.

    kern = pipeline.compile(AP.attention_program(0.125),
                            dims={"M": 2, "D": 2, "N": 4, "L": 2},
                            backend="jax")
    out = kern({"Q": Q, "KT": K, "VT": V.T})["O"]

Backends:

* ``"py"``     — the reference interpreter (``codegen_py.compile_py``);
                 slow, numpy-level, the differential oracle.
* ``"jax"``    — ``codegen_jax.compile_program`` under ``jax.jit``
                 (vmap/scan lowering; runs everywhere, differentiable).
* ``"pallas"`` — ``codegen_pallas.emit_program``: the selected snapshot
                 is partitioned into spine regions and lowered to one
                 real multi-output ``pallas_call`` per region
                 (interpret-mode off-TPU); fully fused snapshots are a
                 single mega-kernel.  Requires ``blocks`` (per-dim block
                 sizes).  ``CompiledKernel.lowering_report`` records the
                 regions emitted and fallbacks taken (zero for every
                 in-repo program — there is no walk-back to a
                 differently-fused snapshot: what selection picked is
                 what runs).

Every compiled kernel takes and returns **merged dense arrays** keyed by
program input/output names, so all three backends are drop-in
interchangeable — that is what the differential test harness exploits.

Results are memoized in a two-level :class:`KernelCache` keyed by
``(Graph.fingerprint(), dims, backend, blocks, fused)`` plus the
``cache.CODEGEN_VERSION`` salt (on-disk plans written by an older
fusion/selection/codegen build are never loaded): in-process hits return
the existing jitted callable; on-disk hits skip fusion + selection and
only re-lower.
"""

from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, replace
from math import lcm
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import calibrate as CAL
from repro.core import selection as SEL
from repro.core.fusion import FusionTrace, fuse
from repro.core.graph import Graph
from repro.pipeline import packing as P
from repro.pipeline.cache import (CacheKey, CachePlan, KernelCache,
                                  default_cache)

BACKENDS = ("py", "jax", "pallas")
AUTOTUNE_OBJECTIVES = ("analytic", "measured")


@dataclass
class CompiledKernel:
    """A ready-to-run fused kernel plus its compilation provenance."""

    key: CacheKey
    backend: str
    graph: Graph                      # the selected snapshot
    dims: Dict[str, int]
    blocks: Optional[Dict[str, int]]
    snapshot_index: int
    cost: float                       # predicted traffic cost (selected)
    initial_cost: float               # same model on the unfused program
    cache_hit: Optional[str]          # None | "memory" | "disk"
    in_names: List[str]
    out_names: List[str]
    _fn: Callable[[Dict[str, Any]], Dict[str, Any]] = None  # type: ignore
    # pallas backend only: regions emitted / fallbacks taken (see
    # codegen_pallas.LoweringReport) and the cost model's per-region
    # traffic attribution of the selected snapshot
    lowering_report: Optional[Any] = None
    region_costs: Optional[Tuple[float, ...]] = None
    # autotune="measured" only: the winner's wall seconds and every
    # (dims, seconds) candidate the autotuner timed (the analytic choice
    # is always among them)
    measured_s: Optional[float] = None
    autotune_timings: Optional[Tuple] = None

    def __call__(self, inputs: Dict[str, Any]) -> Dict[str, Any]:
        missing = [n for n in self.in_names if n not in inputs]
        if missing:
            raise KeyError(f"missing kernel inputs {missing}; "
                           f"expected {self.in_names}")
        return self._fn(inputs)

    @property
    def predicted_traffic_reduction(self) -> float:
        return self.initial_cost / max(self.cost, 1e-30)


def _io_info(g: Graph):
    in_info = [(g.nodes[i].name, g.nodes[i].vtype) for i in g.input_ids]
    out_info = [(g.nodes[o].name, vt)
                for o, vt in zip(g.output_ids, P.output_types(g))]
    return in_info, out_info


def _lower_py(g: Graph, dims: Dict[str, int]):
    from repro.core.codegen_py import compile_py
    in_info, out_info = _io_info(g)
    prog = compile_py(g, dims)

    def call(inputs: Dict[str, Any]) -> Dict[str, Any]:
        nested = {nm: P.to_nested(np.asarray(inputs[nm]), vt, dims)
                  for nm, vt in in_info}
        outs = prog(nested)
        return {nm: P.from_nested(outs[nm], vt, dims)
                for nm, vt in out_info}

    return call


def _lower_jax(g: Graph, dims: Dict[str, int], jit: bool):
    import jax
    from repro.core.codegen_jax import compile_program
    in_info, out_info = _io_info(g)
    prog = compile_program(g)

    def fn(*merged):
        stacked = [P.to_stacked(a, vt, dims)
                   for (_, vt), a in zip(in_info, merged)]
        outs = prog(*stacked)
        return tuple(P.from_stacked(o, vt, dims)
                     for (_, vt), o in zip(out_info, outs))

    if jit:
        fn = jax.jit(fn)

    def call(inputs: Dict[str, Any]) -> Dict[str, Any]:
        outs = fn(*[inputs[nm] for nm, _ in in_info])
        return {nm: o for (nm, _), o in zip(out_info, outs)}

    return call


def _region_plan(g: Graph):
    """Partition the selected snapshot once; the plan is shared between
    per-region cost attribution and the Pallas lowering.  ``None`` when
    the partitioner cannot split (emit_program then takes the
    whole-program fallback)."""
    from repro.core import regions as REG
    try:
        return REG.plan_program(g)
    except REG.RegionError:
        return None


def _lower_pallas(g: Graph, dims: Dict[str, int],
                  blocks: Optional[Dict[str, int]], interpret: bool,
                  program_plan=None):
    """Lower the selected snapshot itself — no walking back to a
    differently-fused candidate.  Returns (call, LoweringReport)."""
    from repro.core.codegen_pallas import emit_program
    if blocks is None:
        raise ValueError(
            "backend='pallas' needs per-dim block sizes: pass blocks=")
    missing = [d for d in dims if d not in blocks]
    if missing:
        raise ValueError(f"blocks missing sizes for dims {missing}")
    f, report = emit_program(g, dims, blocks, interpret=interpret,
                             program_plan=program_plan)
    if report.fallbacks:
        warnings.warn(
            "pallas lowering fallback: "
            f"{report.fallbacks}/{report.n_regions} regions ran on the "
            f"jax backend ({report.summary()})", RuntimeWarning,
            stacklevel=3)
    in_info, out_info = _io_info(g)

    def call(inputs: Dict[str, Any]) -> Dict[str, Any]:
        outs = f(*[inputs[nm] for nm, _ in in_info])
        return {nm: o for (nm, _), o in zip(out_info, outs)}

    # the raw emit_program callable carries the per-region runners the
    # timing harness (core/timing.region_times) needs
    call.raw_program = f
    return call, report


def _measure_harness(graph: Graph,
                     dim_candidates: Dict[str, Sequence[int]], *,
                     backend: str, blocks: Optional[Dict[str, int]],
                     interpret, jit: bool,
                     item_bytes: Optional[Dict[str, int]],
                     profile, fused: bool, cache: KernelCache,
                     repeats: int) -> Callable:
    """The ``measure`` callback ``selection.autotune(objective=
    "measured")`` calls for each top-K survivor: compile the candidate
    through this same driver (so the in-process kernel cache absorbs
    repeats) and time it end-to-end on synthetic inputs.

    Every candidate runs the SAME total problem: per dim the total
    extent is a base block extent (the caller's ``blocks``, else 8;
    1 for stack dims) times the lcm of the candidate counts, and each
    candidate's block extent is ``total // count`` — varying the block
    *count* at fixed problem size, which is the choice the paper's
    selector owns.  Measurements are memoized process-wide
    (``timing.measured``) keyed by (fingerprint, dims, backend, device,
    totals), so re-sweeps never re-time a configuration."""
    from repro.core import timing as T
    sd = T.stack_dims(graph)
    base = {d: (1 if d in sd else (blocks or {}).get(d, 8))
            for d in dim_candidates}
    total = {d: base[d] * lcm(*{int(c) for c in dim_candidates[d]})
             for d in dim_candidates}
    dev = CAL.device_kind()
    fp = graph.fingerprint()
    kernels: Dict[Tuple, CompiledKernel] = {}

    def measure(sel) -> float:
        cand_blocks = {d: total[d] // sel.dims[d] for d in sel.dims}
        bad = [d for d in sd
               if d in cand_blocks and cand_blocks[d] != 1]
        if bad:
            raise ValueError(
                f"stack dims {bad} need equal candidate counts (block "
                "size is pinned to 1)")
        dkey = tuple(sorted(sel.dims.items()))
        # everything the wall time depends on is in the memo key —
        # notably interpret mode (orders of magnitude slower) and the
        # repeat count
        mkey = (fp, dkey, backend, dev, tuple(sorted(total.items())),
                bool(jit), fused, interpret, repeats)

        def thunk() -> float:
            kern = compile(graph, dict(sel.dims), backend=backend,
                           blocks=(cand_blocks if backend == "pallas"
                                   else blocks),
                           item_bytes=item_bytes, fused=fused,
                           interpret=interpret, jit=jit, profile=profile,
                           cache=cache)
            kernels[dkey] = kern
            inputs = T.synth_inputs(graph, sel.dims, cand_blocks)
            return T.time_callable(kern, inputs, warmup=1,
                                   repeats=repeats).median_s

        return T.measured(mkey, thunk)

    measure.kernels = kernels
    return measure


def compile(graph: Graph, dims: Optional[Dict[str, int]] = None, *,
            backend: str = "jax",
            blocks: Optional[Dict[str, int]] = None,
            dim_candidates: Optional[Dict[str, Sequence[int]]] = None,
            item_bytes: Optional[Dict[str, int]] = None,
            fused: bool = True,
            interpret=None,
            jit: bool = True,
            cache: Optional[KernelCache] = None,
            autotune: str = "analytic",
            profile: Optional[CAL.CalibrationProfile] = None,
            top_k: int = 3,
            measure_repeats: int = 3) -> CompiledKernel:
    """Compile a block program into an executing, cached kernel.

    Either ``dims`` (fixed block counts -> ``selection.select``) or
    ``dim_candidates`` (a per-dim sweep -> ``selection.autotune``, which
    also picks the dims) must be given.  ``fused=False`` skips the fusion
    algorithm — the unfused Table-2 program compiles as-is; that is the
    benchmark baseline.

    ``autotune="measured"`` (with ``dim_candidates``) closes the
    predict -> run -> measure loop: the calibrated analytic model prunes
    the sweep, the ``top_k`` cheapest distinct candidates are compiled
    and *timed* (median of ``measure_repeats`` fenced calls on synthetic
    inputs at a fixed total problem size), and the wall-clock winner is
    what lowers, caches, and re-loads.  ``profile`` overrides the
    calibration profile; by default the measured path loads the one
    fitted for this (backend, device) from the cache dir if a
    calibration run saved one — ``benchmarks/run.py --only pipeline``
    fits a ``backend="pallas"`` profile from per-region timings; other
    backends keep the default constants until calibrated (see
    ``core/calibrate.py``).  The analytic path always keeps the
    deterministic defaults.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if dims is None and dim_candidates is None:
        raise ValueError("pass dims= (fixed) or dim_candidates= (autotune)")
    if autotune not in AUTOTUNE_OBJECTIVES:
        raise ValueError(f"unknown autotune objective {autotune!r}; "
                         f"one of {AUTOTUNE_OBJECTIVES}")
    if autotune == "measured" and dim_candidates is None:
        raise ValueError("autotune='measured' needs dim_candidates=")
    cache = cache if cache is not None else default_cache()
    if profile is None and autotune == "measured":
        # the measured path runs under the calibrated cost model fitted
        # for this backend+device (default constants if none saved)
        profile = CAL.load_or_default(cache.root, backend=backend,
                                      device_kind=CAL.device_kind())

    # autotune keys embed the full candidate sweep, so two sweeps over the
    # same dim names but different candidate sets never collide
    key_dims = (dims if dims is not None
                else {k: tuple(v) for k, v in dim_candidates.items()})
    # every option that changes the emitted kernel or the selection plan
    # is part of the key, else a later compile is served a stale kernel
    opts: tuple = ()
    if backend == "jax":
        opts += (("jit", bool(jit)),)
    if backend == "pallas":
        from repro.core.codegen_pallas import resolve_interpret
        interpret = resolve_interpret(interpret)
        opts += (("interpret", interpret),)
    if item_bytes:
        opts += (("item_bytes", tuple(sorted(item_bytes.items()))),)
    if dim_candidates is not None and autotune != "analytic":
        opts += (("autotune", autotune),)
    if (profile is not None
            and profile.digest() != CAL.DEFAULT_PROFILE.digest()):
        # a different calibration profile can select a different
        # snapshot/dims: never serve its plan under the default's key
        opts += (("profile", profile.digest()),)
    key = CacheKey.make(graph.fingerprint(), backend, key_dims, blocks,
                        fused, opts)
    hit = cache.get_kernel(key)
    if hit is not None:
        return replace(hit, cache_hit="memory")

    plan, selected_graph = cache.get_plan(key)
    snaps: Optional[List[Graph]] = None
    pplan = None  # shared region partition (pallas cache-miss path)
    timings = None
    measure = None
    if plan is None:
        # -- the full pipeline: fuse -> select/autotune --------------------
        if fused:
            trace = FusionTrace()
            snaps = fuse(graph, trace)
        else:
            snaps = [graph.clone()]
        if dim_candidates is not None:
            if autotune == "measured":
                measure = _measure_harness(
                    graph, dim_candidates, backend=backend, blocks=blocks,
                    interpret=interpret, jit=jit, item_bytes=item_bytes,
                    profile=profile, fused=fused, cache=cache,
                    repeats=measure_repeats)
                sel = SEL.autotune(graph, dim_candidates, item_bytes,
                                   snapshots=snaps, objective="measured",
                                   profile=profile, measure=measure,
                                   top_k=top_k)
                timings = sel.timings
            else:
                sel = SEL.autotune(graph, dim_candidates, item_bytes,
                                   snapshots=snaps, profile=profile)
        else:
            sel = SEL.select(graph, dims, item_bytes, snapshots=snaps,
                             profile=profile)
        selected_graph = snaps[sel.snapshot_index]
        # per-region traffic attribution of the snapshot that will run
        # (pallas partitions it into one kernel per region; the same
        # plan is reused by the lowering below)
        rcosts = None
        if backend == "pallas":
            pplan = _region_plan(selected_graph)
            rcosts = (SEL.region_costs(selected_graph, sel.dims,
                                       item_bytes, plan=pplan,
                                       profile=profile)
                      if pplan is not None else None)
        plan = CachePlan(sel.snapshot_index, sel.dims, sel.cost,
                         sel.costs, SEL.snapshot_cost(graph, sel.dims,
                                                      item_bytes, profile),
                         region_costs=rcosts, measured_s=sel.measured_s)
        cache.put_plan(key, plan, selected_graph)
        cache_hit = None
    else:
        cache_hit = "disk"
        if selected_graph is None:
            # plan-only disk entry (un-picklable graph): re-fuse
            snaps = fuse(graph) if fused else [graph.clone()]
            selected_graph = snaps[plan.snapshot_index]

    use_dims = plan.dims

    # -- backend lowering: the selected snapshot, nothing else --------------
    # the measured sweep already compiled its candidates through this
    # driver; if the winner's kernel is lowering-identical to what we
    # would emit (same backend, and for pallas the same block extents),
    # reuse it instead of recompiling the same plan
    fn = report = None
    if measure is not None:
        cand = measure.kernels.get(tuple(sorted(use_dims.items())))
        if cand is not None and (
                backend != "pallas"
                or cand.blocks == (dict(blocks) if blocks else None)):
            fn, report = cand._fn, cand.lowering_report
    if fn is not None:
        pass
    elif backend == "py":
        fn = _lower_py(selected_graph, use_dims)
    elif backend == "jax":
        fn = _lower_jax(selected_graph, use_dims, jit)
    else:
        fn, report = _lower_pallas(selected_graph, use_dims, blocks,
                                   interpret, program_plan=pplan)

    in_info, out_info = _io_info(selected_graph)
    kern = CompiledKernel(
        key=key, backend=backend, graph=selected_graph, dims=dict(use_dims),
        blocks=dict(blocks) if blocks else None,
        snapshot_index=plan.snapshot_index, cost=plan.cost,
        initial_cost=plan.initial_cost, cache_hit=cache_hit,
        in_names=[n for n, _ in in_info],
        out_names=[n for n, _ in out_info], _fn=fn,
        lowering_report=report, region_costs=plan.region_costs,
        measured_s=plan.measured_s, autotune_timings=timings)
    cache.put_kernel(key, kern)
    return kern
