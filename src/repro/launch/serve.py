"""Continuous-batching LM serving on pipeline megakernels.

Programmatic API::

    from repro.launch.serve import ServeConfig, run
    report = run(ServeConfig(arch="smollm-135m", n_requests=16))

``ServeConfig`` describes the whole run (model, scheduler shape,
synthetic open-loop trace, sampling); ``run`` builds the engine,
replays the trace and returns a :class:`~repro.launch.engine.ServeReport`
(tokens/sec, p50/p99 per-token latency, occupancy, kernel-cache hit
rate, zero-recompile proof).  The CLI is a thin argparse veneer::

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --n-requests 16 --sampling greedy --json report.json

Sampling is ``--sampling {greedy,categorical}`` (+ ``--temperature``);
the old ``--greedy`` store-true flag defaulted to True and therefore
could never be disabled — replaced by the explicit choice.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.launch.engine import Engine, ServeReport, synth_trace


@dataclass(frozen=True)
class ServeConfig:
    """Everything a serving run needs — model, scheduler, trace, sampling."""
    arch: str = "smollm-135m"
    reduced: bool = True
    backend: str = "pallas"      # pipeline codegen backend for the kernels
    dtype: str = "float32"
    # -- scheduler ----------------------------------------------------------
    max_batch: int = 4
    max_len: int = 96
    prompt_buckets: Tuple[int, ...] = (8, 16, 32)
    max_queue: Optional[int] = None  # bounded admission; None = unbounded
    # -- synthetic open-loop trace -------------------------------------------
    n_requests: int = 16
    arrival_rate: float = 1.0    # requests per engine step
    prompt_lens: Tuple[int, int] = (4, 24)
    gen_lens: Tuple[int, int] = (4, 16)
    # -- sampling -----------------------------------------------------------
    sampling: str = "greedy"     # greedy | categorical
    temperature: float = 1.0
    seed: int = 0
    # -- run ----------------------------------------------------------------
    max_steps: Optional[int] = None
    keep_per_step: bool = True
    strict_no_recompile: bool = True
    # -- self-healing -------------------------------------------------------
    # clean decode ticks on a demoted rung before a half-open probe may
    # re-promote the original; None disables re-promotion
    repromote_after: Optional[int] = 8


def build_engine(cfg: ServeConfig) -> Engine:
    """The configured engine (kernels not yet compiled — call
    ``warmup()`` or let ``Engine.run`` do it)."""
    import jax.numpy as jnp

    from repro import configs, pipeline

    options = pipeline.CompileOptions(backend=cfg.backend)
    mc = (configs.get_reduced_config(cfg.arch)
          if cfg.reduced else configs.get_config(cfg.arch))
    mc = dataclasses.replace(mc, dtype=getattr(jnp, cfg.dtype))
    mc = configs.with_pipeline(mc, options=options)
    return Engine(mc, max_batch=cfg.max_batch, max_len=cfg.max_len,
                  prompt_buckets=cfg.prompt_buckets,
                  sampling=cfg.sampling, temperature=cfg.temperature,
                  seed=cfg.seed, keep_per_step=cfg.keep_per_step,
                  strict_no_recompile=cfg.strict_no_recompile,
                  max_queue=cfg.max_queue,
                  repromote_after=cfg.repromote_after)


def run(cfg: ServeConfig) -> ServeReport:
    """Build the engine, warm the kernel set, replay the trace."""
    engine = build_engine(cfg)
    trace = synth_trace(cfg.n_requests, seed=cfg.seed,
                        arrival_rate=cfg.arrival_rate,
                        prompt_lens=cfg.prompt_lens,
                        gen_lens=cfg.gen_lens,
                        vocab=engine.cfg.vocab)
    engine.warmup()
    return engine.run(trace, max_steps=cfg.max_steps)


def main(argv=None) -> ServeReport:
    ap = argparse.ArgumentParser(
        description="continuous-batching serving on pipeline megakernels")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced)")
    ap.add_argument("--backend", default="pallas",
                    choices=("py", "jax", "pallas"))
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--buckets", type=int, nargs="+", default=[8, 16, 32])
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission: reject arrivals past this "
                         "queue depth (default: unbounded)")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=1.0)
    ap.add_argument("--prompt-lens", type=int, nargs=2, default=[4, 24])
    ap.add_argument("--gen-lens", type=int, nargs=2, default=[4, 16])
    ap.add_argument("--sampling", default="greedy",
                    choices=("greedy", "categorical"))
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-steps", type=int, default=None)
    ap.add_argument("--repromote-after", type=int, default=8,
                    help="clean decode ticks on a demoted rung before a "
                         "half-open probe may re-promote the original "
                         "(0 disables re-promotion)")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the full ServeReport as JSON")
    args = ap.parse_args(argv)

    cfg = ServeConfig(arch=args.arch, reduced=not args.full,
                      backend=args.backend, max_batch=args.max_batch,
                      max_len=args.max_len,
                      prompt_buckets=tuple(args.buckets),
                      max_queue=args.max_queue,
                      n_requests=args.n_requests,
                      arrival_rate=args.arrival_rate,
                      prompt_lens=tuple(args.prompt_lens),
                      gen_lens=tuple(args.gen_lens),
                      sampling=args.sampling,
                      temperature=args.temperature, seed=args.seed,
                      max_steps=args.max_steps,
                      repromote_after=(args.repromote_after
                                       if args.repromote_after > 0
                                       else None))
    report = run(cfg)
    print(f"arch={args.arch} backend={args.backend} "
          f"requests={report.n_completed}/{report.n_requests} "
          f"steps={report.steps} tokens={report.decode_tokens} "
          f"({report.tokens_per_s:.1f} tok/s incl. prefill) "
          f"p50={report.p50_token_ms:.2f}ms p99={report.p99_token_ms:.2f}ms "
          f"occupancy={report.mean_occupancy:.2f} "
          f"cache_hit_rate={report.cache_hit_rate:.3f} "
          f"recompiles={report.decode_recompiles} "
          f"repromotions={report.repromotions}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report.to_json(), f, indent=1)
    return report


if __name__ == "__main__":
    main()
