"""Batched serving example: prefill + cached greedy decode for any of the
10 assigned architectures (reduced configs on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b
    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v3-671b
"""

import argparse

from repro.launch import serve as S

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    S.main(["--arch", args.arch, "--reduced", "--batch", "4",
            "--prompt-len", "16", "--gen", str(args.gen)])
