"""Measured calibration of the traffic cost model.

The selection cost model prices a snapshot as

    cost = sum_kind item_coef[kind] * (loads + stores)[kind]
           + launch_coef * launches

Until this module existed the coefficient vector was a pair of magic
constants in ``core/selection.py`` (the byte size of a 128x128 f32 block
and a guessed launch overhead).  :class:`CalibrationProfile` makes it a
first-class value: the **default** profile reproduces those constants
exactly (single source of truth — selection re-exports them from here),
and :func:`fit_profile` learns a measured replacement by least-squares
over (traffic features, wall seconds) pairs collected from per-region
kernel timings (``core/timing.py`` pairs each emitted kernel's wall time
with its ``selection.region_costs`` entry).  This is the same
measure-then-model loop AutoTVM and Triton's autotuner close: the
analytic proxy prunes, measurements recalibrate the proxy.

Profiles persist as JSON per ``(backend, device_kind)`` under the kernel
cache dir (``<cache>/calibration/``) so one calibration run serves later
processes; :func:`load_profile` falls back to the default — with a
warning — on a stale or corrupt file.

No jax imports at module level: selection (pure graph math) depends on
this module, and jax is only needed to ask the device kind.
"""

from __future__ import annotations

import json
import os
import re
import warnings
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import cost as C
from repro.core.graph import Graph

# the item kinds the block substrate produces; extra kinds found in a
# program's traffic are appended to the fit on the fly
ITEM_KINDS = ("block", "vector", "scalar")

# schema 2 adds per-op-class work coefficients and per-dtype item-coef
# scales; schema-1 files (pre-work-feature) are repaired on load with
# zero work coefficients and a warning
PROFILE_SCHEMA = 2

# the historical magic constants (representative 128x128 f32 blocks and a
# bytes-equivalent launch overhead).  These are the *definition* of the
# default profile; ``selection.DEFAULT_ITEM_BYTES`` / ``KERNEL_LAUNCH_COST``
# are re-exports.
DEFAULT_ITEM_BYTES: Dict[str, float] = {"block": 128 * 128 * 4,
                                        "vector": 128 * 4, "scalar": 4}
KERNEL_LAUNCH_COST = 1e5

# compute term: one coefficient per ``cost.WORK_CLASSES`` class, priced
# per estimated FLOP (``Traffic.flops``).  Zero by default so the default
# profile reproduces the paper's traffic-only objective bit-identically.
WORK_CLASSES = C.WORK_CLASSES
DEFAULT_WORK_COEF: Dict[str, float] = {c: 0.0 for c in WORK_CLASSES}
WORK_FEATURES = tuple("work_" + c for c in WORK_CLASSES)

# per-dtype scale on the item coefficients: a bf16 block moves half the
# bytes of the f32 block the default coefficients price, int8/fp8 a
# quarter.  f32 is the identity so untouched call sites are unchanged.
DEFAULT_DTYPE_SCALE: Dict[str, float] = {"f32": 1.0, "bf16": 0.5,
                                         "f16": 0.5, "int8": 0.25,
                                         "fp8": 0.25}


@dataclass(frozen=True)
class CalibrationProfile:
    """Coefficients of the selection cost model.

    ``item_coef[kind]`` is the cost of moving one item of that kind and
    ``launch_coef`` the cost of one kernel launch.  Units are whatever
    the profile was fitted in — bytes-equivalent for the default,
    seconds for a measured fit; selection only ranks, so units cancel.
    """

    item_coef: Mapping[str, float]
    launch_coef: float
    backend: str = "any"
    device_kind: str = "any"
    source: str = "default"       # "default" | "measured" | "item_bytes"
    n_samples: int = 0
    residual: float = 0.0         # rms relative residual of the fit
    work_coef: Mapping[str, float] = field(
        default_factory=lambda: dict(DEFAULT_WORK_COEF))
    dtype_scale: Mapping[str, float] = field(
        default_factory=lambda: dict(DEFAULT_DTYPE_SCALE))
    # per-grid-cell dispatch overhead (kernel program instances); zero
    # in the default profile so the historical formula is untouched
    instance_coef: float = 0.0

    def item_coef_for(self, dtype: Optional[str] = None
                      ) -> Mapping[str, float]:
        """Item coefficients scaled for ``dtype`` (f32/None: identity —
        the same mapping object, so the default path is unchanged)."""
        if dtype is None or dtype == "f32":
            return self.item_coef
        s = float(self.dtype_scale.get(dtype, 1.0))
        return {k: v * s for k, v in self.item_coef.items()}

    def work_cost(self, t: C.Traffic) -> float:
        """The compute + per-instance term: work coefficients dotted
        with the per-class FLOP features, plus the grid-cell dispatch
        overhead.  Zero for the default profile."""
        tot = self.instance_coef * t.instances
        if any(self.work_coef.values()):
            fl = t.flops()
            tot += sum(self.work_coef.get(c, 0.0) * v
                       for c, v in fl.items())
        return tot

    def cost(self, t: C.Traffic, dtype: Optional[str] = None) -> float:
        base = (t.bytes_moved(self.item_coef_for(dtype))
                + self.launch_coef * t.launches)
        w = self.work_cost(t)
        # skip the add when the compute term is zero so the default
        # (all-zero work_coef) profile stays bit-identical to the
        # pre-work-feature formula
        return base + w if w else base

    def predict(self, features: Mapping[str, float]) -> float:
        """Cost of a :func:`traffic_features` row — identical to
        :meth:`cost` on the traffic it was derived from.  ``work_*``
        keys are priced by ``work_coef``, ``instances`` by
        ``instance_coef``, everything but ``launches`` by
        ``item_coef``."""
        tot = self.launch_coef * features.get("launches", 0.0)
        tot += self.instance_coef * features.get("instances", 0.0)
        for k, v in features.items():
            if k in ("launches", "instances"):
                continue
            if k.startswith("work_"):
                tot += self.work_coef.get(k[len("work_"):], 0.0) * v
            else:
                tot += self.item_coef.get(k, 0.0) * v
        return tot

    def digest(self) -> str:
        """Short stable hash — cache keys embed it so a kernel selected
        under one profile is never served for another."""
        import hashlib
        raw = json.dumps([sorted(self.item_coef.items()),
                          self.launch_coef,
                          sorted(self.work_coef.items()),
                          sorted(self.dtype_scale.items()),
                          self.instance_coef])
        return hashlib.sha256(raw.encode()).hexdigest()[:12]

    def to_json(self) -> Dict:
        return {"schema": PROFILE_SCHEMA,
                "item_coef": dict(self.item_coef),
                "launch_coef": self.launch_coef,
                "backend": self.backend,
                "device_kind": self.device_kind,
                "source": self.source,
                "n_samples": self.n_samples,
                "residual": self.residual,
                "work_coef": dict(self.work_coef),
                "dtype_scale": dict(self.dtype_scale),
                "instance_coef": self.instance_coef}

    @classmethod
    def from_json(cls, d: Dict) -> "CalibrationProfile":
        schema = d.get("schema")
        if schema not in (1, PROFILE_SCHEMA):
            raise ValueError(f"calibration profile schema "
                             f"{schema!r} != {PROFILE_SCHEMA}")
        coef = {str(k): float(v) for k, v in d["item_coef"].items()}
        if not coef or any(v < 0 for v in coef.values()):
            raise ValueError("calibration profile has no/negative "
                             "item coefficients")
        raw_work = d.get("work_coef")
        if schema == 1:
            # stale pre-work-feature profile: its traffic coefficients
            # are still good, so repair rather than discard — the work
            # coefficients take the (scaled) default, which is zero for
            # every class regardless of the fitted unit system
            warnings.warn(
                "calibration profile uses stale schema 1 "
                f"(current {PROFILE_SCHEMA}); loading with default "
                "work coefficients — re-run calibration to refit",
                RuntimeWarning, stacklevel=2)
            raw_work = None
        if raw_work is None:
            work = dict(DEFAULT_WORK_COEF)
        else:
            work = {str(k): float(v) for k, v in raw_work.items()}
            if any(v < 0 for v in work.values()):
                raise ValueError("calibration profile has negative "
                                 "work coefficients")
            if set(work) != set(WORK_CLASSES):
                # wrong-length coefficient vector for this schema:
                # repair to the known classes instead of misfitting
                warnings.warn(
                    "calibration profile work-coefficient vector "
                    f"{sorted(work)} != {sorted(WORK_CLASSES)}; "
                    "repairing with defaults for missing classes",
                    RuntimeWarning, stacklevel=2)
                work = {c: work.get(c, DEFAULT_WORK_COEF[c])
                        for c in WORK_CLASSES}
        raw_scale = d.get("dtype_scale")
        if raw_scale is None:
            scale = dict(DEFAULT_DTYPE_SCALE)
        else:
            scale = {str(k): float(v) for k, v in raw_scale.items()}
            if any(v <= 0 for v in scale.values()):
                raise ValueError("calibration profile has non-positive "
                                 "dtype scales")
        inst = float(d.get("instance_coef", 0.0))
        if inst < 0:
            raise ValueError("calibration profile has a negative "
                             "instance coefficient")
        return cls(coef, float(d["launch_coef"]), str(d.get("backend",
                   "any")), str(d.get("device_kind", "any")),
                   str(d.get("source", "measured")),
                   int(d.get("n_samples", 0)),
                   float(d.get("residual", 0.0)),
                   work_coef=work, dtype_scale=scale,
                   instance_coef=inst)


DEFAULT_PROFILE = CalibrationProfile(dict(DEFAULT_ITEM_BYTES),
                                     KERNEL_LAUNCH_COST)


def resolve_profile(item_bytes: Optional[Mapping[str, float]] = None,
                    profile: Optional[CalibrationProfile] = None
                    ) -> CalibrationProfile:
    """Back-compat shim for the selection entry points: an explicit
    ``item_bytes`` dict (the historical API) overrides the profile's
    item coefficients; no arguments means the default profile."""
    base = profile if profile is not None else DEFAULT_PROFILE
    if item_bytes is not None:
        return replace(base, item_coef=dict(item_bytes),
                       source="item_bytes")
    return base


# ---------------------------------------------------------------------------
# Traffic features: the regressors the fit pairs with measured seconds
# ---------------------------------------------------------------------------

def traffic_features(g: Graph, dims: Dict[str, int]) -> Dict[str, float]:
    """Items moved per kind plus the launch count — exactly the terms of
    ``CalibrationProfile.cost``, so ``cost == coef . features``."""
    return _traffic_to_features(C.traffic(g, dims))


def region_features(g: Graph, dims: Dict[str, int]
                    ) -> Optional[List[Dict[str, float]]]:
    """Per-region feature rows of a snapshot, aligned with the
    *ungrouped* ``selection.region_costs`` / per-region lowering order
    (the partition is deterministic).  ``None`` when the program cannot
    be partitioned."""
    from math import prod

    from repro.core import regions as R
    try:
        plan = R.plan_program(g)
    except R.RegionError:
        return None
    rows = []
    for spec in plan.regions:
        f = traffic_features(spec.graph, dims)
        # the region kernel's grid cells — whole-program traffic can't
        # know the grid, but the region plan does
        f["instances"] = float(prod(dims[d] for d in spec.grid_dims))
        rows.append(f)
    return rows


def _traffic_to_features(t: C.Traffic) -> Dict[str, float]:
    f = {k: float(t.loads.get(k, 0) + t.stores.get(k, 0))
         for k in set(ITEM_KINDS) | set(t.loads) | set(t.stores)}
    for cls, v in t.flops().items():
        f["work_" + cls] = float(v)
    f["instances"] = float(t.instances)
    f["launches"] = float(t.launches)
    return f


def group_features(g: Graph, dims: Dict[str, int],
                   blocks: Optional[Dict[str, int]] = None, *,
                   budget_bytes: Optional[int] = None
                   ) -> Optional[List[Tuple[str, Dict[str, float]]]]:
    """Per-*kernel* feature rows of a snapshot under the region-group
    lowering: one ``(kernel id, features)`` pair per megakernel, with
    VMEM-resident edges uncharged and a single launch per group —
    exactly the terms of ``selection.group_cost``, re-derived from the
    same deterministic grouping the Pallas backend emits, so rows pair
    with measured kernel times *by id*.  ``None`` when the program
    cannot be partitioned."""
    from repro.core import regions as R
    try:
        gp = R.group_plan(R.plan_program(g), dims, blocks,
                          budget_bytes=budget_bytes)
    except R.RegionError:
        return None
    return [(grp.gid, _traffic_to_features(C.group_traffic(grp, dims)))
            for grp in gp.groups]


# ---------------------------------------------------------------------------
# The fit: least-squares over measured region times
# ---------------------------------------------------------------------------

def fit_profile(feature_rows: Sequence[Mapping[str, float]],
                times_s: Sequence[float], *,
                backend: str = "any", device_kind: str = "any",
                base: CalibrationProfile = DEFAULT_PROFILE
                ) -> CalibrationProfile:
    """Fit measured coefficients: ``times ~ features @ coef``.

    Kinds with no signal in the samples (all-zero column) — or whose
    fitted coefficient comes out non-positive, which a ranking model
    cannot use — keep the default profile's coefficient rescaled into
    the fitted unit system, so the profile stays a total cost model for
    programs that move kinds the calibration run never exercised.

    ``work_*`` feature columns (per-op-class FLOPs) fit the compute
    term: their coefficients are clamped non-negative — a column whose
    joint fit comes out negative is dropped and the remaining columns
    refitted, so a bandwidth-bound sample set degrades to the pure
    traffic model instead of producing a work *discount*.
    """
    if len(feature_rows) != len(times_s) or not feature_rows:
        raise ValueError("need equally many feature rows and times")
    kinds = list(ITEM_KINDS)
    for row in feature_rows:
        for k in row:
            if (k not in ("launches", "instances")
                    and not k.startswith("work_") and k not in kinds):
                kinds.append(k)
    work_cols = ["work_" + c for c in WORK_CLASSES]
    cols = kinds + work_cols + ["instances", "launches"]
    A = np.array([[float(row.get(c, 0.0)) for c in cols]
                  for row in feature_rows], dtype=np.float64)
    b = np.asarray(times_s, dtype=np.float64)

    # iterative non-negative clamp on the zero-default columns (work
    # classes + instances): refit without the most negative clamped
    # coefficient until none are negative — these columns have no
    # scaled-default fallback to rescue a nonsense sign
    n_work = len(work_cols)
    clampable = np.zeros(len(cols), dtype=bool)
    clampable[len(kinds):len(kinds) + n_work + 1] = True
    active = np.ones(len(cols), dtype=bool)
    while True:
        coef = np.zeros(len(cols))
        sub, *_ = np.linalg.lstsq(A[:, active], b, rcond=None)
        coef[active] = sub
        bad = clampable & active & (coef < 0)
        if not bad.any():
            break
        worst = int(np.argmin(np.where(bad, coef, 0.0)))
        active[worst] = False

    base_vec = np.array(
        [base.item_coef.get(c, base.item_coef.get("scalar", 1.0))
         for c in kinds]
        + [base.work_coef.get(c, 0.0) for c in WORK_CLASSES]
        + [base.instance_coef, base.launch_coef])
    observed = A.any(axis=0)
    good = observed & active & (coef > 0)
    if not good.any():
        warnings.warn("calibration fit produced no positive "
                      "coefficients; keeping the default profile",
                      RuntimeWarning, stacklevel=2)
        return replace(base, backend=backend, device_kind=device_kind)
    # unit bridge: how many fitted units one default unit is worth,
    # taken as the median over the trustworthy coefficients with a
    # nonzero default (work classes default to 0 and cannot bridge)
    bridge = good & (base_vec > 0)
    unit = (float(np.median(coef[bridge] / base_vec[bridge]))
            if bridge.any() else 1.0)
    fitted = np.where(good, coef, base_vec * unit)
    pred = A @ fitted
    denom = float(np.sqrt(np.mean(b ** 2))) or 1.0
    residual = float(np.sqrt(np.mean((pred - b) ** 2))) / denom
    return CalibrationProfile(
        {k: float(v) for k, v in zip(kinds, fitted[:len(kinds)])},
        float(fitted[-1]), backend=backend, device_kind=device_kind,
        source="measured", n_samples=len(times_s), residual=residual,
        work_coef={c: float(v) for c, v in
                   zip(WORK_CLASSES,
                       fitted[len(kinds):len(kinds) + n_work])},
        dtype_scale=dict(base.dtype_scale),
        instance_coef=float(fitted[len(kinds) + n_work]))


# ---------------------------------------------------------------------------
# Persistence: one JSON per (backend, device_kind) under the cache dir
# ---------------------------------------------------------------------------

def default_cache_root() -> Path:
    """The kernel-cache root (shared with ``pipeline.cache``): profiles
    live next to the plans they tune, under ``<root>/calibration/``."""
    return Path(os.environ.get(
        "REPRO_KERNEL_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "kernels")))


def device_kind(backend_hint: Optional[str] = None) -> str:
    """Best-effort device identity for the profile key.  jax's device
    kind when available (lazy import), else the machine name."""
    try:
        import jax
        return str(jax.devices()[0].device_kind)
    except Exception:
        import platform
        return platform.machine() or "cpu"


def profile_path(root: Optional[os.PathLike], backend: str,
                 dev: str) -> Path:
    root = Path(root) if root is not None else default_cache_root()
    safe = re.sub(r"[^A-Za-z0-9_.-]+", "-", dev) or "any"
    return root / "calibration" / f"{backend}_{safe}.json"


def save_profile(profile: CalibrationProfile,
                 root: Optional[os.PathLike] = None) -> Path:
    path = profile_path(root, profile.backend, profile.device_kind)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(profile.to_json(), indent=2))
    tmp.replace(path)
    return path


def load_profile(root: Optional[os.PathLike] = None, *,
                 backend: str, device_kind: str
                 ) -> Optional[CalibrationProfile]:
    """The saved profile for this (backend, device), or ``None`` — with
    a warning when a file exists but is stale or corrupt."""
    path = profile_path(root, backend, device_kind)
    try:
        raw = path.read_text()
    except OSError:
        return None
    try:
        return CalibrationProfile.from_json(json.loads(raw))
    except (ValueError, KeyError, TypeError) as err:
        warnings.warn(
            f"ignoring stale/corrupt calibration profile {path}: {err}; "
            "falling back to the default cost model", RuntimeWarning,
            stacklevel=2)
        return None


def load_or_default(root: Optional[os.PathLike] = None, *,
                    backend: str, device_kind: str
                    ) -> CalibrationProfile:
    return (load_profile(root, backend=backend, device_kind=device_kind)
            or DEFAULT_PROFILE)
