"""The paper's substitution rules (§3).

Each rule has ``match(g) -> Match | None`` and ``apply(g, match)`` operating
on one graph level (the fusion driver walks the hierarchy).  All rules are
logic-preserving; the interpreter oracle verifies this in tests.

Fusion rules:    1 fuse consecutive maps, 2 fuse sibling maps,
                 3 fuse map with reduction.
Companion rules: 4 swap scale/dot, 5 swap shift/dot, 6 extend map to the
                 entire graph, 7 peel first iteration, 8 duplicate mapped
                 scale, 9 fuse consecutive elementwise.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import ops as O
from repro.core.graph import (GB, Edge, FuncNode, Graph, InputNode, MapNode,
                              MiscNode, OutputNode, Ref, ReduceNode, VType)


@dataclass
class Match:
    rule: str
    data: Dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def copy_node(node):
    if isinstance(node, InputNode):
        return InputNode(node.name, node.vtype)
    if isinstance(node, OutputNode):
        return OutputNode(node.name)
    if isinstance(node, FuncNode):
        return FuncNode(node.op.clone())
    if isinstance(node, ReduceNode):
        return ReduceNode(node.op)
    if isinstance(node, MiscNode):
        return MiscNode(node.name, node.n_in(), node.n_out(), node.fn,
                        node.type_fn)
    if isinstance(node, MapNode):
        return MapNode(node.dim, node.inner.clone(), list(node.mapped),
                       list(node.reduced))
    raise TypeError(node)


def splice(dst: Graph, src: Graph) -> Dict[int, int]:
    """Copy src's nodes (incl. boundary) and edges into dst; return id map."""
    m: Dict[int, int] = {}
    for nid in src.input_ids:
        m[nid] = dst.add(copy_node(src.nodes[nid]))
    for nid, node in src.nodes.items():
        if isinstance(node, (InputNode, OutputNode)):
            continue
        m[nid] = dst.add(copy_node(node))
    for nid in src.output_ids:
        m[nid] = dst.add(copy_node(src.nodes[nid]))
    for e in src.edges:
        dst.connect((m[e.src], e.sp), (m[e.dst], e.dp))
    return m


def drop_input(g: Graph, nid: int, replacement: Optional[Ref]) -> None:
    """Remove an InputNode, redirecting its consumers to ``replacement``."""
    if replacement is not None:
        g.rewire_consumers((nid, 0), replacement)
    g.remove_node(nid)


def _maps(g: Graph) -> List[int]:
    return sorted(n for n in g.op_nodes() if isinstance(g.nodes[n], MapNode))


def _source(g: Graph, nid: int, port: int) -> Ref:
    e = g.in_edge(nid, port)
    return (e.src, e.sp)


def fuse_two_maps(g: Graph, uid: int, vid: int) -> int:
    """Fuse same-dim maps u, v (u possibly feeding v) into one map.

    Connecting edges must be list-typed on u's side and mapped on v's side
    (the rule matchers guarantee this).  Shared (source, mappedness) in-ports
    merge.  Returns the new node id."""
    u: MapNode = g.nodes[uid]
    v: MapNode = g.nodes[vid]
    assert u.dim == v.dim

    W = Graph()
    um = splice(W, u.inner)
    vm = splice(W, v.inner)

    conn = [e for e in g.edges if e.src == uid and e.dst == vid]
    for e in conn:
        assert u.reduced[e.sp] is None and v.mapped[e.dp], (
            "illegal connecting edge for map fusion")

    # internalize connecting edges
    consumed_u_ports = set()
    dropped_v_inputs = set()
    for e in conn:
        u_out_inner = um[u.inner.output_ids[e.sp]]
        src_ref = _source(W, u_out_inner, 0)
        v_in_inner = vm[v.inner.input_ids[e.dp]]
        drop_input(W, v_in_inner, src_ref)
        dropped_v_inputs.add(e.dp)
        consumed_u_ports.add(e.sp)

    # drop u output ports with no external consumers
    kept_u_out: List[int] = []
    for sp in range(u.n_out()):
        ext = [e for e in g.out_edges(uid, sp) if e.dst != vid]
        if sp in consumed_u_ports and not ext:
            oid = um[u.inner.output_ids[sp]]
            W.remove_node(oid)
        else:
            kept_u_out.append(sp)

    # merge identical shared inputs (same level-g source, same mappedness)
    u_sources = {}
    for p in range(u.n_in()):
        u_sources[(_source(g, uid, p), u.mapped[p])] = p
    kept_v_in: List[int] = []
    for p in range(v.n_in()):
        if p in dropped_v_inputs:
            continue
        key = (_source(g, vid, p), v.mapped[p])
        if key in u_sources:
            q = u_sources[key]
            drop_input(W, vm[v.inner.input_ids[p]],
                       (um[u.inner.input_ids[q]], 0))
        else:
            kept_v_in.append(p)

    mapped = [u.mapped[p] for p in range(u.n_in())] + \
             [v.mapped[p] for p in kept_v_in]
    reduced = [u.reduced[sp] for sp in kept_u_out] + list(v.reduced)
    newmap = MapNode(u.dim, W, mapped, reduced)

    # capture external wiring before removal
    u_in_srcs = [_source(g, uid, p) for p in range(u.n_in())]
    v_in_srcs = [_source(g, vid, p) for p in kept_v_in]
    u_out_consumers = {sp: [e for e in g.out_edges(uid, sp) if e.dst != vid]
                       for sp in kept_u_out}
    v_out_consumers = {sp: list(g.out_edges(vid, sp))
                       for sp in range(v.n_out())}

    g.remove_node(uid)
    g.remove_node(vid)
    wid = g.add(newmap)
    for p, src in enumerate(u_in_srcs + v_in_srcs):
        g.connect(src, (wid, p))
    for i, sp in enumerate(kept_u_out):
        for e in u_out_consumers[sp]:
            g.connect((wid, i), (e.dst, e.dp))
    off = len(kept_u_out)
    for sp in range(v.n_out()):
        for e in v_out_consumers[sp]:
            g.connect((wid, off + sp), (e.dst, e.dp))
    return wid


# ---------------------------------------------------------------------------
# Rule 1: fuse consecutive maps
# ---------------------------------------------------------------------------

class Rule1:
    name = "rule1_fuse_consecutive_maps"

    @staticmethod
    def match(g: Graph) -> Optional[Match]:
        for uid in _maps(g):
            u = g.nodes[uid]
            for vid in sorted({e.dst for e in g.out_edges(uid)}):
                v = g.nodes.get(vid)
                if not isinstance(v, MapNode) or v.dim != u.dim or vid == uid:
                    continue
                conn = [e for e in g.edges if e.src == uid and e.dst == vid]
                if not all(u.reduced[e.sp] is None and v.mapped[e.dp]
                           for e in conn):
                    continue
                if g.reachable(uid, vid, skip_direct=True):
                    continue
                return Match(Rule1.name, {"u": uid, "v": vid})
        return None

    @staticmethod
    def apply(g: Graph, m: Match) -> None:
        fuse_two_maps(g, m.data["u"], m.data["v"])


# ---------------------------------------------------------------------------
# Rule 2: fuse sibling maps (shared parent, not reachable from each other)
# ---------------------------------------------------------------------------

class Rule2:
    name = "rule2_fuse_sibling_maps"

    @staticmethod
    def match(g: Graph) -> Optional[Match]:
        ms = _maps(g)
        for i, uid in enumerate(ms):
            u = g.nodes[uid]
            u_srcs = {(_source(g, uid, p), u.mapped[p])
                      for p in range(u.n_in())}
            for vid in ms[i + 1:]:
                v = g.nodes[vid]
                if v.dim != u.dim:
                    continue
                if any(e.src == uid and e.dst == vid or
                       e.src == vid and e.dst == uid for e in g.edges):
                    continue  # Rule 1 territory
                v_srcs = {(_source(g, vid, p), v.mapped[p])
                          for p in range(v.n_in())}
                if not (u_srcs & v_srcs):
                    continue  # no shared parent
                if g.reachable(uid, vid) or g.reachable(vid, uid):
                    continue
                return Match(Rule2.name, {"u": uid, "v": vid})
        return None

    @staticmethod
    def apply(g: Graph, m: Match) -> None:
        fuse_two_maps(g, m.data["u"], m.data["v"])


# ---------------------------------------------------------------------------
# Rule 3: fuse map with reduction
# ---------------------------------------------------------------------------

class Rule3:
    name = "rule3_fuse_map_reduction"

    @staticmethod
    def match(g: Graph) -> Optional[Match]:
        for uid in _maps(g):
            u = g.nodes[uid]
            for sp in range(u.n_out()):
                if u.reduced[sp] is not None:
                    continue
                outs = g.out_edges(uid, sp)
                if len(outs) != 1:
                    continue
                rid = outs[0].dst
                r = g.nodes[rid]
                if not isinstance(r, ReduceNode):
                    continue
                # the port must wrap an item (reduction over exactly u.dim)
                oid = u.inner.output_ids[sp]
                ie = u.inner.in_edge(oid, 0)
                inner_src = u.inner.nodes[ie.src]
                if isinstance(inner_src, MapNode) and \
                        inner_src.reduced[ie.sp] is None:
                    continue  # inner value is itself a list
                if isinstance(inner_src, InputNode) and \
                        inner_src.vtype.is_list:
                    continue
                return Match(Rule3.name, {"u": uid, "sp": sp, "r": rid})
        return None

    @staticmethod
    def apply(g: Graph, m: Match) -> None:
        uid, sp, rid = m.data["u"], m.data["sp"], m.data["r"]
        u: MapNode = g.nodes[uid]
        r: ReduceNode = g.nodes[rid]
        u.reduced[sp] = r.op
        consumers = list(g.out_edges(rid, 0))
        g.remove_node(rid)
        for e in consumers:
            g.connect((uid, sp), (e.dst, e.dp))


# ---------------------------------------------------------------------------
# Rule 4 / 5 shared structure: a mapped scale/shift feeding a matmul map
# ---------------------------------------------------------------------------

def _match_rowop_map(g: Graph, uid: int, opcls) -> Optional[Dict]:
    """u is Map{single row_scale/row_shift}: block in mapped, c broadcast."""
    u = g.nodes[uid]
    if not isinstance(u, MapNode) or u.n_out() != 1 or u.reduced[0] is not None:
        return None
    ops = u.inner.op_nodes()
    if len(ops) != 1:
        return None
    f = u.inner.nodes[ops[0]]
    if not isinstance(f, FuncNode) or not isinstance(f.op, opcls):
        return None
    if u.n_in() != 2:
        return None
    # f arg0 <- inner input (block), f arg1 <- inner input (c)
    e0 = u.inner.in_edge(ops[0], 0)
    e1 = u.inner.in_edge(ops[0], 1)
    if e0 is None or e1 is None:
        return None
    if not (isinstance(u.inner.nodes[e0.src], InputNode)
            and isinstance(u.inner.nodes[e1.src], InputNode)):
        return None
    x_port = u.inner.input_ids.index(e0.src)
    c_port = u.inner.input_ids.index(e1.src)
    if not u.mapped[x_port] or u.mapped[c_port]:
        return None
    oe = u.inner.in_edge(u.inner.output_ids[0], 0)
    if (oe.src, oe.sp) != (ops[0], 0):
        return None
    return {"u": uid, "x_port": x_port, "c_port": c_port}


def _match_matmul_consumer(g: Graph, vid: int, dp: int,
                           k_dim: str) -> Optional[Dict]:
    """v is Map_A{ Map_K{dot} (-> Reduce)? } with the k-list entering at
    broadcast port dp (feeding dot arg0) and weights at a mapped port."""
    v = g.nodes[vid]
    if not isinstance(v, MapNode) or v.mapped[dp] or v.n_in() != 2:
        return None
    if v.n_out() != 1:
        return None
    wp = 1 - dp
    if not v.mapped[wp]:
        return None
    inner = v.inner
    ops = inner.op_nodes()
    mk_ids = [n for n in ops if isinstance(inner.nodes[n], MapNode)]
    if len(mk_ids) != 1:
        return None
    mk = inner.nodes[mk_ids[0]]
    if mk.dim != k_dim or mk.n_in() != 2 or mk.n_out() != 1:
        return None
    # x enters mk arg side feeding dot arg0; w feeds dot arg1
    x_in = inner.input_ids[dp]
    w_in = inner.input_ids[wp]
    ex = inner.in_edge(mk_ids[0], 0)
    e_ports = {p: inner.in_edge(mk_ids[0], p) for p in range(2)}
    x_mk_port = w_mk_port = None
    for p, e in e_ports.items():
        if e.src == x_in:
            x_mk_port = p
        elif e.src == w_in:
            w_mk_port = p
    if x_mk_port is None or w_mk_port is None:
        return None
    if not (mk.mapped[x_mk_port] and mk.mapped[w_mk_port]):
        return None
    dot_ids = mk.inner.op_nodes()
    if len(dot_ids) != 1:
        return None
    dot = mk.inner.nodes[dot_ids[0]]
    if not isinstance(dot, FuncNode) or not isinstance(dot.op, O.Dot):
        return None
    # dot arg0 must be the (scaled) x operand
    a0 = mk.inner.in_edge(dot_ids[0], 0)
    if a0.src != mk.inner.input_ids[x_mk_port]:
        return None
    # mk out: reduced in place, or -> Reduce -> inner output
    out_edge = inner.in_edge(inner.output_ids[0], 0)
    if mk.reduced[0] is not None:
        if (out_edge.src, out_edge.sp) != (mk_ids[0], 0):
            return None
        extra = []
    else:
        rids = [n for n in ops if isinstance(inner.nodes[n], ReduceNode)]
        if len(rids) != 1:
            return None
        re = inner.in_edge(rids[0], 0)
        if (re.src, re.sp) != (mk_ids[0], 0):
            return None
        if (out_edge.src, out_edge.sp) != (rids[0], 0):
            return None
        extra = rids
    if len(ops) != 1 + len(extra):
        return None
    return {"v": vid, "dp": dp, "wp": wp}


def _scale_map_graph(item_kind: str = O.VECTOR) -> Graph:
    gb = GB()
    y = gb.inp("y", VType((), O.BLOCK))
    c = gb.inp("c", VType((), item_kind))
    gb.out("o", gb.func(O.ROW_SCALE, y, c))
    return gb.g


class Rule4:
    name = "rule4_swap_scale_dot"

    @staticmethod
    def match(g: Graph) -> Optional[Match]:
        for uid in _maps(g):
            mu = _match_rowop_map(g, uid, O.RowScale)
            if not mu:
                continue
            outs = g.out_edges(uid, 0)
            if len(outs) != 1:
                continue  # Rule 8 handles fan-out
            e = outs[0]
            mv = _match_matmul_consumer(g, e.dst, e.dp, g.nodes[uid].dim)
            if not mv:
                continue
            return Match(Rule4.name, {**mu, **mv})
        return None

    @staticmethod
    def apply(g: Graph, m: Match) -> None:
        uid, vid = m.data["u"], m.data["v"]
        v: MapNode = g.nodes[vid]
        x_src = _source(g, uid, m.data["x_port"])
        c_src = _source(g, uid, m.data["c_port"])
        # rewire v's broadcast port to the unscaled operand
        old = g.in_edge(vid, m.data["dp"])
        g.disconnect(old)
        g.connect(x_src, (vid, m.data["dp"]))
        g.remove_node(uid)
        # append Map_A{row_scale} after v
        s = MapNode(v.dim, _scale_map_graph(), [True, False], [None])
        sid = g.add(s)
        g.rewire_consumers((vid, 0), (sid, 0))
        g.connect((vid, 0), (sid, 0))
        g.connect(c_src, (sid, 1))


class Rule5:
    name = "rule5_swap_shift_dot"

    @staticmethod
    def match(g: Graph) -> Optional[Match]:
        for uid in _maps(g):
            mu = _match_rowop_map(g, uid, O.RowShift)
            if not mu:
                continue
            outs = g.out_edges(uid, 0)
            if len(outs) != 1:
                continue
            e = outs[0]
            mv = _match_matmul_consumer(g, e.dst, e.dp, g.nodes[uid].dim)
            if not mv:
                continue
            return Match(Rule5.name, {**mu, **mv})
        return None

    @staticmethod
    def apply(g: Graph, m: Match) -> None:
        uid, vid = m.data["u"], m.data["v"]
        u: MapNode = g.nodes[uid]
        v: MapNode = g.nodes[vid]
        k_dim = u.dim
        x_src = _source(g, uid, m.data["x_port"])
        c_src = _source(g, uid, m.data["c_port"])
        w_src = _source(g, vid, m.data["wp"])
        old = g.in_edge(vid, m.data["dp"])
        g.disconnect(old)
        g.connect(x_src, (vid, m.data["dp"]))
        g.remove_node(uid)

        # V2 = Map_A{ Map_K{row_sum(w)} -> Reduce }: column sums of I2
        gk = GB()
        wb = gk.inp("w", VType((), O.BLOCK))
        gk.out("o", gk.func(O.ROW_SUM, wb))
        ga = GB()
        wrow = ga.inp("w", VType((k_dim,), O.BLOCK))
        parts = ga.map(k_dim, gk.g, [(wrow, True)])
        ga.out("o", ga.reduce(parts[0]))
        v2 = MapNode(v.dim, ga.g, [True], [None])
        v2id = g.add(v2)
        g.connect(w_src, (v2id, 0))

        # C = Map_A{ add(outer(c, s), mm) }
        gc = GB()
        cvec = gc.inp("c", VType((), O.VECTOR))
        svec = gc.inp("s", VType((), O.VECTOR))
        mblk = gc.inp("m", VType((), O.BLOCK))
        o = gc.func(O.OUTER, cvec, svec)
        gc.out("o", gc.func(O.EW_ADD.clone(), o, mblk))
        cnode = MapNode(v.dim, gc.g, [False, True, True], [None])
        cid = g.add(cnode)
        g.rewire_consumers((vid, 0), (cid, 0))
        g.connect(c_src, (cid, 0))
        g.connect((v2id, 0), (cid, 1))
        g.connect((vid, 0), (cid, 2))


# ---------------------------------------------------------------------------
# Rule 6: extend a map to the entire graph (replicates work)
# ---------------------------------------------------------------------------

class Rule6:
    name = "rule6_extend_map"

    @staticmethod
    def match(g: Graph) -> Optional[Match]:
        op_ids = g.op_nodes()
        if len(op_ids) < 2 or not g.output_ids:
            return None
        for vid in _maps(g):
            v = g.nodes[vid]
            # all program outputs fed by v
            if not all(g.in_edge(oid, 0).src == vid for oid in g.output_ids):
                continue
            # every other op node's outputs stay internal (no Output edges)
            ok = True
            for nid in op_ids:
                if nid == vid:
                    continue
                for e in g.out_edges(nid):
                    if isinstance(g.nodes[e.dst], OutputNode):
                        ok = False
            if not ok:
                continue
            # edges from op nodes into v must be broadcast ports
            for e in g.in_edges(vid):
                if not isinstance(g.nodes[e.src], InputNode) and \
                        v.mapped[e.dp]:
                    ok = False
            if not ok:
                continue
            # enablement: some other map at this level shares a dim with a
            # top-level map inside v.inner
            inner_dims = {v.inner.nodes[n].dim
                          for n in v.inner.op_nodes()
                          if isinstance(v.inner.nodes[n], MapNode)}
            outer_dims = {g.nodes[n].dim for n in _maps(g) if n != vid}
            if not (inner_dims & outer_dims):
                continue
            return Match(Rule6.name, {"v": vid})
        return None

    @staticmethod
    def apply(g: Graph, m: Match) -> None:
        vid = m.data["v"]
        v: MapNode = g.nodes[vid]
        types = g.infer_types()

        W = Graph()
        ivm = splice(W, v.inner)

        pulled = [n for n in g.op_nodes() if n != vid]
        order = [n for n in g.topo() if n in pulled]
        copies: Dict[int, int] = {}
        for nid in order:
            copies[nid] = W.add(copy_node(g.nodes[nid]))
        for e in g.edges:
            if e.src in copies and e.dst in copies:
                W.connect((copies[e.src], e.sp), (copies[e.dst], e.dp))

        # v's in-ports: keep those fed by g-inputs; internalize the rest
        kept_ports: List[int] = []
        kept_srcs: List[Ref] = []
        input_port_of_src: Dict[Ref, int] = {}
        for p in range(v.n_in()):
            src = _source(g, vid, p)
            if isinstance(g.nodes[src[0]], InputNode):
                kept_ports.append(p)
                kept_srcs.append(src)
                if not v.mapped[p]:
                    input_port_of_src[src] = len(kept_ports) - 1
            else:
                inner_in = ivm[v.inner.input_ids[p]]
                drop_input(W, inner_in, (copies[src[0]], src[1]))

        new_input_ids = [ivm[v.inner.input_ids[p]] for p in kept_ports]
        new_mapped = [v.mapped[p] for p in kept_ports]
        new_srcs = list(kept_srcs)

        # g-inputs consumed by pulled nodes become broadcast ports
        extra_inputs: Dict[Ref, int] = {}
        for e in sorted(g.edges, key=lambda e: (e.src, e.sp, e.dst, e.dp)):
            if e.dst in copies and isinstance(g.nodes[e.src], InputNode):
                key = (e.src, e.sp)
                if key in input_port_of_src:
                    tgt = new_input_ids[input_port_of_src[key]]
                elif key in extra_inputs:
                    tgt = extra_inputs[key]
                else:
                    vt = types[key]
                    tgt = W.add(InputNode(g.nodes[e.src].name, vt))
                    extra_inputs[key] = tgt
                    new_input_ids.append(tgt)
                    new_mapped.append(False)
                    new_srcs.append(key)
                W.connect((tgt, 0), (copies[e.dst], e.dp))

        # fix W's boundary ordering
        W.input_ids = new_input_ids
        newmap = MapNode(v.dim, W, new_mapped, list(v.reduced))

        out_consumers = {sp: list(g.out_edges(vid, sp))
                         for sp in range(v.n_out())}
        for nid in pulled:
            g.remove_node(nid)
        g.remove_node(vid)
        wid = g.add(newmap)
        for p, src in enumerate(new_srcs):
            g.connect(src, (wid, p))
        for sp, es in out_consumers.items():
            for e in es:
                g.connect((wid, sp), (e.dst, e.dp))


# ---------------------------------------------------------------------------
# Rule 7: peel off the first iteration (alternative to Rule 6)
# ---------------------------------------------------------------------------

class Rule7:
    """Peel iteration 0 of a map into a standalone copy of its inner graph.

    The peeled copy consumes element 0 of each mapped input; the residual
    map runs iterations 1..X-1.  We realize "element 0" / "rest" with Misc
    index/slice nodes so the transformation stays logic-preserving and
    interpretable."""

    name = "rule7_peel_first_iteration"

    @staticmethod
    def match(g: Graph, dim: Optional[str] = None) -> Optional[Match]:
        for uid in _maps(g):
            u = g.nodes[uid]
            if dim is not None and u.dim != dim:
                continue
            if any(r is not None for r in u.reduced):
                continue  # peeling accumulated maps needs a combine step
            if not any(u.mapped):
                continue
            return Match(Rule7.name, {"u": uid})
        return None

    @staticmethod
    def apply(g: Graph, m: Match) -> None:
        uid = m.data["u"]
        u: MapNode = g.nodes[uid]
        srcs = [_source(g, uid, p) for p in range(u.n_in())]
        out_consumers = {sp: list(g.out_edges(uid, sp))
                         for sp in range(u.n_out())}

        def head_fn(xp, xs):
            return xs[0]

        def tail_fn(xp, xs):
            return xs[1:]

        def cons_fn(xp, h, t):
            return [h] + list(t)

        def head_type(ins):
            return [ins[0].strip()]

        def tail_type(ins):
            return [VType((u.dim + "_rest",) + ins[0].dims[1:], ins[0].item)]

        def cons_type(ins):
            return [VType((u.dim,) + ins[0].dims, ins[0].item)]

        # peeled first iteration: the inner graph inlined at level g, with
        # head nodes extracting element 0 of each mapped input
        inner = u.inner.clone()
        idmap: Dict[int, int] = {}
        for nid, node in list(inner.nodes.items()):
            if isinstance(node, (InputNode, OutputNode)):
                continue
            idmap[nid] = g.add(copy_node(node))
        for e in inner.edges:
            if e.src in idmap and e.dst in idmap:
                g.connect((idmap[e.src], e.sp), (idmap[e.dst], e.dp))
        for p, iid in enumerate(inner.input_ids):
            if u.mapped[p]:
                h = g.add(MiscNode("head", 1, 1, head_fn, type_fn=head_type))
                g.connect(srcs[p], (h, 0))
                src_ref: Ref = (h, 0)
            else:
                src_ref = srcs[p]
            for e in inner.edges:
                if e.src == iid and e.dst in idmap:
                    g.connect(src_ref, (idmap[e.dst], e.dp))
        peel_out: List[Ref] = []
        for sp, oid in enumerate(inner.output_ids):
            e = inner.in_edge(oid, 0)
            peel_out.append((idmap[e.src], e.sp))

        # residual map over the tails
        tail_refs: List[Ref] = []
        for p in range(u.n_in()):
            if u.mapped[p]:
                tnode = g.add(MiscNode("tail", 1, 1, tail_fn,
                                       type_fn=tail_type))
                g.connect(srcs[p], (tnode, 0))
                tail_refs.append((tnode, 0))
            else:
                tail_refs.append(srcs[p])
        rest = MapNode(u.dim + "_rest", u.inner.clone(), list(u.mapped),
                       list(u.reduced))
        rid = g.add(rest)
        for p, src in enumerate(tail_refs):
            g.connect(src, (rid, p))

        # recombine: cons(head_result, rest_result)
        g.remove_node(uid)
        for sp in range(u.n_out()):
            c = g.add(MiscNode("cons", 2, 1, cons_fn, type_fn=cons_type))
            g.connect(peel_out[sp], (c, 0))
            g.connect((rid, sp), (c, 1))
            for e in out_consumers[sp]:
                g.connect((c, 0), (e.dst, e.dp))


# ---------------------------------------------------------------------------
# Rule 8: duplicate a mapped scale feeding several matmuls
# ---------------------------------------------------------------------------

class Rule8:
    name = "rule8_duplicate_mapped_scale"

    @staticmethod
    def match(g: Graph) -> Optional[Match]:
        for uid in _maps(g):
            mu = _match_rowop_map(g, uid, O.RowScale)
            if not mu:
                continue
            outs = g.out_edges(uid, 0)
            mm_edges = [e for e in outs
                        if _match_matmul_consumer(g, e.dst, e.dp,
                                                  g.nodes[uid].dim)]
            if len(mm_edges) >= 2:
                return Match(Rule8.name, {"u": uid, "edges": mm_edges[1:]})
        return None

    @staticmethod
    def apply(g: Graph, m: Match) -> None:
        uid = m.data["u"]
        u: MapNode = g.nodes[uid]
        srcs = [_source(g, uid, p) for p in range(u.n_in())]
        for e in m.data["edges"]:
            dup = copy_node(u)
            did = g.add(dup)
            for p, src in enumerate(srcs):
                g.connect(src, (did, p))
            g.disconnect(e)
            g.connect((did, 0), (e.dst, e.dp))


# ---------------------------------------------------------------------------
# Rule 9: fuse consecutive elementwise operators
# ---------------------------------------------------------------------------

class Rule9:
    name = "rule9_fuse_consecutive_elementwise"

    @staticmethod
    def match(g: Graph) -> Optional[Match]:
        for uid in sorted(g.op_nodes()):
            u = g.nodes[uid]
            if not isinstance(u, FuncNode) or not O.is_elementwise(u.op):
                continue
            outs = g.out_edges(uid, 0)
            if len(outs) != 1:
                continue
            vid, dp = outs[0].dst, outs[0].dp
            v = g.nodes[vid]
            if not isinstance(v, FuncNode) or not O.is_elementwise(v.op):
                continue
            return Match(Rule9.name, {"u": uid, "v": vid, "dp": dp})
        return None

    @staticmethod
    def apply(g: Graph, m: Match) -> None:
        uid, vid, dp = m.data["u"], m.data["v"], m.data["dp"]
        u, v = g.nodes[uid], g.nodes[vid]
        composed = O.compose_elementwise(u.op, v.op, dp)
        u_srcs = [_source(g, uid, p) for p in range(u.n_in())]
        v_srcs = [_source(g, vid, p) for p in range(v.n_in()) if p != dp]
        consumers = list(g.out_edges(vid, 0))
        g.remove_node(uid)
        g.remove_node(vid)
        nid = g.add(FuncNode(composed))
        for p, src in enumerate(u_srcs + v_srcs):
            g.connect(src, (nid, p))
        for e in consumers:
            g.connect((nid, 0), (e.dst, e.dp))


RULES_PRIORITY = [Rule8, Rule4, Rule5, Rule9, Rule3, Rule1, Rule2]
