"""Batched serving driver: prefill a batch of prompts, then decode with the
per-family cache (KV / compressed-MLA / SSM state).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --reduced --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, get_reduced_config
    from repro.models import build_model

    cfg = (get_reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(args.seed))

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    max_len = args.prompt_len + args.gen

    kw = {}
    if cfg.family == "vlm":
        kw["vision_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_vision_tokens, cfg.d_model)),
            cfg.dtype) * 0.02
        max_len += cfg.n_vision_tokens
    if cfg.family == "encdec":
        kw["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.enc_seq, cfg.d_model)),
            cfg.dtype) * 0.02

    t0 = time.time()
    logits, cache = model.prefill(params, prompts, max_len=max_len, **kw)
    next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    prefill_s = time.time() - t0

    decode = jax.jit(model.decode_step,
                     static_argnames=())
    generated = [next_tok]
    t0 = time.time()
    pos0 = args.prompt_len + (cfg.n_vision_tokens
                              if cfg.family == "vlm" else 0)
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, generated[-1], pos0 + i)
        generated.append(jnp.argmax(logits[:, -1], axis=-1)[:, None])
    decode_s = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    toks_per_s = args.batch * (args.gen - 1) / max(decode_s, 1e-9)
    print(f"arch={cfg.name} prefill={prefill_s*1e3:.1f}ms "
          f"decode={decode_s*1e3:.1f}ms ({toks_per_s:.1f} tok/s) "
          f"out_shape={out.shape}")
    return {"tokens": out, "prefill_s": prefill_s, "decode_s": decode_s}


if __name__ == "__main__":
    main()
