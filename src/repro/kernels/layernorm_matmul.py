"""Pallas TPU kernel: Flash-LayerNorm+Matmul (paper Example 2).

Realizes the paper's fully-fused final listing on TPU:

  forall m: forall n: for k:
      s1 += row_sum(x); s2 += row_sum(x*x)       # LayerNorm row stats
      ys += colsum(gamma*y); yb += beta @ y       # linearity corrections
      acc += (x*gamma) @ y                        # the matmul
    z = (acc - outer(mu, ys)) * invstd + yb       # epilogue

(the affine gamma/beta extension folds into the same single pass via the
same linearity identities the paper's Rules 4/5 exploit:
LN(x)@Y = ((x - mu) / sigma * gamma + beta) @ Y
        = ((x*gamma)@Y - mu * colsum(gamma*Y)) / sigma + beta@Y).

One HBM pass over X and Y per output tile; the K grid dim is the serial
K-map of the paper's listing with 4 VMEM accumulators.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ln_mm_kernel(x_ref, y_ref, g_ref, b_ref, z_ref,
                  acc_ref, s1_ref, s2_ref, ys_ref, yb_ref, *,
                  eps: float, k_dim: int, n_k: int, block_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        s1_ref[...] = jnp.zeros_like(s1_ref)
        s2_ref[...] = jnp.zeros_like(s2_ref)
        ys_ref[...] = jnp.zeros_like(ys_ref)
        yb_ref[...] = jnp.zeros_like(yb_ref)

    x = x_ref[...].astype(jnp.float32)           # (bm, bk)
    y = y_ref[...].astype(jnp.float32)           # (bk, bn)
    gamma = g_ref[...].astype(jnp.float32)       # (1, bk)
    beta = b_ref[...].astype(jnp.float32)        # (1, bk)

    s1_ref[...] += x.sum(axis=1, keepdims=True)
    s2_ref[...] += (x * x).sum(axis=1, keepdims=True)
    yg = y * gamma.T                             # gamma * Y rows
    ys_ref[...] += yg.sum(axis=0, keepdims=True)
    yb_ref[...] += jax.lax.dot(beta, y, preferred_element_type=jnp.float32)
    acc_ref[...] += jax.lax.dot(x, yg, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        mu = s1_ref[...] / k_dim                     # (bm, 1)
        var = s2_ref[...] / k_dim - mu * mu
        istd = jax.lax.rsqrt(var + eps)
        z = (acc_ref[...] - mu * ys_ref[...]) * istd + yb_ref[...]
        z_ref[...] = z.astype(z_ref.dtype)


def layernorm_matmul_pallas(x: jax.Array, y: jax.Array, gamma: jax.Array,
                            beta: jax.Array, *, eps: float = 1e-5,
                            block_m: int = 128, block_n: int = 128,
                            block_k: int = 512,
                            interpret: bool = False) -> jax.Array:
    """x: (M, K); y: (K, N); gamma, beta: (K,).  Returns LN(x)@y: (M, N).

    K must be divisible by block_k (the row statistics must cover the whole
    row; callers pick block_k | K — model dims are powers of two)."""
    m_dim, k_dim = x.shape
    _, n_dim = y.shape
    block_m = min(block_m, m_dim)
    block_n = min(block_n, n_dim)
    block_k = min(block_k, k_dim)
    assert k_dim % block_k == 0, "row stats need full-row coverage"
    pad_m = (-m_dim) % block_m
    pad_n = (-n_dim) % block_n
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    if pad_n:
        y = jnp.pad(y, ((0, 0), (0, pad_n)))
    mp, np_ = m_dim + pad_m, n_dim + pad_n
    g2 = gamma.reshape(1, k_dim)
    b2 = beta.reshape(1, k_dim)
    n_k = k_dim // block_k

    kernel = functools.partial(_ln_mm_kernel, eps=eps, k_dim=k_dim, n_k=n_k,
                               block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(mp // block_m, np_ // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)),
            pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_m, block_n), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((block_m, 1), jnp.float32),
            pltpu.VMEM((1, block_n), jnp.float32),
            pltpu.VMEM((1, block_n), jnp.float32),
        ],
        interpret=interpret,
    )(x, y, g2, b2)
    return out[:m_dim, :n_dim]
