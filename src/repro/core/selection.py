"""Candidate-selection stand-in (the paper defers the real algorithm to
"Blockbuster, Part 2" [9]; this module implements the *contract* §1/§4
describe so the framework is complete):

  * candidates are standard-operator subgraphs (here: whole programs, per
    §4: "if the entire block program is entirely made up of standard
    operators then the entire program can be one of the candidates");
  * the fusion algorithm returns multiple snapshots per candidate;
  * the selector evaluates each snapshot with the traffic cost model and
    picks the cheapest implementation;
  * the selector owns block-shape choice (paper: "the selection algorithm
    is also responsible for choosing the block shapes ... and then
    optimize all the shapes after-the-fact"): ``autotune`` sweeps the
    block-count assignment per dimension and returns the best
    (dims, snapshot) pair — including the degenerate counts (N=1, K=1)
    that the paper notes eliminate Rule-6 work replication.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import cost as C
from repro.core.fusion import fuse
from repro.core.graph import Graph

DEFAULT_ITEM_BYTES = {"block": 128 * 128 * 4, "vector": 128 * 4,
                      "scalar": 4}
KERNEL_LAUNCH_COST = 1e5  # bytes-equivalent of one kernel launch


@dataclass(frozen=True)
class Selected:
    snapshot_index: int
    graph: Graph
    dims: Dict[str, int]
    cost: float
    costs: Tuple[float, ...]  # per snapshot, for inspection


def snapshot_cost(g: Graph, dims: Dict[str, int],
                  item_bytes: Optional[Dict[str, int]] = None) -> float:
    item_bytes = item_bytes or DEFAULT_ITEM_BYTES
    t = C.traffic(g, dims)
    return t.bytes_moved(item_bytes) + KERNEL_LAUNCH_COST * t.launches


def region_costs(g: Graph, dims: Dict[str, int],
                 item_bytes: Optional[Dict[str, int]] = None,
                 plan=None) -> Optional[Tuple[float, ...]]:
    """Per-region traffic attribution of one snapshot.

    The Pallas backend executes a snapshot as its region partition
    (``core/regions.py``): one kernel per region, with every
    cross-region value materialized in global memory.  Each entry is
    ``snapshot_cost`` of one region's standalone program (its loads
    include re-reading cross-region inputs, its launch count is exactly
    one), so the tuple is the honest per-kernel cost breakdown of what
    actually runs — the basis for timing-based calibration later.
    Returns ``None`` for programs the partitioner cannot split
    (MiscNode-bearing graphs take the whole-program fallback).  Pass a
    precomputed ``regions.ProgramPlan`` via ``plan`` to avoid
    re-partitioning (the driver shares one plan with the lowering)."""
    from repro.core import regions as R
    if plan is None:
        try:
            plan = R.plan_program(g)
        except R.RegionError:
            return None
    return tuple(snapshot_cost(spec.graph, dims, item_bytes)
                 for spec in plan.regions)


def select(g: Graph, dims: Dict[str, int],
           item_bytes: Optional[Dict[str, int]] = None,
           snapshots: Optional[List[Graph]] = None) -> Selected:
    """Fuse (if needed) and pick the cheapest snapshot for fixed dims."""
    snaps = snapshots if snapshots is not None else fuse(g)
    costs = tuple(snapshot_cost(s, dims, item_bytes) for s in snaps)
    i = min(range(len(costs)), key=costs.__getitem__)
    return Selected(i, snaps[i], dict(dims), costs[i], costs)


def autotune(g: Graph, dim_candidates: Dict[str, Sequence[int]],
             item_bytes: Optional[Dict[str, int]] = None,
             snapshots: Optional[List[Graph]] = None) -> Selected:
    """Sweep block-count assignments (the paper's block-shape choice) and
    return the globally cheapest (dims, snapshot).  The fusion algorithm is
    invoked ONCE — its choices don't depend on block shapes (paper §1).
    Callers that already ran ``fuse`` (e.g. ``pipeline.compile``) pass the
    snapshot list via ``snapshots`` to avoid re-fusing."""
    snaps = snapshots if snapshots is not None else fuse(g)
    best: Optional[Selected] = None
    names = sorted(dim_candidates)
    for combo in itertools.product(*(dim_candidates[n] for n in names)):
        dims = dict(zip(names, combo))
        sel = select(g, dims, item_bytes, snapshots=snaps)
        if best is None or sel.cost < best.cost:
            best = sel
    assert best is not None
    return best
