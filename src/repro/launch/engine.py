"""Continuous-batching decode engine on pipeline megakernels.

The serving loop the paper's megakernel result plugs into: an open-loop
arrival trace feeds a slot-based scheduler that

* admits requests into free KV-cache slots, prefilling each prompt
  padded to a *shape bucket* (exact under causal masking: pad keys
  occupy only future positions, which the causal frontier excludes, and
  successive decode steps overwrite them);
* runs ONE mixed decode step per tick across every active slot — a
  ragged batch where each sequence sits at its own cache position.
  Positions are kernel *data* (the causal-mask QP/KP position-vector
  inputs), so the ragged batch reuses the same compiled kernels every
  step: one persistent grouped megakernel per (arch, shape-bucket),
  served from the on-disk kernel cache with zero steady-state
  recompiles (pinned by a cache-stats assertion);
* evicts finished sequences (request satisfied) and stalled ones (cache
  slot exhausted) to free slots for the queue.

Observability: every step records queue depth, batch occupancy and the
prefill/decode split; the report aggregates tokens/sec, p50/p99
per-token latency, kernel-cache hit rate and the steady-state recompile
count, and serializes to JSON (``benchmarks/serve_bench.py`` gates the
throughput/latency numbers in CI).
"""

from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import resilience as RZ

# families whose padded-bucket prefill is exactly correct: causal
# attention masks the pad positions; an SSM scan would carry pad state
# forward into real tokens
_SUPPORTED_FAMILIES = ("dense", "moe")


# ---------------------------------------------------------------------------
# trace
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Request:
    """One serving request: a prompt arriving at an (open-loop) step."""
    rid: int
    prompt: Tuple[int, ...]
    max_new_tokens: int
    arrival_step: int
    # latest engine step this request may still be running at: past it,
    # a queued request is dropped and an active one evicted (partial
    # tokens recorded), each with a structured failure record.  None =
    # no deadline (the default; synthetic traces set none)
    deadline_step: Optional[int] = None


def synth_trace(n_requests: int, *, seed: int = 0,
                arrival_rate: float = 1.0,
                prompt_lens: Tuple[int, int] = (4, 24),
                gen_lens: Tuple[int, int] = (4, 16),
                vocab: int = 1000) -> List[Request]:
    """A synthetic open-loop arrival trace: geometric inter-arrival steps
    at ``arrival_rate`` requests/step (open-loop: arrivals don't wait for
    completions, so the queue genuinely builds when the engine lags),
    uniform prompt/generation lengths, uniform random tokens."""
    rng = np.random.default_rng(seed)
    reqs, step = [], 0
    for rid in range(n_requests):
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        glen = int(rng.integers(gen_lens[0], gen_lens[1] + 1))
        reqs.append(Request(
            rid=rid,
            prompt=tuple(int(t) for t in rng.integers(0, vocab, plen)),
            max_new_tokens=glen,
            arrival_step=step))
        # geometric inter-arrival (the discrete-step Poisson analogue)
        step += int(rng.geometric(min(1.0, arrival_rate)) - 1)
    return reqs


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

@dataclass
class StepRecord:
    step: int
    queue_depth: int
    occupancy: int          # active slots after admission
    n_prefill: int          # requests admitted (prefilled) this step
    n_decode: int           # decode tokens emitted this step
    wall_ms: float


@dataclass
class ServeReport:
    """What a serving run did, aggregated for gating and dashboards."""
    n_requests: int = 0
    n_completed: int = 0
    n_evicted_stalled: int = 0
    n_rejected: int = 0
    steps: int = 0
    wall_s: float = 0.0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    tokens_per_s: float = 0.0
    decode_tokens_per_s: float = 0.0
    p50_token_ms: float = 0.0
    p99_token_ms: float = 0.0
    mean_occupancy: float = 0.0
    max_queue_depth: int = 0
    cache_memory_hits: int = 0
    cache_disk_hits: int = 0
    cache_misses: int = 0
    cache_hit_rate: float = 0.0
    warmup_compiles: int = 0
    decode_recompiles: int = 0   # steady-state compile growth; MUST be 0
    pallas_fallbacks: int = 0
    # -- resilience counters (all zero on the clean path; pinned by
    #    check_regression.py so the fault machinery never costs it) -----
    n_poisoned: int = 0          # requests evicted by the finite-logits
                                 # guard (prefill or decode)
    n_deadline_evicted: int = 0  # requests dropped/evicted past deadline
    degradations: int = 0        # ladder demotions over the run: compile
                                 # ladder (resilience.METRICS delta) plus
                                 # tick-watchdog decode demotions
    quarantined: int = 0         # corrupt cache entries quarantined
                                 # (CacheStats delta over the run)
    # -- self-healing counters (the inverse of the watchdog) ------------
    repromotions: int = 0        # demoted decode rungs probed healthy and
                                 # swapped back in mid-run
    probes: int = 0              # half-open re-promotion probes attempted
    probe_failures: int = 0      # probes that failed (breaker re-opened
                                 # at doubled cool-down)
    decode_backend: str = ""     # the rung decode ended the run on
                                 # (e.g. "pipeline-pallas" when healed)
    # structured failure records: {"rid", "reason", "step", ...} — one
    # per poison eviction / deadline / queue_full rejection / watchdog
    # demotion, so a failed request is triageable, not just a counter
    failures: List[dict] = field(default_factory=list)
    tokens: Dict[int, List[int]] = field(default_factory=dict)
    per_step: List[StepRecord] = field(default_factory=list)

    def to_json(self) -> dict:
        d = asdict(self)
        d["tokens"] = {str(k): v for k, v in self.tokens.items()}
        return d


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclass
class _Slot:
    rid: int
    pos: int                 # next cache position to write (filled length)
    remaining: int
    last_token: int
    generated: List[int]
    deadline: Optional[int] = None


def _demote_cfg(cfg):
    """One watchdog rung down for the serving model: pallas pipeline ->
    jax pipeline -> the non-pipeline xla kernels.  Returns
    ``(new_cfg, label)`` or ``(None, None)`` at the bottom.  (The
    interpreter rung is not servable here: the numpy reference kernels
    cannot trace under the engine's jitted decode step.)"""
    import dataclasses

    opts = cfg.pipeline_options
    if cfg.attn_impl != "pipeline" and cfg.mlp_impl != "pipeline":
        return None, None
    backend = opts.backend if opts is not None else cfg.pipeline_backend
    if backend == "pallas":
        new_opts = (opts.replace(backend="jax")
                    if opts is not None else None)
        return dataclasses.replace(cfg, pipeline_backend="jax",
                                   pipeline_options=new_opts), "pipeline-jax"
    return dataclasses.replace(cfg, attn_impl="xla",
                               mlp_impl="fused_ref",
                               pipeline_options=None), "xla"


def _backend_label(cfg) -> str:
    """The serving-ladder rung label of a model config, matching the
    labels ``_demote_cfg`` hands out (``pipeline-pallas`` /
    ``pipeline-jax`` / ``xla``)."""
    if cfg.attn_impl != "pipeline" and cfg.mlp_impl != "pipeline":
        return "xla"
    opts = cfg.pipeline_options
    backend = opts.backend if opts is not None else cfg.pipeline_backend
    return f"pipeline-{backend}"


class Engine:
    """Slot-based continuous-batching scheduler over ``models.lm.LM``.

    The engine owns one batched KV cache of ``max_batch`` slots.  Each
    tick admits queued requests into free slots (bucketed prefill, one
    pipeline kernel per bucket) and then advances every active slot by
    one token through a single jitted ragged decode step (positions as a
    ``(B,)`` vector).  All pipeline kernels are compiled in ``warmup()``;
    after that the run loop never compiles again — ``run()`` asserts it.
    """

    def __init__(self, cfg, *, max_batch: int = 4, max_len: int = 96,
                 prompt_buckets: Sequence[int] = (8, 16, 32),
                 sampling: str = "greedy", temperature: float = 1.0,
                 seed: int = 0, keep_per_step: bool = True,
                 strict_no_recompile: bool = True,
                 max_queue: Optional[int] = None,
                 repromote_after: Optional[int] = 8):
        import jax

        from repro import pipeline
        from repro.models import build_model

        if cfg.family not in _SUPPORTED_FAMILIES:
            raise ValueError(
                f"continuous batching supports attention-family archs "
                f"{_SUPPORTED_FAMILIES}, not family={cfg.family!r}: padded "
                "bucket prefill is exact only under causal masking")
        if sampling not in ("greedy", "categorical"):
            raise ValueError(f"unknown sampling {sampling!r}")
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.prompt_buckets = tuple(sorted(int(b) for b in prompt_buckets))
        if self.prompt_buckets[-1] >= self.max_len:
            raise ValueError("largest prompt bucket must leave room to "
                             f"decode (buckets={self.prompt_buckets}, "
                             f"max_len={self.max_len})")
        self.sampling = sampling
        self.temperature = float(temperature)
        self.keep_per_step = keep_per_step
        self.strict_no_recompile = strict_no_recompile
        # bounded admission: arrivals past this queue depth are rejected
        # with a structured failure record instead of building an
        # unbounded backlog.  None = unbounded (the historical behavior)
        self.max_queue = None if max_queue is None else int(max_queue)
        self._key = jax.random.key(seed)

        # -- self-healing (the inverse of the tick watchdog) ----------------
        # after `repromote_after` clean decode ticks on a demoted rung, a
        # half-open probe re-compiles the original rung off the hot path
        # and swaps it back if it passes the finite-logits guard.  None
        # disables re-promotion (the PR-9 demote-forever behavior).  The
        # ledger's clock is the engine tick counter, so breaker timing is
        # deterministic per trace; state persists under <cache>/health/.
        self.repromote_after = (None if repromote_after is None
                                else int(repromote_after))
        self._tick = 0
        self._clean_ticks = 0
        self._demote_stack: List[Tuple[object, str]] = []  # (cfg, rung)
        self.repromotions = 0
        self.probes = 0
        self.probe_failures = 0
        self.probe_compiles = 0      # compiles explained by probes
        self._ledger = None
        self._hkey = f"serve:{getattr(cfg, 'name', 'model')}:decode"
        if self.repromote_after is not None:
            if self.repromote_after <= 0:
                raise ValueError("repromote_after must be > 0 (or None "
                                 "to disable re-promotion)")
            cache = pipeline.default_cache()
            self._ledger = RZ.HealthLedger(
                cache.root / "health" if cache.disk else None,
                clock=lambda: float(self._tick))
            self._breaker_policy = RZ.ResiliencePolicy(
                breaker_threshold=1,  # one decode crash opens the breaker
                breaker_cooldown_s=float(self.repromote_after),
                breaker_cooldown_max_s=float(self.repromote_after) * 64)
            # adopt persisted breaker state from a crashed/restarted
            # predecessor: start demoted rather than re-crash the same
            # rung, and re-open the cool-down against OUR tick clock
            while True:
                lbl = _backend_label(cfg)
                if self._ledger.state(self._hkey, lbl) == "closed":
                    break
                new_cfg, _ = _demote_cfg(cfg)
                if new_cfg is None:
                    break
                self._demote_stack.append((cfg, lbl))
                self._ledger.reopen(self._hkey, lbl,
                                    float(self.repromote_after))
                warnings.warn(
                    f"serve: decode rung {lbl!r} breaker is open in the "
                    f"health ledger; starting demoted to "
                    f"{_backend_label(new_cfg)!r}", RuntimeWarning,
                    stacklevel=2)
                cfg = new_cfg
            self.cfg = cfg

        self.model = build_model(cfg)
        self.params, _ = self.model.init_params(jax.random.key(seed))
        self._jax = jax

        m, L = self.model, self.max_len
        self._prefill = jax.jit(lambda p, t: m.prefill(p, t, max_len=L))
        self._decode = jax.jit(m.decode_step)

        def insert(batched, one, slot):
            # cache leaves are (n_layers, batch, ...): splice the
            # prefilled single-sequence cache into its slot
            return jax.tree.map(
                lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                    b, s.astype(b.dtype), slot, axis=1), batched, one)

        self._insert = jax.jit(insert)

        self.caches = m.init_cache(self.max_batch, self.max_len)
        self.slots: List[Optional[_Slot]] = [None] * self.max_batch
        self.queue: deque = deque()
        self._warm_stats = None
        self._base_stats = None      # cache counters at warmup start
        self._base_metrics = None    # resilience.METRICS at warmup start
        self.warmup_compiles = 0
        self.pallas_fallbacks = 0
        self.watchdog_demotions = 0  # tick-level decode demotions
        self.demotion_compiles = 0   # compiles explained by demotions
                                     # (excluded from decode_recompiles)

    # -- scheduling helpers -------------------------------------------------
    def _bucket(self, plen: int) -> Optional[int]:
        for b in self.prompt_buckets:
            if plen <= b:
                return b
        return None

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _pos_vector(self) -> np.ndarray:
        return np.asarray([s.pos if s else 0 for s in self.slots], np.int32)

    def _token_vector(self) -> np.ndarray:
        return np.asarray([s.last_token if s else 0 for s in self.slots],
                          np.int32)

    def _sample(self, logits) -> np.ndarray:
        jax, jnp = self._jax, self._jax.numpy
        lg = logits[:, -1]
        if self.sampling == "greedy":
            return np.asarray(jnp.argmax(lg, axis=-1))
        self._key, sub = jax.random.split(self._key)
        return np.asarray(jax.random.categorical(
            sub, lg / max(self.temperature, 1e-6), axis=-1))

    # -- lifecycle ----------------------------------------------------------
    def warmup(self) -> int:
        """Compile every kernel the run loop can touch — one prefill
        pipeline per prompt bucket plus the full-batch ragged decode step
        — then snapshot the kernel-cache counters.  ``run()`` pins the
        steady state against this snapshot: any later compile is a
        recompile.  Returns the number of pipeline compiles performed."""
        from repro import pipeline

        jnp = self._jax.numpy
        stats = pipeline.default_cache().stats
        before = stats.snapshot()
        self._base_stats = before
        self._base_metrics = RZ.METRICS.snapshot()
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            for b in self.prompt_buckets:
                toks = jnp.zeros((1, b), jnp.int32)
                lg, cache = self._prefill(self.params, toks)
                self.caches = self._insert(self.caches, cache, 0)
                lg.block_until_ready()
            lg, self.caches = self._decode(
                self.params, self.caches,
                jnp.zeros((self.max_batch, 1), jnp.int32),
                jnp.zeros((self.max_batch,), jnp.int32))
            lg.block_until_ready()
        self.pallas_fallbacks = sum(
            1 for w in caught if "pallas lowering fallback" in str(w.message))
        # the decode warm-up wrote garbage at position 0 of every slot;
        # real prefills overwrite it before any slot activates
        self.warmup_compiles = stats.delta(before).compiles
        self._warm_stats = stats.snapshot()
        return self.warmup_compiles

    def _admit(self, req: Request, slot: int, report: ServeReport,
               step: int = 0) -> str:
        """Prefill ``req`` into ``slot``.  Returns a status:
        ``"ok"`` (admitted or satisfied outright), ``"rejected"`` (bad
        shape: no bucket, or prompt+generation exceed ``max_len``),
        ``"deadline"`` (its deadline passed while queued), or
        ``"poisoned"`` (the prompt prefilled to non-finite logits — the
        slot stays free, co-batched sequences never see it)."""
        jnp = self._jax.numpy
        plen = len(req.prompt)
        if req.deadline_step is not None and step > req.deadline_step:
            report.failures.append({
                "rid": req.rid, "reason": "deadline_queued", "step": step,
                "deadline": req.deadline_step})
            return "deadline"
        bucket = self._bucket(plen)
        if bucket is None or plen + req.max_new_tokens > self.max_len:
            report.failures.append({
                "rid": req.rid, "reason": "bad_shape", "step": step,
                "prompt_len": plen, "max_new_tokens": req.max_new_tokens})
            return "rejected"
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = req.prompt
        logits, cache = self._prefill(self.params, jnp.asarray(padded))
        # the prompt's next-token logits sit at the last REAL position;
        # pad positions to the right are causally invisible to it
        row = logits[:, plen - 1:plen]
        if not bool(jnp.all(jnp.isfinite(row))):
            # poison prompt: never insert its cache, never occupy a slot
            report.n_poisoned += 1
            report.failures.append({
                "rid": req.rid, "reason": "nonfinite_prefill",
                "step": step, "prompt_len": plen})
            return "poisoned"
        self.caches = self._insert(self.caches, cache, slot)
        first = self._sample(row)
        tok = int(first[0])
        if req.max_new_tokens <= 1:
            # the prefill's token satisfies the request outright
            report.tokens[req.rid] = [tok]
            report.n_completed += 1
            return "ok"
        self.slots[slot] = _Slot(rid=req.rid, pos=plen,
                                 remaining=req.max_new_tokens - 1,
                                 last_token=tok, generated=[tok],
                                 deadline=req.deadline_step)
        return "ok"

    def _decode_once(self):
        jnp = self._jax.numpy
        RZ.check("serve:decode")
        return self._decode(
            self.params, self.caches,
            jnp.asarray(self._token_vector()[:, None]),
            jnp.asarray(self._pos_vector()))

    def _watchdog_demote(self, err: BaseException, step: int,
                         report: ServeReport) -> None:
        """The tick-level watchdog: the decode kernel raised, so rebuild
        the decode step one ladder rung down (pallas pipeline -> jax
        pipeline -> plain xla kernels) and keep serving.  Params and the
        KV cache are impl-independent, so active sequences continue
        in place; prefill kernels (which did not fail) stay as-is.
        Raises the original error when there is no rung left."""
        from repro.models import build_model

        new_cfg, label = _demote_cfg(self.cfg)
        if new_cfg is None:
            raise err
        jax = self._jax
        if self._ledger is not None:
            # open the failed rung's breaker (threshold 1: a decode crash
            # is never cheap) so re-promotion waits out the cool-down
            old_label = _backend_label(self.cfg)
            self._demote_stack.append((self.cfg, old_label))
            self._ledger.record_failure(self._hkey, old_label, err,
                                        policy=self._breaker_policy)
        self.cfg = new_cfg
        self.model = build_model(new_cfg)
        m = self.model
        self._decode = jax.jit(m.decode_step)
        self.watchdog_demotions += 1
        report.failures.append({
            "reason": "decode_demotion", "step": step, "to": label,
            "error": f"{type(err).__name__}: {err}"})
        warnings.warn(
            f"serve watchdog: decode step failed "
            f"({type(err).__name__}: {err}); demoted decode to {label} "
            "and continuing", RuntimeWarning, stacklevel=2)

    def _probe_repromote(self, report: ServeReport, step: int,
                         stats) -> bool:
        """Half-open probe of the rung decode was demoted off: rebuild
        it and run one decode step against the live KV cache *without*
        committing its outputs (the real tick already ran).  A
        finite-logits pass swaps the healthy rung back in and closes the
        breaker; a failure re-opens it at doubled cool-down.  Probe
        compiles are explained (excluded from ``strict_no_recompile``)
        the same way demotion compiles are."""
        from repro.models import build_model

        jnp = self._jax.numpy
        old_cfg, old_label = self._demote_stack[-1]
        self.probes += 1
        before = stats.snapshot()
        try:
            spec = RZ.fire("serve:probe")
            if spec is not None and spec.kind == "raise":
                raise RZ.InjectedFault(f"serve:probe[{spec.message}]")
            model = build_model(old_cfg)
            decode = self._jax.jit(model.decode_step)
            logits, _ = decode(  # outputs discarded: probe only
                self.params, self.caches,
                jnp.asarray(self._token_vector()[:, None]),
                jnp.asarray(self._pos_vector()))
            if spec is not None and spec.kind == "nan":
                logits = logits.at[:, -1].set(jnp.nan)
            if not bool(jnp.all(jnp.isfinite(logits[:, -1]))):
                raise RuntimeError("probe produced non-finite logits")
        except Exception as e:
            self.probe_failures += 1
            self._ledger.record_failure(self._hkey, old_label, e,
                                        policy=self._breaker_policy)
            report.failures.append({
                "reason": "probe_failed", "step": step, "rung": old_label,
                "error": f"{type(e).__name__}: {e}"})
            warnings.warn(
                f"serve: re-promotion probe of {old_label!r} failed "
                f"({type(e).__name__}: {e}); breaker re-opened at doubled "
                "cool-down", RuntimeWarning, stacklevel=2)
            self._clean_ticks = 0
            self.probe_compiles += stats.delta(before).compiles
            self._warm_stats = stats.snapshot()
            return False
        # healthy again: swap the probed decode in and close the breaker
        self.cfg, self.model, self._decode = old_cfg, model, decode
        self._demote_stack.pop()
        self.repromotions += 1
        self._ledger.record_success(self._hkey, old_label)
        report.failures.append({
            "reason": "decode_repromotion", "step": step, "to": old_label})
        warnings.warn(
            f"serve: decode rung {old_label!r} probed healthy after "
            f"{self._clean_ticks} clean ticks; re-promoted",
            RuntimeWarning, stacklevel=2)
        self._clean_ticks = 0
        self.probe_compiles += stats.delta(before).compiles
        self._warm_stats = stats.snapshot()
        return True

    def run(self, trace: Sequence[Request],
            max_steps: Optional[int] = None) -> ServeReport:
        """Drive the trace to completion (or ``max_steps``) and report."""
        from repro import pipeline

        jnp = self._jax.numpy
        if self._warm_stats is None:
            self.warmup()
        stats = pipeline.default_cache().stats

        pending = deque(sorted(trace, key=lambda r: r.arrival_step))
        report = ServeReport(n_requests=len(trace))
        token_lat_ms: List[float] = []
        occupancy_sum = 0
        step = 0
        t_run = time.perf_counter()
        while pending or self.queue or any(self.slots):
            if max_steps is not None and step >= max_steps:
                break
            self._tick = step  # the health ledger's deterministic clock
            t0 = time.perf_counter()
            while pending and pending[0].arrival_step <= step:
                req = pending.popleft()
                if (self.max_queue is not None
                        and len(self.queue) >= self.max_queue):
                    # bounded admission: reject loudly instead of
                    # building an unbounded backlog
                    report.n_rejected += 1
                    report.failures.append({
                        "rid": req.rid, "reason": "queue_full",
                        "step": step, "queue_depth": len(self.queue)})
                else:
                    self.queue.append(req)
            n_prefill = 0
            for slot in self._free_slots():
                if not self.queue:
                    break
                req = self.queue.popleft()
                status = self._admit(req, slot, report, step)
                if status == "ok":
                    n_prefill += 1
                    report.prefill_tokens += len(req.prompt)
                    report.decode_tokens += 1  # the prefill's first token
                elif status == "rejected":
                    report.n_rejected += 1
                elif status == "deadline":
                    report.n_deadline_evicted += 1
                # "poisoned" is counted inside _admit; the slot stays
                # free either way and co-batched sequences are untouched
            active = [i for i, s in enumerate(self.slots) if s is not None]
            n_decode = 0
            if active:
                try:
                    logits, caches = self._decode_once()
                    self._clean_ticks += 1
                except Exception as e:  # watchdog: demote, retry once
                    before = stats.snapshot()
                    self._watchdog_demote(e, step, report)
                    logits, caches = self._decode_once()
                    # the demoted decode's compiles are explained — keep
                    # strict_no_recompile armed for *unexplained* ones
                    self.demotion_compiles += stats.delta(before).compiles
                    self._warm_stats = stats.snapshot()
                    self._clean_ticks = 0
                self.caches = caches
                spec = RZ.fire("serve:logits")
                if spec is not None and spec.kind == "nan":
                    # poison exactly one co-batched row; the guard below
                    # must contain it to that sequence
                    logits = logits.at[active[0], -1].set(jnp.nan)
                # cheap post-step guard: one finite-check over the new
                # logits row per slot, evict poisoned sequences instead
                # of letting NaNs propagate through their KV cache
                fin = np.asarray(jnp.all(jnp.isfinite(logits[:, -1]),
                                         axis=-1))
                for i in active:
                    if bool(fin[i]):
                        continue
                    s = self.slots[i]
                    report.n_poisoned += 1
                    report.failures.append({
                        "rid": s.rid, "reason": "nonfinite_logits",
                        "step": step, "pos": s.pos})
                    report.tokens[s.rid] = s.generated  # partial output
                    self.slots[i] = None
                active = [i for i in active if bool(fin[i])]
                sampled = self._sample(logits)
                for i in active:
                    s = self.slots[i]
                    tok = int(sampled[i])
                    s.pos += 1
                    s.generated.append(tok)
                    s.last_token = tok
                    s.remaining -= 1
                    n_decode += 1
                    if s.remaining <= 0 or s.pos >= self.max_len:
                        # finished (request satisfied) or stalled (slot
                        # exhausted): free the slot for the queue
                        if s.remaining > 0:
                            report.n_evicted_stalled += 1
                        else:
                            report.n_completed += 1
                        report.tokens[s.rid] = s.generated
                        self.slots[i] = None
                    elif s.deadline is not None and step >= s.deadline:
                        report.n_deadline_evicted += 1
                        report.failures.append({
                            "rid": s.rid, "reason": "deadline",
                            "step": step, "deadline": s.deadline})
                        report.tokens[s.rid] = s.generated
                        self.slots[i] = None
            # re-promotion: after enough clean ticks on a demoted rung,
            # let the breaker admit one half-open probe of the original
            if (self._demote_stack and self._ledger is not None
                    and self._clean_ticks >= self.repromote_after
                    and self._ledger.decision(
                        self._hkey, self._demote_stack[-1][1]) == "probe"):
                self._probe_repromote(report, step, stats)
            wall_ms = (time.perf_counter() - t0) * 1e3
            token_lat_ms.extend([wall_ms] * (n_decode + n_prefill))
            occ = sum(1 for s in self.slots if s is not None)
            occupancy_sum += occ
            report.decode_tokens += n_decode
            if self.keep_per_step:
                report.per_step.append(StepRecord(
                    step=step, queue_depth=len(self.queue), occupancy=occ,
                    n_prefill=n_prefill, n_decode=n_decode,
                    wall_ms=wall_ms))
            report.max_queue_depth = max(report.max_queue_depth,
                                         len(self.queue))
            step += 1

        report.steps = step
        report.wall_s = time.perf_counter() - t_run
        total = report.prefill_tokens + report.decode_tokens
        report.tokens_per_s = total / max(report.wall_s, 1e-9)
        report.decode_tokens_per_s = (report.decode_tokens
                                      / max(report.wall_s, 1e-9))
        if token_lat_ms:
            report.p50_token_ms = float(np.percentile(token_lat_ms, 50))
            report.p99_token_ms = float(np.percentile(token_lat_ms, 99))
        report.mean_occupancy = occupancy_sum / max(step, 1)
        report.cache_memory_hits = stats.memory_hits
        report.cache_disk_hits = stats.disk_hits
        report.cache_misses = stats.misses
        report.cache_hit_rate = stats.hit_rate
        report.warmup_compiles = self.warmup_compiles
        report.decode_recompiles = stats.delta(self._warm_stats).compiles
        report.pallas_fallbacks = self.pallas_fallbacks
        # resilience counters over the whole engine lifetime (warmup
        # included): compile-ladder demotions + watchdog demotions, and
        # cache-integrity quarantines
        report.degradations = (RZ.METRICS.delta(self._base_metrics)
                               .demotions + self.watchdog_demotions)
        report.quarantined = stats.delta(self._base_stats).quarantined
        report.repromotions = self.repromotions
        report.probes = self.probes
        report.probe_failures = self.probe_failures
        report.decode_backend = _backend_label(self.cfg)
        if self.strict_no_recompile and report.decode_recompiles:
            raise RuntimeError(
                f"{report.decode_recompiles} pipeline recompiles after "
                "warmup — a steady-state decode step compiled a kernel "
                "(shape bucket or batch drifted out of the warmed set)")
        return report
