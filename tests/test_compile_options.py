"""CompileOptions: hashing, cache-key identity with the kwargs shim,
and threading through configs/layers."""

import pytest

from repro import configs, pipeline
from repro.core import array_program as AP


def _graph():
    return AP.layernorm_matmul_program(32.0)


DIMS = {"M": 2, "K": 4, "N": 2}


def test_hash_equality_dict_order_insensitive():
    a = pipeline.CompileOptions(backend="pallas",
                                blocks={"M": 8, "N": 4},
                                item_bytes={"x": 4, "y": 2})
    b = pipeline.CompileOptions(backend="pallas",
                                blocks={"N": 4, "M": 8},
                                item_bytes={"y": 2, "x": 4})
    assert a == b
    assert hash(a) == hash(b)
    assert a.blocks_dict == {"M": 8, "N": 4}
    assert a != a.replace(group=False)
    # usable as a dict key (the layer lru_caches rely on this)
    assert {a: 1}[b] == 1


def test_kwargs_shim_aliases_options_form():
    cache = pipeline.KernelCache(disk=False)
    k1 = pipeline.compile(_graph(), DIMS, backend="py", cache=cache)
    k2 = pipeline.compile(_graph(), DIMS,
                          options=pipeline.CompileOptions(backend="py"),
                          cache=cache)
    assert k1.key == k2.key
    assert k2.cache_hit == "memory"


def test_default_options_alias():
    cache = pipeline.KernelCache(disk=False)
    k1 = pipeline.compile(_graph(), DIMS, cache=cache)
    k2 = pipeline.compile(_graph(), DIMS,
                          options=pipeline.DEFAULT_OPTIONS, cache=cache)
    assert k2.cache_hit == "memory"
    assert k1.key == k2.key


def test_both_forms_is_type_error():
    with pytest.raises(TypeError, match="not both"):
        pipeline.compile(_graph(), DIMS,
                         options=pipeline.CompileOptions(), backend="py",
                         cache=pipeline.KernelCache(disk=False))


def test_unknown_kwarg_is_type_error():
    with pytest.raises(TypeError, match="unexpected keyword"):
        pipeline.compile(_graph(), DIMS, bogus_flag=True,
                         cache=pipeline.KernelCache(disk=False))


def test_unequal_options_never_alias():
    cache = pipeline.KernelCache(disk=False)
    k1 = pipeline.compile(_graph(), DIMS, cache=cache)  # jax backend
    k2 = pipeline.compile(_graph(), DIMS,
                          options=pipeline.CompileOptions(jit=False),
                          cache=cache)
    assert k1.key != k2.key
    assert k2.cache_hit is None


def test_cache_opts_reflects_resolved_decisions():
    o = pipeline.CompileOptions(backend="pallas", interpret=True,
                                group=False)
    opts = o.cache_opts(stabilized=True, autotuned=False)
    assert ("stabilize", True) in opts
    assert ("interpret", True) in opts
    assert ("group", False) in opts
    # analytic autotune never salts the key (autotuned or not)
    o2 = pipeline.CompileOptions()
    assert all(k != "autotune"
               for k, _ in o2.cache_opts(stabilized=False, autotuned=True))


def test_with_pipeline_threads_options():
    o = pipeline.CompileOptions(backend="pallas", interpret=True)
    cfg = configs.with_pipeline(configs.get_reduced_config("smollm-135m"),
                                options=o)
    assert cfg.pipeline_options == o
    assert cfg.pipeline_backend == "pallas"
    assert cfg.attn_impl == "pipeline" and cfg.mlp_impl == "pipeline"
    # hashability survives (ModelConfig is a frozen dataclass key)
    hash(cfg)


def test_stats_helpers():
    s = pipeline.CacheStats(memory_hits=3, disk_hits=1, misses=2)
    assert s.compiles == 3
    assert abs(s.hit_rate - 4 / 6) < 1e-9
    snap = s.snapshot()
    s.misses += 5
    d = s.delta(snap)
    assert d.misses == 5 and d.compiles == 5 and d.memory_hits == 0
