"""Gradient compression for data-parallel sync: int8 quantization with
error feedback.

Inside a ``shard_map`` over the data axes, each replica quantizes its local
gradient shard to int8 (per-tensor scale), all-reduces the int8 payload
(8x less ICI traffic than f32, 4x less than bf16), dequantizes, and feeds
the quantization residual back into the next step's gradient (error
feedback keeps the compression bias bounded — Seide et al. 2014 / Karimireddy
et al. 2019).

This is an *opt-in* distributed-optimization trick for collective-bound
training cells (see EXPERIMENTS §Perf): exact when gradients are already
replica-identical, and convergence-neutral under error feedback otherwise.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(grads, mesh, axes=("data",), errors=None):
    """Mean of per-replica gradients across ``axes`` with int8 payloads +
    error feedback.  grads: pytree of per-replica f32 arrays (unsharded
    leaves inside shard_map).  Returns (synced_grads, new_errors)."""
    if errors is None:
        errors = jax.tree.map(jnp.zeros_like, grads)

    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def one(g, e):
        corrected = g + e
        q, scale = quantize_int8(corrected)
        total = jax.lax.psum(dequantize_int8(q, scale), axes)
        new_e = corrected - dequantize_int8(q, scale)
        return total / n, new_e

    def body(grads, errors):
        out = jax.tree.map(one, grads, errors)
        synced = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        return synced, new_err

    from jax.experimental.shard_map import shard_map
    spec = jax.tree.map(lambda _: P(*axes), grads)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(spec, spec),
                   out_specs=(spec, spec))
    return fn(grads, errors)


def compress_roundtrip_error(x: jax.Array) -> float:
    """Utility for tests/benchmarks: relative L2 error of one int8
    round-trip."""
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    return float(jnp.linalg.norm(back - x) / (jnp.linalg.norm(x) + 1e-12))
