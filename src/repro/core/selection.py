"""Candidate-selection stand-in (the paper defers the real algorithm to
"Blockbuster, Part 2" [9]; this module implements the *contract* §1/§4
describe so the framework is complete):

  * candidates are standard-operator subgraphs (here: whole programs, per
    §4: "if the entire block program is entirely made up of standard
    operators then the entire program can be one of the candidates");
  * the fusion algorithm returns multiple snapshots per candidate;
  * the selector evaluates each snapshot with the traffic cost model and
    picks the cheapest implementation;
  * the selector owns block-shape choice (paper: "the selection algorithm
    is also responsible for choosing the block shapes ... and then
    optimize all the shapes after-the-fact"): ``autotune`` sweeps the
    block-count assignment per dimension and returns the best
    (dims, snapshot) pair — including the degenerate counts (N=1, K=1)
    that the paper notes eliminate Rule-6 work replication.

The cost model's coefficients live in a ``calibrate.CalibrationProfile``
(the default reproduces the historical constants; a *measured* profile is
fitted from per-region kernel timings — see ``core/calibrate.py``).
``autotune(objective="measured")`` closes the loop end-to-end: the
(calibrated) analytic model prunes the sweep, and only the top-K
survivors are actually run and timed — the wall-clock winner is
returned.  ``pipeline.compile(..., autotune="measured")`` supplies the
``measure`` callback (compile + synthetic inputs + the timing harness).
"""

from __future__ import annotations

import itertools
import warnings
from dataclasses import dataclass, replace
from typing import (Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

from repro.core import calibrate as CAL
from repro.core import cost as C
from repro.core.fusion import fuse
from repro.core.graph import Graph

# single source of truth for the default coefficients is the default
# CalibrationProfile; these names remain the public aliases
DEFAULT_ITEM_BYTES = CAL.DEFAULT_ITEM_BYTES
KERNEL_LAUNCH_COST = CAL.KERNEL_LAUNCH_COST


@dataclass(frozen=True)
class Selected:
    snapshot_index: int
    graph: Graph
    dims: Dict[str, int]
    cost: float
    costs: Tuple[float, ...]  # per snapshot, for inspection
    # objective="measured" only: the winner's wall seconds and every
    # (dims, seconds) pair the autotuner timed — the analytic choice is
    # always among them, so callers can verify measured <= analytic
    measured_s: Optional[float] = None
    timings: Tuple[Tuple[Tuple[Tuple[str, int], ...], float], ...] = ()


def snapshot_cost(g: Graph, dims: Dict[str, int],
                  item_bytes: Optional[Dict[str, int]] = None,
                  profile: Optional[CAL.CalibrationProfile] = None
                  ) -> float:
    """Cost of one snapshot under a calibration profile (default: the
    historical constants; pass a measured profile — or the legacy
    ``item_bytes`` dict, which overrides its item coefficients)."""
    prof = CAL.resolve_profile(item_bytes, profile)
    return prof.cost(C.traffic(g, dims))


def group_cost(group, dims: Dict[str, int],
               item_bytes: Optional[Dict[str, int]] = None,
               profile: Optional[CAL.CalibrationProfile] = None) -> float:
    """Cost of one region-group megakernel under a calibration profile:
    member traffic with every VMEM-resident edge uncharged (no stores by
    the producer, no loads by in-group consumers) plus exactly one
    kernel launch — the residency-aware cost of what actually runs."""
    prof = CAL.resolve_profile(item_bytes, profile)
    return prof.cost(C.group_traffic(group, dims))


def region_costs(g: Graph, dims: Dict[str, int],
                 item_bytes: Optional[Dict[str, int]] = None,
                 plan=None,
                 profile: Optional[CAL.CalibrationProfile] = None
                 ) -> Optional[Tuple[float, ...]]:
    """Per-kernel traffic attribution of one snapshot.

    The Pallas backend executes a snapshot as its grouped region
    partition (``core/regions.py``): one kernel per region *group*,
    with in-group cross-region values VMEM-resident and only
    cross-group values materialized in global memory.  Pass the
    ``regions.GroupedPlan`` the lowering uses via ``plan`` to get one
    :func:`group_cost` entry per emitted kernel (the honest per-kernel
    breakdown ``core/timing.region_times`` pairs wall times with, by
    kernel id); pass a ``regions.ProgramPlan`` (or nothing) for the
    ungrouped per-region attribution — each entry ``snapshot_cost`` of
    one region's standalone program.  Returns ``None`` for programs the
    partitioner cannot split (MiscNode-bearing graphs take the
    whole-program fallback)."""
    from repro.core import regions as R
    if plan is None:
        try:
            plan = R.plan_program(g)
        except R.RegionError:
            return None
    if isinstance(plan, R.GroupedPlan):
        return tuple(group_cost(grp, dims, item_bytes, profile)
                     for grp in plan.groups)
    return tuple(snapshot_cost(spec.graph, dims, item_bytes, profile)
                 for spec in plan.regions)


def objective_cost(g: Graph, dims: Dict[str, int],
                   item_bytes: Optional[Dict[str, int]] = None,
                   profile: Optional[CAL.CalibrationProfile] = None, *,
                   group: bool = False,
                   blocks: Optional[Dict[str, int]] = None,
                   plan=None,
                   vmem_budget: Optional[int] = None) -> float:
    """The selection objective for one snapshot at fixed dims.

    ``group=False`` (the paper's objective): whole-program traffic with
    every edge charged against global memory — :func:`snapshot_cost`.
    ``group=True`` (the residency-aware objective): ``sum(group_cost)``
    over the deterministic grouped region partition the Pallas backend
    actually emits — resident cross-region edges are free and each
    group costs one launch, so snapshots are ranked by the cost of what
    runs, not the paper's all-edges-global upper bound.  A program the
    partitioner cannot split falls back to :func:`snapshot_cost`
    (whole-program lowering: the two objectives coincide).

    ``plan`` optionally passes a precomputed ``regions.ProgramPlan``
    for ``g`` (the partition is dims-independent, so sweeps reuse it).
    """
    if not group:
        return snapshot_cost(g, dims, item_bytes, profile)
    from repro.core import regions as R
    if plan is None:
        try:
            plan = R.plan_program(g)
        except R.RegionError:
            return snapshot_cost(g, dims, item_bytes, profile)
    gp = R.group_plan(plan, dims, blocks, budget_bytes=vmem_budget)
    return sum(group_cost(grp, dims, item_bytes, profile)
               for grp in gp.groups)


def select(g: Graph, dims: Dict[str, int],
           item_bytes: Optional[Dict[str, int]] = None,
           snapshots: Optional[List[Graph]] = None,
           profile: Optional[CAL.CalibrationProfile] = None, *,
           group: bool = False,
           blocks: Optional[Dict[str, int]] = None,
           vmem_budget: Optional[int] = None,
           _plans: Optional[List] = None) -> Selected:
    """Fuse (if needed) and pick the cheapest snapshot for fixed dims.

    ``group=True`` ranks by the grouped, residency-aware objective (see
    :func:`objective_cost`) — what the Pallas region-group lowering will
    actually pay.  ``_plans`` (internal) carries per-snapshot region
    plans across ``autotune``'s dims sweep so each snapshot is
    partitioned once, not once per assignment."""
    snaps = snapshots if snapshots is not None else fuse(g)
    plans: Optional[List] = None
    if group:
        from repro.core import regions as R
        if _plans is not None and len(_plans) == len(snaps):
            plans = _plans
        else:
            plans = []
            for s in snaps:
                try:
                    plans.append(R.plan_program(s))
                except R.RegionError:
                    plans.append(None)
            if _plans is not None:
                _plans[:] = plans
    costs = tuple(
        objective_cost(s, dims, item_bytes, profile, group=group,
                       blocks=blocks, vmem_budget=vmem_budget,
                       plan=plans[j] if plans is not None else None)
        if group else snapshot_cost(s, dims, item_bytes, profile)
        for j, s in enumerate(snaps))
    i = min(range(len(costs)), key=costs.__getitem__)
    return Selected(i, snaps[i], dict(dims), costs[i], costs)


def _dims_key(dims: Dict[str, int]) -> Tuple[Tuple[str, int], ...]:
    return tuple(sorted(dims.items()))


def sweep_assignments(dim_candidates: Dict[str, Sequence[int]]
                      ) -> Iterable[Dict[str, int]]:
    """The deduplicated block-count grid: assignments that would produce
    an identical ``(Graph.fingerprint(), dims)`` compile key — e.g. from
    repeated candidate values — are yielded exactly once, so they are
    costed (and measured) once."""
    names = sorted(dim_candidates)
    seen = set()
    for combo in itertools.product(*(dim_candidates[n] for n in names)):
        dims = dict(zip(names, combo))
        key = _dims_key(dims)
        if key in seen:
            continue
        seen.add(key)
        yield dims


def autotune(g: Graph, dim_candidates: Dict[str, Sequence[int]],
             item_bytes: Optional[Dict[str, int]] = None,
             snapshots: Optional[List[Graph]] = None, *,
             objective: str = "analytic",
             profile: Optional[CAL.CalibrationProfile] = None,
             measure: Optional[Callable[[Selected], float]] = None,
             top_k: int = 3,
             group: bool = False,
             blocks: Optional[Dict[str, int]] = None,
             vmem_budget: Optional[int] = None) -> Selected:
    """Sweep block-count assignments (the paper's block-shape choice) and
    return the globally cheapest (dims, snapshot).  The fusion algorithm
    is invoked ONCE — its choices don't depend on block shapes (paper
    §1).  Callers that already ran ``fuse`` (e.g. ``pipeline.compile``)
    pass the snapshot list via ``snapshots`` to avoid re-fusing.

    ``objective="analytic"`` (default) ranks by the calibrated traffic
    model alone.  ``objective="measured"`` uses the analytic model only
    to *prune*: the ``top_k`` cheapest distinct assignments are handed
    to ``measure`` (compile + run + time; built by ``pipeline.compile``)
    and the wall-clock winner is returned, with its seconds in
    ``Selected.measured_s`` and every timed candidate in
    ``Selected.timings``.  The analytic winner is always measured, so
    the result is never slower than the analytic choice (ties allowed);
    candidates that fail to compile or time are skipped with a warning,
    and if every measurement fails the analytic choice is returned.
    """
    if objective not in ("analytic", "measured"):
        raise ValueError(f"unknown objective {objective!r}; "
                         "one of ('analytic', 'measured')")
    if objective == "measured" and measure is None:
        raise ValueError(
            "objective='measured' needs a measure callback; call through "
            "pipeline.compile(..., autotune='measured'), which builds it")
    snaps = snapshots if snapshots is not None else fuse(g)
    cands: List[Selected] = []
    shared_plans: List = []  # per-snapshot region plans, computed once
    for dims in sweep_assignments(dim_candidates):
        cands.append(select(g, dims, item_bytes, snapshots=snaps,
                            profile=profile, group=group, blocks=blocks,
                            vmem_budget=vmem_budget,
                            _plans=shared_plans if group else None))
    if not cands:
        raise ValueError("empty dim_candidates sweep")
    # stable: equal analytic costs keep sweep order, so the analytic
    # winner is always finalists[0]
    cands.sort(key=lambda s: s.cost)
    if objective == "analytic":
        return cands[0]

    finalists = cands[:max(1, top_k)]
    timed: List[Tuple[float, Selected]] = []
    for sel in finalists:
        try:
            t = float(measure(sel))
        except Exception as err:  # a candidate that cannot run is skipped
            warnings.warn(f"measured autotune: skipping {sel.dims} "
                          f"({type(err).__name__}: {err})", RuntimeWarning,
                          stacklevel=2)
            continue
        if not (t > 0.0 and t < float("inf")):
            continue
        timed.append((t, sel))
    if not timed:
        warnings.warn("measured autotune: every measurement failed; "
                      "returning the analytic choice", RuntimeWarning,
                      stacklevel=2)
        return cands[0]
    timings = tuple((_dims_key(sel.dims), t) for t, sel in timed)
    t_best, best = min(timed, key=lambda p: p[0])
    return replace(best, measured_s=t_best, timings=timings)
