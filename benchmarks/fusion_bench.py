"""One benchmark per paper example (the paper's results are its three
worked examples): global-memory traffic before/after fusion, kernel-launch
counts, work replication across snapshots, and fusion-algorithm runtime.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import array_program as AP
from repro.core import cost as C
from repro.core.fusion import FusionTrace, fuse

# representative block sizes (bytes): 128x128 f32 blocks, 128 f32 vectors
ITEM_BYTES = {"block": 128 * 128 * 4, "vector": 128 * 4, "scalar": 4}

EXAMPLES = {
    "attention": (lambda: AP.attention_program(0.125),
                  {"M": 8, "D": 4, "N": 16, "L": 4}),
    "layernorm_matmul": (lambda: AP.layernorm_matmul_program(512.0),
                         {"M": 8, "K": 16, "N": 8}),
    "rmsnorm_ffn_swiglu": (lambda: AP.rmsnorm_ffn_swiglu_program(512.0),
                           {"M": 8, "D": 8, "K": 16, "N": 8}),
}


def bench_example(name: str) -> List[Dict]:
    build, dims = EXAMPLES[name]
    g = build()
    t0 = time.perf_counter()
    trace = FusionTrace()
    snaps = fuse(g, trace)
    fuse_us = (time.perf_counter() - t0) * 1e6

    t_init = C.traffic(g, dims)
    rows = []
    init_bytes = t_init.bytes_moved(ITEM_BYTES)
    for i, s in enumerate(snaps):
        t = C.traffic(s, dims)
        rows.append({
            "name": f"fusion_{name}_snap{i}",
            "us_per_call": fuse_us,
            "derived": (
                f"traffic_bytes={t.bytes_moved(ITEM_BYTES)};"
                f"traffic_reduction={init_bytes / max(t.bytes_moved(ITEM_BYTES), 1):.2f}x;"
                f"stores={sum(t.stores.values())};"
                f"loads={sum(t.loads.values())};"
                f"launches={t_init.launches}->{t.launches};"
                f"work_factor={sum(t.work.values()) / max(sum(t_init.work.values()), 1):.2f};"
                f"rule_applications={len(trace.steps)}"
            ),
        })
    return rows


def run() -> List[Dict]:
    rows = []
    for name in EXAMPLES:
        rows.extend(bench_example(name))
    return rows
