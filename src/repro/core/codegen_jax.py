"""Compile a block program into an executable, jit-able JAX function.

Lowering rules (block lists are stacked jnp arrays, one leading axis per
list level — block decompositions must be uniform):

  * parallel Map           -> jax.vmap   (mapped ports: in_axes=0)
  * serial Map (Rule 3'd)  -> jax.lax.scan with the accumulated out-ports
                              as f32 carries (paper: serial loop + accum)
  * Reduce                 -> sum over the leading axis
  * Func                   -> the op's jnp implementation

This closes the compiler pipeline: array program -> (Table 2) block
program -> fusion algorithm -> executable kernel.  ``compile_program``'s
output is a plain JAX function: it can be jitted, differentiated, sharded
with pjit, or lowered to HLO like any other.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as O
from repro.core.graph import (FuncNode, Graph, InputNode, MapNode, MiscNode,
                              OutputNode, ReduceNode)


def stack_blocks(nested) -> jnp.ndarray:
    """Nested lists of equal-shaped blocks -> one stacked array."""
    if isinstance(nested, list):
        return jnp.stack([stack_blocks(x) for x in nested], axis=0)
    return jnp.asarray(nested)


def _eval(g: Graph, inputs: Sequence[Any]) -> List[Any]:
    env: Dict = {}
    for nid, v in zip(g.input_ids, inputs):
        env[(nid, 0)] = v
    outs: Dict[int, Any] = {}
    for nid in g.topo():
        node = g.nodes[nid]
        if isinstance(node, InputNode):
            continue
        ins = [env[(e.src, e.sp)] for e in g.in_edges(nid)]
        if isinstance(node, OutputNode):
            outs[nid] = ins[0]
        elif isinstance(node, FuncNode):
            env[(nid, 0)] = node.op.apply(jnp, *ins)
        elif isinstance(node, ReduceNode):
            env[(nid, 0)] = _lower_reduce(node, ins[0])
        elif isinstance(node, MiscNode):
            res = node.fn(jnp, *ins)
            if node.n_out() == 1:
                env[(nid, 0)] = res
            else:
                for p, r in enumerate(res):
                    env[(nid, p)] = r
        elif isinstance(node, MapNode):
            results = _lower_map(node, ins)
            for p, r in enumerate(results):
                env[(nid, p)] = r
        else:
            raise TypeError(node)
    return [outs[oid] for oid in g.output_ids]


def _lower_reduce(node: ReduceNode, stacked) -> Any:
    if node.op == O.REDUCE_MAX:
        return jnp.max(stacked.astype(jnp.float32),
                       axis=0).astype(stacked.dtype)
    assert node.op == O.REDUCE_ADD, node.op
    return jnp.sum(stacked.astype(jnp.float32),
                   axis=0).astype(stacked.dtype)


def _lower_map(node: MapNode, ins: Sequence[Any]) -> List[Any]:
    mapped_ins = [v for v, m in zip(ins, node.mapped) if m]
    assert mapped_ins, "maps with no mapped input need static lengths"

    def body(*per_iter):
        it = iter(per_iter)
        full = [next(it) if m else b
                for b, m in zip(ins, node.mapped)]
        return _eval(node.inner, full)

    if not node.serial:
        outs = jax.vmap(body, in_axes=[0] * len(mapped_ins))(*mapped_ins)
        return list(outs)

    # serial map: accumulated ports become f32 scan carries.  "max"
    # ports carry a running maximum (init -inf) and "+@k" ports are
    # additive carries rescaled against max port k on every step —
    # together they are the online-softmax recurrence (see ops.py).
    first = jax.tree.map(lambda x: x[0], tuple(mapped_ins))
    out_shapes = jax.eval_shape(lambda xs: body(*xs), first)

    red_ports = [p for p, r in enumerate(node.reduced) if r is not None]
    cidx = {p: i for i, p in enumerate(red_ports)}

    def scan_body(carry, xs):
        res = body(*xs)
        vals = {p: res[p].astype(jnp.float32) for p in red_ports}
        z_old, z_new = {}, {}
        for p in red_ports:
            if node.reduced[p] == O.REDUCE_MAX:
                z_old[p] = carry[cidx[p]]
                z_new[p] = jnp.maximum(z_old[p], vals[p])
        new_carry = list(carry)
        ys = []
        for p, r in enumerate(node.reduced):
            if r is None:
                ys.append(res[p])
                continue
            c = carry[cidx[p]]
            if r == O.REDUCE_ADD:
                nc = c + vals[p]
            elif r == O.REDUCE_MAX:
                nc = z_new[p]
            else:
                k = O.rescaled_ref(r)
                assert k is not None, r
                step = vals[p] * O.bcast_to(
                    jnp, jnp.exp(vals[k] - z_new[k]), vals[p])
                nc = c * O.bcast_to(
                    jnp, jnp.exp(z_old[k] - z_new[k]), c) + step
            new_carry[cidx[p]] = nc
        return tuple(new_carry), tuple(ys)

    carry0 = tuple(
        jnp.full(out_shapes[p].shape, -jnp.inf, jnp.float32)
        if node.reduced[p] == O.REDUCE_MAX
        else jnp.zeros(out_shapes[p].shape, jnp.float32)
        for p in red_ports)
    carry, ys = jax.lax.scan(scan_body, carry0, tuple(mapped_ins))
    results: List[Any] = []
    ci = yi = 0
    for p, r in enumerate(node.reduced):
        if r is None:
            results.append(ys[yi])
            yi += 1
        else:
            results.append(carry[ci].astype(out_shapes[p].dtype))
            ci += 1
    return results


def compile_program(g: Graph, per_op_jit: bool = False
                    ) -> Callable[..., List[Any]]:
    """Return f(*stacked_inputs) -> [stacked_outputs], ready for jax.jit.

    With ``per_op_jit`` each top-level operator is jitted *separately*
    and dispatched sequentially from python, with every intermediate
    list materialized between launches.  That is the paper's
    launch-per-operator unfused baseline; jitting the whole unfused
    program instead hands the full graph to XLA, which fuses it itself,
    and the benchmark then measures "our fusion vs XLA's fusion" rather
    than fusion vs no fusion.
    """

    if not per_op_jit:
        def fn(*inputs):
            return _eval(g, inputs)

        return fn

    node_fns: Dict[int, Callable] = {}
    for nid in g.topo():
        node = g.nodes[nid]
        if isinstance(node, (InputNode, OutputNode)):
            continue

        def make(node=node):
            if isinstance(node, MapNode):
                def nf(*ins):
                    return tuple(_lower_map(node, ins))
            elif isinstance(node, FuncNode):
                def nf(*ins):
                    return (node.op.apply(jnp, *ins),)
            elif isinstance(node, ReduceNode):
                def nf(*ins):
                    return (_lower_reduce(node, ins[0]),)
            elif isinstance(node, MiscNode):
                def nf(*ins):
                    res = node.fn(jnp, *ins)
                    return res if node.n_out() > 1 else (res,)
            else:
                raise TypeError(node)
            return jax.jit(nf)

        node_fns[nid] = make()

    def fn_per_op(*inputs):
        env: Dict = {}
        for nid, v in zip(g.input_ids, inputs):
            env[(nid, 0)] = v
        outs: Dict[int, Any] = {}
        for nid in g.topo():
            node = g.nodes[nid]
            if isinstance(node, InputNode):
                continue
            ins = [env[(e.src, e.sp)] for e in g.in_edges(nid)]
            if isinstance(node, OutputNode):
                outs[nid] = ins[0]
                continue
            for p, r in enumerate(node_fns[nid](*ins)):
                env[(nid, p)] = r
        return [outs[oid] for oid in g.output_ids]

    return fn_per_op


def run_jax(g: Graph, inputs: Dict[str, Any]) -> Dict[str, Any]:
    """Convenience: run a program on nested-list block inputs via jit."""
    stacked = [stack_blocks(inputs[g.nodes[nid].name])
               for nid in g.input_ids]
    out = jax.jit(compile_program(g))(*stacked)
    return {g.nodes[oid].name: v
            for oid, v in zip(g.output_ids, out)}
