"""Step functions + abstract input specs for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) — the
dry-run lowers against these.  ``train_4k``/``prefill_32k`` lower
``train_step``/``prefill_step``; ``decode_*``/``long_*`` lower
``serve_step`` (one new token against a seq_len cache)."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec
from repro.models import build_model
from repro.models.common import ModelConfig
from repro.optim import AdamW, cosine_schedule
from repro.runtime.sharding import logical_to_spec, tree_shardings


def default_optimizer(total_steps: int = 10000) -> AdamW:
    return AdamW(lr=cosine_schedule(3e-4, 200, total_steps))


def sanitize_shardings(shardings, abstract, mesh):
    """Drop mesh axes that don't evenly divide an argument dimension
    (explicit jit arg shardings require divisibility — e.g. 8 KV heads on a
    16-way model axis, or batch=1 long-context decode on the data axis).
    Inner with_sharding_constraints may still shard unevenly (GSPMD pads).
    """
    import math
    from jax.sharding import NamedSharding, PartitionSpec as P

    def fix(sh, ab):
        if sh is None or not hasattr(ab, "shape"):
            return sh
        spec = list(sh.spec) + [None] * (len(ab.shape) - len(sh.spec))
        new = []
        for i, ax in enumerate(spec):
            if ax is None:
                new.append(None)
                continue
            axes = list(ax) if isinstance(ax, tuple) else [ax]
            while axes:
                size = math.prod(mesh.shape[a] for a in axes)
                if ab.shape[i] % size == 0:
                    break
                axes.pop()
            if not axes:
                new.append(None)
            elif len(axes) == 1:
                new.append(axes[0])
            else:
                new.append(tuple(axes))
        while new and new[-1] is None:
            new.pop()
        return NamedSharding(mesh, P(*new))

    return jax.tree.map(fix, shardings, abstract)


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract model inputs for one cell (kind-dependent)."""
    b, s = shape.global_batch, shape.seq_len
    extras: Dict[str, Any] = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = _sds((b, cfg.n_vision_tokens, cfg.d_model),
                                       cfg.dtype)
    if cfg.family == "encdec":
        extras["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), cfg.dtype)

    if shape.kind == "train":
        return {"tokens": _sds((b, s), jnp.int32),
                "labels": _sds((b, s), jnp.int32), **extras}
    if shape.kind == "prefill":
        return {"tokens": _sds((b, s), jnp.int32), **extras}
    if shape.kind == "decode":
        model = build_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
        return {"tokens": _sds((b, 1), jnp.int32),
                "pos": _sds((), jnp.int32),
                "cache": cache}
    raise ValueError(shape.kind)


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh,
                    model: Any) -> Dict[str, Any]:
    """NamedShardings matching input_specs' structure."""
    from jax.sharding import NamedSharding

    def ns(axes):
        return NamedSharding(mesh, logical_to_spec(axes, mesh))

    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = ns(("batch", None, None))
    if cfg.family == "encdec":
        extras["frames"] = ns(("batch", None, None))
    if shape.kind == "train":
        return {"tokens": ns(("batch", None)), "labels": ns(("batch", None)),
                **extras}
    if shape.kind == "prefill":
        return {"tokens": ns(("batch", None)), **extras}
    cache_specs = model.cache_specs()
    return {"tokens": ns(("batch", None)), "pos": ns(()),
            "cache": tree_shardings(cache_specs, mesh)}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(model, optimizer: AdamW):
    def train_step(params, opt_state, batch):
        kw = {k: v for k, v in batch.items()
              if k not in ("tokens", "labels")}
        loss, grads = jax.value_and_grad(model.loss)(
            params, batch["tokens"], batch["labels"], **kw)
        params, opt_state, metrics = optimizer.update(grads, opt_state,
                                                      params)
        return params, opt_state, {"loss": loss, **metrics}
    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        kw = {k: v for k, v in batch.items() if k != "tokens"}
        logits, cache = model.prefill(params, batch["tokens"], **kw)
        return logits[:, -1], cache
    return prefill_step


def make_serve_step(model):
    def serve_step(params, batch):
        logits, cache = model.decode_step(params, batch["cache"],
                                          batch["tokens"], batch["pos"])
        next_token = jnp.argmax(logits[:, -1], axis=-1)
        return next_token, cache
    return serve_step


def make_step(cfg: ModelConfig, shape: ShapeSpec, model=None,
              optimizer: Optional[AdamW] = None):
    """Returns (step_fn, abstract_args, arg_shardings_builder).

    abstract_args is a tuple matching step_fn's signature; the shardings
    builder takes a mesh and returns matching NamedShardings."""
    model = model or build_model(cfg)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        optimizer = optimizer or default_optimizer()
        step = make_train_step(model, optimizer)
        params_s = jax.eval_shape(lambda k: model.init_params(k)[0],
                                  jax.random.key(0))
        param_specs = _abstract_param_specs(model)
        opt_s = jax.eval_shape(optimizer.init, params_s)
        opt_specs = optimizer.state_specs(param_specs)
        args = (params_s, opt_s, specs)

        def shardings(mesh):
            raw = (tree_shardings(param_specs, mesh),
                   tree_shardings(opt_specs, mesh),
                   batch_shardings(cfg, shape, mesh, model))
            return sanitize_shardings(raw, args, mesh)
        return step, args, shardings

    if shape.kind == "prefill":
        step = make_prefill_step(model)
    else:
        step = make_serve_step(model)
    params_s = jax.eval_shape(lambda k: model.init_params(k)[0],
                              jax.random.key(0))
    param_specs = _abstract_param_specs(model)
    args = (params_s, specs)

    def shardings(mesh):
        raw = (tree_shardings(param_specs, mesh),
               batch_shardings(cfg, shape, mesh, model))
        return sanitize_shardings(raw, args, mesh)
    return step, args, shardings


def _abstract_param_specs(model):
    """The logical-axis spec tree (pure structure; no allocation)."""
    import numpy as np

    class _Capture:
        specs = None

    # init_params is pure; evaluate abstractly and capture the spec tree by
    # running the builder under eval_shape, returning specs via closure.
    out = {}

    def f(k):
        p, s = model.init_params(k)
        out["specs"] = s
        return p

    jax.eval_shape(f, jax.random.key(0))
    return out["specs"]
