"""Pure-jnp oracles for every fused kernel.

These are the *unfused* semantics (what the paper's array programs compute)
written directly in jnp.  Kernel tests sweep shapes/dtypes and
assert_allclose against these; they are also the default implementation on
backends without Pallas TPU support (this CPU container, and the multi-pod
dry-run, which lowers the jnp path to XLA HLO).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  scale: Optional[float] = None, causal: bool = False,
                  q_offset: int = 0) -> jax.Array:
    """Multi-head attention with GQA.

    q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh); Hq % Hkv == 0.
    Softmax in f32 with max subtraction (the appendix's safety, unfused).
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, group, sq, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    if causal:
        skv = k.shape[2]
        off = jnp.asarray(q_offset)
        if off.ndim == 1:  # ragged: per-sequence causal frontier
            off = off[:, None, None, None, None]
        rows = off + jnp.arange(sq)[:, None]
        cols = jnp.arange(skv)[None, :]
        s = jnp.where(rows >= cols, s, -1e30)
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(b, hq, sq, dv).astype(q.dtype)


def attention_xla_flash(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: Optional[float] = None, causal: bool = False,
                        q_offset: int = 0, block_kv: int = 512,
                        unroll: bool = False,
                        p_half: bool = False) -> jax.Array:
    """Flash-attention semantics expressed in pure XLA (lax.scan over KV
    chunks with the appendix's running-max carry).

    This is the lowering used at scale on backends without Pallas (and by
    the multi-pod dry-run): memory stays O(Sq * Dh + block_kv * Dh) instead
    of O(Sq * Skv), so compiled memory/cost analysis reflects the fused
    kernel rather than the naive quadratic program.
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    block_kv = min(block_kv, skv)
    pad = (-skv) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_blocks = (skv + pad) // block_kv
    # keep operands in the model dtype; accumulate in f32 on the MXU.
    # GQA: broadcast kv heads up to the full query-head count instead of
    # folding the group into the sequence dim — the (b,hkv,g*sq,d) reshape
    # crosses the tensor-sharded head axis and forces GSPMD into
    # "involuntary full rematerialization" (observed on the 256-chip mesh).
    qf = q
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    kb = jnp.moveaxis(k.reshape(b, hq, n_blocks, block_kv, dh), 2, 0)
    vb = jnp.moveaxis(v.reshape(b, hq, n_blocks, block_kv, dv), 2, 0)

    off = jnp.asarray(q_offset)
    if off.ndim == 1:  # ragged: per-sequence causal frontier
        off = off[:, None, None, None]
    rows = off + jnp.arange(sq)[None, None, :, None]

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kc, vc, idx = blk
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc,
                       preferred_element_type=jnp.float32) * scale
        cols = idx * block_kv + jnp.arange(block_kv)[None, None, None, :]
        mask = cols < skv
        if causal:
            mask = mask & (rows >= cols)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + p.sum(-1, keepdims=True)
        if p_half:
            # half-precision probabilities for the PV dot (what the Pallas
            # kernel feeds the MXU); f32 accumulator
            p = p.astype(q.dtype)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vc,
                                       preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hq, sq, 1), -1e30, jnp.float32),
            jnp.zeros((b, hq, sq, 1), jnp.float32),
            jnp.zeros((b, hq, sq, dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init,
                                  (kb, vb, jnp.arange(n_blocks)),
                                  unroll=n_blocks if unroll else 1)
    return (acc / l).astype(q.dtype)


def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array,
                  eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = xf.var(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * gamma + beta).astype(x.dtype)


def layernorm_matmul_ref(x: jax.Array, y: jax.Array, gamma: jax.Array,
                         beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Paper Example 2 (with the affine extension): LayerNorm_rows(X) @ Y."""
    ln = layernorm_ref(x, gamma, beta, eps).astype(jnp.float32)
    return (ln @ y.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    irms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * irms * gamma).astype(x.dtype)


def swish(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def rmsnorm_swiglu_ref(x: jax.Array, w: jax.Array, v: jax.Array,
                       u: jax.Array, gamma: jax.Array,
                       eps: float = 1e-6) -> jax.Array:
    """Paper Example 3: O = (Swish(RMS(X)@W) * (RMS(X)@V)) @ U."""
    xn = rmsnorm_ref(x, gamma, eps).astype(jnp.float32)
    g = swish(xn @ w.astype(jnp.float32))
    h = g * (xn @ v.astype(jnp.float32))
    return (h @ u.astype(jnp.float32)).astype(x.dtype)
