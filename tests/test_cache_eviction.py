"""Size-capped LRU eviction of the on-disk kernel-plan cache.

The ``CODEGEN_VERSION`` salt makes stale plans invisible, but until now
nothing deleted them (ROADMAP open item).  ``KernelCache`` evicts the
least-recently-*used* entries (hits touch mtime) after every write until
the directory fits ``max_disk_bytes``.
"""

import os
import time

import numpy as np
import pytest

from repro import pipeline
from repro.core import array_program as AP
from repro.pipeline.cache import CacheKey, CachePlan, KernelCache


def _plan(i):
    return CachePlan(0, {"M": i + 1}, 1.0, (1.0,), 2.0)


def _key(i):
    return CacheKey.make(f"fp{i}", "jax", {"M": i + 1}, None, True)


def _age(cache, key, seconds):
    """Backdate an entry's mtime (the LRU clock)."""
    for path in cache._paths(key):
        if path.exists():
            t = time.time() - seconds
            os.utime(path, (t, t))


def _age_all(cache, seconds):
    t = time.time() - seconds
    for path in cache.root.glob("*"):
        os.utime(path, (t, t))


def test_old_plans_evicted_fresh_survive(tmp_path):
    g = AP.layernorm_matmul_program(32.0).clone()
    cache = KernelCache(tmp_path, max_disk_bytes=1 << 40)
    sizes = []
    for i in range(3):
        cache.put_plan(_key(i), _plan(i), g)
        sizes.append(sum(s for _, _, s in cache.disk_entries()))
    per_entry = sizes[0]
    assert len(cache.disk_entries()) == 3

    # cap to two entries; oldest first in mtime order
    cache.max_disk_bytes = int(per_entry * 2.5)
    _age(cache, _key(0), 300)
    _age(cache, _key(1), 200)
    _age(cache, _key(2), 100)
    assert cache.evict() == 1
    assert cache.get_plan(_key(0)) == (None, None)   # evicted
    assert cache.get_plan(_key(1))[0] is not None    # survives
    assert cache.get_plan(_key(2))[0] is not None    # survives


def test_eviction_is_lru_not_fifo(tmp_path):
    g = AP.layernorm_matmul_program(32.0).clone()
    cache = KernelCache(tmp_path, max_disk_bytes=1 << 40)
    for i in range(2):
        cache.put_plan(_key(i), _plan(i), g)
    per_entry = sum(s for _, _, s in cache.disk_entries()) / 2
    _age(cache, _key(0), 300)
    _age(cache, _key(1), 200)
    # a hit on the older entry refreshes it ...
    assert cache.get_plan(_key(0))[0] is not None
    # ... so the cap evicts key 1, the least recently USED
    cache.max_disk_bytes = int(per_entry * 1.5)
    assert cache.evict() == 1
    assert cache.get_plan(_key(0))[0] is not None
    assert cache.get_plan(_key(1)) == (None, None)


def test_writes_trigger_eviction_and_compile_recovers(tmp_path):
    """Driver-level: a tiny cap keeps the newest plan usable and a
    re-compile of an evicted program just misses and re-plans."""
    case_g = AP.layernorm_matmul_program(32.0)
    att_g = AP.attention_program(0.125)
    dims_ln = {"M": 2, "K": 4, "N": 2}
    dims_att = {"M": 2, "D": 2, "N": 2, "L": 2}

    cache = KernelCache(tmp_path, max_disk_bytes=1 << 40)
    pipeline.compile(case_g, dims_ln, backend="jax", cache=cache)
    per_entry = sum(s for _, _, s in cache.disk_entries())
    # cap to ~one entry: writing the attention plan evicts layernorm's
    cache.max_disk_bytes = int(per_entry * 1.5)
    _age_all(cache, 300)
    pipeline.compile(att_g, dims_att, backend="jax", cache=cache)
    assert len(cache.disk_entries()) == 1

    # fresh cache object over the same dir (== new process): attention
    # hits disk, layernorm misses and recompiles fine
    c2 = KernelCache(tmp_path, max_disk_bytes=1 << 40)
    assert pipeline.compile(att_g, dims_att, backend="jax",
                            cache=c2).cache_hit == "disk"
    k = pipeline.compile(case_g, dims_ln, backend="jax", cache=c2)
    assert k.cache_hit is None


def test_zero_cap_disables_eviction(tmp_path):
    g = AP.layernorm_matmul_program(32.0).clone()
    cache = KernelCache(tmp_path, max_disk_bytes=0)
    for i in range(3):
        cache.put_plan(_key(i), _plan(i), g)
    assert cache.evict() == 0
    assert len(cache.disk_entries()) == 3


def test_cap_from_environment(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_CACHE_MAX_BYTES", "12345")
    assert KernelCache(tmp_path).max_disk_bytes == 12345
