"""Emit Pallas TPU kernels from *any* fusion snapshot.

The lowering is region-based (``core/regions.py``): the snapshot is
partitioned into a DAG of spine regions — each a nest of parallel maps
(-> pallas grid dimensions) around at most one accumulating node (a
serial map or a reduce -> the trailing sequential grid dimension with
f32 VMEM scratch carries) — the regions are packed into megakernel
*groups* (``regions.group_plan``: compatible parallel spines merge
under a VMEM budget), and ``emit_program`` emits one multi-stage
``pallas_call`` per group.  Stages run in sequence inside the kernel
body with their off-grid dims evaluated over whole-VMEM-resident data;
cross-region values whose producer and consumers share a group are
kernel-local VMEM carries, and only values that cross a *group*
boundary spill to merged global arrays between kernels (with dying
intermediates donated via ``input_output_aliases``).
The fully fused snapshots still lower to exactly one mega-kernel (the
paper's Example 1 epilogue == ``kernels/flash_attention.py`` modulo the
online-softmax rescale); partially fused snapshots and multi-output
programs lower to the multi-kernel schedule their traffic cost already
described, instead of raising ``"expected a single-map-spine"``.

Layout convention (program boundary and inter-region values alike): a
value typed ``block[A,B]`` is one merged array; leading list dims beyond
the item rank are plain stack axes of extent ``dims[d]``, the remaining
list dims split the item's axes in order — with the *actual* per-axis
item extents, which for intermediates (e.g. matmul partials
``block[M,N,K]``) need not equal ``blocks[d]``.  Item shapes are
propagated region-to-region via ``pipeline/packing.py`` helpers.  Dims
on a region's grid are tiled by BlockSpecs; other dims are
whole-resident in VMEM and in-kernel loops slice them.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ops as O
from repro.core import regions as R
from repro.core.blocks import item_shape as infer_item_shape
from repro.core.blocks import merged_shape
from repro.core.graph import (FuncNode, Graph, InputNode, MapNode,
                              OutputNode, Ref, ReduceNode, VType)
from repro.core.regions import ProgramPlan, RegionError, RegionSpec


# ---------------------------------------------------------------------------
# Reports: what lowered, how, and what (if anything) fell back
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RegionReport:
    label: str
    grid_dims: Tuple[str, ...]
    red_dim: Optional[str]
    n_outputs: int
    fallback: Optional[str] = None  # reason, when not lowered to Pallas
    group: str = ""                 # id of the kernel serving this region


@dataclass(frozen=True)
class KernelRun:
    """One emitted ``pallas_call``: the unit the executor launches.  The
    timing harness pairs each kernel's wall time with the per-kernel
    cost attribution by ``gid``, never by position."""

    gid: str
    label: str
    in_refs: Tuple[Ref, ...]
    out_refs: Tuple[Ref, ...]


@dataclass
class LoweringReport:
    """Provenance of one ``emit_program`` call: every region emitted,
    every fallback taken (which must be zero for in-repo programs), how
    many kernels actually launch (grouped regions share one), and how
    many cross-region values stayed VMEM-resident instead of
    round-tripping through global memory."""

    regions: List[RegionReport] = field(default_factory=list)
    launches: int = 0
    resident_edges: int = 0
    # the RegionError that made partitioning fall back to one
    # whole-program jax region (None when the partitioner succeeded) —
    # recorded so check_regression.py and the serve warmup fallback
    # checks can see the demotion instead of a silent except
    plan_error: Optional[str] = None

    @property
    def n_regions(self) -> int:
        return len(self.regions)

    @property
    def fallbacks(self) -> int:
        return sum(1 for r in self.regions if r.fallback is not None)

    def summary(self) -> str:
        parts = []
        for r in self.regions:
            grid = ",".join(r.grid_dims)
            tail = f"+{r.red_dim}*" if r.red_dim else ""
            note = f" FALLBACK({r.fallback})" if r.fallback else ""
            tag = f"@{r.group}" if r.group else ""
            parts.append(f"{r.label}[{grid}{tail}]{tag}{note}")
        return (f"{self.n_regions} regions in {self.launches} kernels "
                f"({self.resident_edges} resident edges): "
                + "; ".join(parts))


def plan(g: Graph) -> ProgramPlan:
    """Partition ``g`` into its Pallas region DAG (no codegen)."""
    return R.plan_program(g)


def resolve_interpret(interpret) -> bool:
    """``"auto"``/``None`` -> interpret everywhere except a real TPU
    backend.  Single source of the policy for emit and pipeline.compile."""
    if interpret in (None, "auto"):
        return jax.default_backend() != "tpu"
    return bool(interpret)


# ---------------------------------------------------------------------------
# Merged-layout helpers (actual item extents, not blocks[d])
# ---------------------------------------------------------------------------

def _axes(vt: VType, item_shape: Sequence[int]):
    """Per merged axis: ``(dim_or_None, per_block_extent)``.  Leading list
    dims beyond the item rank are stack axes (extent 1 per block); the
    next ``len(vt.dims) - lead`` item axes are split by the remaining
    dims; trailing item axes are untouched."""
    lead = max(len(vt.dims) - len(item_shape), 0)
    k = len(vt.dims) - lead
    axes = [(d, 1) for d in vt.dims[:lead]]
    axes += [(vt.dims[lead + j], item_shape[j]) for j in range(k)]
    axes += [(None, item_shape[j]) for j in range(k, len(item_shape))]
    return axes


def _block_shape(vt, item_shape, dims, grid_axes) -> Tuple[int, ...]:
    return tuple(b if d in grid_axes else (b * dims[d] if d else b)
                 for d, b in _axes(vt, item_shape))


def _block_spec(vt, item_shape, dims, grid_axes) -> pl.BlockSpec:
    axes = _axes(vt, item_shape)
    shape = _block_shape(vt, item_shape, dims, grid_axes)

    def index_map(*gids, axes=tuple(axes)):
        pos = dict(zip(grid_axes, gids))
        return tuple(pos[d] if d in grid_axes else 0 for d, _ in axes)

    return pl.BlockSpec(shape, index_map)


def _split_whole(arr, vt_dims, dims, grid_axes, axis=0):
    """Split non-grid list dims of a kernel block into nested python
    lists (the IR's value layout)."""
    if not vt_dims:
        return arr
    d = vt_dims[0]
    if d in grid_axes:
        return _split_whole(arr, vt_dims[1:], dims, grid_axes, axis + 1)
    n = dims[d]
    size = arr.shape[axis] // n
    parts = []
    for i in range(n):
        idx = [slice(None)] * arr.ndim
        idx[axis] = slice(i * size, (i + 1) * size)
        parts.append(_split_whole(arr[tuple(idx)], vt_dims[1:], dims,
                                  grid_axes, axis + 1))
    return parts


def _split_value(arr, vt: VType, item_shape, dims, grid_axes):
    """Kernel block -> the IR's nested-list value layout: leading stack
    axes are squeezed when grid-selected or unrolled into in-kernel
    lists; the remaining list dims slice item axes."""
    lead = max(len(vt.dims) - len(item_shape), 0)

    def rec(a, vt_dims, lead):
        if lead:
            d = vt_dims[0]
            if d in grid_axes:
                return rec(a[0], vt_dims[1:], lead - 1)
            return [rec(a[i], vt_dims[1:], lead - 1)
                    for i in range(dims[d])]
        return _split_whole(a, list(vt_dims), dims, grid_axes)

    return rec(arr, vt.dims, lead)


def _merge_value(val, vt: VType, item_rank: int, dims, grid_axes):
    """Inverse of :func:`_split_value` for an output value: stack
    off-grid lead lists, concatenate off-grid split lists along their
    item axis.  Grid-selected dims contribute nothing (the BlockSpec
    positions the block); the caller reshapes to the out-ref block."""
    lead = max(len(vt.dims) - item_rank, 0)

    def rec(v, ds, lead, axis):
        if not ds:
            return v
        d = ds[0]
        if lead:
            if d in grid_axes:
                return rec(v, ds[1:], lead - 1, axis)
            return jnp.stack([rec(x, ds[1:], lead - 1, axis) for x in v],
                             axis=0)
        if d in grid_axes:
            return rec(v, ds[1:], 0, axis + 1)
        return jnp.concatenate([rec(x, ds[1:], 0, axis + 1) for x in v],
                               axis=axis)

    return rec(val, vt.dims, lead, 0)


def _first_item(v):
    while isinstance(v, list):
        v = v[0]
    return v


# ---------------------------------------------------------------------------
# In-kernel evaluation
# ---------------------------------------------------------------------------

def _eval_inner(g: Graph, env: Dict, dims: Dict[str, int],
                grid_axes: frozenset = frozenset()) -> List[Any]:
    """In-kernel evaluation; list values are python lists of VMEM slices,
    serial maps unroll statically.  A map over a dim in ``grid_axes``
    (the grouped-kernel path: the pallas grid already selected that
    block) runs a single iteration with mapped values passed through
    unsplit and outputs left unwrapped."""
    out: Dict[int, Any] = {}
    for nid in g.topo():
        node = g.nodes[nid]
        if isinstance(node, InputNode):
            continue
        ins = [env[(e.src, e.sp)] for e in g.in_edges(nid)]
        if isinstance(node, OutputNode):
            out[nid] = ins[0]
        elif isinstance(node, FuncNode):
            env[(nid, 0)] = node.op.apply(jnp, *ins)
        elif isinstance(node, ReduceNode):
            acc = ins[0][0]
            for item in ins[0][1:]:
                acc = (jnp.maximum(acc, item)
                       if node.op == O.REDUCE_MAX else acc + item)
            env[(nid, 0)] = acc
        elif isinstance(node, MapNode) and node.dim in grid_axes:
            if node.serial:
                raise RegionError(
                    f"serial map[{node.dim}] over a grid-selected dim")
            ienv: Dict = {}
            for p, e in enumerate(g.in_edges(nid)):
                ienv[(node.inner.input_ids[p], 0)] = env[(e.src, e.sp)]
            res = _eval_inner(node.inner, ienv, dims, grid_axes)
            for pp in range(node.n_out()):
                env[(nid, pp)] = res[pp]
        elif isinstance(node, MapNode):
            n = dims[node.dim]
            collected: List[Any] = [[] if r is None else None
                                    for r in node.reduced]
            for i in range(n):
                ienv: Dict = {}
                for p, e in enumerate(g.in_edges(nid)):
                    v = env[(e.src, e.sp)]
                    if node.mapped[p]:
                        v = v[i]
                    ienv[(node.inner.input_ids[p], 0)] = v
                res = _eval_inner(node.inner, ienv, dims, grid_axes)
                # handles plain "+" and the coupled "max"/"+@k" carries
                # of stabilized programs alike (static unroll)
                O.serial_accum_step(collected, res, node.reduced, jnp)
            for pp in range(node.n_out()):
                env[(nid, pp)] = collected[pp]
        else:
            raise TypeError(node)
    return [out[oid] for oid in g.output_ids]


def _eval_funcs(g: Graph, env: Dict, skip: set, dims) -> Dict:
    """Evaluate every FuncNode of one spine level except ``skip``
    (the spine map / the accumulator and its epilogue)."""
    env = dict(env)
    for nid in g.topo():
        node = g.nodes[nid]
        if isinstance(node, FuncNode) and nid not in skip:
            ins = [env[(e.src, e.sp)] for e in g.in_edges(nid)]
            env[(nid, 0)] = node.op.apply(jnp, *ins)
    return env


def _downstream(g: Graph, nid: int) -> set:
    seen = {nid}
    frontier = [nid]
    while frontier:
        n = frontier.pop()
        for e in g.out_edges(n):
            if e.dst not in seen:
                seen.add(e.dst)
                frontier.append(e.dst)
    return seen


# ---------------------------------------------------------------------------
# Region lowering
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _OutSlot:
    kind: str            # "step" (written every serial step) | "final"
    level: int           # spine level index (len(levels) == base level)
    ref: Ref             # value ref at that level (final slots)
    step_port: int = -1  # acc list-port index (step slots)
    vt: VType = VType()


def _region_levels(spec: RegionSpec):
    """(parallel levels [(graph, map id)], base graph, acc id or None)."""
    rg = spec.graph
    root = [n for n in rg.op_nodes()][0]
    levels: List[Tuple[Graph, int]] = []
    g_lvl, node = rg, rg.nodes[root]
    nid = root
    while isinstance(node, MapNode) and not node.serial:
        gi = node.inner
        pars = [n for n in sorted(gi.op_nodes())
                if isinstance(gi.nodes[n], MapNode)
                and not gi.nodes[n].serial]
        accs = [n for n in sorted(gi.op_nodes())
                if (isinstance(gi.nodes[n], MapNode)
                    and gi.nodes[n].serial)
                or isinstance(gi.nodes[n], ReduceNode)]
        levels.append((g_lvl, nid))
        if len(pars) == 1 and not accs:
            g_lvl, nid, node = gi, pars[0], gi.nodes[pars[0]]
            continue
        if pars:
            raise RegionError(f"not a spine region: {spec.label}")
        return levels, gi, (accs[0] if accs else None)
    if isinstance(node, (MapNode, ReduceNode)):  # serial root / reduce root
        return levels, g_lvl, nid
    return levels, g_lvl, None  # func root


def _classify_outputs(spec: RegionSpec, levels, base_g, acc_id,
                      red_dim, types) -> List[_OutSlot]:
    rg = spec.graph
    slots: List[_OutSlot] = []
    for oid in rg.output_ids:
        e = rg.in_edge(oid, 0)
        ref: Ref = (e.src, e.sp)
        lvl = 0
        while lvl < len(levels) and ref[0] == levels[lvl][1]:
            mnode: MapNode = levels[lvl][0].nodes[levels[lvl][1]]
            inner = mnode.inner
            ie = inner.in_edge(inner.output_ids[ref[1]], 0)
            ref = (ie.src, ie.sp)
            lvl += 1
        vt = types[(e.src, e.sp)]
        if (acc_id is not None and ref[0] == acc_id
                and isinstance(base_g.nodes[acc_id], MapNode)
                and base_g.nodes[acc_id].reduced[ref[1]] is None):
            slots.append(_OutSlot("step", lvl, ref, ref[1], vt))
        else:
            slots.append(_OutSlot("final", lvl, ref, -1, vt))
    return slots


def _alias_map(merged_inputs, out_shapes, dtype, donate,
               in_layouts=None, out_layouts=None):
    """``input_output_aliases`` for one ``pallas_call``: donate each
    dying merged intermediate (``donate[i]`` True — its last consumer is
    this kernel and it is not a program value) to the first unclaimed
    output of identical shape, dtype, AND block layout
    (``(vt.dims, item_shape)`` — which fixes the BlockSpec/index map).
    The layout match matters for correctness: grid steps run in
    sequence, so an aliased pair with identical index maps means step
    *i* overwrites exactly the block it just read, while mismatched
    index maps could clobber blocks a later step still reads.  XLA
    copies when an aliased input is still live (e.g. the timing harness
    re-calling a kernel), so donation never corrupts caller data."""
    if not donate:
        return {}
    aliases: Dict[int, int] = {}
    used: set = set()
    for i, ok in enumerate(donate):
        if not ok or merged_inputs[i].dtype != dtype:
            continue
        for j, s in enumerate(out_shapes):
            if (j not in used
                    and tuple(merged_inputs[i].shape) == tuple(s)
                    and (in_layouts is None or out_layouts is None
                         or in_layouts[i] == out_layouts[j])):
                aliases[i] = j
                used.add(j)
                break
    return aliases


def emit_region(spec: RegionSpec, dims: Dict[str, int],
                in_item_shapes: List[Tuple[int, ...]], interpret: bool,
                donate: Optional[Sequence[bool]] = None):
    """Lower one region to a single multi-output ``pallas_call``.

    Returns ``(fn, out_item_shapes, report)`` where ``fn`` maps merged
    input arrays to a tuple of merged output arrays.  ``donate[i]``
    marks input *i* as a dying intermediate whose buffer may be aliased
    to a same-shape output."""
    rg = spec.graph
    levels, base_g, acc_id = _region_levels(spec)
    red_dim = spec.red_dim
    grid_dims = list(spec.grid_dims)
    grid_axes = grid_dims + ([red_dim] if red_dim else [])
    for d in grid_axes:
        if d not in dims:
            raise RegionError(f"grid dim {d} missing from dims")

    in_types = [rg.nodes[i].vtype for i in rg.input_ids]
    types = rg.infer_types()
    acc_node = base_g.nodes[acc_id] if acc_id is not None else None
    if isinstance(acc_node, ReduceNode) and acc_node.op not in (
            O.REDUCE_ADD, O.REDUCE_MAX):
        raise RegionError(f"unsupported reduce {acc_node.op!r}")
    # reduced tags of the compressed accumulator list, and the port ->
    # accumulator-index map "+@k" tags resolve through
    if isinstance(acc_node, ReduceNode):
        acc_tags: List[Any] = [acc_node.op]
        acc_of_port: Dict[int, int] = {0: 0}
    elif acc_node is not None:
        acc_tags = [r for r in acc_node.reduced if r is not None]
        acc_of_port = {p: ai for ai, p in enumerate(
            p for p, r in enumerate(acc_node.reduced) if r is not None)}
        for r in acc_tags:
            if (r not in (O.REDUCE_ADD, O.REDUCE_MAX)
                    and O.rescaled_ref(r) is None):
                raise RegionError(f"unsupported reduced tag {r!r}")
    else:
        acc_tags, acc_of_port = [], {}
    epilogue_skip = (_downstream(base_g, acc_id)
                     if acc_id is not None else set())
    slots = _classify_outputs(spec, levels, base_g, acc_id, red_dim, types)

    def bind_values(values: Dict[int, Any]):
        """Walk the parallel levels, evaluating level funcs; returns the
        per-level envs plus the base-level env (pre-accumulator)."""
        envs: List[Dict] = []
        env = {(iid, 0): values[iid] for iid in rg.input_ids}
        for lg, mid in levels:
            env = _eval_funcs(lg, env, {mid}, dims)
            envs.append(env)
            mnode: MapNode = lg.nodes[mid]
            nxt = {}
            for p, e in enumerate(lg.in_edges(mid)):
                nxt[(mnode.inner.input_ids[p], 0)] = env[(e.src, e.sp)]
            env = nxt
        return envs, env

    def serial_step(values: Dict[int, Any]):
        """One accumulator step: (partials, {list port: step value})."""
        _, env = bind_values(values)
        env = _eval_funcs(base_g, env, epilogue_skip, dims)
        if isinstance(acc_node, ReduceNode):
            e = base_g.in_edge(acc_id, 0)
            return [env[(e.src, e.sp)]], {}
        senv: Dict = {}
        for p, e in enumerate(base_g.in_edges(acc_id)):
            senv[(acc_node.inner.input_ids[p], 0)] = env[(e.src, e.sp)]
        res = _eval_inner(acc_node.inner, senv, dims)
        partials = [res[p] for p, r in enumerate(acc_node.reduced)
                    if r is not None]
        steps = {p: res[p] for p, r in enumerate(acc_node.reduced)
                 if r is None}
        return partials, steps

    def final_envs(values: Dict[int, Any], acc_vals: List[Any]):
        envs, env = bind_values(values)
        if acc_id is not None:
            ai = 0
            if isinstance(acc_node, ReduceNode):
                env[(acc_id, 0)] = acc_vals[0]
            else:
                for p, r in enumerate(acc_node.reduced):
                    if r is not None:
                        env[(acc_id, p)] = acc_vals[ai]
                        ai += 1
        env = _eval_funcs(base_g, env, {acc_id} if acc_id is not None
                          else set(), dims)
        envs.append(env)
        return envs

    # -- abstract shape analysis (one invocation) ---------------------------
    abstract_ins = [
        jax.ShapeDtypeStruct(_block_shape(vt, ish, dims, grid_axes),
                             jnp.float32)
        for vt, ish in zip(in_types, in_item_shapes)]

    def abs_values(arrs):
        return {iid: _split_value(a, vt, ish, dims, grid_axes)
                for iid, a, vt, ish in zip(rg.input_ids, arrs, in_types,
                                           in_item_shapes)}

    n_acc = 0
    scratch: List[Any] = []
    if acc_id is not None:
        acc_shapes = jax.eval_shape(
            lambda *a: tuple(serial_step(abs_values(a))[0]), *abstract_ins)
        scratch = [pltpu.VMEM(a.shape, jnp.float32) for a in acc_shapes]
        n_acc = len(acc_shapes)

    def out_items(*arrs):
        values = abs_values(arrs)
        steps: Dict[int, Any] = {}
        if acc_id is not None:
            partials, steps = serial_step(values)
            envs = final_envs(values, list(partials))
        else:
            envs = final_envs(values, [])
        picked = []
        for s in slots:
            v = steps[s.step_port] if s.kind == "step" else envs[s.level][s.ref]
            picked.append(_first_item(v))
        return tuple(picked)

    out_item_abs = jax.eval_shape(out_items, *abstract_ins)
    out_item_shapes = [tuple(a.shape) for a in out_item_abs]
    out_full = [merged_shape(s.vt, ish, dims)
                for s, ish in zip(slots, out_item_shapes)]
    out_specs = [_block_spec(s.vt, ish, dims, grid_axes)
                 for s, ish in zip(slots, out_item_shapes)]
    in_specs = [_block_spec(vt, ish, dims, grid_axes)
                for vt, ish in zip(in_types, in_item_shapes)]

    n_in, n_out = len(rg.input_ids), len(slots)
    n_red = dims[red_dim] if red_dim else 0

    def write(o_ref, slot, ish, v):
        merged = _merge_value(v, slot.vt, len(ish), dims, grid_axes)
        o_ref[...] = merged.reshape(o_ref.shape).astype(o_ref.dtype)

    def kernel(*refs):
        in_refs = refs[:n_in]
        out_refs = refs[n_in:n_in + n_out]
        acc_refs = refs[n_in + n_out:]
        values = {iid: _split_value(r[...], vt, ish, dims, grid_axes)
                  for iid, r, vt, ish in zip(rg.input_ids, in_refs,
                                             in_types, in_item_shapes)}
        if acc_id is None:
            envs = final_envs(values, [])
            for o_ref, slot, ish in zip(out_refs, slots, out_item_shapes):
                write(o_ref, slot, ish, envs[slot.level][slot.ref])
            return
        ri = pl.program_id(len(grid_dims))

        @pl.when(ri == 0)
        def _init():
            for a, tag in zip(acc_refs, acc_tags):
                a[...] = (jnp.full_like(a, -jnp.inf)
                          if tag == O.REDUCE_MAX else jnp.zeros_like(a))

        partials, steps = serial_step(values)
        vals = [p_val.astype(jnp.float32) for p_val in partials]
        # two-phase coupled update (see ops.serial_accum_step): read the
        # old running maxima before any scratch write, then advance every
        # accumulator — "+@k" ports rescale by exp(z_old-z_new) exactly as
        # in the online-softmax recurrence
        z_old: Dict[int, Any] = {}
        z_new: Dict[int, Any] = {}
        for ai, tag in enumerate(acc_tags):
            if tag == O.REDUCE_MAX:
                z_old[ai] = acc_refs[ai][...]
                z_new[ai] = jnp.maximum(z_old[ai], vals[ai])
        for ai, tag in enumerate(acc_tags):
            if tag == O.REDUCE_ADD:
                acc_refs[ai][...] += vals[ai]
            elif tag == O.REDUCE_MAX:
                acc_refs[ai][...] = z_new[ai]
            else:
                ak = acc_of_port[O.rescaled_ref(tag)]
                step = vals[ai] * O.bcast_to(
                    jnp, jnp.exp(vals[ak] - z_new[ak]), vals[ai])
                acc_refs[ai][...] = (
                    acc_refs[ai][...]
                    * O.bcast_to(jnp, jnp.exp(z_old[ak] - z_new[ak]),
                                 acc_refs[ai][...])
                    + step)
        for o_ref, slot, ish in zip(out_refs, slots, out_item_shapes):
            if slot.kind == "step":
                write(o_ref, slot, ish, steps[slot.step_port])

        @pl.when(ri == n_red - 1)
        def _done():
            envs = final_envs(values, [a[...] for a in acc_refs])
            for o_ref, slot, ish in zip(out_refs, slots, out_item_shapes):
                if slot.kind == "final":
                    write(o_ref, slot, ish, envs[slot.level][slot.ref])

    grid = tuple(dims[d] for d in grid_axes)

    in_layouts = [(vt.dims, tuple(ish))
                  for vt, ish in zip(in_types, in_item_shapes)]
    out_layouts = [(s.vt.dims, tuple(ish))
                   for s, ish in zip(slots, out_item_shapes)]

    def region_fn(*merged_inputs):
        dtype = (jnp.result_type(*merged_inputs) if merged_inputs
                 else jnp.float32)
        outs = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=[jax.ShapeDtypeStruct(s, dtype) for s in out_full],
            scratch_shapes=scratch,
            input_output_aliases=_alias_map(merged_inputs, out_full,
                                            dtype, donate, in_layouts,
                                            out_layouts),
            interpret=interpret,
        )(*merged_inputs)
        return tuple(outs)

    report = RegionReport(spec.label, tuple(grid_dims), red_dim, n_out)
    return region_fn, out_item_shapes, report


def emit_group(group, types: Dict[Ref, VType], dims: Dict[str, int],
               in_item_shapes: List[Tuple[int, ...]], interpret: bool,
               donate: Optional[Sequence[bool]] = None):
    """Lower one region *group* to a single multi-stage ``pallas_call``.

    The kernel grid is the group's shared parallel spine; every member
    region runs in sequence inside the kernel body with its off-grid
    dims evaluated over whole-VMEM-resident data (serial spines unroll
    in-kernel), and every in-group cross-region value is carried as a
    kernel-local VMEM value — it never touches global memory.  Only the
    group's spilled ``out_refs`` are written out.

    Returns ``(fn, out_item_shapes, reports)`` with one
    :class:`RegionReport` per member."""
    grid_axes = list(group.grid_dims)
    gset = frozenset(grid_axes)
    for d in grid_axes:
        if d not in dims:
            raise RegionError(f"grid dim {d} missing from dims")
    in_types = [types[r] for r in group.in_refs]
    out_types = [types[r] for r in group.out_refs]

    def run_stages(values: Dict[Ref, Any]) -> Dict[Ref, Any]:
        env = dict(values)
        for spec in group.members:
            ienv = {}
            for iid, r in zip(spec.graph.input_ids, spec.in_refs):
                ienv[(iid, 0)] = env[r]
            res = _eval_inner(spec.graph, ienv, dims, gset)
            for r, v in zip(spec.out_refs, res):
                env[r] = v
        return env

    abstract_ins = [
        jax.ShapeDtypeStruct(_block_shape(vt, ish, dims, grid_axes),
                             jnp.float32)
        for vt, ish in zip(in_types, in_item_shapes)]

    def abs_values(arrs):
        return {r: _split_value(a, vt, ish, dims, grid_axes)
                for r, a, vt, ish in zip(group.in_refs, arrs, in_types,
                                         in_item_shapes)}

    def out_items(*arrs):
        env = run_stages(abs_values(arrs))
        return tuple(_first_item(env[r]) for r in group.out_refs)

    out_item_abs = jax.eval_shape(out_items, *abstract_ins)
    out_item_shapes = [tuple(a.shape) for a in out_item_abs]
    out_full = [merged_shape(vt, ish, dims)
                for vt, ish in zip(out_types, out_item_shapes)]
    out_specs = [_block_spec(vt, ish, dims, grid_axes)
                 for vt, ish in zip(out_types, out_item_shapes)]
    in_specs = [_block_spec(vt, ish, dims, grid_axes)
                for vt, ish in zip(in_types, in_item_shapes)]
    n_in, n_out = len(group.in_refs), len(group.out_refs)

    def kernel(*refs):
        in_refs_, out_refs_ = refs[:n_in], refs[n_in:n_in + n_out]
        values = {r: _split_value(ref[...], vt, ish, dims, grid_axes)
                  for r, ref, vt, ish in zip(group.in_refs, in_refs_,
                                             in_types, in_item_shapes)}
        env = run_stages(values)
        for o_ref, r, vt, ish in zip(out_refs_, group.out_refs,
                                     out_types, out_item_shapes):
            merged = _merge_value(env[r], vt, len(ish), dims, grid_axes)
            o_ref[...] = merged.reshape(o_ref.shape).astype(o_ref.dtype)

    grid = tuple(dims[d] for d in grid_axes)

    in_layouts = [(vt.dims, tuple(ish))
                  for vt, ish in zip(in_types, in_item_shapes)]
    out_layouts = [(vt.dims, tuple(ish))
                   for vt, ish in zip(out_types, out_item_shapes)]

    def group_fn(*merged_inputs):
        dtype = (jnp.result_type(*merged_inputs) if merged_inputs
                 else jnp.float32)
        outs = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=[jax.ShapeDtypeStruct(s, dtype) for s in out_full],
            input_output_aliases=_alias_map(merged_inputs, out_full,
                                            dtype, donate, in_layouts,
                                            out_layouts),
            interpret=interpret,
        )(*merged_inputs)
        return tuple(outs)

    reports = [RegionReport(spec.label, spec.grid_dims, spec.red_dim,
                            len(spec.out_refs), group=group.gid)
               for spec in group.members]
    return group_fn, out_item_shapes, reports


def _fallback_region(spec: RegionSpec, dims: Dict[str, int],
                     in_item_shapes, reason: str):
    """Region the Pallas emitter cannot express: lower it with the jax
    backend (vmap/scan) behind the same merged-array contract."""
    from repro.core.codegen_jax import compile_program
    from repro.pipeline import packing as P
    rg = spec.graph
    in_info = [(rg.nodes[i].name, rg.nodes[i].vtype)
               for i in rg.input_ids]
    out_types = P.output_types(rg)
    prog = compile_program(rg)

    def fn(*merged):
        stacked = [P.to_stacked(a, vt, dims)
                   for (_, vt), a in zip(in_info, merged)]
        outs = prog(*stacked)
        return tuple(P.from_stacked(o, vt, dims)
                     for vt, o in zip(out_types, outs))

    in_full = [merged_shape(vt, ish, dims)
               for (_, vt), ish in zip(in_info, in_item_shapes)]
    abs_out = jax.eval_shape(
        fn, *[jax.ShapeDtypeStruct(s, jnp.float32) for s in in_full])
    out_item_shapes = [infer_item_shape(a.shape, vt, dims)
                       for a, vt in zip(abs_out, out_types)]
    report = RegionReport(spec.label, tuple(spec.grid_dims), spec.red_dim,
                          len(out_types), fallback=reason)
    return fn, out_item_shapes, report


# ---------------------------------------------------------------------------
# Whole-program lowering
# ---------------------------------------------------------------------------

def emit_program(g: Graph, dims: Dict[str, int], blocks: Dict[str, int],
                 interpret="auto",
                 program_plan: Optional[ProgramPlan] = None,
                 grouped_plan=None, group: bool = True
                 ) -> Tuple[Callable[..., Tuple], LoweringReport]:
    """Lower every region of (the partition of) ``g``.

    Regions are first packed into megakernel groups
    (``regions.group_plan``, unless ``group=False``): one multi-stage
    ``pallas_call`` per group, with in-group cross-region values carried
    in VMEM.  Returns ``(fn, report)``: ``fn`` takes one merged array
    per program input and returns a tuple of merged arrays, one per
    program output; ``report`` records the regions emitted, the kernels
    launched (``report.launches``), the VMEM-resident edges, and any
    fallbacks taken (a region the Pallas emitter cannot express runs on
    the jax backend — zero for all in-repo programs, and pinned to zero
    by ``tests/test_lowering_coverage.py``).  Callers that already
    partitioned/grouped ``g`` (the driver shares one plan between
    lowering and per-kernel cost attribution) pass it via
    ``program_plan``/``grouped_plan``."""
    interpret = resolve_interpret(interpret)
    try:
        pp = program_plan if program_plan is not None else plan(g)
    except RegionError as err:
        # un-partitionable program (MiscNode, exotic pass-through): one
        # whole-program jax region, reported as a fallback
        whole = RegionSpec(-1, "program", (), None, g.clone(),
                           [(i, 0) for i in g.input_ids],
                           [(o, 0) for o in g.output_ids])
        in_items = [
            tuple(blocks[d] for d in vt.dims[vt.lead_dims:])
            for vt in (g.nodes[i].vtype for i in g.input_ids)]
        fn, _, rep = _fallback_region(whole, dims, in_items, str(err))
        fn.region_runners = [(KernelRun("g0:program", "program",
                                        tuple(whole.in_refs),
                                        tuple(whole.out_refs)), fn)]
        fn.input_refs = [(i, 0) for i in g.input_ids]
        fn.emitted_kernels = [("g0:program", whole)]
        return fn, LoweringReport([rep], launches=1,
                                  plan_error=str(err))
    gp = grouped_plan
    if gp is None:
        gp = (R.group_plan(pp, dims, blocks) if group
              else R.ungrouped_plan(pp))
    types = pp.graph.infer_types()
    report = LoweringReport()

    item_shapes: Dict[Ref, Tuple[int, ...]] = {}
    prog_in = set()
    for iid in pp.graph.input_ids:
        vt = pp.graph.nodes[iid].vtype
        for d in vt.dims[:vt.lead_dims]:
            if blocks.get(d, 1) != 1:
                raise ValueError(
                    f"stack dim {d} of {vt!r} needs block size 1, got "
                    f"{blocks[d]}")
        item_shapes[(iid, 0)] = tuple(blocks[d]
                                      for d in vt.dims[vt.lead_dims:])
        prog_in.add((iid, 0))
    prog_out = {(e.src, e.sp) for oid in pp.graph.output_ids
                for e in [pp.graph.in_edge(oid, 0)]}

    # a merged intermediate dies at its last consuming kernel: that
    # kernel may donate its buffer to a same-shape output
    last_use: Dict[Ref, int] = {}
    for gi, grp in enumerate(gp.groups):
        for r in grp.in_refs:
            last_use[r] = gi

    def donatable(refs: Sequence[Ref], gi: int) -> List[bool]:
        return [r not in prog_in and r not in prog_out
                and last_use.get(r) == gi for r in refs]

    lowered: List[Tuple[KernelRun, Callable]] = []
    # what each emitted kernel actually serves (a RegionGroup, or a
    # RegionSpec for singleton/degraded kernels) — the driver recomputes
    # per-kernel cost provenance from this when emission diverged from
    # the planned grouping
    emitted: List[Tuple[str, Any]] = []

    def lower_one(spec: RegionSpec, gid: str, gi: int) -> None:
        in_items = [item_shapes[r] for r in spec.in_refs]
        try:
            fn, out_items, rep = emit_region(
                spec, dims, in_items, interpret,
                donate=donatable(spec.in_refs, gi))
        except (RegionError, NotImplementedError) as err:
            fn, out_items, rep = _fallback_region(spec, dims, in_items,
                                                  str(err))
        rep = replace(rep, group=gid)
        for ref, ish in zip(spec.out_refs, out_items):
            item_shapes[ref] = ish
        lowered.append((KernelRun(gid, rep.label, tuple(spec.in_refs),
                                  tuple(spec.out_refs)), fn))
        emitted.append((gid, spec))
        report.regions.append(rep)

    for gi, grp in enumerate(gp.groups):
        if len(grp.members) == 1:
            lower_one(grp.members[0], grp.gid, gi)
            continue
        try:
            in_items = [item_shapes[r] for r in grp.in_refs]
            fn, out_items, reps = emit_group(
                grp, types, dims, in_items, interpret,
                donate=donatable(grp.in_refs, gi))
        except (RegionError, NotImplementedError) as err:
            # a group the emitter cannot express degrades to per-region
            # kernels (still Pallas when possible), never to one big
            # jax fallback
            warnings.warn(
                f"grouped lowering of {grp.gid} fell back to per-region "
                f"kernels ({err})", RuntimeWarning, stacklevel=2)
            for spec in grp.members:
                lower_one(spec, f"{grp.gid}.{spec.node}", gi)
            continue
        for ref, ish in zip(grp.out_refs, out_items):
            item_shapes[ref] = ish
        lowered.append((KernelRun(grp.gid, grp.label, tuple(grp.in_refs),
                                  tuple(grp.out_refs)), fn))
        emitted.append((grp.gid, grp))
        report.regions.extend(reps)
        report.resident_edges += len(grp.resident)
    report.launches = len(lowered)

    out_refs: List[Ref] = []
    for oid in pp.graph.output_ids:
        e = pp.graph.in_edge(oid, 0)
        out_refs.append((e.src, e.sp))

    def run(*merged_inputs):
        env: Dict[Ref, Any] = {
            (iid, 0): a
            for iid, a in zip(pp.graph.input_ids, merged_inputs)}
        for kr, fn in lowered:
            outs = fn(*[env[r] for r in kr.in_refs])
            for ref, o in zip(kr.out_refs, outs):
                env[ref] = o
        return tuple(env[r] for r in out_refs)

    # per-kernel callables for the timing harness: core/timing.py
    # re-threads the same env and times each kernel standalone, pairing
    # wall times with the per-kernel cost attribution by KernelRun.gid
    run.region_runners = lowered
    run.input_refs = [(iid, 0) for iid in pp.graph.input_ids]
    run.emitted_kernels = emitted
    return run, report


def emit(g: Graph, dims: Dict[str, int], blocks: Dict[str, int],
         interpret="auto") -> Callable[..., jax.Array]:
    """Strict single-output convenience wrapper around
    :func:`emit_program`: every region must lower to Pallas (no jax
    fallback) and the program must have exactly one output, which is
    returned as a bare array.  ``interpret`` may be a bool, ``None``, or
    ``"auto"`` (see :func:`resolve_interpret`)."""
    fn, report = emit_program(g, dims, blocks, interpret=interpret)
    if report.fallbacks:
        bad = [r for r in report.regions if r.fallback]
        raise ValueError(
            f"not fully Pallas-lowerable: {[r.fallback for r in bad]}")
    if len(g.output_ids) != 1:
        raise ValueError("emit() expects a single-output program; use "
                         "emit_program for multi-output lowering")

    def single(*merged_inputs):
        return fn(*merged_inputs)[0]

    return single
