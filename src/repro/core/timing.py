"""Wall-clock kernel timing: the measurement half of the
predict -> run -> measure -> recalibrate loop.

* :func:`time_callable` — the robust harness every measurement goes
  through: warmup calls first (compilation, tracing), then median-of-K
  timed calls, each fenced with ``jax.block_until_ready`` so async
  dispatch cannot leak work across the stopwatch.
* :func:`region_times` — per-kernel timing of a compiled
  ``pipeline.CompiledKernel`` on the Pallas backend: each emitted
  kernel (a region-group megakernel counts once) is timed standalone
  with inputs threaded exactly as the real execution threads them.
  Entries carry the kernel id; :func:`pair_region_times` matches them
  with ``CompiledKernel.region_costs`` *by id* — the (features,
  seconds) samples ``core/calibrate.py`` fits — and
  :func:`stage_time_attribution` splits a megakernel's time across its
  member regions.
* :func:`synth_inputs` — synthetic merged inputs for a program at given
  dims/block extents (position vectors get ``arange``, data gets scaled
  normals), shared by the measured autotuner and the benchmarks.
* :func:`measured` — a process-wide measurement memo keyed by
  ``(fingerprint, dims, backend, device, ...)`` so the autotuner never
  times the same configuration twice.
* :func:`spearman` — rank agreement between predicted and measured
  orderings (the calibration acceptance metric).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blocks import merged_shape
from repro.core.graph import Graph

# names that carry global positions, not data (the attention programs'
# query/key position vectors) — synthetic inputs must keep them ordinal
POSITION_INPUTS = ("QP", "KP")


def _sync(out) -> None:
    """Block until ``out`` (any pytree of arrays) is actually computed;
    numpy leaves pass through untouched."""
    try:
        import jax
        jax.block_until_ready(out)
    except ImportError:  # pragma: no cover - jax is a hard dep in-repo
        pass


@dataclass(frozen=True)
class TimingResult:
    times_s: Tuple[float, ...]

    @property
    def median_s(self) -> float:
        return float(np.median(self.times_s))

    @property
    def best_s(self) -> float:
        return float(min(self.times_s))


def time_callable(fn: Callable, *args, warmup: int = 1, repeats: int = 5,
                  **kwargs) -> TimingResult:
    """Median-of-``repeats`` wall time of ``fn(*args, **kwargs)`` after
    ``warmup`` untimed calls; every call is fenced."""
    for _ in range(max(warmup, 0)):
        _sync(fn(*args, **kwargs))
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        _sync(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return TimingResult(tuple(times))


# ---------------------------------------------------------------------------
# Synthetic inputs
# ---------------------------------------------------------------------------

def stack_dims(g: Graph) -> frozenset:
    """Dims that appear as leading stack axes of some program input —
    the Pallas backend requires block size 1 for them."""
    out = set()
    for nid in g.input_ids:
        vt = g.nodes[nid].vtype
        out.update(vt.dims[:vt.lead_dims])
    return frozenset(out)


def synth_blocks(g: Graph, dims: Dict[str, int],
                 item: int = 8) -> Dict[str, int]:
    """A valid per-dim block-extent map for ``g``: ``item`` everywhere,
    1 on stack dims (the Pallas constraint)."""
    sd = stack_dims(g)
    return {d: (1 if d in sd else item) for d in dims}


def synth_inputs(g: Graph, dims: Dict[str, int],
                 blocks: Optional[Dict[str, int]] = None, *,
                 item: int = 8, seed: int = 0) -> Dict[str, np.ndarray]:
    """Random merged input arrays for ``g`` at ``dims`` with per-dim
    block extents ``blocks`` (default: :func:`synth_blocks`).  Data
    inputs are normals scaled by the contraction width; position inputs
    get ``arange`` so causal masks stay meaningful."""
    rng = np.random.default_rng(seed)
    blocks = blocks if blocks is not None else synth_blocks(g, dims, item)
    out = {}
    for nid in g.input_ids:
        node = g.nodes[nid]
        vt = node.vtype
        ish = tuple(blocks.get(d, item) for d in vt.dims[vt.lead_dims:])
        shape = merged_shape(vt, ish, dims)
        if node.name in POSITION_INPUTS:
            out[node.name] = np.arange(shape[0], dtype=np.float32)
        else:
            out[node.name] = (rng.normal(size=shape)
                              / max(shape[-1], 1) ** 0.5
                              ).astype(np.float32)
    return out


# ---------------------------------------------------------------------------
# Per-region timing of a compiled plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RegionTime:
    label: str
    result: TimingResult
    gid: str = ""  # id of the emitted kernel (codegen_pallas.KernelRun)

    @property
    def median_s(self) -> float:
        return self.result.median_s


def region_times(kern, inputs: Dict[str, Any], *, warmup: int = 1,
                 repeats: int = 5) -> Optional[List[RegionTime]]:
    """Wall time of each emitted kernel of a compiled Pallas
    ``CompiledKernel``.  One entry per launched kernel (a region-group
    megakernel serving several regions is one entry), each carrying the
    kernel id (``gid``) — pair with ``kern.region_costs`` via
    :func:`pair_region_times`, never by position.

    The kernels are executed in topological order with real
    intermediates threaded between them (exactly what ``kern(inputs)``
    does), but each kernel is warmed up and timed standalone.  Returns
    ``None`` for kernels that do not expose region runners (py/jax
    backends)."""
    raw = getattr(getattr(kern, "_fn", None), "raw_program", None)
    runners = getattr(raw, "region_runners", None)
    if runners is None:
        return None
    try:  # time the COMPILED kernel: eager interpret-mode dispatch costs
        import jax  # scale with the traced body size, not with traffic,
        jit = jax.jit  # which would skew megakernel-vs-region comparisons
    except ImportError:  # pragma: no cover - jax is a hard dep in-repo
        def jit(f):
            return f
    merged = [inputs[nm] for nm in kern.in_names]
    env: Dict[Tuple[int, int], Any] = dict(zip(raw.input_refs, merged))
    out: List[RegionTime] = []
    for spec, fn in runners:
        jfn = jit(fn)
        args = [env[r] for r in spec.in_refs]
        # the first warmup call (also the trace+compile) doubles as the
        # real execution whose outputs thread into downstream kernels
        outs = jfn(*args)
        _sync(outs)
        for ref, o in zip(spec.out_refs, outs):
            env[ref] = o
        res = time_callable(jfn, *args, warmup=max(warmup - 1, 0),
                            repeats=repeats)
        out.append(RegionTime(spec.label, res, getattr(spec, "gid", "")))
    return out


def pair_region_times(kern, times: Sequence[RegionTime]
                      ) -> List[Tuple[str, float, float]]:
    """Explicit id-based pairing of measured kernel times with the
    driver's per-kernel cost attribution: ``(gid, predicted cost,
    measured seconds)`` for every kernel present in BOTH
    ``kern.kernel_ids``/``kern.region_costs`` and ``times``.  Robust to
    a kernel serving several regions and to emission-time degradation
    (a degraded group's kernels carry derived ids and simply do not
    pair)."""
    ids = getattr(kern, "kernel_ids", None)
    costs = getattr(kern, "region_costs", None)
    if not ids or not costs or len(ids) != len(costs):
        return []
    cost_of = dict(zip(ids, costs))
    out = []
    for t in times:
        if t.gid in cost_of:
            out.append((t.gid, float(cost_of[t.gid]), t.median_s))
    return out


def pair_region_features(times: Sequence[RegionTime],
                         features: Sequence[Tuple[str, Dict[str, float]]]
                         ) -> List[Tuple[str, Dict[str, float], float]]:
    """Id-based pairing of measured kernel times with per-kernel
    *feature rows* (``calibrate.group_features`` output — item counts,
    per-class ``work_*`` FLOPs, launches): ``(gid, features, seconds)``
    for every kernel present in both.  These pairs are what
    ``calibrate.fit_profile`` consumes, so the fit regresses against
    the full schema-2 feature vector, not just the scalar cost."""
    feat_of = {gid: f for gid, f in features}
    out = []
    for t in times:
        if t.gid in feat_of:
            out.append((t.gid, feat_of[t.gid], t.median_s))
    return out


def stage_time_attribution(kern, times: Sequence[RegionTime]
                           ) -> List[Tuple[str, str, float]]:
    """Attribute each measured kernel time to the *regions* it serves:
    ``(gid, region label, seconds)`` rows where a megakernel's wall time
    is split across its member regions proportionally to their analytic
    standalone costs (``selection.snapshot_cost`` of each region graph —
    a model-based attribution, since stages inside one ``pallas_call``
    cannot be fenced individually).  Single-region kernels get their
    full time."""
    report = getattr(kern, "lowering_report", None)
    if report is None:
        return []
    labels_of: Dict[str, List[str]] = {}
    for r in report.regions:
        labels_of.setdefault(r.group, []).append(r.label)
    weights_of: Dict[str, List[float]] = {}
    from repro.core import regions as R
    from repro.core import selection as SEL
    try:
        gp = R.group_plan(R.plan_program(kern.graph), kern.dims,
                          kern.blocks)
    except R.RegionError:  # un-partitionable kernel graph: equal split
        gp = None
    # only trust the re-derived grouping when it reproduces the
    # kernel's own ids (it may not, e.g. under a changed VMEM budget)
    if gp is not None and (tuple(grp.gid for grp in gp.groups)
                           == tuple(kern.kernel_ids or ())):
        for grp in gp.groups:
            labels_of[grp.gid] = [m.label for m in grp.members]
            weights_of[grp.gid] = [SEL.snapshot_cost(m.graph, kern.dims)
                                   for m in grp.members]
    out = []
    for t in times:
        labels = labels_of.get(t.gid, [t.label])
        weights = weights_of.get(t.gid, [1.0] * len(labels))
        total = sum(weights) or 1.0
        for lbl, w in zip(labels, weights):
            out.append((t.gid, lbl, t.median_s * w / total))
    return out


# ---------------------------------------------------------------------------
# Measurement memo
# ---------------------------------------------------------------------------

_MEASUREMENTS: Dict[Tuple, float] = {}


def measured(key: Tuple, thunk: Callable[[], float]) -> float:
    """Process-wide memo: run ``thunk`` (seconds) once per ``key``.
    Keys embed everything the measurement depends on — graph
    fingerprint, dims, backend, device, problem extents — so re-sweeps
    and overlapping top-K sets never re-time a configuration."""
    if key not in _MEASUREMENTS:
        _MEASUREMENTS[key] = float(thunk())
    return _MEASUREMENTS[key]


def clear_measurements() -> None:
    """Drop the memo (tests)."""
    _MEASUREMENTS.clear()


def measurement_count() -> int:
    return len(_MEASUREMENTS)


# ---------------------------------------------------------------------------
# Rank agreement
# ---------------------------------------------------------------------------

def _ranks(v: Sequence[float]) -> np.ndarray:
    a = np.asarray(v, dtype=np.float64)
    order = np.argsort(a, kind="stable")
    ranks = np.empty(len(a), dtype=np.float64)
    ranks[order] = np.arange(len(a), dtype=np.float64)
    # average ties so equal values cannot fake agreement
    for val in np.unique(a):
        m = a == val
        if m.sum() > 1:
            ranks[m] = ranks[m].mean()
    return ranks


def spearman(pred: Sequence[float], meas: Sequence[float]) -> float:
    """Spearman rank correlation between a predicted and a measured
    ordering.  Fewer than two samples is vacuous agreement (1.0); one
    constant side against a varying one is no agreement (0.0)."""
    if len(pred) != len(meas):
        raise ValueError("length mismatch")
    if len(pred) < 2:
        return 1.0
    rp, rm = _ranks(pred), _ranks(meas)
    sp, sm = rp.std(), rm.std()
    if sp == 0.0 and sm == 0.0:
        return 1.0
    if sp == 0.0 or sm == 0.0:
        return 0.0
    return float(np.corrcoef(rp, rm)[0, 1])
