"""Traffic cost model (the fusion objective made explicit).

Counts, symbolically from the hierarchy, exactly the ``load``/``store``
instructions that the paper's listings contain:

* a *store* for every item written into a buffered (list-typed) value.
  Lists materialize at the map out-port that wraps a locally-produced item
  (one ``store`` per iteration); outer ports that merely re-wrap an
  already-global list are views, not extra traffic.
* a *load* whenever a global item is brought into a local temp — once per
  consuming loop iteration, shared between consumers at that level
  (``t1 = load(X[m,d])`` serves every use of ``t1``); a reduce over a
  global list loads each item.

Also counts functional-operator applications (work; Rule 6 replicates work)
and top-level operator count (kernel launches before candidate selection
splits the program).

Causal masking (``Graph.causal_dims`` maps a key-block dim to its
query-block dim): a fully-masked tile is never loaded, computed, or
stored — a map over a masked key dim nested inside its query dim iterates
only the non-fully-masked tiles, so its trip count drops from ``N`` to
the average ``sum_m ceil((m+1)*N/M) / M`` (``(N+1)/2`` when the two dims
tile the sequence identically).  This is exactly the traffic win causal
fusion buys, and it is what makes the cost model prefer the causal
program's snapshots for decoder workloads.

VMEM residency (the region-group megakernel lowering): when several
regions share one kernel, their cross-region values never touch global
memory.  ``traffic`` takes ``in_global`` flags (a non-global input is a
VMEM-resident value: consuming it loads nothing) and ``resident_out``
flags (a resident output is kept in VMEM for a same-kernel consumer:
producing it stores nothing); :func:`group_traffic` aggregates a region
group's members under those flags with a single launch — the cost of the
megakernel that actually runs.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from math import prod
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import (FuncNode, Graph, InputNode, MapNode, MiscNode,
                              OutputNode, ReduceNode, VType)


# --- per-op-class work (FLOP) features --------------------------------------
# ``Traffic.work`` counts op *applications* by op name (already weighted
# by loop trip counts).  For the compute term of the cost model each op
# name maps to a class whose per-application FLOP weight is taken at the
# same representative block extent as DEFAULT_ITEM_BYTES (128x128 f32
# blocks): a block matmul is O(e^3), everything else touches each item
# element once, O(e^2).  Ranking only needs the relative weights.

WORK_CLASSES = ("matmul", "elementwise", "reduce")
MATMUL_OPS = frozenset({"dot", "outer"})
REDUCE_OPS = frozenset({"row_sum", "row_max", "reduce_add", "reduce_max"})
REPR_BLOCK_EXTENT = 128


def op_class(name: str) -> str:
    """The work class of one functional operator name."""
    if name in MATMUL_OPS:
        return "matmul"
    if name in REDUCE_OPS:
        return "reduce"
    return "elementwise"


def flop_weights(extent: int = REPR_BLOCK_EXTENT) -> Dict[str, float]:
    """FLOPs of one op application on ``extent``-sized square blocks."""
    return {"matmul": 2.0 * extent ** 3,
            "elementwise": float(extent ** 2),
            "reduce": float(extent ** 2)}


@dataclass
class Traffic:
    loads: Counter = field(default_factory=Counter)    # item kind -> count
    stores: Counter = field(default_factory=Counter)
    work: Counter = field(default_factory=Counter)     # op name -> count
    launches: int = 0
    # kernel grid cells per launch (program instances): each cell pays
    # dispatch/prologue overhead on top of its loads/stores/FLOPs.  Only
    # region-level accounting knows the grid (``group_traffic`` fills it
    # from the group's grid dims); whole-program traffic leaves it 0.
    instances: float = 0.0

    def total_items(self) -> int:
        return sum(self.loads.values()) + sum(self.stores.values())

    def bytes_moved(self, item_bytes: Dict[str, int]) -> int:
        return (sum(item_bytes.get(k, 0) * v for k, v in self.loads.items())
                + sum(item_bytes.get(k, 0) * v for k, v in self.stores.items()))

    def flops(self, extent: int = REPR_BLOCK_EXTENT) -> Dict[str, float]:
        """Estimated FLOPs per work class: op applications weighted by
        the per-class FLOP count at ``extent``-sized blocks.  Every
        class is always present (zero when the program does no such
        work), so feature vectors have a stable column set."""
        w = flop_weights(extent)
        out = {c: 0.0 for c in WORK_CLASSES}
        for name, n in self.work.items():
            cls = op_class(name)
            out[cls] += w[cls] * n
        return out


def _causal_trips(q_count: int, k_count: int) -> float:
    """Expected non-fully-masked key-block count per query block, assuming
    both dims tile the same sequence uniformly.  Equals ``(k+1)/2`` when
    ``q_count == k_count``; always ``<= k_count``."""
    tot = 0
    for m in range(q_count):
        tot += min(k_count, -(-((m + 1) * k_count) // q_count))
    return tot / q_count


def _eff_count(dim: str, sizes: Dict[str, int], causal: Dict[str, str],
               enclosing: frozenset):
    """Trip count of ``dim``, discounted when it is causally masked
    against an enclosing query dim (masked tiles are skipped)."""
    q_dim = causal.get(dim)
    if q_dim is not None and q_dim in enclosing:
        return _causal_trips(sizes[q_dim], sizes[dim])
    return sizes[dim]


def _n_items(dims: Tuple[str, ...], sizes: Dict[str, int],
             causal: Dict[str, str] = {},
             enclosing: frozenset = frozenset()):
    return prod(_eff_count(d, sizes, causal, enclosing) for d in dims)


def _walk(g: Graph, in_types: Sequence[VType], in_global: Sequence[bool],
          mult: float, sizes: Dict[str, int], t: Traffic, top: bool,
          causal: Dict[str, str] = {},
          enclosing: frozenset = frozenset(),
          skip_oids: frozenset = frozenset()) -> None:
    types = g.infer_types(in_types)
    glob: Dict[Tuple[int, int], bool] = {}
    for nid, gl in zip(g.input_ids, in_global):
        glob[(nid, 0)] = gl
    order = g.topo()

    for nid in order:
        node = g.nodes[nid]
        if isinstance(node, (InputNode, OutputNode)):
            continue
        for p in range(node.n_out()):
            glob[(nid, p)] = types[(nid, p)].is_list

    # loads of global items into local temps; reduce loads over global lists
    for nid in order:
        node = g.nodes[nid]
        if isinstance(node, OutputNode):
            continue
        for p in range(node.n_out()):
            vt = types[(nid, p)]
            cons = [e for e in g.out_edges(nid, p)
                    if not isinstance(g.nodes[e.dst], OutputNode)]
            if glob[(nid, p)] and not vt.is_list and cons:
                t.loads[vt.item] += mult
                glob[(nid, p)] = False  # now in a local temp
            if vt.is_list and glob[(nid, p)]:
                # a VMEM-resident list (in_global False) is read in
                # place: the reduce costs no global loads
                for e in cons:
                    if isinstance(g.nodes[e.dst], ReduceNode):
                        t.loads[vt.item] += mult * _n_items(
                            vt.dims, sizes, causal, enclosing)

    if top:  # item-typed program outputs get a single store
        for oid in g.output_ids:
            if oid in skip_oids:
                continue  # VMEM-resident output: no global store
            e = g.in_edge(oid, 0)
            vt = types[(e.src, e.sp)]
            if not vt.is_list:
                t.stores[vt.item] += mult

    # work + stores-at-materialization + recursion into maps
    for nid in order:
        node = g.nodes[nid]
        if isinstance(node, FuncNode):
            t.work[node.op.name] += mult
        elif isinstance(node, ReduceNode):
            e = g.in_edge(nid, 0)
            vt = types[(e.src, e.sp)]
            key = "reduce_max" if node.op == "max" else "reduce_add"
            t.work[key] += mult * max(
                _n_items(vt.dims, sizes, causal, enclosing) - 1, 0)
        elif isinstance(node, MapNode):
            dim_n = _eff_count(node.dim, sizes, causal, enclosing)
            inner_types: List[VType] = []
            inner_glob: List[bool] = []
            for p in range(node.n_in()):
                e = g.in_edge(nid, p)
                vt = types[(e.src, e.sp)]
                src_glob = glob[(e.src, e.sp)]
                if node.mapped[p]:
                    inner_types.append(vt.strip())
                    inner_glob.append(src_glob)
                else:
                    inner_types.append(vt)
                    inner_glob.append(src_glob)
            inner_tmap = node.inner.infer_types(inner_types)
            for p, oid in enumerate(node.inner.output_ids):
                ie = node.inner.in_edge(oid, 0)
                ivt = inner_tmap[(ie.src, ie.sp)]
                consumed = any(e.dst not in skip_oids
                               for e in g.out_edges(nid, p))
                if node.reduced[p] is None and not ivt.is_list and consumed:
                    # the list materializes here: one store per iteration
                    t.stores[ivt.item] += mult * dim_n
            _walk(node.inner, inner_types, inner_glob, mult * dim_n, sizes, t,
                  top=False, causal=causal,
                  enclosing=enclosing | {node.dim})


def traffic(g: Graph, sizes: Dict[str, int],
            in_global: Optional[Sequence[bool]] = None,
            resident_out: Optional[Sequence[bool]] = None) -> Traffic:
    """Global-memory traffic of ``g``.

    ``in_global`` (per ``g.input_ids``): ``False`` marks an input that is
    already VMEM-resident — consuming it loads nothing.  ``resident_out``
    (per ``g.output_ids``): ``True`` marks an output kept in VMEM for a
    same-kernel consumer — producing it stores nothing.  Both default to
    the historical all-global accounting.
    """
    t = Traffic()
    in_types = [g.nodes[nid].vtype for nid in g.input_ids]
    causal = dict(getattr(g, "causal_dims", None) or {})
    glob = (list(in_global) if in_global is not None
            else [True] * len(in_types))
    if len(glob) != len(in_types):
        raise ValueError("in_global length != number of inputs")
    skip: frozenset = frozenset()
    if resident_out is not None:
        if len(resident_out) != len(g.output_ids):
            raise ValueError("resident_out length != number of outputs")
        skip = frozenset(oid for oid, r in zip(g.output_ids, resident_out)
                         if r)
    _walk(g, in_types, glob, 1, sizes, t, top=True, causal=causal,
          skip_oids=skip)
    t.launches = len(g.op_nodes())
    return t


def group_traffic(group, sizes: Dict[str, int]) -> Traffic:
    """Aggregate traffic of one region-group megakernel.

    ``group`` is a ``regions.RegionGroup`` (duck-typed: ``members`` with
    per-member ``graph``/``in_refs``/``out_refs``, plus the group-level
    ``out_refs``).  Member traffic is summed with every in-group edge
    uncharged — an input produced by a fellow member is VMEM-resident
    (loads nothing) and an output consumed only inside the group stores
    nothing — and the whole group costs exactly one kernel launch.  A
    global input shared by several members is charged once (the first
    consumer pays the load): the emitted kernel dedupes it to a single
    input with one BlockSpec fetch, and later stages read the same VMEM
    copy.
    """
    produced = {r for m in group.members for r in m.out_refs}
    spilled = set(group.out_refs)
    seen: set = set()
    total = Traffic()
    for m in group.members:
        t = traffic(m.graph, sizes,
                    in_global=[r not in produced and r not in seen
                               for r in m.in_refs],
                    resident_out=[r not in spilled for r in m.out_refs])
        seen.update(m.in_refs)
        total.loads.update(t.loads)
        total.stores.update(t.stores)
        total.work.update(t.work)
    total.launches = 1
    total.instances = float(prod(sizes[d] for d in group.grid_dims))
    return total


def traffic_bytes(g: Graph, sizes: Dict[str, int],
                  item_bytes: Dict[str, int]) -> int:
    return traffic(g, sizes).bytes_moved(item_bytes)
