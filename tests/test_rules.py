"""Per-rule unit tests (paper §3), each on a minimal synthetic program."""

import numpy as np
import pytest

from repro.core import ops as O
from repro.core.blocks import merge, split
from repro.core.graph import GB, MapNode, VType
from repro.core.interpreter import eval_graph, run
from repro.core.rules import (Rule1, Rule2, Rule3, Rule7, Rule9)


def _ew_map_graph(expr, n_in=1):
    gb = GB()
    ins = [gb.inp(f"a{i}", VType((), O.BLOCK)) for i in range(n_in)]
    gb.out("o", gb.func(O.ew(expr, n_in), *ins))
    return gb.g


def _chain_program():
    """X -> map(x*2) -> map(x+1) -> O."""
    gb = GB()
    x = gb.inp("X", VType(("N",), O.BLOCK))
    m1 = gb.map("N", _ew_map_graph("a0*2.0"), [(x, True)])
    m2 = gb.map("N", _ew_map_graph("a0+1.0"), [(m1[0], True)])
    gb.out("O", m2[0])
    return gb.g


def test_rule1_fuses_chain():
    g = _chain_program()
    xs = [np.full((2, 2), float(i)) for i in range(3)]
    ref = eval_graph(g, [xs], {"N": 3})[0]
    m = Rule1.match(g)
    assert m is not None
    Rule1.apply(g, m)
    assert len(g.op_nodes()) == 1
    out = eval_graph(g, [xs], {"N": 3})[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))
    assert Rule1.match(g) is None


def test_rule1_blocked_by_indirect_path():
    """u -> w -> v plus u -> v: fusing u,v would create a cycle."""
    gb = GB()
    x = gb.inp("X", VType(("N",), O.BLOCK))
    u = gb.map("N", _ew_map_graph("a0*2.0"), [(x, True)])
    w = gb.map("N", _ew_map_graph("a0+3.0"), [(u[0], True)])
    v = gb.map("N", _ew_map_graph("a0+a1", 2), [(u[0], True), (w[0], True)])
    gb.out("O", v[0])
    g = gb.g
    uid = u[0][0]
    vid = v[0][0]
    m = Rule1.match(g)
    assert m is not None and not (m.data["u"] == uid and m.data["v"] == vid)


def test_rule1_blocked_by_reduced_edge():
    """v consuming u's accumulated (completed) output cannot fuse."""
    gb = GB()
    inner = GB()
    a = inner.inp("a", VType((), O.BLOCK))
    inner.out("o", inner.func(O.ew("a0"), a))
    x = gb.inp("X", VType(("N",), O.BLOCK))
    u = gb.map("N", inner.g, [(x, True)], reduced=["+"])
    inner2 = GB()
    b = inner2.inp("b", VType((), O.BLOCK))
    c = inner2.inp("c", VType((), O.BLOCK))
    inner2.out("o", inner2.func(O.ew("a0+a1", 2), b, c))
    v = gb.map("N", inner2.g, [(x, True), (u[0], False)])
    gb.out("O", v[0])
    assert Rule1.match(gb.g) is None


def test_rule2_fuses_siblings_and_merges_parent():
    gb = GB()
    x = gb.inp("X", VType(("N",), O.BLOCK))
    m1 = gb.map("N", _ew_map_graph("a0*2.0"), [(x, True)])
    m2 = gb.map("N", _ew_map_graph("a0+1.0"), [(x, True)])
    o1 = gb.out("O1", m1[0])
    o2 = gb.out("O2", m2[0])
    g = gb.g
    m = Rule2.match(g)
    assert m is not None
    Rule2.apply(g, m)
    assert len(g.op_nodes()) == 1
    fused = g.nodes[g.op_nodes()[0]]
    assert fused.n_in() == 1  # shared parent merged into one port
    xs = [np.full((2, 2), float(i)) for i in range(3)]
    out = eval_graph(g, [xs], {"N": 3})
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray([x * 2 for x in xs]))
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray([x + 1 for x in xs]))


def test_rule3_moves_reduction_inside():
    gb = GB()
    inner = GB()
    a = inner.inp("a", VType((), O.BLOCK))
    inner.out("o", inner.func(O.ROW_SUM, a))
    x = gb.inp("X", VType(("N",), O.BLOCK))
    m1 = gb.map("N", inner.g, [(x, True)])
    r = gb.reduce(m1[0])
    gb.out("O", r)
    g = gb.g
    m = Rule3.match(g)
    assert m is not None
    Rule3.apply(g, m)
    mnode = g.nodes[g.op_nodes()[0]]
    assert isinstance(mnode, MapNode) and mnode.reduced[0] == "+"
    xs = [np.arange(6.0).reshape(2, 3) + i for i in range(4)]
    out = eval_graph(g, [xs], {"N": 4})[0]
    np.testing.assert_allclose(out, np.sum([x.sum(1) for x in xs], axis=0))


def test_rule3_requires_sole_consumer():
    gb = GB()
    inner = GB()
    a = inner.inp("a", VType((), O.BLOCK))
    inner.out("o", inner.func(O.ROW_SUM, a))
    x = gb.inp("X", VType(("N",), O.BLOCK))
    m1 = gb.map("N", inner.g, [(x, True)])
    r = gb.reduce(m1[0])
    gb.out("O", r)
    gb.out("O2", m1[0])  # second consumer of the list
    assert Rule3.match(gb.g) is None


def test_rule7_peel_first_iteration():
    g = _chain_program()
    xs = [np.full((2, 2), float(i)) for i in range(4)]
    ref = eval_graph(g, [xs], {"N": 4})[0]
    m = Rule7.match(g)
    assert m is not None
    Rule7.apply(g, m)
    out = eval_graph(g, [xs], {"N": 4})[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


def test_rule9_composes_elementwise():
    gb = GB()
    x = gb.inp("x", VType((), O.BLOCK))
    f1 = gb.func(O.ew("a0*C0", 1, C0=0.5), x)
    f2 = gb.func(O.ew("exp(a0)"), f1)
    gb.out("o", f2)
    g = gb.g
    m = Rule9.match(g)
    assert m is not None
    Rule9.apply(g, m)
    assert len(g.op_nodes()) == 1
    xv = np.array([[1.0, 2.0]])
    out = eval_graph(g, [xv], {})[0]
    np.testing.assert_allclose(out, np.exp(xv * 0.5))


def test_rule9_requires_sole_consumer():
    gb = GB()
    x = gb.inp("x", VType((), O.BLOCK))
    f1 = gb.func(O.ew("a0*2.0"), x)
    f2 = gb.func(O.ew("exp(a0)"), f1)
    gb.out("o", f2)
    gb.out("o2", f1)
    assert Rule9.match(gb.g) is None
