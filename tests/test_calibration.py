"""Calibration of the traffic cost model (``core/calibrate.py``).

* the default profile IS the historical constants (single source of
  truth for ``selection.DEFAULT_ITEM_BYTES``/``KERNEL_LAUNCH_COST``);
* synthetic timings generated from known coefficients are recovered by
  the least-squares fit (tolerance-bounded), including the
  scaled-default fallback for item kinds the samples never exercised;
* profiles round-trip through the cache dir, and a stale or corrupt
  profile falls back to the defaults with a warning;
* the committed ``BENCH_pipeline.json`` artifact carries wall-clock
  speedups for all five programs and a calibrated predicted-vs-measured
  region ranking with Spearman >= 0.6 (the acceptance metric).
"""

import json
from dataclasses import replace
from pathlib import Path

import numpy as np
import pytest

from repro.core import array_program as AP
from repro.core import calibrate as CAL
from repro.core import cost as C
from repro.core import selection as SEL
from repro.core import timing as T
from repro.core.fusion import fuse

REPO_ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# Default profile == the historical constants
# ---------------------------------------------------------------------------

def test_default_profile_is_single_source_of_truth():
    assert SEL.DEFAULT_ITEM_BYTES is CAL.DEFAULT_ITEM_BYTES
    assert SEL.KERNEL_LAUNCH_COST == CAL.KERNEL_LAUNCH_COST
    assert dict(CAL.DEFAULT_PROFILE.item_coef) == dict(
        CAL.DEFAULT_ITEM_BYTES)
    assert CAL.DEFAULT_PROFILE.launch_coef == CAL.KERNEL_LAUNCH_COST


def test_snapshot_cost_default_matches_historical_formula():
    g = AP.attention_program(0.125)
    dims = {"M": 2, "D": 2, "N": 3, "L": 2}
    t = C.traffic(g, dims)
    expect = (t.bytes_moved(CAL.DEFAULT_ITEM_BYTES)
              + CAL.KERNEL_LAUNCH_COST * t.launches)
    assert SEL.snapshot_cost(g, dims) == expect
    # a profile with doubled coefficients doubles the cost exactly
    doubled = replace(
        CAL.DEFAULT_PROFILE,
        item_coef={k: 2 * v for k, v in CAL.DEFAULT_ITEM_BYTES.items()},
        launch_coef=2 * CAL.KERNEL_LAUNCH_COST)
    assert SEL.snapshot_cost(g, dims, profile=doubled) == 2 * expect
    # the legacy item_bytes dict still overrides
    ones = {"block": 1, "vector": 1, "scalar": 1}
    assert SEL.snapshot_cost(g, dims, item_bytes=ones) == (
        t.bytes_moved(ones) + CAL.KERNEL_LAUNCH_COST * t.launches)


def test_region_features_pair_with_region_costs():
    """``profile.predict`` on a region's feature row IS that region's
    ``snapshot_cost`` — the fit regresses against the exact terms the
    selector sums."""
    g = fuse(AP.attention_program(0.125))[0]
    dims = {"M": 2, "D": 2, "N": 3, "L": 2}
    feats = CAL.region_features(g, dims)
    costs = SEL.region_costs(g, dims)
    assert feats is not None and costs is not None
    assert len(feats) == len(costs) >= 2
    for f, c in zip(feats, costs):
        assert CAL.DEFAULT_PROFILE.predict(f) == pytest.approx(c)


# ---------------------------------------------------------------------------
# The fit
# ---------------------------------------------------------------------------

def _rows(rng, n=40):
    rows = []
    for _ in range(n):
        rows.append({"block": float(rng.integers(1, 200)),
                     "vector": float(rng.integers(0, 50)),
                     "scalar": float(rng.integers(0, 10)),
                     "launches": 1.0})
    return rows


def test_fit_recovers_known_coefficients():
    rng = np.random.default_rng(7)
    rows = _rows(rng)
    true = {"block": 3e-5, "vector": 2e-6, "scalar": 1e-7,
            "launches": 4e-4}
    times = [sum(true[k] * v for k, v in r.items()) for r in rows]
    prof = CAL.fit_profile(rows, times, backend="pallas",
                           device_kind="testdev")
    assert prof.source == "measured"
    assert prof.n_samples == len(rows)
    assert prof.residual < 1e-6
    for k in ("block", "vector", "scalar"):
        assert prof.item_coef[k] == pytest.approx(true[k], rel=1e-6)
    assert prof.launch_coef == pytest.approx(true["launches"], rel=1e-6)
    # the fitted model reproduces every sample
    for r, t in zip(rows, times):
        assert prof.predict(r) == pytest.approx(t, rel=1e-6)


def test_fit_scales_default_for_unobserved_kind():
    """A kind the calibration run never moved keeps the default
    profile's coefficient, rescaled into the fitted unit system."""
    rng = np.random.default_rng(3)
    rows = _rows(rng)
    for r in rows:
        r["vector"] = 0.0
    unit = 2.0  # fitted units are exactly 2x the default's
    times = [unit * (CAL.DEFAULT_ITEM_BYTES["block"] * r["block"]
                     + CAL.DEFAULT_ITEM_BYTES["scalar"] * r["scalar"]
                     + CAL.KERNEL_LAUNCH_COST * r["launches"])
             for r in rows]
    prof = CAL.fit_profile(rows, times)
    assert prof.item_coef["block"] == pytest.approx(
        unit * CAL.DEFAULT_ITEM_BYTES["block"], rel=1e-6)
    assert prof.item_coef["vector"] == pytest.approx(
        unit * CAL.DEFAULT_ITEM_BYTES["vector"], rel=1e-6)


def test_fit_degenerate_samples_keep_default_with_warning():
    rows = [{"block": 1.0, "launches": 1.0}] * 4
    with pytest.warns(RuntimeWarning, match="no positive"):
        prof = CAL.fit_profile(rows, [0.0] * 4, backend="pallas",
                               device_kind="x")
    assert dict(prof.item_coef) == dict(CAL.DEFAULT_ITEM_BYTES)
    assert prof.launch_coef == CAL.KERNEL_LAUNCH_COST
    assert prof.backend == "pallas" and prof.device_kind == "x"


def test_fit_input_validation():
    with pytest.raises(ValueError):
        CAL.fit_profile([], [])
    with pytest.raises(ValueError):
        CAL.fit_profile([{"block": 1.0}], [1.0, 2.0])


# ---------------------------------------------------------------------------
# Persistence
# ---------------------------------------------------------------------------

def test_profile_roundtrips_through_cache_dir(tmp_path):
    rng = np.random.default_rng(11)
    rows = _rows(rng)
    times = [3e-5 * r["block"] + 1e-6 * r["vector"]
             + 1e-7 * r["scalar"] + 2e-4 for r in rows]
    prof = CAL.fit_profile(rows, times, backend="pallas",
                           device_kind="Fake TPU v9")
    path = CAL.save_profile(prof, root=tmp_path)
    assert path.is_file() and path.parent.name == "calibration"
    back = CAL.load_profile(tmp_path, backend="pallas",
                            device_kind="Fake TPU v9")
    assert back is not None
    assert dict(back.item_coef) == pytest.approx(dict(prof.item_coef))
    assert back.launch_coef == pytest.approx(prof.launch_coef)
    assert back.source == "measured"
    assert back.n_samples == prof.n_samples
    assert back.digest() == prof.digest()


def test_missing_profile_is_silent_default(tmp_path):
    assert CAL.load_profile(tmp_path, backend="pallas",
                            device_kind="none") is None
    prof = CAL.load_or_default(tmp_path, backend="pallas",
                               device_kind="none")
    assert prof is CAL.DEFAULT_PROFILE


@pytest.mark.parametrize("payload", [
    "not json at all {",
    json.dumps({"schema": 99, "item_coef": {"block": 1.0},
                "launch_coef": 1.0}),
    json.dumps({"schema": CAL.PROFILE_SCHEMA, "item_coef": {},
                "launch_coef": 1.0}),
    json.dumps({"schema": CAL.PROFILE_SCHEMA,
                "item_coef": {"block": -5.0}, "launch_coef": 1.0}),
])
def test_stale_or_corrupt_profile_warns_and_falls_back(tmp_path, payload):
    path = CAL.profile_path(tmp_path, "pallas", "dev")
    path.parent.mkdir(parents=True)
    path.write_text(payload)
    with pytest.warns(RuntimeWarning, match="stale/corrupt"):
        got = CAL.load_profile(tmp_path, backend="pallas",
                               device_kind="dev")
    assert got is None
    with pytest.warns(RuntimeWarning):
        prof = CAL.load_or_default(tmp_path, backend="pallas",
                                   device_kind="dev")
    assert prof is CAL.DEFAULT_PROFILE


# ---------------------------------------------------------------------------
# Schema migration: stale and malformed coefficient vectors
# ---------------------------------------------------------------------------

def test_schema1_profile_loads_with_default_work(tmp_path):
    """A persisted pre-work-feature (schema 1) profile is repaired on
    load — its traffic coefficients survive, the work coefficients take
    the default (zero) — instead of being discarded."""
    payload = {"schema": 1, "backend": "pallas", "device_kind": "dev",
               "item_coef": {"block": 2.0, "vector": 1.0, "scalar": 0.5},
               "launch_coef": 3.0, "source": "measured", "n_samples": 9,
               "residual": 0.1,
               # schema-1 writers never produced this key; even if one
               # sneaks in, the repair ignores it
               "work_coef": {"bogus": 5.0}}
    with pytest.warns(RuntimeWarning, match="stale schema 1"):
        prof = CAL.CalibrationProfile.from_json(payload)
    assert dict(prof.work_coef) == {c: 0.0 for c in CAL.WORK_CLASSES}
    assert prof.instance_coef == 0.0
    assert dict(prof.dtype_scale) == dict(CAL.DEFAULT_DTYPE_SCALE)
    assert dict(prof.item_coef) == {"block": 2.0, "vector": 1.0,
                                    "scalar": 0.5}
    assert prof.launch_coef == 3.0 and prof.n_samples == 9
    # zero work => the repaired profile's cost is the pure traffic
    # formula under its own coefficients (no silent misfit)
    t = C.Traffic()
    t.loads["block"] = 7
    t.work["dot"] = 3
    t.launches = 2
    assert prof.cost(t) == 7 * 2.0 + 2 * 3.0

    # and through the disk loader: repaired, not None
    path = CAL.profile_path(tmp_path, "pallas", "dev")
    path.parent.mkdir(parents=True)
    path.write_text(json.dumps(payload))
    with pytest.warns(RuntimeWarning, match="stale schema 1"):
        back = CAL.load_profile(tmp_path, backend="pallas",
                                device_kind="dev")
    assert back is not None
    assert back.digest() == prof.digest()


def test_wrong_length_work_vector_repaired():
    """A schema-2 profile whose work vector doesn't match the current
    class set loads with the known classes repaired (missing -> default,
    unknown -> dropped) and a warning."""
    base = {"schema": CAL.PROFILE_SCHEMA,
            "item_coef": {"block": 1.0, "vector": 1.0, "scalar": 1.0},
            "launch_coef": 1.0}
    with pytest.warns(RuntimeWarning, match="repairing"):
        prof = CAL.CalibrationProfile.from_json(
            {**base, "work_coef": {"matmul": 1e-9}})
    assert dict(prof.work_coef) == {"matmul": 1e-9, "elementwise": 0.0,
                                    "reduce": 0.0}
    with pytest.warns(RuntimeWarning, match="repairing"):
        prof = CAL.CalibrationProfile.from_json(
            {**base, "work_coef": {"matmul": 1e-9, "conv2d": 7.0,
                                   "elementwise": 0.0, "reduce": 0.0}})
    assert set(prof.work_coef) == set(CAL.WORK_CLASSES)
    assert "conv2d" not in prof.work_coef


def test_negative_work_or_instance_coef_rejected(tmp_path):
    base = {"schema": CAL.PROFILE_SCHEMA, "launch_coef": 1.0,
            "item_coef": {"block": 1.0, "vector": 1.0, "scalar": 1.0}}
    for bad in ({"work_coef": {"matmul": -1.0, "elementwise": 0.0,
                               "reduce": 0.0}},
                {"instance_coef": -0.5},
                {"dtype_scale": {"f32": 0.0}}):
        with pytest.raises(ValueError):
            CAL.CalibrationProfile.from_json({**base, **bad})
    # on disk that's a corrupt file: warn and fall back to the default
    path = CAL.profile_path(tmp_path, "pallas", "dev")
    path.parent.mkdir(parents=True)
    path.write_text(json.dumps({**base, "instance_coef": -0.5}))
    with pytest.warns(RuntimeWarning, match="stale/corrupt"):
        assert CAL.load_profile(tmp_path, backend="pallas",
                                device_kind="dev") is None


def test_item_bytes_override_keeps_work_term():
    """The legacy ``item_bytes`` dict overrides only the item
    coefficients — a measured profile's compute term survives the
    back-compat shim."""
    prof = replace(CAL.DEFAULT_PROFILE,
                   work_coef={"matmul": 1e-9, "elementwise": 0.0,
                              "reduce": 0.0},
                   instance_coef=2.0)
    ones = {"block": 1, "vector": 1, "scalar": 1}
    merged = CAL.resolve_profile(ones, prof)
    assert merged.source == "item_bytes"
    assert dict(merged.work_coef) == dict(prof.work_coef)
    assert merged.instance_coef == prof.instance_coef
    t = C.Traffic()
    t.loads["block"] = 10
    t.work["dot"] = 3
    t.launches = 2
    t.instances = 4.0
    expect = (10 * 1 + CAL.KERNEL_LAUNCH_COST * 2    # overridden items
              + 2.0 * 4.0                            # instance term
              + 1e-9 * 2.0 * 128 ** 3 * 3)           # matmul FLOPs
    assert merged.cost(t) == pytest.approx(expect)


# ---------------------------------------------------------------------------
# Fitting the compute term
# ---------------------------------------------------------------------------

def _rows_with_work(rng, n=60):
    rows = []
    for _ in range(n):
        rows.append({"block": float(rng.integers(1, 200)),
                     "vector": float(rng.integers(0, 50)),
                     "scalar": float(rng.integers(0, 10)),
                     "work_matmul": float(rng.integers(0, 40)) * 1e6,
                     "work_elementwise": float(rng.integers(0, 400)) * 1e4,
                     "work_reduce": float(rng.integers(0, 100)) * 1e4,
                     "instances": float(rng.integers(1, 64)),
                     "launches": 1.0})
    return rows


def test_fit_recovers_work_and_instance_coefficients():
    rng = np.random.default_rng(5)
    rows = _rows_with_work(rng)
    true = {"block": 3e-5, "vector": 2e-6, "scalar": 1e-7,
            "work_matmul": 4e-12, "work_elementwise": 6e-11,
            "work_reduce": 2e-11, "instances": 3e-4, "launches": 4e-4}
    times = [sum(true[k] * v for k, v in r.items()) for r in rows]
    prof = CAL.fit_profile(rows, times, backend="pallas",
                           device_kind="testdev")
    for cls in CAL.WORK_CLASSES:
        assert prof.work_coef[cls] == pytest.approx(
            true["work_" + cls], rel=1e-5)
    assert prof.instance_coef == pytest.approx(true["instances"],
                                               rel=1e-5)
    assert prof.residual < 1e-6
    for r, t in zip(rows, times):
        assert prof.predict(r) == pytest.approx(t, rel=1e-5)


def test_fit_clamps_negative_work_coefficient_to_zero():
    """A work class whose joint fit would come out negative (a work
    *discount* no ranking model can use) is clamped to zero and the
    rest refitted — it must not poison the traffic coefficients."""
    rng = np.random.default_rng(9)
    rows = _rows_with_work(rng)
    times = [3e-5 * r["block"] + 2e-6 * r["vector"] + 1e-7 * r["scalar"]
             + 6e-11 * r["work_elementwise"] + 2e-11 * r["work_reduce"]
             + 3e-4 * r["instances"] + 4e-4 * r["launches"]
             - 1e-13 * r["work_matmul"]        # the anti-physical term
             for r in rows]
    prof = CAL.fit_profile(rows, times)
    assert prof.work_coef["matmul"] == 0.0
    assert prof.work_coef["elementwise"] > 0
    assert prof.work_coef["reduce"] > 0
    assert prof.item_coef["block"] == pytest.approx(3e-5, rel=0.05)
    assert prof.instance_coef == pytest.approx(3e-4, rel=0.05)


def test_fitted_profile_with_work_roundtrips(tmp_path):
    rng = np.random.default_rng(13)
    rows = _rows_with_work(rng)
    times = [sum({"block": 3e-5, "vector": 2e-6, "scalar": 1e-7,
                  "work_matmul": 4e-12, "work_elementwise": 6e-11,
                  "work_reduce": 2e-11, "instances": 3e-4,
                  "launches": 4e-4}[k] * v for k, v in r.items())
             for r in rows]
    prof = CAL.fit_profile(rows, times, backend="pallas",
                           device_kind="dev")
    CAL.save_profile(prof, root=tmp_path)
    back = CAL.load_profile(tmp_path, backend="pallas",
                            device_kind="dev")
    assert back is not None
    assert dict(back.work_coef) == pytest.approx(dict(prof.work_coef))
    assert back.instance_coef == pytest.approx(prof.instance_coef)
    assert back.digest() == prof.digest()


# ---------------------------------------------------------------------------
# Rank agreement helper
# ---------------------------------------------------------------------------

def test_spearman():
    assert T.spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert T.spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
    assert T.spearman([1.0], [2.0]) == 1.0
    assert T.spearman([1, 1, 1], [1, 2, 3]) == 0.0
    assert T.spearman([1, 1, 1], [2, 2, 2]) == 1.0
    # monotone but nonlinear is still rank-perfect
    assert T.spearman([1, 2, 3, 4], [1, 10, 100, 1000]) == pytest.approx(
        1.0)
    with pytest.raises(ValueError):
        T.spearman([1, 2], [1, 2, 3])


# ---------------------------------------------------------------------------
# The committed bench artifact (the acceptance evidence)
# ---------------------------------------------------------------------------

def test_bench_pipeline_artifact_committed():
    """``BENCH_pipeline.json`` at the repo root holds wall-clock
    fused-vs-unfused speedups for all five programs and a calibration
    row whose predicted-vs-measured region ranking agrees (Spearman
    >= 0.6).  Regenerate with::

        PYTHONPATH=src:. python benchmarks/run.py --only pipeline \\
            --json BENCH_pipeline.json
    """
    path = REPO_ROOT / "BENCH_pipeline.json"
    assert path.is_file(), "BENCH_pipeline.json missing from repo root"
    data = json.loads(path.read_text())
    rows = {r["name"]: dict(p.split("=", 1)
                            for p in r["derived"].split(";") if "=" in p)
            for r in data["rows"]}
    programs = {f"pipeline_{n}" for n in
                ("attention", "causal_attention", "gqa_attention",
                 "layernorm_matmul", "rmsnorm_ffn_swiglu")}
    assert programs <= set(rows)
    for name in programs:
        assert float(rows[name]["speedup"].rstrip("x")) > 0
        assert rows[name]["pallas_fallbacks"] == "0"
        assert "region_times_us" in rows[name]
    cal = rows["calibration_profile"]
    assert float(cal["pooled_spearman"]) >= 0.6
    assert int(cal["n_samples"]) >= 5


def test_bench_artifact_region_rank_agreement():
    """Every multi-region row in the committed artifact must have a
    non-negative per-row region rank agreement, and the attention rows
    — whose softmax+PV kernel the byte-only model ranked dead wrong
    (Spearman -1.00 before the compute-aware features) — must agree
    decisively (>= 0.5).  The pooled Spearman floor is the tentpole's
    acceptance threshold (0.7)."""
    path = REPO_ROOT / "BENCH_pipeline.json"
    data = json.loads(path.read_text())
    rows = {r["name"]: dict(p.split("=", 1)
                            for p in r["derived"].split(";") if "=" in p)
            for r in data["rows"]}
    for name, d in rows.items():
        if "/" in d.get("region_times_us", ""):  # multi-region lowering
            assert float(d["region_spearman"]) >= 0.0, (
                f"{name}: region rank agreement went negative")
    for name in ("pipeline_attention", "pipeline_causal_attention",
                 "pipeline_gqa_attention"):
        assert float(rows[name]["region_spearman"]) >= 0.5, name
    cal = rows["calibration_profile"]
    assert float(cal["pooled_spearman"]) >= 0.7
    # the calibration row reports the full compute-aware coefficient
    # vector so artifact diffs show what the fit learned
    for cls in CAL.WORK_CLASSES:
        assert f"work_{cls}_coef" in cal
