"""CI benchmark-regression gate.

    python benchmarks/check_regression.py BENCH_ci.json benchmarks/baseline.json

Compares a fresh ``run.py --only pipeline --preset ci --json BENCH_ci.json``
run against the committed baseline and exits non-zero if

  * any pipeline row's **predicted traffic reduction** regresses more
    than 10% below the baseline (the fusion objective got worse for the
    same program/config),
  * any **Pallas region falls back** off the Pallas backend in ANY row,
    baseline-listed or new (``pallas_fallbacks != 0`` — the selected
    snapshot must lower), or
  * a baseline row is missing from the fresh run.

Wall-clock columns are never gated — CI runners are too noisy; the
gated quantities are deterministic functions of the cost model and the
lowering, which is exactly what makes them gateable.

Re-pin the baseline with

    python benchmarks/check_regression.py --pin BENCH_ci.json benchmarks/baseline.json

which writes ONLY the gated keys (predicted traffic reduction, region
and fallback counts) so baseline diffs show real changes, not
machine-local wall-clock noise.
"""

from __future__ import annotations

import json
import sys

TOLERANCE = 0.10  # fail when reduction drops >10% below baseline
GATED_KEYS = ("pred_traffic_reduction", "pallas_regions",
              "pallas_fallbacks")


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def _rows(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    rows = data["rows"] if isinstance(data, dict) else data
    return {r["name"]: _parse_derived(r["derived"]) for r in rows
            if r["name"].startswith("pipeline_")}


def _reduction(derived: dict) -> float:
    return float(derived["pred_traffic_reduction"].rstrip("x"))


def _pin(current_path: str, baseline_path: str) -> int:
    """Write a gated-keys-only baseline from a fresh run."""
    with open(current_path) as f:
        data = json.load(f)
    rows = data["rows"] if isinstance(data, dict) else data
    pinned = []
    for r in rows:
        if not r["name"].startswith("pipeline_"):
            continue
        derived = _parse_derived(r["derived"])
        kept = ";".join(f"{k}={derived[k]}" for k in GATED_KEYS
                        if k in derived)
        pinned.append({"name": r["name"], "derived": kept})
    with open(baseline_path, "w") as f:
        json.dump({"preset": data.get("preset", "ci"), "rows": pinned}, f,
                  indent=2)
        f.write("\n")
    print(f"pinned {len(pinned)} row(s) -> {baseline_path}")
    return 0


def main(argv) -> int:
    if len(argv) == 4 and argv[1] == "--pin":
        return _pin(argv[2], argv[3])
    if len(argv) != 3:
        print(__doc__)
        return 2
    current, baseline = _rows(argv[1]), _rows(argv[2])
    failures, improved = [], []
    print(f"{'benchmark':32s} {'base':>8s} {'now':>8s}  verdict")
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run")
            continue
        base_red, cur_red = _reduction(base), _reduction(cur)
        floor = base_red * (1.0 - TOLERANCE)
        verdict = "ok"
        if cur_red < floor:
            verdict = "REGRESSED"
            failures.append(
                f"{name}: predicted traffic reduction {cur_red:.2f}x < "
                f"{floor:.2f}x (baseline {base_red:.2f}x - {TOLERANCE:.0%})")
        elif cur_red > base_red * (1.0 + TOLERANCE):
            verdict = "improved (re-pin baseline?)"
            improved.append(name)
        # region count is pinned too: MORE kernels for the same program
        # is a lowering regression (launches + cross-region traffic);
        # fewer is an improvement worth re-pinning
        base_rg, cur_rg = base.get("pallas_regions"), cur.get(
            "pallas_regions")
        if base_rg is not None and cur_rg is not None:
            if int(cur_rg) > int(base_rg):
                verdict = "MORE REGIONS"
                failures.append(
                    f"{name}: selected snapshot now lowers to {cur_rg} "
                    f"Pallas kernels (baseline {base_rg})")
            elif int(cur_rg) < int(base_rg) and verdict == "ok":
                verdict = "improved (re-pin baseline?)"
                improved.append(name)
        print(f"{name:32s} {base_red:7.2f}x {cur_red:7.2f}x  {verdict}")
    # the fallback gate covers EVERY current row, including programs not
    # yet pinned into the baseline — a new benchmark may not sneak a
    # non-lowering snapshot past the gate
    for name, cur in sorted(current.items()):
        fb = cur.get("pallas_fallbacks")
        if fb is not None and fb != "0":
            failures.append(f"{name}: {fb} Pallas region(s) fell back to "
                            "the jax backend")
    extra = sorted(set(current) - set(baseline))
    if extra:
        print("note: rows not in baseline (traffic unchecked, fallbacks "
              f"still gated): {', '.join(extra)}")
    if failures:
        print("\nBENCH GATE FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench gate passed"
          + (f" ({len(improved)} row(s) improved)" if improved else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
