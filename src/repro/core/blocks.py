"""Utilities for splitting arrays into blocks and merging them back.

The paper stores each matrix as a list of lists-of-blocks (row-major).
"""

from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import numpy as np


def split(arr, n_row_blocks: int, n_col_blocks: int) -> List[List[Any]]:
    """Split a matrix into an ``n_row_blocks x n_col_blocks`` nested list."""
    rows = np.array_split(arr, n_row_blocks, axis=0)
    return [list(np.array_split(r, n_col_blocks, axis=1)) for r in rows]


def split_rows(arr, n_row_blocks: int) -> List[Any]:
    return list(np.array_split(arr, n_row_blocks, axis=0))


def merge(blocks) -> np.ndarray:
    """Merge a nested list (or flat list) of blocks back into one array."""
    if isinstance(blocks[0], list):
        return np.concatenate([np.concatenate(row, axis=1) for row in blocks],
                              axis=0)
    if getattr(blocks[0], "ndim", 0) == 2:
        return np.concatenate(blocks, axis=0)
    return np.concatenate(blocks, axis=0)


def merge_vectors(vectors) -> np.ndarray:
    return np.concatenate(vectors, axis=0)
