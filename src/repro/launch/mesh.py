"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required so smoke tests / benches see one CPU
device while the dry-run forces 512 host devices)."""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(jax.devices())}; run via launch/dryrun.py which forces "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.sharding.Mesh(
        __import__("numpy").asarray(devices).reshape(shape), axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """A mesh over however many devices exist (tests on 1-8 CPU devices)."""
    import numpy as np
    n = math.prod(shape)
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n]).reshape(shape), axes)
