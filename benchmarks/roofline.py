"""Render the §Roofline table from dryrun_results.json.

    PYTHONPATH=src python -m benchmarks.roofline [path/to/dryrun_results.json]

Terms per (arch x shape), single-pod 16x16 mesh, TPU v5e constants:
  compute    = HLO_FLOPs / peak;  memory = HLO_bytes / HBM_bw;
  collective = collective_bytes / link_bw.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List


def load(path: str = "dryrun_results.json") -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def fmt_row(r: Dict) -> str:
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | skipped | — | — | — | — | — |"
                f" {r['reason'][:40]}... |")
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | FAILED | — | — | — | — | — |"
                f" {r.get('error', '')[:40]} |")
    tc, tm, tl = r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]
    frac = r.get("roofline_fraction", 0.0)
    ufr = r.get("useful_flops_ratio", 0.0)
    return (f"| {r['arch']} | {r['shape']} | {r['bottleneck']} "
            f"| {tc:.3e} | {tm:.3e} | {tl:.3e} "
            f"| {ufr:.3f} | {frac:.4f} | |")


def table(results: List[Dict], mesh: str = "single") -> str:
    rows = [r for r in results if r["mesh"] == mesh
            and (mesh == "multi" or "t_compute_s" in r
                 or r["status"] != "ok")]
    out = [
        "| arch | shape | bottleneck | t_compute (s) | t_memory (s) "
        "| t_collective (s) | MODEL/HLO flops | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(fmt_row(r))
    return "\n".join(out)


def run(path: str = "dryrun_results.json") -> List[Dict]:
    if not os.path.exists(path):
        return [{"name": "roofline", "us_per_call": 0,
                 "derived": f"no {path}; run launch/dryrun.py --all first"}]
    results = load(path)
    ok = [r for r in results if r["status"] == "ok" and "t_compute_s" in r]
    rows = []
    for r in ok:
        t_bound = max(r["t_compute_s"], r["t_memory_s"],
                      r["t_collective_s"])
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            "us_per_call": t_bound * 1e6,
            "derived": (f"bottleneck={r['bottleneck']};"
                        f"frac={r.get('roofline_fraction', 0):.4f};"
                        f"useful={r.get('useful_flops_ratio', 0):.3f}"),
        })
    return rows


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    print(table(load(path)))
    print()
    print("## multi-pod (runnability)")
    print(table(load(path), mesh="multi"))
