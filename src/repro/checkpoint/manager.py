"""Checkpointing for fault tolerance and elastic restarts.

Design (multi-host-shaped, single-process-functional):

* **atomic publish** — a checkpoint directory is written under a ``tmp.``
  name and os.rename'd into place only when complete, so a crash mid-save
  can never corrupt the latest checkpoint;
* **async save** — device->host transfer happens synchronously (cheap),
  serialization happens on a background thread so the train loop resumes
  immediately (``wait()`` joins before the next save or at exit);
* **resharding restore** — checkpoints store *global* arrays; restore
  re-shards onto whatever mesh is active, so a job can come back on a
  different topology (elastic scaling, tested in test_checkpoint.py);
* **auto-resume** — ``latest_step()`` + deterministic data pipeline keyed
  by step give bitwise-identical replay after a failure;
* **retention** — keep the last N checkpoints.

On a real multi-host pod each process writes only its addressable shards
(jax.experimental.multihost_utils); this container is single-process, so
``_gather`` is a direct device_get.
"""

from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- discovery -----------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.startswith("tmp."):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             blocking: bool = False) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def _write():
            try:
                tmp = os.path.join(self.dir, f"tmp.step_{step}")
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                flat, _ = _flatten(host_state)
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{k: v for k, v in flat.items()
                            if isinstance(v, np.ndarray)})
                meta = {
                    "step": step,
                    "time": time.time(),
                    "treedef": None,
                }
                # NB: None leaves disappear from pytrees; use a 0 sentinel
                with open(os.path.join(tmp, "tree.pkl"), "wb") as f:
                    pickle.dump(jax.tree.map(lambda x: 0, host_state), f)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if blocking:
            _write()
            if self._error:
                raise self._error
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def restore(self, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Dict[str, Any]:
        """Load a checkpoint; if ``shardings`` (a matching pytree of
        NamedShardings) is given, place each array with jax.device_put —
        onto a possibly different mesh than it was saved from."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "tree.pkl"), "rb") as f:
            skeleton = pickle.load(f)
        arrays = np.load(os.path.join(path, "arrays.npz"))
        flat_keys, treedef = _flatten(skeleton)
        leaves = [arrays[k] for k in flat_keys]
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None
                else jax.device_put(x), state, shardings)
        return state
