"""AdamW with sharded first/second moments + global-norm clipping.

Moments inherit each parameter's sharding (ZeRO-style: with fsdp rules the
optimizer state lives fully sharded over the data axis and the update is
shard-local; XLA inserts the reduce-scatter/all-gather around it)."""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> Dict[str, Any]:
        f32 = functools.partial(jnp.zeros_like, dtype=jnp.float32)
        return {
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def state_specs(self, param_specs) -> Dict[str, Any]:
        return {"m": param_specs, "v": param_specs, "step": ()}

    def update(self, grads, state, params) -> Tuple[Any, Dict[str, Any],
                                                    Dict[str, jax.Array]]:
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
