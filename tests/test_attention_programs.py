"""Differential test matrix for the decoder attention path.

{causal, non-causal} x {MHA, GQA 4:1} x {py, jax, pallas}: every compiled
kernel must agree with (a) the dense numpy reference and (b) the
block-program interpreter oracle on the ORIGINAL (unfused) program.  On
top of the matrix: prefill-vs-decode parity through the model layer —
decoding token-by-token through ``pipeline.compile`` must reproduce the
causal prefill output position by position.
"""

import dataclasses

import numpy as np
import pytest

from repro import pipeline
from repro.core import array_program as AP
from repro.core.interpreter import run as interp_run
from repro.pipeline import packing as P

BACKENDS = ["py", "jax", "pallas"]

H = 4                       # GQA group size (4 query heads : 1 kv head)
DIMS = {"M": 3, "D": 2, "N": 3, "L": 2}
BLOCKS = {"M": 8, "D": 8, "N": 8, "L": 8, "H": 1}
SCALE = 0.125


@pytest.fixture()
def cache(tmp_path):
    return pipeline.KernelCache(tmp_path)


def _case(rng, grouped: bool, causal: bool):
    """(program, dims, blocks, merged inputs, dense numpy reference)."""
    s_q = DIMS["M"] * BLOCKS["M"]
    s_kv = DIMS["N"] * BLOCKS["N"]
    d = DIMS["D"] * BLOCKS["D"]
    dv = DIMS["L"] * BLOCKS["L"]
    lead = (H,) if grouped else ()
    Q = rng.normal(size=lead + (s_q, d)).astype(np.float32)
    K = rng.normal(size=(s_kv, d)).astype(np.float32)
    V = rng.normal(size=(s_kv, dv)).astype(np.float32)
    qp = np.arange(s_q, dtype=np.float32)
    kp = np.arange(s_kv, dtype=np.float32)

    s = Q @ K.T                                  # (*lead, s_q, s_kv)
    if causal:
        s = np.where(qp[:, None] >= kp[None, :], s, -1e30)
    s = s * SCALE
    p = np.exp(s - s.max(-1, keepdims=True))
    ref = (p / p.sum(-1, keepdims=True)) @ V

    if grouped:
        g = AP.gqa_attention_program(SCALE, causal=causal)
    elif causal:
        g = AP.causal_attention_program(SCALE)
    else:
        g = AP.attention_program(SCALE)
    dims = dict(DIMS, **({"H": H} if grouped else {}))
    inputs = {"Q": Q, "KT": K, "VT": V.T}
    if causal:
        inputs.update(QP=qp, KP=kp)
    return g, dims, inputs, ref


def _oracle(g, dims, inputs):
    """Interpreter run of the unfused program on nested-block inputs."""
    nested = {}
    for nid in g.input_ids:
        node = g.nodes[nid]
        nested[node.name] = P.to_nested(inputs[node.name], node.vtype,
                                        dims)
    out = interp_run(g, nested, dims)["O"]
    out_vt = P.output_types(g)[0]
    return P.from_nested(out, out_vt, dims)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("grouped", [False, True], ids=["mha", "gqa"])
@pytest.mark.parametrize("causal", [False, True],
                         ids=["noncausal", "causal"])
def test_attention_matrix_differential(causal, grouped, backend, cache,
                                       rng):
    g, dims, inputs, ref = _case(rng, grouped, causal)
    kern = pipeline.compile(g, dims, backend=backend, blocks=BLOCKS,
                            cache=cache)
    assert kern.cache_hit is None
    got = np.asarray(kern(inputs)[kern.out_names[0]])
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
    oracle = _oracle(g, dims, inputs)
    np.testing.assert_allclose(got, oracle, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("grouped", [False, True], ids=["mha", "gqa"])
def test_prefill_decode_parity_through_pipeline(grouped, tmp_path,
                                                monkeypatch):
    """Causal prefill and token-by-token decode, both through
    ``pipeline.compile``, agree position by position."""
    import jax
    import jax.numpy as jnp

    monkeypatch.setenv("REPRO_KERNEL_CACHE", str(tmp_path))
    pipeline.reset_default_cache()
    from repro.models import layers as L
    from repro.models.common import ModelConfig, ParamBuilder

    n_heads = 4
    cfg = ModelConfig(d_model=64, n_heads=n_heads,
                      n_kv_heads=1 if grouped else n_heads, d_head=16,
                      d_ff=128, dtype=jnp.float32, norm_eps=1e-6)
    cfg = dataclasses.replace(cfg, attn_impl="pipeline",
                              pipeline_backend="jax", rope_theta=0.0)
    pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    L.init_attention(pb, cfg)
    p = pb.params
    batch, seq = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, 64),
                          jnp.float32)

    prefill = L.attention_apply(p, x, cfg, causal=True)
    cache = L.attention_init_cache(cfg, batch, seq, jnp.float32)
    for pos in range(seq):
        step, cache = L.attention_decode(p, x[:, pos:pos + 1], cache, pos,
                                         cfg)
        np.testing.assert_allclose(np.asarray(step[:, 0]),
                                   np.asarray(prefill[:, pos]),
                                   rtol=2e-5, atol=2e-5)
    pipeline.reset_default_cache()


def test_gqa_shares_kv_blocks_across_group():
    """The head-group broadcast is structural: K/V enter the H map as
    broadcast (non-mapped) ports, so one kv-head block set serves every
    query head in the group."""
    from repro.core.graph import MapNode

    g = AP.gqa_attention_program(SCALE, causal=True)
    (hid,) = [n for n in g.op_nodes()
              if isinstance(g.nodes[n], MapNode)]
    h = g.nodes[hid]
    assert h.dim == "H"
    by_port = {g.nodes[g.in_edge(hid, p).src].name: h.mapped[p]
               for p in range(h.n_in())}
    assert by_port == {"Q": True, "KT": False, "VT": False,
                       "QP": False, "KP": False}
