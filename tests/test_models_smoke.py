"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of the same family runs one forward + one train step on CPU, asserting
output shapes and no NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_reduced_config
from repro.models import build_model

pytestmark = pytest.mark.slow  # full-zoo forward+backward: not tier-1

ALL_ARCHS = sorted(ARCHS)


def _batch(rng, cfg, b=2, s=16):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_vision_tokens, cfg.d_model)),
            cfg.dtype) * 0.02
    if cfg.family == "encdec":
        kw["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)), cfg.dtype) * 0.02
    return toks[:, :-1], toks[:, 1:], kw


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params, specs = model.init_params(jax.random.key(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple))

    tokens, labels, kw = _batch(rng, cfg)
    logits = model.forward(params, tokens, **kw)
    exp_len = tokens.shape[1] + (cfg.n_vision_tokens
                                 if cfg.family == "vlm" else 0)
    assert logits.shape == (2, exp_len, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    loss, grads = jax.value_and_grad(model.loss)(params, tokens, labels,
                                                 **kw)
    assert bool(jnp.isfinite(loss)), "NaN loss"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), "NaN grads"
    # loss should be near ln(vocab) at init (sanity on the head scaling)
    assert 0.2 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_abstract_init(arch):
    """The FULL assigned config's parameter tree is constructible abstractly
    (eval_shape only; no allocation) and its sizes match the paper-reported
    scale."""
    from repro.configs import get_config
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init_params(k)[0],
                            jax.random.key(0))
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    expected = {
        "qwen2-7b": 7.6e9, "smollm-135m": 0.134e9, "llama3.2-1b": 1.24e9,
        "qwen3-32b": 33e9, "internvl2-26b": 25e9, "whisper-tiny": 0.06e9,
        "mamba2-2.7b": 2.7e9, "deepseek-v3-671b": 671e9,
        "qwen3-moe-30b-a3b": 30.5e9, "jamba-1.5-large-398b": 398e9,
    }[arch]
    assert 0.55 * expected < n_params < 1.7 * expected, (
        f"{arch}: {n_params/1e9:.2f}B params vs expected "
        f"{expected/1e9:.1f}B")
