"""Measured autotuning: selection optimizes for wall time, not bytes.

The (calibrated) analytic model prunes the block-count sweep; only the
top-K survivors are compiled and timed; the wall-clock winner is
returned, cached, and re-loaded.  Because the analytic choice is always
among the timed finalists, the measured result can never be slower than
it (ties allowed) — the slow-tier test pins that on all five in-repo
programs through the real driver-built measurement harness.
"""

import numpy as np
import pytest

from repro import pipeline
from repro.core import array_program as AP
from repro.core import selection as SEL
from repro.core import timing as T
from repro.core.fusion import fuse

# the five in-repo example programs and a small candidate grid each
# (stack dims — gqa's H — must keep a fixed count: block size is pinned
# to 1 on the Pallas path)
PROGRAMS = {
    "layernorm_matmul": (lambda: AP.layernorm_matmul_program(32.0),
                         {"M": [1, 2], "K": [2, 4], "N": [1, 2]}),
    "rmsnorm_swiglu": (lambda: AP.rmsnorm_ffn_swiglu_program(16.0),
                       {"M": [1, 2], "D": [2], "K": [2, 3], "N": [2]}),
    "flash": (lambda: AP.attention_program(0.125),
              {"M": [1, 2], "D": [2], "N": [2, 3], "L": [2]}),
    "causal": (lambda: AP.causal_attention_program(0.25),
               {"M": [2], "D": [2], "N": [2], "L": [1, 2]}),
    "gqa": (lambda: AP.gqa_attention_program(0.25, causal=True),
            {"H": [2], "M": [1, 2], "D": [2], "N": [2], "L": [2]}),
}


@pytest.fixture(autouse=True)
def _fresh_measurements():
    T.clear_measurements()
    yield
    T.clear_measurements()


# ---------------------------------------------------------------------------
# Selection-level: the measured objective over a fake harness
# ---------------------------------------------------------------------------

def test_sweep_dedupes_equivalent_assignments():
    """Assignments that produce identical (fingerprint, dims) keys are
    costed once."""
    got = list(SEL.sweep_assignments({"M": [2, 2, 2], "K": [4, 4],
                                      "N": [1, 2, 1]}))
    assert got == [{"M": 2, "K": 4, "N": 1}, {"M": 2, "K": 4, "N": 2}]


def test_measured_objective_returns_wallclock_winner():
    g = AP.layernorm_matmul_program(32.0)
    snaps = fuse(g)
    calls = []

    def measure(sel):
        calls.append(dict(sel.dims))
        # wall time anti-correlated with the analytic model: the
        # analytically-cheapest config is the slowest to run
        return 1.0 / sel.cost

    best = SEL.autotune(g, {"M": [1, 2], "K": [2, 4], "N": [1, 2]},
                        snapshots=snaps, objective="measured",
                        measure=measure, top_k=4)
    assert len(calls) == 4  # exactly the top-K survivors were timed
    assert best.measured_s is not None
    assert len(best.timings) == 4
    # the winner is the measured minimum, not the analytic minimum
    assert best.measured_s == min(t for _, t in best.timings)
    analytic = SEL.autotune(g, {"M": [1, 2], "K": [2, 4], "N": [1, 2]},
                            snapshots=snaps)
    assert best.cost >= analytic.cost  # it lost the analytic ranking...
    times = dict(best.timings)
    akey = tuple(sorted(analytic.dims.items()))
    assert akey in times  # ...but the analytic choice WAS timed
    assert best.measured_s <= times[akey]


def test_measured_duplicate_assignments_timed_once():
    g = AP.layernorm_matmul_program(32.0)
    calls = []

    def measure(sel):
        calls.append(dict(sel.dims))
        return 1e-3

    SEL.autotune(g, {"M": [2, 2], "K": [4, 4], "N": [2]},
                 objective="measured", measure=measure, top_k=8)
    assert calls == [{"M": 2, "K": 4, "N": 2}]


def test_measured_failures_fall_back_to_analytic():
    g = AP.layernorm_matmul_program(32.0)

    def broken(sel):
        raise RuntimeError("no device")

    with pytest.warns(RuntimeWarning, match="every measurement failed"):
        best = SEL.autotune(g, {"M": [1, 2], "K": [2], "N": [2]},
                            objective="measured", measure=broken,
                            top_k=2)
    analytic = SEL.autotune(g, {"M": [1, 2], "K": [2], "N": [2]})
    assert best.dims == analytic.dims and best.measured_s is None


def test_measured_objective_validation():
    g = AP.layernorm_matmul_program(32.0)
    with pytest.raises(ValueError, match="objective"):
        SEL.autotune(g, {"M": [1]}, objective="psychic")
    with pytest.raises(ValueError, match="measure callback"):
        SEL.autotune(g, {"M": [1]}, objective="measured")


def test_measurement_memo():
    calls = []

    def thunk():
        calls.append(1)
        return 1.5

    key = ("fp", (("M", 2),), "jax", "cpu")
    assert T.measured(key, thunk) == 1.5
    assert T.measured(key, thunk) == 1.5
    assert len(calls) == 1
    assert T.measurement_count() == 1


# ---------------------------------------------------------------------------
# Driver-level: pipeline.compile(..., autotune="measured")
# ---------------------------------------------------------------------------

def test_pipeline_measured_autotune_jax(tmp_path):
    g = AP.layernorm_matmul_program(32.0)
    cands = {"M": [1, 2], "K": [2, 4], "N": [1, 2]}
    cache = pipeline.KernelCache(root=tmp_path)
    kern = pipeline.compile(g, backend="jax", dim_candidates=cands,
                            autotune="measured", top_k=2,
                            measure_repeats=2, cache=cache)
    assert kern.cache_hit is None
    assert all(kern.dims[d] in cands[d] for d in cands)
    assert kern.measured_s is not None and kern.measured_s > 0
    assert kern.autotune_timings and len(kern.autotune_timings) <= 2
    assert kern.measured_s == min(t for _, t in kern.autotune_timings)
    # the kernel executes
    inputs = T.synth_inputs(g, kern.dims)
    out = kern(inputs)
    assert set(out) == {"Z"}
    # analytic sweep over the same candidates keys separately
    ka = pipeline.compile(g, backend="jax", dim_candidates=cands,
                          cache=cache)
    assert ka.key != kern.key
    # second measured compile: in-process hit, no new measurements
    n = T.measurement_count()
    k2 = pipeline.compile(g, backend="jax", dim_candidates=cands,
                          autotune="measured", top_k=2,
                          measure_repeats=2, cache=cache)
    assert k2.cache_hit == "memory" and T.measurement_count() == n
    # a fresh process (new in-process cache, same disk root) re-loads
    # the measured winner from the plan cache without re-measuring
    T.clear_measurements()
    cache2 = pipeline.KernelCache(root=tmp_path)
    k3 = pipeline.compile(g, backend="jax", dim_candidates=cands,
                          autotune="measured", top_k=2,
                          measure_repeats=2, cache=cache2)
    assert k3.cache_hit == "disk"
    assert k3.dims == kern.dims
    assert k3.measured_s == pytest.approx(kern.measured_s)
    assert T.measurement_count() == 0


def test_pipeline_measured_autotune_pallas(tmp_path):
    """The measured path through the Pallas backend: candidates compile
    at a fixed total problem size (block extents shrink as counts grow)
    and the winner lowers with zero fallbacks."""
    g = AP.layernorm_matmul_program(32.0)
    cands = {"M": [1, 2], "K": [2], "N": [2]}
    cache = pipeline.KernelCache(root=tmp_path)
    kern = pipeline.compile(g, backend="pallas",
                            blocks={"M": 4, "K": 4, "N": 4},
                            dim_candidates=cands, autotune="measured",
                            top_k=2, measure_repeats=1, cache=cache)
    assert kern.measured_s is not None and kern.measured_s > 0
    assert kern.lowering_report is not None
    assert kern.lowering_report.fallbacks == 0
    inputs = T.synth_inputs(g, kern.dims, kern.blocks)
    out = kern(inputs)
    assert set(out) == {"Z"}


def test_region_times_pair_with_region_costs(tmp_path):
    """Per-kernel wall times pair with the per-kernel traffic
    attribution — by kernel id, not position — and a megakernel serving
    several regions pairs once; the (features, seconds) pairing is what
    calibration fits."""
    g = AP.rmsnorm_ffn_swiglu_program(16.0)
    dims = {"M": 2, "D": 2, "K": 3, "N": 2}
    blocks = {"M": 4, "D": 8, "K": 4, "N": 4}
    cache = pipeline.KernelCache(root=tmp_path)
    kern = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                            cache=cache)
    inputs = T.synth_inputs(g, dims, blocks)
    rts = T.region_times(kern, inputs, warmup=1, repeats=2)
    assert rts is not None
    assert kern.region_costs is not None
    assert len(rts) == len(kern.region_costs)
    assert len(rts) == kern.lowering_report.launches
    assert all(r.median_s > 0 for r in rts)
    assert all(r.gid for r in rts)
    paired = T.pair_region_times(kern, rts)
    assert len(paired) == len(rts)
    assert [gid for gid, _, _ in paired] == list(kern.kernel_ids)
    # id-based pairing survives reordering; positional pairing wouldn't
    paired_rev = T.pair_region_times(kern, list(reversed(rts)))
    assert sorted(paired) == sorted(paired_rev)
    # the megakernel's wall time splits across its member regions
    stages = T.stage_time_attribution(kern, rts)
    assert len(stages) == kern.lowering_report.n_regions
    for t in rts:
        parts = [s for g_, _, s in stages if g_ == t.gid]
        assert sum(parts) == pytest.approx(t.median_s)
    # non-pallas kernels don't expose region runners
    kj = pipeline.compile(g, dims, backend="jax", cache=cache)
    assert T.region_times(kj, inputs) is None


def test_cache_plan_persists_measured_seconds():
    from repro.pipeline.cache import CachePlan
    plan = CachePlan(1, {"M": 2}, 10.0, (10.0, 20.0), 20.0,
                     region_costs=(5.0, 5.0), measured_s=1.25e-3)
    back = CachePlan.from_json(plan.to_json())
    assert back == plan
    # older entries without the key load as None
    d = plan.to_json()
    del d["measured_s"]
    assert CachePlan.from_json(d).measured_s is None


# ---------------------------------------------------------------------------
# Slow tier: the acceptance property on all five in-repo programs
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_measured_choice_never_slower_than_analytic(name, tmp_path):
    """autotune(objective='measured') returns a config whose measured
    wall time is <= the analytic default's choice (ties allowed),
    through the real driver-built measurement harness."""
    build, cands = PROGRAMS[name]
    g = build()
    cache = pipeline.KernelCache(root=tmp_path)
    kern = pipeline.compile(g, backend="jax", dim_candidates=cands,
                            autotune="measured", top_k=3,
                            measure_repeats=3, cache=cache)
    analytic = pipeline.compile(g, backend="jax", dim_candidates=cands,
                                cache=cache)
    times = dict(kern.autotune_timings)
    akey = tuple(sorted(analytic.dims.items()))
    # the analytic winner is always among the timed finalists...
    assert akey in times
    # ...so the measured winner can never be slower
    assert kern.measured_s is not None
    assert kern.measured_s <= times[akey]
