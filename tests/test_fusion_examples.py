"""The paper's three worked examples, end to end (§5).

For each example we check:
  * every fusion snapshot interprets to the same outputs as the original
    (the rules are logic-preserving);
  * the final snapshot is fully fused (no internal buffered edges — the
    paper's epilogues);
  * the rules applied match the paper's trace (kinds and counts);
  * global-memory traffic collapses vs. the initial program.
"""

from collections import Counter

import numpy as np
import pytest

from repro.core import cost as C
from repro.core.blocks import merge
from repro.core.fusion import FusionTrace, fuse
from repro.core.graph import MapNode, internal_buffered_edges
from repro.core.interpreter import run


def _apply_and_check(case, expected_rules=None, expected_snapshots=None):
    trace = FusionTrace()
    snaps = fuse(case.graph, trace)
    for s in snaps:
        out = run(s, case.inputs, case.dims)
        np.testing.assert_allclose(merge(out[case.out_name]), case.ref,
                                   rtol=1e-9, atol=1e-9)
    assert internal_buffered_edges(snaps[-1]) == []
    if expected_snapshots is not None:
        assert len(snaps) == expected_snapshots
    if expected_rules is not None:
        got = Counter(r for r, _ in trace.steps)
        for rule, count in expected_rules.items():
            assert got[rule] == count, (rule, got)
    return snaps, trace


def test_flash_attention_rediscovery(attention_case):
    """Example 1: the algorithm rediscovers Flash Attention in exactly the
    paper's 17 steps (6+4+1 map fusions, 1 scale/dot swap, 3 map+reduction
    fusions, 1 elementwise fusion, 1 map extension)."""
    snaps, trace = _apply_and_check(
        attention_case,
        expected_rules={
            "rule1_fuse_consecutive_maps": 11,
            "rule4_swap_scale_dot": 1,
            "rule3_fuse_map_reduction": 3,
            "rule9_fuse_consecutive_elementwise": 1,
            "rule6_extend_map": 1,
        },
        expected_snapshots=2,
    )
    assert len(trace.steps) == 17  # the paper's step count

    # final structure: M-map{ L-map{ serial N-map{ serial D-map } } }
    final = snaps[-1]
    assert len(final.op_nodes()) == 1
    m = final.nodes[final.op_nodes()[0]]
    assert isinstance(m, MapNode) and m.dim == "M" and not m.serial
    l = [m.inner.nodes[n] for n in m.inner.op_nodes()
         if isinstance(m.inner.nodes[n], MapNode)]
    assert len(l) == 1 and l[0].dim == "L"
    n_maps = [l[0].inner.nodes[n] for n in l[0].inner.op_nodes()
              if isinstance(l[0].inner.nodes[n], MapNode)]
    assert len(n_maps) == 1 and n_maps[0].dim == "N" and n_maps[0].serial
    # the N loop carries exactly two accumulators (softmax denom + PV)
    assert sum(r is not None for r in n_maps[0].reduced) == 2


def test_flash_attention_traffic_collapse(attention_case):
    snaps, _ = _apply_and_check(attention_case)
    t0 = C.traffic(attention_case.graph, attention_case.dims)
    t1 = C.traffic(snaps[0], attention_case.dims)
    # intermediate stores vanish except the program output
    dims = attention_case.dims
    assert sum(t1.stores.values()) <= dims["M"] * dims["L"] * 3
    assert sum(t0.stores.values()) > 5 * sum(t1.stores.values())
    assert t1.launches == 1 and t0.launches == 7


def test_layernorm_matmul(layernorm_case):
    """Example 2: Flash-LayerNorm+Matmul; uses both linearity swaps."""
    snaps, trace = _apply_and_check(
        layernorm_case,
        expected_rules={
            "rule4_swap_scale_dot": 1,
            "rule5_swap_shift_dot": 1,
            "rule6_extend_map": 1,
        },
        expected_snapshots=2,
    )
    final = snaps[-1]
    m = final.nodes[final.op_nodes()[0]]
    assert isinstance(m, MapNode) and m.dim == "M"
    # inside: a single N-map whose K-loop carries 4 accumulators
    n = [m.inner.nodes[i] for i in m.inner.op_nodes()
         if isinstance(m.inner.nodes[i], MapNode)]
    assert len(n) == 1 and n[0].dim == "N"
    k = [n[0].inner.nodes[i] for i in n[0].inner.op_nodes()
         if isinstance(n[0].inner.nodes[i], MapNode)]
    assert len(k) == 1 and k[0].dim == "K" and k[0].serial
    assert sum(r is not None for r in k[0].reduced) == 4


def test_rmsnorm_ffn_swiglu(swiglu_case):
    """Example 3: the Flash-RMSNorm+FFN-SwiGLU mega-kernel: three matmuls,
    a Hadamard, a reduction and elementwise ops fused into one kernel, with
    two map extensions (paper steps 23 and 25) and the Rule-8 duplication."""
    snaps, trace = _apply_and_check(
        swiglu_case,
        expected_rules={
            "rule8_duplicate_mapped_scale": 1,
            "rule4_swap_scale_dot": 2,
            "rule6_extend_map": 2,
        },
        expected_snapshots=3,
    )
    final = snaps[-1]
    # fully nested M{N{K{D}}} with the D-loop carrying x^2, xW and xV accums
    m = final.nodes[final.op_nodes()[0]]
    n = [m.inner.nodes[i] for i in m.inner.op_nodes()
         if isinstance(m.inner.nodes[i], MapNode)][0]
    k = [n.inner.nodes[i] for i in n.inner.op_nodes()
         if isinstance(n.inner.nodes[i], MapNode)][0]
    d = [k.inner.nodes[i] for i in k.inner.op_nodes()
         if isinstance(k.inner.nodes[i], MapNode)][0]
    assert (m.dim, n.dim, k.dim, d.dim) == ("M", "N", "K", "D")
    assert sum(r is not None for r in d.reduced) == 3
    assert sum(r is not None for r in k.reduced) == 1


def test_snapshots_trade_replication_for_buffering(swiglu_case):
    """Rule 6 replicates work in exchange for fusion (paper §3.2): later
    snapshots do more functional work but store less."""
    snaps = fuse(swiglu_case.graph)
    dims = swiglu_case.dims
    works = [sum(C.traffic(s, dims).work.values()) for s in snaps]
    stores = [sum(C.traffic(s, dims).stores.values()) for s in snaps]
    assert works == sorted(works)
    assert stores == sorted(stores, reverse=True)


def test_fusion_does_not_mutate_input(attention_case):
    before = attention_case.graph.describe()
    fuse(attention_case.graph)
    assert attention_case.graph.describe() == before
