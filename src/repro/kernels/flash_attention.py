"""Pallas TPU kernel: fused attention (paper Example 1 + Appendix).

This is the kernel the fusion algorithm *derives* (tests assert the derived
block program has exactly this loop structure), hand-written with TPU
BlockSpec tiling:

  grid = (batch*heads, Sq/block_q, Skv/block_kv)
  the trailing grid dim is the serial N-map of the paper's final listing;
  the two accumulators (softmax denominator and P@V) live in VMEM scratch,
  carried across grid steps with the running-max rescaling of the appendix
  (significand-exponent pairs with a row-wise shared exponent).

GQA is handled in the k/v index maps (a q-head group reads its kv head).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, q_offset: int, block_q: int,
                  block_kv: int, n_kv: int, kv_len: Optional[int]):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, dh)
    k = k_ref[0].astype(jnp.float32)          # (bkv, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    cols = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    if causal:
        qi = pl.program_id(1)
        rows = q_offset + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        s = jnp.where(rows >= cols, s, NEG_INF)
    if kv_len is not None:
        s = jnp.where(cols < kv_len, s, NEG_INF)

    m_prev = m_ref[...]                        # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)            # rescale: e^{t_old - z}
    p = jnp.exp(s - m_new)                     # significand block
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0, ...] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           scale: Optional[float] = None,
                           causal: bool = False, q_offset: int = 0,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, Dh); k, v: (B, Hkv, Skv, Dh).  Returns (B, Hq, Sq, Dh).

    Sq and Skv are padded to the block sizes; Dh is used whole (VMEM lane
    dim; pad to a multiple of 128 upstream for peak MXU utilization)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)

    block_q = min(block_q, max(sq, 8))
    block_kv = min(block_kv, max(skv, 8))
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    if pad_kv:
        # pad keys so padded columns are masked out by a large negative score
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_kv

    qf = qp.reshape(b * hq, sq_p, dh)
    kf = k.reshape(b * hkv, skv_p, dh)
    vf = v.reshape(b * hkv, skv_p, dh)
    n_q = sq_p // block_q
    n_kv = skv_p // block_kv

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, q_offset=q_offset,
        block_q=block_q, block_kv=block_kv, n_kv=n_kv,
        kv_len=skv if pad_kv else None)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_kv, dh),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_kv, dh),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, hq, sq_p, dh)
    return out[:, :, :sq, :]
