"""Substrate tests: data pipeline determinism, optimizer behaviour,
checkpoint save/restore (incl. resharding + atomicity), sharding rules."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.optim import AdamW, cosine_schedule
from repro.runtime.sharding import (DEFAULT_RULES, logical_to_spec,
                                    use_mesh)


def test_data_is_deterministic_and_step_keyed():
    d = SyntheticLMData(vocab=100, seq_len=16, global_batch=4, seed=7)
    b1 = d.batch(3)
    b2 = d.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch(4)["tokens"], b1["tokens"])
    # labels are tokens shifted by one
    full1 = np.concatenate([np.asarray(b1["tokens"]),
                            np.asarray(b1["labels"][:, -1:])], axis=1)
    np.testing.assert_array_equal(full1[:, 1:], b1["labels"])


def test_data_host_slices_partition_batch():
    d = SyntheticLMData(vocab=100, seq_len=8, global_batch=8, seed=0)
    full = d.batch(0)
    parts = [d.host_slice(0, i, 4) for i in range(4)]
    glued = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(glued, full["tokens"])


def test_adamw_reduces_loss_on_quadratic():
    opt = AdamW(lr=cosine_schedule(0.1, 5, 100), weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(loss(params)) < 0.05 * l0


def test_adamw_clips_gradients():
    opt = AdamW(lr=lambda s: 0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    grads = {"w": jnp.array([1e6, 1e6, 1e6])}
    _, _, metrics = opt.update(grads, state, params)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.asarray(5)}
    mgr.save(5, state, blocking=True)
    assert mgr.latest_step() == 5
    got = mgr.restore()
    np.testing.assert_array_equal(got["params"]["w"],
                                  np.arange(6.0).reshape(2, 3))
    assert int(got["step"]) == 5


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        mgr.save(s, {"x": jnp.asarray(s)}, blocking=True)
    assert mgr.steps() == [2, 3]
    assert int(mgr.restore()["x"]) == 3


def test_checkpoint_async_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones((128, 128))})
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_tmp_dirs_are_not_published(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "tmp.step_9")  # simulated crash mid-save
    assert mgr.latest_step() is None


def test_logical_rules_drop_missing_axes():
    # no mesh: specs still build, dropping unknown axes
    spec = logical_to_spec(("batch", "tensor", None))
    assert spec == jax.sharding.PartitionSpec()


def test_logical_rules_no_double_use():
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        # batch uses data; fsdp would also map to data -> dropped
        spec = logical_to_spec(("batch", "fsdp"))
        assert spec == jax.sharding.PartitionSpec("data")
