"""``repro.pipeline`` — the end-to-end fusion pipeline.

``compile(graph, dims, options=CompileOptions(...))`` drives the whole
paper loop — fusion algorithm -> snapshot/block-shape selection
(traffic cost model) -> backend codegen — and memoizes the result in a
two-level kernel cache (in-process callables + on-disk compilation
plans).  ``CompileOptions`` is the frozen, hashable description of
*how* a program compiles (backend, blocks, stabilize, autotune, group,
...) and hashes directly into the cache key; the flat keyword form
``compile(graph, dims, backend=...)`` remains as a deprecated
back-compat shim.  Model layers and benchmarks execute through this
driver; it is the substrate later scaling work (sharding, batching,
serving) compiles through.

Failures degrade instead of aborting: lowering walks the resilience
ladder (grouped -> ungrouped -> jax -> interpreter, see
``repro.resilience``) under the ``CompileOptions.resilience`` policy,
every attempt recorded in ``CompiledKernel.resilience_report``; corrupt
on-disk cache entries are checksummed, quarantined, and counted in
``CacheStats`` rather than silently recompiled.
"""

from repro.pipeline.cache import (CODEGEN_VERSION, CacheKey, CachePlan,
                                  CacheStats, KernelCache, default_cache,
                                  reset_default_cache)
from repro.pipeline.driver import BACKENDS, CompiledKernel, compile
from repro.pipeline.options import DEFAULT_OPTIONS, CompileOptions
from repro.resilience import LADDER, LadderError, ResiliencePolicy

__all__ = [
    "BACKENDS", "CODEGEN_VERSION", "CacheKey", "CachePlan", "CacheStats",
    "CompileOptions", "CompiledKernel", "DEFAULT_OPTIONS", "KernelCache",
    "LADDER", "LadderError", "ResiliencePolicy",
    "compile", "default_cache", "reset_default_cache",
]
