"""One benchmark per paper example (the paper's results are its three
worked examples): global-memory traffic before/after fusion, kernel-launch
counts, work replication across snapshots, and fusion-algorithm runtime.

``run_pipeline`` additionally *executes* each example through
``pipeline.compile`` on the jax backend — fused vs unfused wall time next
to the cost model's predicted traffic, from the same driver the model
layers use.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import array_program as AP
from repro.core import cost as C
from repro.core.fusion import FusionTrace, fuse

# representative block sizes (bytes): 128x128 f32 blocks, 128 f32 vectors
ITEM_BYTES = {"block": 128 * 128 * 4, "vector": 128 * 4, "scalar": 4}

EXAMPLES = {
    "attention": (lambda: AP.attention_program(0.125),
                  {"M": 8, "D": 4, "N": 16, "L": 4}),
    # decoder prefill: M == N tile the same sequence; the mask-aware cost
    # model skips fully-masked tiles, so predicted traffic is ~(N+1)/2N
    # of the non-causal program's
    "causal_attention": (lambda: AP.causal_attention_program(0.125),
                         {"M": 16, "D": 4, "N": 16, "L": 4}),
    "layernorm_matmul": (lambda: AP.layernorm_matmul_program(512.0),
                         {"M": 8, "K": 16, "N": 8}),
    "rmsnorm_ffn_swiglu": (lambda: AP.rmsnorm_ffn_swiglu_program(512.0),
                           {"M": 8, "D": 8, "K": 16, "N": 8}),
}

# the tiny fixed configuration CI's bench job runs (block size 8,
# 2 repeats): small enough for an ubuntu runner, same programs, and the
# derived values the regression gate compares (predicted traffic
# reduction, pallas region/fallback counts) are deterministic
CI_EXAMPLES = {
    "attention": (lambda: AP.attention_program(0.125),
                  {"M": 2, "D": 2, "N": 4, "L": 2}),
    "causal_attention": (lambda: AP.causal_attention_program(0.125),
                         {"M": 4, "D": 2, "N": 4, "L": 2}),
    "layernorm_matmul": (lambda: AP.layernorm_matmul_program(64.0),
                         {"M": 2, "K": 4, "N": 2}),
    "rmsnorm_ffn_swiglu": (lambda: AP.rmsnorm_ffn_swiglu_program(64.0),
                           {"M": 2, "D": 2, "K": 4, "N": 2}),
}

PRESETS = {"full": (EXAMPLES, 5, 16), "ci": (CI_EXAMPLES, 2, 8)}


def bench_example(name: str) -> List[Dict]:
    build, dims = EXAMPLES[name]
    g = build()
    t0 = time.perf_counter()
    trace = FusionTrace()
    snaps = fuse(g, trace)
    fuse_us = (time.perf_counter() - t0) * 1e6

    t_init = C.traffic(g, dims)
    rows = []
    init_bytes = t_init.bytes_moved(ITEM_BYTES)
    for i, s in enumerate(snaps):
        t = C.traffic(s, dims)
        rows.append({
            "name": f"fusion_{name}_snap{i}",
            "us_per_call": fuse_us,
            "derived": (
                f"traffic_bytes={t.bytes_moved(ITEM_BYTES)};"
                f"traffic_reduction={init_bytes / max(t.bytes_moved(ITEM_BYTES), 1):.2f}x;"
                f"stores={sum(t.stores.values())};"
                f"loads={sum(t.loads.values())};"
                f"launches={t_init.launches}->{t.launches};"
                f"work_factor={sum(t.work.values()) / max(sum(t_init.work.values()), 1):.2f};"
                f"rule_applications={len(trace.steps)}"
            ),
        })
    return rows


def _random_inputs(g, dims: Dict[str, int], bs: int, rng) -> Dict:
    out = {}
    for nid in g.input_ids:
        node = g.nodes[nid]
        shape = tuple(dims[d] * bs for d in node.vtype.dims)
        if node.name in ("QP", "KP"):  # global positions, not data
            out[node.name] = np.arange(shape[0], dtype=np.float32)
        else:
            out[node.name] = (rng.normal(size=shape)
                              / max(shape[-1], 1) ** 0.5).astype(np.float32)
    return out


def bench_pipeline_example(name: str, repeats: int = 5, bs: int = 16,
                           examples: Dict = None) -> List[Dict]:
    """Fused vs unfused wall time through ``pipeline.compile`` (jax
    backend), with the cost model's predicted traffic side by side, plus
    the Pallas lowering report of the selected snapshot (regions emitted
    and fallbacks taken — the CI gate pins fallbacks to zero)."""
    import jax

    from repro import pipeline

    build, dims = (examples or EXAMPLES)[name]
    g = build()
    blocks = {d: bs for d in dims}
    inputs = _random_inputs(g, dims, bs, np.random.default_rng(0))
    cache = pipeline.KernelCache(disk=False)

    def timed(kern) -> float:
        jax.block_until_ready(list(kern(inputs).values()))  # warmup/compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(list(kern(inputs).values()))
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    kf = pipeline.compile(g, dims, backend="jax", blocks=blocks,
                          cache=cache)
    ku = pipeline.compile(g, dims, backend="jax", blocks=blocks,
                          fused=False, cache=cache)
    fused_us, unfused_us = timed(kf), timed(ku)
    # the second compile must be an in-process cache hit
    rehit = pipeline.compile(g, dims, backend="jax", blocks=blocks,
                             cache=cache).cache_hit
    # Pallas lowering of the SAME selected snapshot (emission only):
    # region DAG size and fallback count, gated to zero in CI
    kp = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          interpret=True, cache=cache)
    rep = kp.lowering_report
    return [{
        "name": f"pipeline_{name}",
        "us_per_call": fused_us,
        "derived": (
            f"unfused_us={unfused_us:.1f};"
            f"speedup={unfused_us / max(fused_us, 1e-9):.2f}x;"
            f"pred_cost_fused={kf.cost:.3g};"
            f"pred_cost_unfused={kf.initial_cost:.3g};"
            f"pred_traffic_reduction={kf.predicted_traffic_reduction:.2f}x;"
            f"snapshot={kf.snapshot_index};recompile_hit={rehit};"
            f"pallas_regions={rep.n_regions};"
            f"pallas_fallbacks={rep.fallbacks}"
        ),
    }]


def run_pipeline(preset: str = "full") -> List[Dict]:
    examples, repeats, bs = PRESETS[preset]
    rows = []
    for name in examples:
        rows.extend(bench_pipeline_example(name, repeats=repeats, bs=bs,
                                           examples=examples))
    return rows


def run() -> List[Dict]:
    """Traffic-model rows only (the original entry point); executing
    pipeline rows are a separate section: ``run_pipeline``."""
    rows = []
    for name in EXAMPLES:
        rows.extend(bench_example(name))
    return rows
