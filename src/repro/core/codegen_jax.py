"""Compile a block program into an executable, jit-able JAX function.

Lowering rules (block lists are stacked jnp arrays, one leading axis per
list level — block decompositions must be uniform):

  * parallel Map           -> jax.vmap   (mapped ports: in_axes=0)
  * serial Map (Rule 3'd)  -> jax.lax.scan with the accumulated out-ports
                              as f32 carries (paper: serial loop + accum)
  * Reduce                 -> sum over the leading axis
  * Func                   -> the op's jnp implementation

This closes the compiler pipeline: array program -> (Table 2) block
program -> fusion algorithm -> executable kernel.  ``compile_program``'s
output is a plain JAX function: it can be jitted, differentiated, sharded
with pjit, or lowered to HLO like any other.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as O
from repro.core.graph import (FuncNode, Graph, InputNode, MapNode, MiscNode,
                              OutputNode, ReduceNode)


def stack_blocks(nested) -> jnp.ndarray:
    """Nested lists of equal-shaped blocks -> one stacked array."""
    if isinstance(nested, list):
        return jnp.stack([stack_blocks(x) for x in nested], axis=0)
    return jnp.asarray(nested)


def _eval(g: Graph, inputs: Sequence[Any]) -> List[Any]:
    env: Dict = {}
    for nid, v in zip(g.input_ids, inputs):
        env[(nid, 0)] = v
    outs: Dict[int, Any] = {}
    for nid in g.topo():
        node = g.nodes[nid]
        if isinstance(node, InputNode):
            continue
        ins = [env[(e.src, e.sp)] for e in g.in_edges(nid)]
        if isinstance(node, OutputNode):
            outs[nid] = ins[0]
        elif isinstance(node, FuncNode):
            env[(nid, 0)] = node.op.apply(jnp, *ins)
        elif isinstance(node, ReduceNode):
            env[(nid, 0)] = jnp.sum(ins[0].astype(jnp.float32),
                                    axis=0).astype(ins[0].dtype)
        elif isinstance(node, MiscNode):
            res = node.fn(jnp, *ins)
            if node.n_out() == 1:
                env[(nid, 0)] = res
            else:
                for p, r in enumerate(res):
                    env[(nid, p)] = r
        elif isinstance(node, MapNode):
            results = _lower_map(node, ins)
            for p, r in enumerate(results):
                env[(nid, p)] = r
        else:
            raise TypeError(node)
    return [outs[oid] for oid in g.output_ids]


def _lower_map(node: MapNode, ins: Sequence[Any]) -> List[Any]:
    mapped_ins = [v for v, m in zip(ins, node.mapped) if m]
    assert mapped_ins, "maps with no mapped input need static lengths"

    def body(*per_iter):
        it = iter(per_iter)
        full = [next(it) if m else b
                for b, m in zip(ins, node.mapped)]
        return _eval(node.inner, full)

    if not node.serial:
        outs = jax.vmap(body, in_axes=[0] * len(mapped_ins))(*mapped_ins)
        return list(outs)

    # serial map: accumulated ports become f32 scan carries
    first = jax.tree.map(lambda x: x[0], tuple(mapped_ins))
    out_shapes = jax.eval_shape(lambda xs: body(*xs), first)

    def scan_body(carry, xs):
        res = body(*xs)
        new_carry, ys = [], []
        ci = 0
        for p, r in enumerate(node.reduced):
            if r is None:
                ys.append(res[p])
            else:
                new_carry.append(carry[ci] + res[p].astype(jnp.float32))
                ci += 1
        return tuple(new_carry), tuple(ys)

    carry0 = tuple(
        jnp.zeros(out_shapes[p].shape, jnp.float32)
        for p, r in enumerate(node.reduced) if r is not None)
    carry, ys = jax.lax.scan(scan_body, carry0, tuple(mapped_ins))
    results: List[Any] = []
    ci = yi = 0
    for p, r in enumerate(node.reduced):
        if r is None:
            results.append(ys[yi])
            yi += 1
        else:
            results.append(carry[ci].astype(out_shapes[p].dtype))
            ci += 1
    return results


def compile_program(g: Graph) -> Callable[..., List[Any]]:
    """Return f(*stacked_inputs) -> [stacked_outputs], ready for jax.jit."""

    def fn(*inputs):
        return _eval(g, inputs)

    return fn


def run_jax(g: Graph, inputs: Dict[str, Any]) -> Dict[str, Any]:
    """Convenience: run a program on nested-list block inputs via jit."""
    stacked = [stack_blocks(inputs[g.nodes[nid].name])
               for nid in g.input_ids]
    out = jax.jit(compile_program(g))(*stacked)
    return {g.nodes[oid].name: v
            for oid, v in zip(g.output_ids, out)}
