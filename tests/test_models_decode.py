"""Serving correctness: prefill + one-token decode steps must reproduce the
teacher-forced forward logits for every cache type (GQA KV, MLA compressed
KV with absorbed decode, Mamba SSM state + conv window, jamba's mix,
whisper's self+cross caches)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model

pytestmark = pytest.mark.slow  # per-arch prefill/decode loops: not tier-1

CASES = ["smollm-135m", "deepseek-v3-671b", "mamba2-2.7b",
         "jamba-1.5-large-398b"]


@pytest.mark.parametrize("arch", CASES)
def test_prefill_decode_matches_forward(arch, rng):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(1))
    B, S, P = 2, 12, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full = model.forward(params, toks)
    lp, cache = model.prefill(params, toks[:, :P], max_len=S)
    np.testing.assert_allclose(np.asarray(lp[:, -1], np.float32),
                               np.asarray(full[:, P - 1], np.float32),
                               atol=1e-4, rtol=1e-4)
    for t in range(P, S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1], t)
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   atol=1e-4, rtol=1e-4)


def test_whisper_prefill_decode(rng):
    cfg = get_reduced_config("whisper-tiny")
    model = build_model(cfg)
    params, _ = model.init_params(jax.random.key(1))
    B, S, P = 2, 12, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    frames = jnp.asarray(rng.normal(size=(B, cfg.enc_seq, cfg.d_model)),
                         cfg.dtype) * 0.02
    full = model.forward(params, toks, frames=frames)
    lp, cache = model.prefill(params, toks[:, :P], frames=frames, max_len=S)
    np.testing.assert_allclose(np.asarray(lp[:, -1], np.float32),
                               np.asarray(full[:, P - 1], np.float32),
                               atol=1e-4, rtol=1e-4)
    for t in range(P, S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1], t)
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   atol=1e-4, rtol=1e-4)


def test_moe_scatter_dispatch_matches_loop_oracle(rng):
    """The capacity/scatter MoE equals a dense per-expert loop when no
    tokens are dropped."""
    from repro.models import layers as L
    from repro.models.common import ParamBuilder
    cfg = get_reduced_config("qwen3-moe-30b-a3b")
    pb = ParamBuilder(jax.random.key(2), cfg.dtype)
    L.init_moe(pb, cfg)
    p, _ = pb.build()
    gamma = jnp.ones((cfg.d_model,), cfg.dtype)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), cfg.dtype) * 0.3
    got = L.moe_apply(p, x, gamma, cfg)
    want = L.moe_ref(p, x, gamma, cfg)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-4, rtol=2e-3)


def test_ssd_chunk_size_invariance(rng):
    """The SSD chunked scan is exact: results do not depend on chunk size
    (the chunking is the paper's block decomposition applied to the SSM)."""
    import dataclasses
    from repro.models import layers as L
    from repro.models.common import ParamBuilder
    cfg = get_reduced_config("mamba2-2.7b")
    pb = ParamBuilder(jax.random.key(3), cfg.dtype)
    L.init_mamba(pb, cfg)
    p, _ = pb.build()
    gamma = jnp.ones((cfg.d_model,), cfg.dtype)
    x = jnp.asarray(rng.normal(size=(2, 24, cfg.d_model)), cfg.dtype) * 0.3
    outs = []
    for q in (4, 8, 24):
        c = dataclasses.replace(cfg, ssm_chunk=q)
        outs.append(np.asarray(L.mamba_apply(p, x, gamma, c), np.float32))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-5, rtol=2e-5)
