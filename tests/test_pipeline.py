"""Differential test harness for ``pipeline.compile``.

For every paper example program and every backend (py / jax /
pallas-interpret), the compiled kernel must agree with (a) the dense
numpy reference and (b) the block-program interpreter oracle — all
backends consume the same merged dense arrays, so a single harness covers
the whole matrix.  Cache behavior (in-process hits, cross-process disk
hits) and fingerprint stability are pinned here too.
"""

import dataclasses

import numpy as np
import pytest

from repro import pipeline
from repro.core import array_program as AP
from repro.core.blocks import merge
from repro.core.interpreter import run as interp_run
from repro.pipeline import packing as P

BACKENDS = ["py", "jax", "pallas"]

# block sizes matching the conftest cases (merged arrays are rebuilt from
# the same nested-block inputs the interpreter consumes)
CASE_BLOCKS = {
    "attention": {"M": 8, "D": 16, "N": 8, "L": 16},
    "layernorm": {"M": 8, "K": 8, "N": 16},
    "swiglu": {"M": 8, "D": 8, "K": 8, "N": 8},
}


@pytest.fixture()
def cache(tmp_path):
    return pipeline.KernelCache(tmp_path)


def _get_case(name, attention_case, layernorm_case, swiglu_case):
    return {"attention": attention_case, "layernorm": layernorm_case,
            "swiglu": swiglu_case}[name]


def _merged_inputs(case):
    """Rebuild dense merged arrays from the case's nested block inputs."""
    out = {}
    for nid in case.graph.input_ids:
        node = case.graph.nodes[nid]
        out[node.name] = P.from_nested(
            case.inputs[node.name], node.vtype, case.dims
        ).astype(np.float32)
    return out


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case_name", ["attention", "layernorm", "swiglu"])
def test_pipeline_differential(case_name, backend, cache, attention_case,
                               layernorm_case, swiglu_case):
    """pipeline.compile output == numpy reference == interpreter oracle,
    for all three examples on all three backends."""
    case = _get_case(case_name, attention_case, layernorm_case, swiglu_case)
    kern = pipeline.compile(case.graph, case.dims, backend=backend,
                            blocks=CASE_BLOCKS[case_name], cache=cache)
    assert kern.cache_hit is None  # fresh compile
    got = np.asarray(kern(_merged_inputs(case))[case.out_name])

    # (a) dense numpy reference
    np.testing.assert_allclose(got, case.ref, rtol=2e-4, atol=2e-4)
    # (b) interpreter oracle on the ORIGINAL (unfused) program
    oracle = merge(interp_run(case.graph, case.inputs, case.dims)
                   [case.out_name])
    np.testing.assert_allclose(got, oracle, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case_name", ["attention", "layernorm", "swiglu"])
def test_pipeline_second_compile_is_cache_hit(case_name, backend, cache,
                                              attention_case,
                                              layernorm_case, swiglu_case):
    case = _get_case(case_name, attention_case, layernorm_case, swiglu_case)
    blocks = CASE_BLOCKS[case_name]
    k1 = pipeline.compile(case.graph, case.dims, backend=backend,
                          blocks=blocks, cache=cache)
    k2 = pipeline.compile(case.graph, case.dims, backend=backend,
                          blocks=blocks, cache=cache)
    assert k1.cache_hit is None and k2.cache_hit == "memory"
    assert k2._fn is k1._fn  # the jitted callable is reused, not rebuilt
    assert cache.stats.memory_hits >= 1


def test_pipeline_disk_cache_survives_process_boundary(tmp_path,
                                                       attention_case):
    """A fresh KernelCache over the same directory (== a new process)
    loads the plan + selected snapshot from disk: no fusion rerun."""
    case = attention_case
    c1 = pipeline.KernelCache(tmp_path)
    k1 = pipeline.compile(case.graph, case.dims, backend="jax", cache=c1)
    assert k1.cache_hit is None

    c2 = pipeline.KernelCache(tmp_path)
    k2 = pipeline.compile(case.graph, case.dims, backend="jax", cache=c2)
    assert k2.cache_hit == "disk"
    assert k2.snapshot_index == k1.snapshot_index
    assert k2.dims == k1.dims and k2.cost == k1.cost
    got = np.asarray(k2(_merged_inputs(case))[case.out_name])
    np.testing.assert_allclose(got, case.ref, rtol=2e-4, atol=2e-4)


def test_pipeline_unfused_baseline_matches(cache, layernorm_case):
    """fused=False compiles the raw Table-2 program; same numerics, its
    key never collides with the fused kernel's."""
    case = layernorm_case
    kf = pipeline.compile(case.graph, case.dims, backend="jax", cache=cache)
    ku = pipeline.compile(case.graph, case.dims, backend="jax", fused=False,
                          cache=cache)
    assert ku.key != kf.key and ku.cache_hit is None
    assert ku.cost >= kf.cost  # fusion can only cut predicted traffic
    got = np.asarray(ku(_merged_inputs(case))[case.out_name])
    np.testing.assert_allclose(got, case.ref, rtol=2e-4, atol=2e-4)


def test_pipeline_autotune_selects_dims(cache, layernorm_case):
    case = layernorm_case
    kern = pipeline.compile(
        case.graph, backend="jax",
        dim_candidates={"M": [1, 3], "K": [2, 4], "N": [1, 2]},
        cache=cache)
    assert set(kern.dims) == {"M", "K", "N"}
    assert kern.cost <= kern.initial_cost


def test_cache_key_covers_kernel_affecting_options(cache, layernorm_case):
    """Options that change the emitted kernel (jit) or the selection plan
    (item_bytes) must key separately — no stale-kernel serving."""
    case = layernorm_case
    k1 = pipeline.compile(case.graph, case.dims, backend="jax", cache=cache)
    k2 = pipeline.compile(case.graph, case.dims, backend="jax", jit=False,
                          cache=cache)
    assert k2.key != k1.key and k2.cache_hit is None
    k3 = pipeline.compile(case.graph, case.dims, backend="jax",
                          item_bytes={"block": 1, "vector": 1, "scalar": 1},
                          cache=cache)
    assert k3.key != k1.key and k3.cache_hit is None


def test_fingerprint_stable_and_discriminating():
    a1 = AP.attention_program(0.125)
    a2 = AP.attention_program(0.125)
    assert a1.fingerprint() == a2.fingerprint()
    # a different baked-in constant must change the fingerprint (else the
    # kernel cache would serve a wrongly-scaled kernel)
    assert AP.attention_program(0.5).fingerprint() != a1.fingerprint()
    assert AP.layernorm_matmul_program(64.0).fingerprint() != \
        a1.fingerprint()
    # fusion output is deterministic, so fingerprints of snapshots agree
    from repro.core.fusion import fuse
    assert fuse(a1)[-1].fingerprint() == fuse(a2)[-1].fingerprint()
    # and differs from the unfused program's
    assert fuse(a1)[-1].fingerprint() != a1.fingerprint()


def test_pipeline_rejects_bad_calls(cache, attention_case):
    case = attention_case
    with pytest.raises(ValueError):
        pipeline.compile(case.graph, case.dims, backend="nope", cache=cache)
    with pytest.raises(ValueError):
        pipeline.compile(case.graph, backend="jax", cache=cache)  # no dims
    with pytest.raises(ValueError):  # pallas needs block sizes
        pipeline.compile(case.graph, case.dims, backend="pallas",
                         cache=cache)
    kern = pipeline.compile(case.graph, case.dims, backend="jax",
                            cache=cache)
    with pytest.raises(KeyError):
        kern({"Q": np.zeros((16, 32))})  # missing inputs


def test_model_layers_execute_through_pipeline(monkeypatch, tmp_path):
    """The flag-gated model path: cfg.mlp_impl/attn_impl == "pipeline"
    routes the SwiGLU MLP and (non-causal) attention through
    pipeline.compile and matches the unfused reference layers."""
    import jax
    import jax.numpy as jnp

    monkeypatch.setenv("REPRO_KERNEL_CACHE", str(tmp_path))
    pipeline.reset_default_cache()
    from repro.models import layers as L
    from repro.models.common import ModelConfig, ParamBuilder

    cfg = ModelConfig(d_model=64, n_heads=2, n_kv_heads=2, d_head=32,
                      d_ff=128, dtype=jnp.float32, norm_eps=1e-6)
    cfg_ref = dataclasses.replace(cfg, mlp_impl="unfused", attn_impl="ref",
                                  rope_theta=0.0)
    cfg_pipe = dataclasses.replace(cfg, mlp_impl="pipeline",
                                   attn_impl="pipeline",
                                   pipeline_backend="jax", rope_theta=0.0)
    pb = ParamBuilder(jax.random.PRNGKey(0), jnp.float32)
    L.init_swiglu(pb, cfg, cfg.d_ff)
    L.init_attention(pb, cfg)
    p = pb.params
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64), jnp.float32)
    gamma = jnp.full((64,), 1.3, jnp.float32)

    ref = L.rmsnorm_swiglu_apply(p, x, gamma, cfg_ref)
    got = L.rmsnorm_swiglu_apply(p, x, gamma, cfg_pipe)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # ... and under jit (compile happens at trace time, cached after)
    jit_got = jax.jit(
        lambda xx: L.rmsnorm_swiglu_apply(p, xx, gamma, cfg_pipe))(x)
    np.testing.assert_allclose(np.asarray(jit_got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    a_ref = L.attention_apply(p, x, cfg_ref, causal=False)
    a_got = L.attention_apply(p, x, cfg_pipe, causal=False)
    np.testing.assert_allclose(np.asarray(a_got), np.asarray(a_ref),
                               rtol=2e-5, atol=2e-5)
    # causal attention also compiles through the pipeline (the causal
    # block program — no XLA fallback; see test_attention_programs.py
    # for the full {causal} x {MHA, GQA} x backend matrix)
    c_ref = L.attention_apply(p, x, cfg_ref, causal=True)
    c_got = L.attention_apply(p, x, cfg_pipe, causal=True)
    np.testing.assert_allclose(np.asarray(c_got), np.asarray(c_ref),
                               rtol=2e-5, atol=2e-5)
    pipeline.reset_default_cache()


def test_codegen_version_salts_disk_cache(tmp_path, layernorm_case,
                                          monkeypatch):
    """Bumping CODEGEN_VERSION must miss the on-disk plan cache: plans
    written by an older compiler are never re-lowered by a newer one."""
    from repro.pipeline import cache as cache_mod

    case = layernorm_case
    c1 = pipeline.KernelCache(tmp_path)
    k1 = pipeline.compile(case.graph, case.dims, backend="jax", cache=c1)
    assert k1.cache_hit is None

    # same version, fresh process (fresh KernelCache object): disk hit
    c2 = pipeline.KernelCache(tmp_path)
    assert pipeline.compile(case.graph, case.dims, backend="jax",
                            cache=c2).cache_hit == "disk"

    # bumped version, fresh process: the stale plan is invisible
    monkeypatch.setattr(cache_mod, "CODEGEN_VERSION",
                        cache_mod.CODEGEN_VERSION + 1)
    c3 = pipeline.KernelCache(tmp_path)
    k3 = pipeline.compile(case.graph, case.dims, backend="jax", cache=c3)
    assert k3.cache_hit is None
    got = np.asarray(k3(_merged_inputs(case))[case.out_name])
    np.testing.assert_allclose(got, case.ref, rtol=2e-4, atol=2e-4)


def test_packing_roundtrip(rng):
    from repro.core.graph import VType
    arr = rng.normal(size=(12, 20)).astype(np.float32)
    vt = VType(("M", "N"), "block")
    dims = {"M": 3, "N": 4}
    st = P.to_stacked(arr, vt, dims)
    assert st.shape == (3, 4, 4, 5)
    np.testing.assert_array_equal(P.from_stacked(st, vt, dims), arr)
    nested = P.to_nested(arr, vt, dims)
    assert isinstance(nested, list) and isinstance(nested[0], list)
    np.testing.assert_array_equal(nested[1][2], arr[4:8, 10:15])
    np.testing.assert_array_equal(P.from_nested(nested, vt, dims), arr)
