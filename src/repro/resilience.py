"""``repro.resilience`` — the degradation ladder, fault isolation, and
deterministic fault injection for the compile pipeline and the serving
engine.

The paper's framework targets "any multiprocessor architecture", which
in production terms means lowering WILL fail on some backend/shape
combinations, on-disk state WILL corrupt, and a request WILL produce
non-finite logits.  This module is the shared vocabulary for surviving
all three:

* **The ladder** — :data:`LADDER` orders the compile strategies from
  fastest to most conservative::

      grouped      one multi-stage megakernel pallas_call per region group
      ungrouped    one pallas_call per region (no VMEM residency)
      jax          codegen_jax under jax.jit (runs everywhere)
      interpreter  the numpy reference interpreter (always correct)

  ``pipeline.compile`` starts at the rung its options ask for and, when
  an attempt raises or times out, *demotes* one rung at a time until
  :class:`ResiliencePolicy.max_rung`, recording every attempt in a
  :class:`ResilienceReport` on the returned kernel.  The default policy
  adds **zero happy-path overhead**: no timeout thread, no retry sleep —
  one ``try`` around the lowering call that already existed.

* **Fault injection** — :class:`FaultPlan` fires deterministic faults
  (exceptions, slow compiles, cache corruption, NaN logits) at chosen
  per-site call indices.  Sites are string names checked by the
  production code paths (``compile:<rung>``, ``cache:get_plan``,
  ``serve:logits``, ``serve:decode``); an inactive plan costs one
  ``None`` check.  Activate programmatically (:func:`install` /
  :func:`faults`) or via ``$REPRO_FAULT_PLAN`` (inline JSON or a path
  to a JSON file), so CI chaos jobs can drive every rung reproducibly.

* **Metrics** — :data:`METRICS` counts ladder demotions process-wide
  (the serving engine reports the delta per run), mirroring how
  ``pipeline.CacheStats`` counts quarantines.
"""

from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import asdict, dataclass, field, fields, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

# fastest first; each entry is strictly more conservative than the one
# before it.  ``pipeline.compile`` maps its options to a starting rung
# (pallas+group -> grouped, pallas -> ungrouped, jax -> jax, py ->
# interpreter) and only ever moves DOWN the list.
LADDER = ("grouped", "ungrouped", "jax", "interpreter")

FAULT_KINDS = ("raise", "sleep", "nan", "corrupt")


class InjectedFault(RuntimeError):
    """Raised by :func:`check` at a site a :class:`FaultPlan` targets."""


class AttemptTimeout(RuntimeError):
    """A ladder attempt exceeded ``ResiliencePolicy.attempt_timeout_s``.
    The underlying work keeps running in its worker thread (python
    cannot kill it); the ladder moves on without waiting."""


class LadderError(RuntimeError):
    """Every allowed rung failed.  ``.report`` carries the full
    per-attempt record (rung, elapsed, error) for triage."""

    def __init__(self, msg: str, report: "ResilienceReport"):
        super().__init__(msg)
        self.report = report


def rung_index(rung: str) -> int:
    if rung not in LADDER:
        raise ValueError(f"unknown ladder rung {rung!r}; one of {LADDER}")
    return LADDER.index(rung)


def start_rung(backend: str, group: bool) -> str:
    """The rung ``pipeline.compile`` starts at for a backend/group pair."""
    if backend == "pallas":
        return "grouped" if group else "ungrouped"
    if backend == "jax":
        return "jax"
    return "interpreter"


def rungs_from(start: str, max_rung: str) -> Tuple[str, ...]:
    """The rungs a compile may attempt, in order: ``start`` down to
    ``max_rung`` inclusive.  A ``max_rung`` *above* the start permits no
    demotion at all — only the starting rung is attempted."""
    s, m = rung_index(start), rung_index(max_rung)
    if m < s:
        return (start,)
    return LADDER[s:m + 1]


@dataclass(frozen=True)
class ResiliencePolicy:
    """How far, how patiently, and how often a compile may retry before
    demoting.  Frozen and hashable: lives on ``CompileOptions`` and
    participates in the kernel-cache key (non-default policies only, so
    default cache keys stay byte-identical to pre-resilience builds).

    * ``max_rung`` — the deepest ladder rung a compile may demote to;
      exhausting it raises :class:`LadderError`.
    * ``attempt_timeout_s`` — wall-clock budget per attempt; ``None``
      (default) runs inline with no watchdog thread.
    * ``retries`` — extra same-rung attempts for transient failures
      (including timeouts) before demoting, with exponential backoff
      ``backoff_s * 2**retry`` between them.
    """

    max_rung: str = "interpreter"
    attempt_timeout_s: Optional[float] = None
    retries: int = 0
    backoff_s: float = 0.05

    def __post_init__(self):
        rung_index(self.max_rung)  # validate
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")

    def key(self) -> Tuple:
        """Canonical value tuple (hashing / cache-key embedding)."""
        return (self.max_rung, self.attempt_timeout_s, int(self.retries),
                float(self.backoff_s))


DEFAULT_POLICY = ResiliencePolicy()


@dataclass
class Attempt:
    """One ladder attempt: a (rung, retry) pair and how it went."""

    rung: str
    ok: bool
    elapsed_s: float
    error: Optional[str] = None   # "ExcType: message" when not ok
    retry: int = 0                # 0 = first try at this rung
    timed_out: bool = False


@dataclass
class ResilienceReport:
    """The compile's fault provenance: which rung was requested, which
    rung actually served it, and every attempt in between.  Attached to
    ``CompiledKernel.resilience_report`` on every compile (the happy
    path is one ok attempt at the requested rung, zero demotions)."""

    requested: str = "grouped"
    rung: Optional[str] = None        # the rung that served the compile
    attempts: List[Attempt] = field(default_factory=list)
    # RegionError from the driver's region partitioning, when the
    # partitioner could not split the selected snapshot (the lowering
    # then took emit_program's whole-program fallback)
    plan_error: Optional[str] = None

    @property
    def demotions(self) -> int:
        """Rungs descended from the requested one (0 on the happy path)."""
        if self.rung is None:
            return 0
        return max(rung_index(self.rung) - rung_index(self.requested), 0)

    @property
    def errors(self) -> List[str]:
        return [a.error for a in self.attempts if a.error]

    def to_json(self) -> Dict[str, Any]:
        d = asdict(self)
        d["demotions"] = self.demotions
        return d

    def summary(self) -> str:
        steps = ", ".join(
            f"{a.rung}{'#%d' % a.retry if a.retry else ''}:"
            f"{'ok' if a.ok else ('timeout' if a.timed_out else 'fail')}"
            for a in self.attempts)
        return (f"requested={self.requested} served={self.rung} "
                f"demotions={self.demotions} [{steps}]")


# ---------------------------------------------------------------------------
# process-wide resilience metrics (mirrors pipeline.CacheStats)
# ---------------------------------------------------------------------------

@dataclass
class ResilienceMetrics:
    demotions: int = 0        # ladder rungs descended (compile pipeline)
    ladder_failures: int = 0  # compiles that exhausted every rung
    faults_fired: int = 0     # injected faults that actually fired

    def snapshot(self) -> "ResilienceMetrics":
        return replace(self)

    def delta(self, since: "ResilienceMetrics") -> "ResilienceMetrics":
        return ResilienceMetrics(**{
            f.name: getattr(self, f.name) - getattr(since, f.name)
            for f in fields(self)})


METRICS = ResilienceMetrics()


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultSpec:
    """Fire ``kind`` at ``site`` on the listed 0-based call indices.

    Kinds: ``raise`` (an :class:`InjectedFault` from :func:`check`),
    ``sleep`` (stall ``sleep_s`` — drives the attempt-timeout path),
    ``nan`` / ``corrupt`` (returned to the caller, which applies the
    mutation itself: the engine NaNs one logits row, the kernel cache
    garbles the on-disk entry so the REAL integrity machinery detects
    it)."""

    site: str
    indices: Tuple[int, ...] = (0,)
    kind: str = "raise"
    message: str = "injected fault"
    sleep_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        object.__setattr__(self, "indices",
                           tuple(int(i) for i in self.indices))

    def to_json(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "FaultSpec":
        return cls(site=str(d["site"]),
                   indices=tuple(d.get("indices", (0,))),
                   kind=str(d.get("kind", "raise")),
                   message=str(d.get("message", "injected fault")),
                   sleep_s=float(d.get("sleep_s", 0.0)))


class FaultPlan:
    """A deterministic schedule of faults.  Each production site calls
    :func:`fire`; the plan counts the call (per site) and fires the
    matching :class:`FaultSpec` when the count hits one of its indices.
    Everything is index-based, so the same plan against the same code
    path fires identically every run — that is what lets the chaos CI
    job pin quarantine/demotion counters *exactly*.

    ``seed`` is provenance (recorded in reports) and the randomness
    source for :meth:`seeded` helpers; the plan itself is deterministic
    by construction."""

    def __init__(self, specs: Sequence[FaultSpec] = (), *, seed: int = 0):
        self.specs = tuple(specs)
        self.seed = int(seed)
        self._by_site: Dict[str, List[FaultSpec]] = {}
        for s in self.specs:
            self._by_site.setdefault(s.site, []).append(s)
        self._calls: Dict[str, int] = {}
        self.fired: List[Tuple[str, int, str]] = []  # (site, index, kind)
        self._lock = threading.Lock()

    def fire(self, site: str) -> Optional[FaultSpec]:
        """Count one call at ``site``; return the spec that fires at
        this index, if any (thread-safe: ladder attempts may run in
        timeout worker threads)."""
        with self._lock:
            idx = self._calls.get(site, 0)
            self._calls[site] = idx + 1
            for spec in self._by_site.get(site, ()):
                if idx in spec.indices:
                    self.fired.append((site, idx, spec.kind))
                    METRICS.faults_fired += 1
                    return spec
        return None

    def calls(self, site: str) -> int:
        return self._calls.get(site, 0)

    def fired_count(self, site: Optional[str] = None) -> int:
        if site is None:
            return len(self.fired)
        return sum(1 for s, _, _ in self.fired if s == site)

    def expected_count(self, site_prefix: str = "") -> int:
        """How many faults this plan schedules at sites matching the
        prefix — what the chaos gate pins counters against."""
        return sum(len(s.indices) for s in self.specs
                   if s.site.startswith(site_prefix))

    def reset(self) -> None:
        with self._lock:
            self._calls.clear()
            self.fired.clear()

    def to_json(self) -> Dict[str, Any]:
        return {"seed": self.seed,
                "faults": [s.to_json() for s in self.specs]}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "FaultPlan":
        return cls([FaultSpec.from_json(s) for s in d.get("faults", ())],
                   seed=int(d.get("seed", 0)))


_ACTIVE: Optional[FaultPlan] = None
# lazily-parsed $REPRO_FAULT_PLAN, cached per env value so per-site call
# counters survive across active() calls
_ENV_PLAN: Tuple[Optional[str], Optional[FaultPlan]] = (None, None)


def install(plan: Optional[FaultPlan]) -> None:
    """Set (or clear, with ``None``) the process-wide fault plan."""
    global _ACTIVE
    _ACTIVE = plan


@contextmanager
def faults(plan: FaultPlan):
    """Scope a fault plan: ``with resilience.faults(plan): ...``."""
    prev = _ACTIVE
    install(plan)
    try:
        yield plan
    finally:
        install(prev)


def active() -> Optional[FaultPlan]:
    """The installed plan, else one parsed from ``$REPRO_FAULT_PLAN``
    (inline JSON or a path to a JSON file), else ``None``."""
    if _ACTIVE is not None:
        return _ACTIVE
    global _ENV_PLAN
    raw = os.environ.get("REPRO_FAULT_PLAN")
    if not raw:
        return None
    if _ENV_PLAN[0] == raw:
        return _ENV_PLAN[1]
    text = raw
    if not raw.lstrip().startswith("{"):
        with open(raw) as f:
            text = f.read()
    plan = FaultPlan.from_json(json.loads(text))
    _ENV_PLAN = (raw, plan)
    return plan


def fire(site: str) -> Optional[FaultSpec]:
    """Consult the active plan at ``site``.  No plan -> ``None`` (one
    global read: the cost injection adds to the happy path)."""
    plan = active()
    return plan.fire(site) if plan is not None else None


def check(site: str) -> None:
    """The compile-site hook: raise on ``raise`` faults, stall on
    ``sleep`` faults (so an ``attempt_timeout_s`` watchdog can catch the
    slow compile), ignore kinds the site does not implement."""
    spec = fire(site)
    if spec is None:
        return
    if spec.kind == "sleep":
        time.sleep(spec.sleep_s)
        return
    if spec.kind == "raise":
        raise InjectedFault(f"{site}[{spec.message}]")


# ---------------------------------------------------------------------------
# timeout runner
# ---------------------------------------------------------------------------

def run_with_timeout(fn, timeout_s: float):
    """Run ``fn()`` in a worker thread and wait at most ``timeout_s``.
    On timeout the worker keeps running (python offers no preemption) but
    the caller gets :class:`AttemptTimeout` immediately and the ladder
    moves on — a hung Pallas lowering must not hang the server."""
    import concurrent.futures as CF
    ex = CF.ThreadPoolExecutor(max_workers=1,
                               thread_name_prefix="repro-ladder")
    fut = ex.submit(fn)
    try:
        return fut.result(timeout=timeout_s)
    except CF.TimeoutError:
        raise AttemptTimeout(
            f"attempt exceeded {timeout_s:g}s (worker left running)"
        ) from None
    finally:
        # never join the (possibly still running) worker
        ex.shutdown(wait=False)
