"""whisper-tiny [audio]: enc-dec, conv frontend is a STUB per the
assignment (``input_specs()`` provides precomputed frame embeddings).
Uses LayerNorm -> the MLP runs through Flash-LayerNorm+Matmul (Example 2).
[arXiv:2212.04356]"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,           # decoder layers
    n_enc_layers=4,
    enc_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_head=64,
    d_ff=1536,
    vocab=51865,
    rope_theta=0.0,       # learned/sinusoidal positions, no rope
    norm="ln",
    norm_eps=1e-5,
    tie_embeddings=True,
    max_seq=524288,       # decoder position table sized for long shapes
)
