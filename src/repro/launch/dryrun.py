import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh and record memory/cost analysis +
roofline terms.

MUST be invoked as its own process (the two lines above run before any
other import — jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import (ARCHS, SHAPES, all_cells, cell_supported,  # noqa: E402
                           get_config)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_step  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.runtime import sharding as SH  # noqa: E402
from repro.runtime.hlo_analysis import (Roofline, model_flops,  # noqa: E402
                                        roofline_from_compiled)


def active_params(cfg) -> float:
    """Parameter count active per token (MoE: top-k + shared only)."""
    import numpy as np
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init_params(k)[0],
                            jax.random.key(0))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        n = int(np.prod(leaf.shape))
        if any(str(k).startswith("we_") for k in keys) and cfg.n_experts:
            n = int(n * cfg.top_k / cfg.n_experts)
        total += n
    return float(total)


def layer_knobs(cfg):
    """Per-family layer-count knobs: (name, full_count, with_counts)."""
    import dataclasses as dc
    if cfg.family in ("dense", "vlm", "ssm"):
        return ([("layers", cfg.n_layers)],
                lambda c: dc.replace(cfg, n_layers=c["layers"]))
    if cfg.family == "moe":
        knobs = []
        if cfg.n_dense_layers:
            knobs.append(("dense", cfg.n_dense_layers))
        knobs.append(("moe", cfg.n_layers - cfg.n_dense_layers))

        def wc(c):
            nd = c.get("dense", 0)
            return dc.replace(cfg, n_dense_layers=nd,
                              n_layers=nd + c["moe"])
        return knobs, wc
    if cfg.family == "hybrid":
        return ([("blocks", cfg.n_layers // cfg.attn_period)],
                lambda c: dc.replace(cfg,
                                     n_layers=c["blocks"] * cfg.attn_period))
    if cfg.family == "encdec":
        return ([("enc", cfg.n_enc_layers), ("dec", cfg.n_layers)],
                lambda c: dc.replace(cfg, n_enc_layers=c["enc"],
                                     n_layers=c["dec"]))
    raise ValueError(cfg.family)


def _measure(cfg, shape, mesh, rules=None, out_shardings=False):
    """Lower+compile one config (scans unrolled) -> roofline raw terms."""
    import dataclasses as dc
    cfg = dc.replace(cfg, unroll_scans=True)
    with SH.use_mesh(mesh, rules=rules):
        step, args, shardings_fn = make_step(cfg, shape)
        in_sh = shardings_fn(mesh)
        kw = {}
        if out_shardings and shape.kind == "train":
            # pin result shardings to the input shardings (params/opt) and
            # donate the old state: lets XLA keep grads reduce-scattered
            kw["out_shardings"] = (in_sh[0], in_sh[1], None)
            kw["donate_argnums"] = (0, 1)
        jitted = jax.jit(step, in_shardings=in_sh, **kw)
        compiled = jitted.lower(*args).compile()
        return roofline_from_compiled(compiled)


def extrapolated_roofline(cfg, shape, mesh, rules=None,
                          out_shardings=False) -> Roofline:
    """cost_analysis counts each while-loop body once, so scanned layer
    stacks are undercounted.  We unroll the in-layer scans, compile with
    every stage count at 1 and at 2, and extrapolate linearly to the full
    depth (flops/bytes/collectives are exactly linear in stage counts)."""
    knobs, with_counts = layer_knobs(cfg)
    ones = {k: 1 for k, _ in knobs}
    base = _measure(with_counts(ones), shape, mesh, rules, out_shardings)
    flops, hbm = base.flops, base.hbm_bytes
    coll = dict(base.coll_bytes)
    for name, full in knobs:
        two = dict(ones)
        two[name] = 2
        m2 = _measure(with_counts(two), shape, mesh, rules, out_shardings)
        flops += (full - 1) * (m2.flops - base.flops)
        hbm += (full - 1) * (m2.hbm_bytes - base.hbm_bytes)
        for k in set(m2.coll_bytes) | set(base.coll_bytes):
            d = m2.coll_bytes.get(k, 0.0) - base.coll_bytes.get(k, 0.0)
            coll[k] = coll.get(k, 0.0) + (full - 1) * d
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll)


def attention_intermediate_bytes(cfg, shape) -> float:
    """Bytes of materialized attention score/probability intermediates in
    the XLA lowering, PER CHIP.  The Pallas kernel keeps these in VMEM on
    TPU, so the kernel-adjusted memory term subtracts them (convention:
    write+read once forward; x3 for train to cover the remat recompute and
    backward reads)."""
    if cfg.family == "ssm":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    sq = 1 if shape.kind == "decode" else s
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_period
    if cfg.family == "encdec":
        n_attn = cfg.n_layers * 2 + cfg.n_enc_layers  # self+cross+enc
    p_elems = b * cfg.n_heads * sq * s
    passes = 2.0 if shape.kind != "train" else 6.0
    return n_attn * p_elems * 4.0 * passes / 256  # per chip (data+tensor)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, roofline: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    # 1) the runnability proof: full config, compact (scanned) HLO
    with SH.use_mesh(mesh):
        step, args, shardings_fn = make_step(cfg, shape)
        in_shardings = shardings_fn(mesh)
        jitted = jax.jit(step, in_shardings=in_shardings)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
    full_compile_s = round(time.time() - t0, 1)

    n_chips = mesh.devices.size
    if not roofline:
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "multi" if multi_pod else "single",
                  "status": "ok", "n_chips": n_chips,
                  "compile_s": full_compile_s}
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    result[k] = int(v)
        if verbose:
            print(json.dumps(result, default=str))
            print(f"--- memory_analysis({arch}/{shape_name}):", mem)
        return result

    # 2) roofline terms: stage-count extrapolation with unrolled scans
    t0 = time.time()
    roof = extrapolated_roofline(cfg, shape, mesh)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = model_flops(active_params(cfg), tokens,
                     "train" if shape.kind == "train" else "serve")
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_chips": n_chips,
        "compile_s": full_compile_s,
        "roofline_compile_s": round(time.time() - t0, 1),
        "flops_per_chip": roof.flops,
        "hbm_bytes_per_chip": roof.hbm_bytes,
        "coll_bytes_per_chip": roof.coll_bytes,
        "t_compute_s": roof.t_compute,
        "t_memory_s": roof.t_memory,
        "t_collective_s": roof.t_collective,
        "bottleneck": roof.bottleneck,
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / roof.flops
        if roof.flops else 0.0,
        "roofline_fraction": roof.fraction_of_roofline(mf / n_chips),
    }
    adj = attention_intermediate_bytes(cfg, shape)
    from repro.runtime.hlo_analysis import HBM_BW
    result["hbm_bytes_kernel_adj"] = max(roof.hbm_bytes - adj, 0.0)
    result["t_memory_kernel_adj_s"] = result["hbm_bytes_kernel_adj"] / HBM_BW
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                result[k] = int(v)
        peak = (result.get("argument_size_in_bytes", 0)
                + result.get("temp_size_in_bytes", 0))
        result["fits_16g_hbm"] = bool(peak < 16e9)
    if verbose:
        print(json.dumps(result, indent=None, default=str))
        print(f"--- memory_analysis({arch}/{shape_name}):", mem)
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        brief = {k: v for k, v in sorted(ca.items())
                 if k in ("flops", "bytes accessed", "optimal_seconds")}
        print(f"--- cost_analysis({arch}/{shape_name}):", brief)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default=None, help="write results JSON here")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = all_cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    results = []
    failed = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
            print(f"=== dry-run {tag}", flush=True)
            try:
                results.append(run_cell(arch, shape, mp,
                                        roofline=not mp))
            except Exception as e:  # noqa: BLE001
                failed += 1
                traceback.print_exc()
                results.append({"arch": arch, "shape": shape,
                                "mesh": "multi" if mp else "single",
                                "status": "failed", "error": repr(e)})
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=2, default=str)
    print(f"=== done: {sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{failed} failed")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
