"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  * fusion_*    — the paper's three worked examples: traffic collapse,
                  launch counts, work replication, rule applications;
  * pipeline_*  — the same examples *executed* through
                  ``pipeline.compile``: fused vs unfused wall time next to
                  the cost model's predicted traffic (the end-to-end loop);
  * kernel_*    — fused vs naive kernel wall times (host backend);
  * roofline_*  — per (arch x shape x mesh) bound times from the dry-run
                  artifact (if dryrun_results.json exists).

``--only SECTION`` (fusion | pipeline | kernel | roofline) restricts the
run; default runs everything.  ``--preset ci`` shrinks the pipeline
section to the tiny fixed configuration the CI benchmark gate compares
against ``benchmarks/baseline.json``; ``--json PATH`` additionally
writes the rows as JSON (CI uploads it as the ``BENCH_ci.json``
artifact and feeds it to ``benchmarks/check_regression.py``).
"""

from __future__ import annotations

import argparse
import functools
import json


def main() -> None:
    from benchmarks import fusion_bench, kernel_bench, roofline

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=["fusion", "pipeline", "kernel",
                                       "roofline"], default=None)
    ap.add_argument("--preset", choices=sorted(fusion_bench.PRESETS),
                    default="full")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (the CI artifact)")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="also write the fitted calibration profile "
                         "(pipeline section) to PATH; it is always "
                         "saved to the kernel cache dir")
    ap.add_argument("--lowering-out", default=None, metavar="PATH",
                    help="write the per-program Pallas lowering reports "
                         "(launches, resident edges, kernel ids) as "
                         "JSON — CI uploads it as an artifact")
    args = ap.parse_args()

    sections = {
        "fusion": fusion_bench.run,
        "pipeline": functools.partial(fusion_bench.run_pipeline,
                                      preset=args.preset,
                                      profile_out=args.profile_out,
                                      lowering_out=args.lowering_out),
        "kernel": kernel_bench.run,
        "roofline": roofline.run,
    }
    rows = []
    for name, fn in sections.items():
        if args.only is None or args.only == name:
            rows += fn()

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"preset": args.preset, "rows": rows}, f, indent=2)


if __name__ == "__main__":
    main()
