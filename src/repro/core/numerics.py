"""Numerical-safety pass (paper Appendix).

Represents exponentiated values as significand–exponent pairs
``x = S * e^t`` with a *row-wise shared exponent* (the variant the appendix
identifies with Flash Attention's "online softmax").  The pass is applied
*after* fusion, exactly as the paper prescribes: the fused graph is
unchanged; only the value representation and the operator semantics change.

Pair algebra (appendix):

    (S1,t1) + (S2,t2)  = (S1*e^{t1-z} + S2*e^{t2-z}, z),  z = max(t1,t2)
    (S1,t1) * (S2,t2)  = (S1*S2, t1+t2)
    dot((S,t), B)      = (dot(S,B), t)          # t is per-row, rows survive
    row_sum((S,t))     = (row_sum(S), t)
    1/(S,t)            = (1/S, -t)

Any elementwise operator whose top-level operation is ``exp`` produces a
pair with ``t = rowmax(arg)``; pairs collapse back to plain values
(``S * e^t``) when they reach a consumer without pair semantics or a
program output.

Two executors implement the algebra:

* :func:`run_stabilized` — the interpreter-level oracle: plain graphs run
  under pair-aware operator semantics (``stabilized_apply``).
* :func:`stabilize` — the graph-level rewrite the compiled backends
  lower: pairs become explicit (significand, exponent) value edges, the
  ``exp`` producer splits into ``row_max``/``row_shift``/``exp``, and a
  serial map accumulating a pair grows a ``"max"`` carry port with its
  additive ports retagged ``"+@k"`` (rescale-on-new-max; see
  ``ops.serial_accum_step``).  The output graph contains only ordinary
  operators plus those carry tags, so ``codegen_jax``/``codegen_pallas``
  need no pair representation at runtime — running the paper's fused
  Flash-Attention program this way *is* online softmax, with the running
  max and the rescaled accumulators as extra serial-spine carries.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import ops as O
from repro.core.graph import (FuncNode, Graph, InputNode, MapNode, MiscNode,
                              OutputNode, Ref, ReduceNode, VType)
from repro.core.interpreter import run as _run


# ---------------------------------------------------------------------------
# Expression matching (normalized: whitespace- and commutativity-robust)
# ---------------------------------------------------------------------------

_WS_RE = re.compile(r"\s+")
_COMM_RE = re.compile(r"^a(\d+)([+*])a(\d+)$")


def _canon_expr(expr: str) -> str:
    """Whitespace-stripped form with commutative two-arg expressions in
    canonical operand order, so ``a1 + a0`` matches ``a0+a1``."""
    e = _WS_RE.sub("", expr)
    m = _COMM_RE.match(e)
    if m and int(m.group(1)) > int(m.group(3)):
        return f"a{m.group(3)}{m.group(2)}a{m.group(1)}"
    return e


def _is_recip(op: O.Op) -> bool:
    return isinstance(op, O.Elementwise) and _canon_expr(op.expr) == "1/a0"


def _is_add(op: O.Op) -> bool:
    return isinstance(op, O.Elementwise) and _canon_expr(op.expr) == "a0+a1"


def _is_mul(op: O.Op) -> bool:
    return isinstance(op, O.Elementwise) and _canon_expr(op.expr) == "a0*a1"


def _top_level_exp(expr: str) -> bool:
    """True iff the expression is exp(<...>) at the top level."""
    e = expr.strip()
    if not e.startswith("exp(") or not e.endswith(")"):
        return False
    depth = 0
    for i, ch in enumerate(e[3:], start=3):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return i == len(e) - 1
    return False


# ---------------------------------------------------------------------------
# Pair value algebra (uniform rank rule)
# ---------------------------------------------------------------------------
# The leading axis is the row axis at every rank: a block's exponent is a
# vector (one per row), a vector's exponent is a vector (every element is
# its own row), a scalar's exponent is a scalar.  Factors broadcast by
# appending trailing singleton axes (ops.bcast_to) — never by a
# whole-array collapse.


def _rowmax(xp, a):
    """Row-wise max: reduce every non-leading axis.  1-D and 0-D values
    are their own row maxima (identity), so per-row exponents survive
    rank-1 significands instead of collapsing to a whole-array max."""
    a = xp.asarray(a)
    if a.ndim >= 2:
        return a.max(axis=tuple(range(1, a.ndim)))
    return a


@dataclass
class SEPair:
    """Significand block/vector + per-row (or scalar) exponent."""

    s: Any
    t: Any

    def materialize(self, xp):
        s = xp.asarray(self.s)
        return s * O.bcast_to(xp, xp.exp(xp.asarray(self.t)), s)


def _plain(xp, v):
    return v.materialize(xp) if isinstance(v, SEPair) else v


def pair_add(xp, a, b):
    if not isinstance(a, SEPair):
        a = SEPair(a, xp.zeros_like(_rowmax(xp, a)))
    if not isinstance(b, SEPair):
        b = SEPair(b, xp.zeros_like(_rowmax(xp, b)))
    z = xp.maximum(a.t, b.t)

    def scale(p):
        s = xp.asarray(p.s)
        return s * O.bcast_to(xp, xp.exp(p.t - z), s)

    return SEPair(scale(a) + scale(b), z)


def stabilized_apply(op: O.Op, xp, *args):
    """Pair-aware operator semantics (the appendix's compiler pass)."""
    if isinstance(op, O.Elementwise):
        if _top_level_exp(op.expr):
            # evaluate the exponent argument plainly, then split
            inner = O.Elementwise(op.expr.strip()[4:-1], op.n_in,
                                  dict(op.consts))
            arg = xp.asarray(inner.apply(xp, *[_plain(xp, a) for a in args]))
            z = _rowmax(xp, arg)
            return SEPair(xp.exp(arg - O.bcast_to(xp, z, arg)), z)
        if _is_recip(op) and isinstance(args[0], SEPair):
            return SEPair(1.0 / args[0].s, -args[0].t)
        if _is_add(op) and any(isinstance(a, SEPair) for a in args):
            return pair_add(xp, *args)
        if _is_mul(op) and any(isinstance(a, SEPair) for a in args):
            a, b = args
            if isinstance(a, SEPair) and isinstance(b, SEPair):
                return SEPair(a.s * b.s, a.t + b.t)
            p, q = (a, b) if isinstance(a, SEPair) else (b, a)
            return SEPair(p.s * q, p.t)
        return op.apply(xp, *[_plain(xp, a) for a in args])
    if isinstance(op, O.RowSum) and isinstance(args[0], SEPair):
        return SEPair(args[0].s.sum(axis=1), args[0].t)
    if isinstance(op, O.Dot) and isinstance(args[0], SEPair):
        b = _plain(xp, args[1])
        return SEPair(args[0].s @ b.T, args[0].t)
    if isinstance(op, O.RowScale):
        a, c = args
        if isinstance(c, SEPair):
            sa = a.s if isinstance(a, SEPair) else a
            ta = a.t if isinstance(a, SEPair) else 0.0
            sa = xp.asarray(sa)
            scaled = sa * O.bcast_to(xp, xp.asarray(c.s), sa)
            return SEPair(scaled, ta + c.t)
        if isinstance(a, SEPair):
            return SEPair(op.apply(xp, a.s, c), a.t)
    return op.apply(xp, *[_plain(xp, a) for a in args])


def stabilized_accum(acc, val, op: str, xp):
    if acc is None:
        return val
    if op == O.REDUCE_MAX and not isinstance(acc, SEPair) \
            and not isinstance(val, SEPair):
        return xp.maximum(acc, val)
    if op != "+":
        raise NotImplementedError(op)
    if isinstance(acc, SEPair) or isinstance(val, SEPair):
        return pair_add(xp, acc, val)
    return acc + val


def run_stabilized(g: Graph, inputs, dims, xp=np):
    """Run a block program under the appendix's numerical-safety pass."""
    out = _run(g, inputs, dims, xp=xp, apply_fn=stabilized_apply,
               accum_fn=stabilized_accum)

    def mat(v):
        if isinstance(v, SEPair):
            return v.materialize(xp)
        if isinstance(v, list):
            return [mat(x) for x in v]
        return v

    return {k: mat(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# Graph-level rewrite: numerics.stabilize
# ---------------------------------------------------------------------------


def needs_stabilization(g: Graph,
                        in_types: Optional[List[VType]] = None) -> bool:
    """True when the program computes a block-valued top-level ``exp``
    anywhere in its hierarchy — the producers that overflow for
    |argument| beyond ~88 in float32 (attention softmax).  Vector- and
    scalar-valued exps (e.g. inside swish, where exp is not top-level
    anyway) do not qualify: the driver uses this to decide the default
    of ``pipeline.compile(..., stabilize=None)``."""
    types = g.infer_types(in_types)
    for nid in g.topo():
        node = g.nodes[nid]
        if (isinstance(node, FuncNode)
                and isinstance(node.op, O.Elementwise)
                and _top_level_exp(node.op.expr)
                and types[(nid, 0)].item == O.BLOCK):
            return True
        if isinstance(node, MapNode):
            ins = []
            for p in range(node.n_in()):
                e = g.in_edge(nid, p)
                t = types[(e.src, e.sp)]
                ins.append(t.strip() if node.mapped[p] else t)
            if needs_stabilization(node.inner, ins):
                return True
    return False


@dataclass
class _Pair:
    """A value split into (significand ref, exponent ref) at one graph
    level.  ``t_vt`` caches the exponent's VType (it may live on a node
    this pass created, absent from the pre-pass type map)."""

    s: Ref
    t: Ref
    t_vt: VType


def _exp_kind(kind: str) -> str:
    """Exponent item kind of a significand kind (uniform rank rule:
    block -> vector, vector -> vector, scalar -> scalar)."""
    return O.SCALAR if kind == O.SCALAR else O.VECTOR


def _mat_graph(dims: Tuple[str, ...], s_kind: str, t_kind: str) -> Graph:
    """Inner graph materializing one (s, t) pair item (or nested list):
    inputs ``s``/``t`` (both mapped at every level), output ``s*e^t``."""
    g = Graph()
    s = g.add(InputNode("s", VType(dims, s_kind)))
    t = g.add(InputNode("t", VType(dims, t_kind)))
    if dims:
        mid = g.add(MapNode(dims[0], _mat_graph(dims[1:], s_kind, t_kind),
                            [True, True], [None]))
        g.connect((s, 0), (mid, 0))
        g.connect((t, 0), (mid, 1))
        src: Ref = (mid, 0)
    else:
        e = g.add(FuncNode(O.ew("exp(a0)")))
        g.connect((t, 0), (e, 0))
        if s_kind == O.BLOCK and t_kind == O.VECTOR:
            m = g.add(FuncNode(O.ROW_SCALE))
        else:
            m = g.add(FuncNode(O.EW_MUL.clone()))
        g.connect((s, 0), (m, 0))
        g.connect((e, 0), (m, 1))
        src = (m, 0)
    oid = g.add(OutputNode("m"))
    g.connect(src, (oid, 0))
    return g


def _rescale_graph(dims: Tuple[str, ...], s_kind: str, t_kind: str) -> Graph:
    """Inner graph computing ``s * e^{t - z}`` per item: inputs ``s``,
    ``t``, ``z`` (all mapped below the outermost level; the caller maps
    ``s``/``t`` and broadcasts the reduced ``z`` at the top)."""
    g = Graph()
    s = g.add(InputNode("s", VType(dims, s_kind)))
    t = g.add(InputNode("t", VType(dims, t_kind)))
    z = g.add(InputNode("z", VType(dims, t_kind)))
    if dims:
        mid = g.add(MapNode(dims[0],
                            _rescale_graph(dims[1:], s_kind, t_kind),
                            [True, True, True], [None]))
        for p, src in enumerate(((s, 0), (t, 0), (z, 0))):
            g.connect(src, (mid, p))
        out_src: Ref = (mid, 0)
    else:
        f = g.add(FuncNode(O.ew("exp(a0-a1)", 2)))
        g.connect((t, 0), (f, 0))
        g.connect((z, 0), (f, 1))
        if s_kind == O.BLOCK and t_kind == O.VECTOR:
            m = g.add(FuncNode(O.ROW_SCALE))
        else:
            m = g.add(FuncNode(O.EW_MUL.clone()))
        g.connect((s, 0), (m, 0))
        g.connect((f, 0), (m, 1))
        out_src = (m, 0)
    oid = g.add(OutputNode("r"))
    g.connect(out_src, (oid, 0))
    return g


def _prune_dead(g: Graph) -> None:
    """Drop op nodes with no consumers (e.g. a negated exponent whose
    sum cancelled) — they would otherwise be charged as work and lowered
    for nothing."""
    while True:
        dead = [nid for nid, n in g.nodes.items()
                if not isinstance(n, (InputNode, OutputNode))
                and not g.out_edges(nid)]
        if not dead:
            return
        for nid in dead:
            g.remove_node(nid)


def stabilize(g: Graph) -> Graph:
    """Rewrite block-valued top-level ``exp`` producers (and their pair
    consumers) into explicit significand/exponent edges with
    rescale-on-max serial carries.  Returns the input graph unchanged
    (same object) when nothing needed stabilizing.  The rewritten graph
    contains only ordinary operators plus the ``"max"``/``"+@k"``
    reduced tags every backend lowers, and is numerically safe at any
    logit magnitude."""
    g2 = g.clone()
    _, changed = _stab_graph(g2, {}, top=True)
    if not changed:
        return g
    _prune_dead(g2)
    g2.validate()
    return g2


def _stab_graph(g: Graph, in_pairs: Dict[Ref, _Pair], top: bool
                ) -> Tuple[Dict[int, _Pair], bool]:
    """Stabilize one graph level in place.  ``in_pairs`` maps input refs
    to pairs (their exponent ports were added by the caller).  Returns
    ``(out_pairs, changed)`` where ``out_pairs`` maps output *port
    indices* to pairs whose significand already feeds the port (``top``
    levels materialize instead and return no pairs)."""
    types = g.infer_types()
    order = g.topo()
    new_vt: Dict[Ref, VType] = {}
    pairs: Dict[Ref, _Pair] = dict(in_pairs)
    neg_of: Dict[Ref, Ref] = {}
    mat_cache: Dict[Ref, Ref] = {}
    out_pairs: Dict[int, _Pair] = {}
    changed = False

    def vt(ref: Ref) -> VType:
        return new_vt[ref] if ref in new_vt else types[ref]

    def add_func(op: O.Op, *srcs: Ref) -> Ref:
        nid = g.add(FuncNode(op))
        for p, s in enumerate(srcs):
            g.connect(s, (nid, p))
        kind = op.result_kind(tuple(vt(s).item for s in srcs))
        new_vt[(nid, 0)] = VType((), kind)
        return (nid, 0)

    def neg(t_ref: Ref) -> Ref:
        if t_ref in neg_of:
            return neg_of[t_ref]
        r = add_func(O.ew("-a0"), t_ref)
        neg_of[t_ref] = r
        neg_of[r] = t_ref
        return r

    def t_sum(t1: Optional[Ref], t2: Optional[Ref],
              tv1: Optional[VType], tv2: Optional[VType]
              ) -> Tuple[Optional[Ref], Optional[VType]]:
        """Exponent sum; ``None`` is the zero exponent.  Mutual
        negations cancel to ``None`` — the attention epilogue
        ``row_scale(num, 1/den)`` ends exponent-free this way."""
        if t1 is None:
            return t2, tv2
        if t2 is None:
            return t1, tv1
        if neg_of.get(t1) == t2:
            return None, None
        r = add_func(O.ew("a0+a1", 2), t1, t2)
        return r, vt(r)

    def materialize(pr: _Pair) -> Ref:
        if pr.s in mat_cache:
            return mat_cache[pr.s]
        svt = vt(pr.s)
        if not svt.is_list:
            if svt.item == O.BLOCK and pr.t_vt.item == O.VECTOR:
                e = add_func(O.ew("exp(a0)"), pr.t)
                m = add_func(O.ROW_SCALE, pr.s, e)
            else:
                m = add_func(O.ew("a0*exp(a1)", 2), pr.s, pr.t)
        else:
            inner = _mat_graph(svt.dims[1:], svt.item, pr.t_vt.item)
            mid = g.add(MapNode(svt.dims[0], inner, [True, True], [None]))
            g.connect(pr.s, (mid, 0))
            g.connect(pr.t, (mid, 1))
            new_vt[(mid, 0)] = svt
            m = (mid, 0)
        mat_cache[pr.s] = m
        return m

    def rewire_port(nid: int, port: int, new_ref: Ref) -> None:
        e = g.in_edge(nid, port)
        if (e.src, e.sp) == new_ref:
            return
        g.disconnect(e)
        g.connect(new_ref, (nid, port))

    def mat_args(nid: int, arg_pairs) -> None:
        for p, pr in enumerate(arg_pairs):
            if pr is not None:
                rewire_port(nid, p, materialize(pr))

    def inner_input_port(inner: Graph, ref: Ref) -> Optional[int]:
        if ref[1] == 0 and ref[0] in inner.input_ids:
            return inner.input_ids.index(ref[0])
        return None

    for nid in order:
        if nid not in g.nodes:
            continue
        node = g.nodes[nid]
        if isinstance(node, InputNode):
            continue

        if isinstance(node, OutputNode):
            e = g.in_edge(nid, 0)
            pr = pairs.get((e.src, e.sp))
            if pr is None:
                continue
            if top:
                rewire_port(nid, 0, materialize(pr))
            else:
                out_pairs[g.output_ids.index(nid)] = pr
            continue

        in_refs = [(e.src, e.sp) for e in g.in_edges(nid)]
        arg_pairs = [pairs.get(r) for r in in_refs]

        if isinstance(node, FuncNode):
            op = node.op
            if (isinstance(op, O.Elementwise) and _top_level_exp(op.expr)
                    and types[(nid, 0)].item == O.BLOCK):
                # the producer: exp(arg) -> (exp(arg - rowmax), rowmax)
                changed = True
                mat_args(nid, arg_pairs)
                in_refs = [(e.src, e.sp) for e in g.in_edges(nid)]
                inner_op = O.Elementwise(op.expr.strip()[4:-1], op.n_in,
                                         dict(op.consts))
                arg = add_func(inner_op, *in_refs)
                m = add_func(O.ROW_MAX, arg)
                shifted = add_func(O.ROW_SHIFT, arg, neg(m))
                s = add_func(O.ew("exp(a0)"), shifted)
                g.rewire_consumers((nid, 0), s)
                g.remove_node(nid)
                pairs[s] = _Pair(s, m, VType((), O.VECTOR))
                continue
            if not any(arg_pairs):
                continue
            # pair-consuming operators (appendix algebra)
            if _is_recip(op) and arg_pairs[0] is not None:
                pr = arg_pairs[0]
                rewire_port(nid, 0, pr.s)
                pairs[(nid, 0)] = _Pair((nid, 0), neg(pr.t), pr.t_vt)
            elif _is_add(op) and all(arg_pairs):
                p1, p2 = arg_pairs
                z = add_func(O.ew("maximum(a0,a1)", 2), p1.t, p2.t)
                for port, pr in enumerate((p1, p2)):
                    f = add_func(O.ew("exp(a0-a1)", 2), pr.t, z)
                    if vt(pr.s).item == O.BLOCK \
                            and pr.t_vt.item == O.VECTOR:
                        sc = add_func(O.ROW_SCALE, pr.s, f)
                    else:
                        sc = add_func(O.EW_MUL.clone(), pr.s, f)
                    rewire_port(nid, port, sc)
                pairs[(nid, 0)] = _Pair((nid, 0), z, vt(z))
            elif _is_mul(op) and any(arg_pairs):
                for port, pr in enumerate(arg_pairs):
                    if pr is not None:
                        rewire_port(nid, port, pr.s)
                t, tv = t_sum(
                    arg_pairs[0].t if arg_pairs[0] else None,
                    arg_pairs[1].t if arg_pairs[1] else None,
                    arg_pairs[0].t_vt if arg_pairs[0] else None,
                    arg_pairs[1].t_vt if arg_pairs[1] else None)
                if t is not None:
                    pairs[(nid, 0)] = _Pair((nid, 0), t, tv)
            elif isinstance(op, O.RowSum) and arg_pairs[0] is not None:
                pr = arg_pairs[0]
                rewire_port(nid, 0, pr.s)
                pairs[(nid, 0)] = _Pair((nid, 0), pr.t, pr.t_vt)
            elif isinstance(op, O.Dot) and arg_pairs[0] is not None:
                pr = arg_pairs[0]
                rewire_port(nid, 0, pr.s)
                if arg_pairs[1] is not None:
                    rewire_port(nid, 1, materialize(arg_pairs[1]))
                pairs[(nid, 0)] = _Pair((nid, 0), pr.t, pr.t_vt)
            elif isinstance(op, O.RowScale):
                pa, pc = arg_pairs
                if pa is not None:
                    rewire_port(nid, 0, pa.s)
                if pc is not None:
                    rewire_port(nid, 1, pc.s)
                t, tv = t_sum(pa.t if pa else None, pc.t if pc else None,
                              pa.t_vt if pa else None,
                              pc.t_vt if pc else None)
                if t is not None:
                    pairs[(nid, 0)] = _Pair((nid, 0), t, tv)
            else:
                # no pair semantics for this op: collapse the pairs
                mat_args(nid, arg_pairs)
            continue

        if isinstance(node, ReduceNode):
            pr = arg_pairs[0]
            if pr is None:
                continue
            if node.op != "+":
                raise NotImplementedError(
                    f"cannot stabilize reduce[{node.op}] over a pair")
            changed = True
            svt, tvt = vt(pr.s), pr.t_vt
            # two-pass streaming sum: z = max over the exponent list,
            # then sum the rescaled significands s_i * e^{t_i - z}
            zid = g.add(ReduceNode(O.REDUCE_MAX))
            g.connect(pr.t, (zid, 0))
            z_vt = VType(tvt.dims[1:], tvt.item)
            new_vt[(zid, 0)] = z_vt
            inner = _rescale_graph(svt.dims[1:], svt.item, tvt.item)
            mid = g.add(MapNode(svt.dims[0], inner,
                                [True, True, False], [None]))
            g.connect(pr.s, (mid, 0))
            g.connect(pr.t, (mid, 1))
            g.connect((zid, 0), (mid, 2))
            new_vt[(mid, 0)] = svt
            rewire_port(nid, 0, (mid, 0))
            pairs[(nid, 0)] = _Pair((nid, 0), (zid, 0), z_vt)
            continue

        if isinstance(node, MiscNode):
            mat_args(nid, arg_pairs)
            continue

        if isinstance(node, MapNode):
            inner = node.inner
            inner_in_ids = list(inner.input_ids)
            inner_pairs: Dict[Ref, _Pair] = {}
            for p, pr in enumerate(arg_pairs):
                if pr is None:
                    continue
                changed = True
                iid = inner_in_ids[p]
                rewire_port(nid, p, pr.s)
                t_vt_in = pr.t_vt.strip() if node.mapped[p] else pr.t_vt
                tid = inner.add(InputNode(
                    f"{inner.nodes[iid].name}_t", t_vt_in))
                node.mapped.append(node.mapped[p])
                g.connect(pr.t, (nid, node.n_in() - 1))
                inner_pairs[(iid, 0)] = _Pair((iid, 0), (tid, 0), t_vt_in)
            inner_out, ch = _stab_graph(inner, inner_pairs, top=False)
            changed = changed or ch
            if not inner_out:
                if ch:
                    _prune_dead(inner)
                continue
            # expose the inner exponents: one out-port per distinct
            # (exponent ref, reduced?) — reduced pair ports become
            # "+@k" carries against a shared "max" port k
            t_out: Dict[Tuple[Ref, bool], int] = {}
            for p_out in sorted(inner_out):
                pr = inner_out[p_out]
                red = node.reduced[p_out]
                if red is not None and red != O.REDUCE_ADD:
                    raise NotImplementedError(
                        f"cannot stabilize reduced tag {red!r}")
                p_in = inner_input_port(inner, pr.t)
                if p_in is not None and (not node.mapped[p_in]
                                         or red is None):
                    # exponent passes straight through from a map input:
                    # broadcast inputs are loop-invariant (so a "+"
                    # carry stays plain), and a mapped input feeding a
                    # plain list port already has its outer list —
                    # either way consumers reuse the outer ref instead
                    # of a new pass-through out-port
                    e_in = g.in_edge(nid, p_in)
                    outer_t = (e_in.src, e_in.sp)
                    pairs[(nid, p_out)] = _Pair(
                        (nid, p_out), outer_t, vt(outer_t))
                    continue
                key = (pr.t, red is not None)
                if key not in t_out:
                    toid = inner.add(OutputNode(f"t{len(node.reduced)}"))
                    inner.connect(pr.t, (toid, 0))
                    node.reduced.append(
                        O.REDUCE_MAX if red is not None else None)
                    t_out[key] = len(node.reduced) - 1
                k = t_out[key]
                if red is not None:
                    node.reduced[p_out] = O.rescaled_add(k)
                    outer_tvt = pr.t_vt
                else:
                    outer_tvt = pr.t_vt.wrap(node.dim)
                new_vt[(nid, k)] = outer_tvt
                pairs[(nid, p_out)] = _Pair((nid, p_out), (nid, k),
                                            outer_tvt)
            if ch:
                # safe only now: the t out-ports wired above consume
                # nodes that looked dead at the end of the recursion
                _prune_dead(inner)
            continue

        raise TypeError(node)

    return out_pairs, changed
