"""Lowering coverage: EVERY fusion snapshot of every in-repo example
program lowers on ``backend="pallas"`` with zero fallbacks.

This is the acceptance gate for the region-partitioned Pallas backend
(``core/regions.py`` + ``codegen_pallas.emit_program``): whichever
snapshot the traffic cost model selects, the driver lowers *that*
snapshot — there is no walk-back to a differently-fused candidate, so a
program that stops partitioning cleanly shows up here, not as a silent
performance regression.  Each snapshot is also executed (interpret mode)
against the block-program interpreter oracle on the original program.
"""

import numpy as np
import pytest

from repro import pipeline
from repro.core import array_program as AP
from repro.core import codegen_pallas as CP
from repro.core import numerics as NU
from repro.core import selection as SEL
from repro.core.fusion import fuse
from repro.core.interpreter import run as interp_run
from repro.pipeline import packing as P

# the five in-repo example programs, at deliberately tiny dims so the
# whole snapshot matrix stays inside the tier-1 budget
PROGRAMS = {
    "layernorm_matmul": (lambda: AP.layernorm_matmul_program(32.0),
                         {"M": 2, "K": 4, "N": 2},
                         {"M": 4, "K": 8, "N": 8}),
    "rmsnorm_swiglu": (lambda: AP.rmsnorm_ffn_swiglu_program(16.0),
                       {"M": 2, "D": 2, "K": 3, "N": 2},
                       {"M": 4, "D": 8, "K": 4, "N": 4}),
    "flash": (lambda: AP.attention_program(0.125),
              {"M": 2, "D": 2, "N": 3, "L": 2},
              {"M": 4, "D": 8, "N": 4, "L": 8}),
    "causal": (lambda: AP.causal_attention_program(0.25),
               {"M": 2, "D": 2, "N": 2, "L": 2},
               {"M": 4, "D": 8, "N": 4, "L": 8}),
    "gqa": (lambda: AP.gqa_attention_program(0.25, causal=True),
            {"H": 2, "M": 2, "D": 2, "N": 2, "L": 2},
            {"H": 1, "M": 4, "D": 8, "N": 4, "L": 8}),
}


def _merged_inputs(g, dims, blocks, rng):
    out = {}
    for nid in g.input_ids:
        node = g.nodes[nid]
        vt = node.vtype
        item = tuple(blocks[d] for d in vt.dims[vt.lead_dims:])
        shape = P.merged_shape(vt, item, dims)
        if node.name in ("QP", "KP"):  # global positions, not data
            out[node.name] = np.arange(shape[0], dtype=np.float32)
        else:
            out[node.name] = (rng.normal(size=shape)
                              / max(shape[-1], 1) ** 0.5).astype(np.float32)
    return out


@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_every_snapshot_lowers_with_zero_fallbacks(name, rng):
    build, dims, blocks = PROGRAMS[name]
    g = build()
    inputs = _merged_inputs(g, dims, blocks, rng)
    nested = {g.nodes[i].name: P.to_nested(inputs[g.nodes[i].name],
                                           g.nodes[i].vtype, dims)
              for i in g.input_ids}
    oracle = interp_run(g, nested, dims)
    out_types = P.output_types(g)

    snaps = fuse(g)
    assert len(snaps) >= 2  # the programs all have fusion opportunities
    for i, snap in enumerate(snaps):
        fn, report = CP.emit_program(snap, dims, blocks, interpret=True)
        assert report.fallbacks == 0, (
            f"{name} snapshot {i}: {report.summary()}")
        assert report.n_regions >= 1
        # the final snapshot is fully fused: exactly one mega-kernel
        if i == len(snaps) - 1:
            assert report.n_regions == 1
        outs = fn(*[inputs[snap.nodes[j].name] for j in snap.input_ids])
        for o, oid, vt in zip(outs, snap.output_ids, out_types):
            ref = P.from_nested(oracle[snap.nodes[oid].name], vt, dims)
            np.testing.assert_allclose(
                np.asarray(o), ref, rtol=2e-4, atol=2e-4,
                err_msg=f"{name} snapshot {i}")


@pytest.mark.parametrize("name", sorted(PROGRAMS))
def test_pipeline_lowers_selected_snapshot(name, rng):
    """The driver lowers what selection picked, reports the region
    breakdown, and attributes traffic per region."""
    build, dims, blocks = PROGRAMS[name]
    g = build()
    cache = pipeline.KernelCache(disk=False)
    kern = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                            cache=cache)
    rep = kern.lowering_report
    assert rep is not None and rep.fallbacks == 0, rep.summary()
    # selection's choice is what lowered: the driver no longer rewrites
    # snapshot_index/cost after the fact.  The pallas backend selects
    # under the grouped, residency-aware objective — the cost of the
    # kernels the region-group lowering actually emits.  The driver
    # stabilizes softmax-bearing snapshots before selection, so mirror
    # that here: same snapshots in, same choice out
    snaps = fuse(g)
    base = g
    if NU.needs_stabilization(g):
        snaps = [NU.stabilize(s) for s in snaps]
        base = NU.stabilize(g)
    sel = SEL.select(base, dims, snapshots=snaps, group=True,
                     blocks=blocks)
    assert kern.snapshot_index == sel.snapshot_index
    assert kern.cost == sel.cost
    # per-kernel traffic attribution matches the emitted kernels (a
    # region-group megakernel counts once), paired by kernel id
    assert kern.region_costs is not None
    assert len(kern.region_costs) == rep.launches
    assert kern.kernel_ids is not None
    assert len(kern.kernel_ids) == rep.launches
    assert all(c > 0 for c in kern.region_costs)
    assert 1 <= rep.launches <= rep.n_regions
    out = kern(_merged_inputs(g, dims, blocks, rng))
    assert set(out) == {g.nodes[o].name for o in g.output_ids}


def test_multi_output_program_compiles_on_pallas(rng):
    """A program with two outputs (the fused result AND an intermediate)
    lowers through the pipeline — multi-output pallas_call support."""
    KK = 32.0
    ap = AP.ArrayProgramBuilder()
    x = ap.input("X", ("M", "K"))
    yt = ap.input("YT", ("N", "K"))
    ln = ap.layernorm_rows(x, KK)
    z = ap.matmul_t(ln, yt, out_dim="N")
    ap.output("Z", z)
    ap.output("XN", ln)
    g = ap.build()

    dims = {"M": 2, "K": 4, "N": 2}
    blocks = {"M": 4, "K": 8, "N": 8}
    cache = pipeline.KernelCache(disk=False)
    kern = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                            cache=cache)
    assert kern.lowering_report.fallbacks == 0
    assert set(kern.out_names) == {"Z", "XN"}

    X = rng.normal(size=(8, 32)).astype(np.float32)
    Y = rng.normal(size=(32, 16)).astype(np.float32)
    out = kern({"X": X, "YT": Y.T})
    mu = X.mean(1, keepdims=True)
    sd = np.sqrt((X ** 2).mean(1, keepdims=True) - mu ** 2)
    xn = (X - mu) / sd
    np.testing.assert_allclose(np.asarray(out["XN"]), xn,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out["Z"]), xn @ Y,
                               rtol=1e-4, atol=1e-4)


def test_region_costs_sum_to_snapshot_scale():
    """Region attribution is consistent: for a fully fused snapshot the
    single region's cost equals the snapshot cost; for partitioned
    snapshots the per-region sum is at least the snapshot cost (regions
    re-load shared inputs) and every region costs at least one launch."""
    g = AP.attention_program(0.125)
    dims = {"M": 2, "D": 2, "N": 3, "L": 2}
    snaps = fuse(g)
    full = SEL.region_costs(snaps[-1], dims)
    assert full is not None and len(full) == 1
    assert full[0] == SEL.snapshot_cost(snaps[-1], dims)
    part = SEL.region_costs(snaps[0], dims)
    assert part is not None and len(part) >= 2
    assert sum(part) >= SEL.snapshot_cost(snaps[0], dims)
