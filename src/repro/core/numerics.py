"""Numerical-safety pass (paper Appendix).

Represents exponentiated values as significand–exponent pairs
``x = S * e^t`` with a *row-wise shared exponent* (the variant the appendix
identifies with Flash Attention's "online softmax").  The pass is applied
*after* fusion, exactly as the paper prescribes: the fused graph is
unchanged; only the value representation and the operator semantics change.

Pair algebra (appendix):

    (S1,t1) + (S2,t2)  = (S1*e^{t1-z} + S2*e^{t2-z}, z),  z = max(t1,t2)
    (S1,t1) * (S2,t2)  = (S1*S2, t1+t2)
    dot((S,t), B)      = (dot(S,B), t)          # t is per-row, rows survive
    row_sum((S,t))     = (row_sum(S), t)
    1/(S,t)            = (1/S, -t)

Any elementwise operator whose top-level operation is ``exp`` produces a
pair with ``t = rowmax(arg)``; pairs collapse back to plain values
(``S * e^t``) when they reach a consumer without pair semantics or a
program output.  Running the paper's fused Flash-Attention program under
this executor reproduces online softmax bit-for-bit in behaviour: the two
accumulators are rescaled by ``e^{t_old - z}`` whenever the running max
grows.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any

import numpy as np

from repro.core import ops as O
from repro.core.graph import Graph
from repro.core.interpreter import run as _run


@dataclass
class SEPair:
    """Significand block/vector + per-row (or scalar) exponent."""

    s: Any
    t: Any

    def materialize(self, xp):
        t = xp.asarray(self.t)
        s = xp.asarray(self.s)
        if t.ndim == 1 and s.ndim == 2:
            return s * xp.exp(t)[:, None]
        return s * xp.exp(t)


def _rowmax(xp, a):
    a = xp.asarray(a)
    if a.ndim == 2:
        return a.max(axis=1)
    return a.max()


def _top_level_exp(expr: str) -> bool:
    """True iff the expression is exp(<...>) at the top level."""
    e = expr.strip()
    if not e.startswith("exp(") or not e.endswith(")"):
        return False
    depth = 0
    for i, ch in enumerate(e[3:], start=3):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return i == len(e) - 1
    return False


def _plain(xp, v):
    return v.materialize(xp) if isinstance(v, SEPair) else v


def pair_add(xp, a, b):
    if not isinstance(a, SEPair):
        a = SEPair(a, xp.zeros_like(_rowmax(xp, a)))
    if not isinstance(b, SEPair):
        b = SEPair(b, xp.zeros_like(_rowmax(xp, b)))
    z = xp.maximum(a.t, b.t)

    def scale(p):
        f = xp.exp(p.t - z)
        s = xp.asarray(p.s)
        if s.ndim == 2 and xp.asarray(f).ndim == 1:
            return s * f[:, None]
        return s * f

    return SEPair(scale(a) + scale(b), z)


def stabilized_apply(op: O.Op, xp, *args):
    """Pair-aware operator semantics (the appendix's compiler pass)."""
    if isinstance(op, O.Elementwise):
        if _top_level_exp(op.expr):
            # evaluate the exponent argument plainly, then split
            inner = O.Elementwise(op.expr.strip()[4:-1], op.n_in,
                                  dict(op.consts))
            arg = inner.apply(xp, *[_plain(xp, a) for a in args])
            z = _rowmax(xp, arg)
            arg = xp.asarray(arg)
            if arg.ndim == 2:
                return SEPair(xp.exp(arg - z[:, None]), z)
            return SEPair(xp.exp(arg - z), z)
        if op.expr.strip() in ("1/a0", "1 / a0") and isinstance(args[0],
                                                                SEPair):
            return SEPair(1.0 / args[0].s, -args[0].t)
        if op.expr.strip() in ("a0+a1", "a0 + a1") and any(
                isinstance(a, SEPair) for a in args):
            return pair_add(xp, *args)
        if op.expr.strip() in ("a0*a1", "a0 * a1") and any(
                isinstance(a, SEPair) for a in args):
            a, b = args
            if isinstance(a, SEPair) and isinstance(b, SEPair):
                return SEPair(a.s * b.s, a.t + b.t)
            p, q = (a, b) if isinstance(a, SEPair) else (b, a)
            return SEPair(p.s * q, p.t)
        return op.apply(xp, *[_plain(xp, a) for a in args])
    if isinstance(op, O.RowSum) and isinstance(args[0], SEPair):
        return SEPair(args[0].s.sum(axis=1), args[0].t)
    if isinstance(op, O.Dot) and isinstance(args[0], SEPair):
        b = _plain(xp, args[1])
        return SEPair(args[0].s @ b.T, args[0].t)
    if isinstance(op, O.RowScale):
        a, c = args
        if isinstance(c, SEPair):
            sa = a.s if isinstance(a, SEPair) else a
            ta = a.t if isinstance(a, SEPair) else 0.0
            cs = xp.asarray(c.s)
            scaled = sa * (cs[:, None] if cs.ndim == 1 else cs)
            return SEPair(scaled, ta + c.t)
        if isinstance(a, SEPair):
            return SEPair(op.apply(xp, a.s, c), a.t)
    return op.apply(xp, *[_plain(xp, a) for a in args])


def stabilized_accum(acc, val, op: str, xp):
    if acc is None:
        return val
    if op != "+":
        raise NotImplementedError(op)
    if isinstance(acc, SEPair) or isinstance(val, SEPair):
        return pair_add(xp, acc, val)
    return acc + val


def run_stabilized(g: Graph, inputs, dims, xp=np):
    """Run a block program under the appendix's numerical-safety pass."""
    out = _run(g, inputs, dims, xp=xp, apply_fn=stabilized_apply,
               accum_fn=stabilized_accum)

    def mat(v):
        if isinstance(v, SEPair):
            return v.materialize(xp)
        if isinstance(v, list):
            return [mat(x) for x in v]
        return v

    return {k: mat(v) for k, v in out.items()}
