"""Whisper-style encoder-decoder backbone (audio frontend is a stub: the
assignment's ``input_specs()`` provides precomputed conv-frontend frame
embeddings).

Whisper uses LayerNorm + GELU MLPs: the LN -> fc1 matmul pair is exactly
the paper's Example 2, so the MLP here runs through the
Flash-LayerNorm+Matmul kernel (``layernorm_matmul``).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as K
from repro.models import layers as L
from repro.models.common import (ModelConfig, ParamBuilder, layer_norm,
                                 softmax_xent, stack_layers, stack_specs)
from repro.runtime.sharding import constrain


def _remat(fn, cfg):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _init_ln(pb: ParamBuilder, name: str, d: int):
    pb.ones(name + "_g", (d,), (None,))
    pb.zeros(name + "_b", (d,), (None,))


def _init_enc_layer(pb: ParamBuilder, cfg: ModelConfig):
    _init_ln(pb, "ln1", cfg.d_model)
    L.init_attention(pb.sub("attn"), cfg)
    _init_ln(pb, "ln2", cfg.d_model)
    pb.dense("fc1", (cfg.d_model, cfg.d_ff), ("fsdp", "tensor"))
    pb.zeros("fc1_b", (cfg.d_ff,), ("tensor",))
    pb.dense("fc2", (cfg.d_ff, cfg.d_model), ("tensor", "fsdp"))
    pb.zeros("fc2_b", (cfg.d_model,), (None,))


def _init_dec_layer(pb: ParamBuilder, cfg: ModelConfig):
    _init_enc_layer(pb, cfg)  # ln1+self-attn, ln2+mlp
    _init_ln(pb, "ln_x", cfg.d_model)
    L.init_attention(pb.sub("xattn"), cfg)


def _mlp(p, x, cfg: ModelConfig):
    """LN -> fc1 via the fused Example-2 kernel, then GELU -> fc2."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    impl = {"fused_ref": "ref", "pallas": "pallas", "interpret": "interpret",
            "unfused": None}[cfg.mlp_impl]
    if impl is None:
        h = layer_norm(x2, p["ln2_g"], p["ln2_b"], cfg.norm_eps) @ p["fc1"]
    else:
        h = K.layernorm_matmul(x2, p["fc1"], p["ln2_g"], p["ln2_b"],
                               eps=cfg.norm_eps, impl=impl)
    h = jax.nn.gelu(h + p["fc1_b"])
    out = h @ p["fc2"] + p["fc2_b"]
    return constrain(out.reshape(b, s, d), "batch", None, None)


def _attn_block(p, x, cfg, ln, causal, kv=None):
    xn = layer_norm(x, p[ln + "_g"], p[ln + "_b"], cfg.norm_eps)
    name = "attn" if ln == "ln1" else "xattn"
    if kv is None:
        return L.attention_apply(p[name], xn, cfg, causal=causal,
                                 positions=None)
    # cross attention: q from x, k/v provided (encoder memory)
    b, s, _ = xn.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = (xn @ p[name]["wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    o = K.flash_attention(q, kv["k"], kv["v"], causal=False,
                          impl=cfg.attn_impl, unroll=cfg.unroll_scans)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return constrain(o @ p[name]["wo"], "batch", None, None)


def _cross_kv(p, mem, cfg):
    b, s, _ = mem.shape
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    k = (mem @ p["xattn"]["wk"]).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = (mem @ p["xattn"]["wv"]).reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    return {"k": k, "v": v}


def _sinusoid(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _sinusoid_at(pos, d: int) -> jax.Array:
    i = jnp.arange(d // 2).astype(jnp.float32)
    ang = pos.astype(jnp.float32) if hasattr(pos, "astype") else float(pos)
    ang = ang / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[None, None]


class EncDec:
    """Whisper backbone: bidirectional encoder over frame embeddings +
    causal decoder with cross attention."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init_params(self, key):
        cfg = self.cfg
        pb = ParamBuilder(key, cfg.dtype)
        pb.dense("embed", (cfg.vocab, cfg.d_model), ("tensor", "fsdp"),
                 scale=0.02)
        for name, n, init in (("enc", cfg.n_enc_layers, _init_enc_layer),
                              ("dec", cfg.n_layers, _init_dec_layer)):
            reps, spec = [], None
            for _ in range(n):
                b = ParamBuilder(pb._split(), cfg.dtype)
                init(b, cfg)
                reps.append(b.params)
                spec = b.specs
            pb.params[name] = stack_layers(reps)
            pb.specs[name] = stack_specs(spec)
        _init_ln(pb, "ln_enc_f", cfg.d_model)
        _init_ln(pb, "ln_f", cfg.d_model)
        return pb.build()

    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(cfg.dtype)
        x = x + _sinusoid(x.shape[1], cfg.d_model).astype(cfg.dtype)
        x = constrain(x, "batch", None, None)

        def body(x, lp):
            x = x + _attn_block(lp, x, cfg, "ln1", causal=False)
            x = x + _mlp(lp, x, cfg)
            return x, None

        fn = _remat(body, cfg)
        x, _ = jax.lax.scan(fn, x, params["enc"],
                            unroll=cfg.n_enc_layers if cfg.unroll_scans
                            else 1)
        return layer_norm(x, params["ln_enc_f_g"], params["ln_enc_f_b"],
                          cfg.norm_eps)

    def decode(self, params, mem, tokens):
        cfg = self.cfg
        s = tokens.shape[1]
        x = params["embed"][tokens].astype(cfg.dtype)
        x = x + _sinusoid(s, cfg.d_model).astype(cfg.dtype)
        x = constrain(x, "batch", None, None)

        def body(x, lp):
            x = x + _attn_block(lp, x, cfg, "ln1", causal=True)
            kv = _cross_kv(lp, mem, cfg)
            x = x + _attn_block(lp, x, cfg, "ln_x", causal=False, kv=kv)
            x = x + _mlp(lp, x, cfg)
            return x, None

        fn = _remat(body, cfg)
        x, _ = jax.lax.scan(fn, x, params["dec"],
                            unroll=cfg.n_layers if cfg.unroll_scans else 1)
        x = layer_norm(x, params["ln_f_g"], params["ln_f_b"], cfg.norm_eps)
        logits = x @ params["embed"].T
        return constrain(logits, "batch", None, "tensor")

    def forward(self, params, tokens, frames=None):
        mem = self.encode(params, frames)
        return self.decode(params, mem, tokens)

    def loss(self, params, tokens, labels, frames=None):
        return softmax_xent(self.forward(params, tokens, frames), labels)

    # -- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        one = lambda: {
            "self": L.attention_init_cache(cfg, batch, max_len, cfg.dtype),
            "cross": {
                "k": jnp.zeros((batch, cfg.n_kv_heads, cfg.enc_seq,
                                cfg.d_head), cfg.dtype),
                "v": jnp.zeros((batch, cfg.n_kv_heads, cfg.enc_seq,
                                cfg.d_head), cfg.dtype)},
        }
        return stack_layers([one() for _ in range(cfg.n_layers)])

    def cache_specs(self):
        spec = {"self": L.attention_cache_specs(self.cfg),
                "cross": {"k": ("batch", "tensor", None, None),
                          "v": ("batch", "tensor", None, None)}}
        return stack_specs(spec)

    def prefill(self, params, tokens, frames=None, max_len=None):
        """Encode audio + run the decoder prompt; build self+cross caches."""
        cfg = self.cfg
        mem = self.encode(params, frames)
        s = tokens.shape[1]
        max_len = max_len or s
        x = params["embed"][tokens].astype(cfg.dtype)
        x = x + _sinusoid(s, cfg.d_model).astype(cfg.dtype)

        def body(x, lp):
            xn = layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
            q, k, v = L._qkv(lp["attn"], xn, cfg, None)
            y = K.flash_attention(q, k, v, causal=True, impl=cfg.attn_impl,
                                  unroll=cfg.unroll_scans)
            b = x.shape[0]
            y = y.transpose(0, 2, 1, 3).reshape(b, s,
                                                cfg.n_heads * cfg.d_head)
            x = x + constrain(y @ lp["attn"]["wo"], "batch", None, None)
            kv_cross = _cross_kv(lp, mem, cfg)
            x = x + _attn_block(lp, x, cfg, "ln_x", causal=False,
                                kv=kv_cross)
            x = x + _mlp(lp, x, cfg)
            pad = max_len - s
            cache = {
                "self": {"k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))
                                      ).astype(cfg.dtype),
                         "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))
                                      ).astype(cfg.dtype)},
                "cross": jax.tree.map(lambda a: a.astype(cfg.dtype),
                                      kv_cross),
            }
            return x, cache

        x, caches = jax.lax.scan(body, x, params["dec"],
                                 unroll=cfg.n_layers if cfg.unroll_scans
                                 else 1)
        x = layer_norm(x, params["ln_f_g"], params["ln_f_b"], cfg.norm_eps)
        logits = x @ params["embed"].T
        return constrain(logits, "batch", None, "tensor"), caches

    def decode_step(self, params, caches, tokens, pos):
        cfg = self.cfg
        b = tokens.shape[0]
        x = params["embed"][tokens].astype(cfg.dtype)
        x = x + _sinusoid_at(pos, cfg.d_model).astype(cfg.dtype)

        def body(x, inp):
            lp, cache = inp
            xn = layer_norm(x, lp["ln1_g"], lp["ln1_b"], cfg.norm_eps)
            y, new_self = L.attention_decode(lp["attn"], xn, cache["self"],
                                             pos, cfg)
            x = x + y
            x = x + _attn_block(lp, x, cfg, "ln_x", causal=False,
                                kv=cache["cross"])
            x = x + _mlp(lp, x, cfg)
            return x, {"self": new_self, "cross": cache["cross"]}

        x, new_caches = jax.lax.scan(body, x, (params["dec"], caches),
                                     unroll=cfg.n_layers if cfg.unroll_scans
                                     else 1)
        x = layer_norm(x, params["ln_f_g"], params["ln_f_b"], cfg.norm_eps)
        logits = x @ params["embed"].T
        return constrain(logits, "batch", None, "tensor"), new_caches
