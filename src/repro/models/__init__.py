from repro.models.common import ModelConfig
from repro.models.lm import LM, build_model
