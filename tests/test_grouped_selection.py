"""Grouped selection objective: ``select(group=True)`` ranks snapshots
by the sum of residency-aware group costs — the cost of the kernels the
Pallas region-group lowering actually emits — instead of the paper's
all-edges-global snapshot sum.

Pinned here:

* the grouped objective uncharges resident cross-region edges (a
  chained two-map program costs strictly less grouped than global; a
  single fully-fused map costs the same either way),
* a real program/dims pair where the two objectives pick *different*
  snapshots (``layernorm_matmul`` at single-block dims: the globally
  cheaper snapshot partitions into regions whose grouped megakernels
  are more expensive than the other snapshot's),
* ``select(group=True)`` returns exactly the argmin of
  ``sum(group_cost)`` over each snapshot's grouped plan,
* the grouped selection survives a pipeline disk-cache round-trip
  (same snapshot, same outputs, ``cache_hit == "disk"``), and
* ``autotune(objective="measured", group=True)`` is never slower than
  the grouped-analytic choice, which is always among the timed
  finalists.
"""

import numpy as np
import pytest

from repro import pipeline
from repro.core import array_program as AP
from repro.core import ops as O
from repro.core import regions as R
from repro.core import selection as SEL
from repro.core import timing as T
from repro.core.fusion import fuse
from repro.core.graph import GB, VType

# dims where the global and grouped objectives provably disagree on
# layernorm_matmul (verified below, not just assumed): the globally
# cheaper snapshot groups *strictly* worse
DISAGREE_DIMS = {"M": 1, "K": 1, "N": 2}


@pytest.fixture(autouse=True)
def _fresh_measurements():
    T.clear_measurements()
    yield
    T.clear_measurements()


# ---------------------------------------------------------------------------
# The objective itself
# ---------------------------------------------------------------------------

def _ew_inner(expr):
    gi = GB()
    a = gi.inp("a", VType((), O.BLOCK))
    gi.out("o", gi.func(O.ew(expr), a))
    return gi.g


def _chained_two_map_program():
    """O = (X * 2) + 1 in two chained maps over M: the intermediate T
    round-trips through global memory under the global objective but is
    VMEM-resident under the grouped one."""
    b = GB()
    x = b.inp("X", VType(("M",), O.BLOCK))
    t = b.map("M", _ew_inner("a0*2.0"), [(x, True)])[0]
    o = b.map("M", _ew_inner("a0+1.0"), [(t, True)])[0]
    b.out("O", o)
    return b.g


def _single_map_program():
    """The same function fused into one map: nothing to uncharge."""
    b = GB()
    x = b.inp("X", VType(("M",), O.BLOCK))
    o = b.map("M", _ew_inner("a0*2.0+1.0"), [(x, True)])[0]
    b.out("O", o)
    return b.g


def test_grouped_objective_uncharges_resident_edges():
    dims = {"M": 4}
    chained = _chained_two_map_program()
    glob = SEL.objective_cost(chained, dims)
    grp = SEL.objective_cost(chained, dims, group=True)
    assert grp < glob  # T never touches global memory; one launch, not 2

    fused = _single_map_program()
    assert (SEL.objective_cost(fused, dims, group=True)
            == SEL.objective_cost(fused, dims))
    # grouping the chain reaches the fully-fused program's cost exactly:
    # same loads/stores survive, same single launch
    assert grp == SEL.objective_cost(fused, dims)


def test_grouped_objective_matches_sum_of_group_costs():
    """objective_cost(group=True) is literally sum(group_cost) over the
    snapshot's grouped region partition."""
    g = AP.attention_program(0.125)
    dims = {"M": 2, "D": 2, "N": 3, "L": 2}
    for snap in fuse(g):
        try:
            plan = R.plan_program(snap)
        except R.RegionError:
            continue
        gp = R.group_plan(plan, dims, None)
        want = sum(SEL.group_cost(grp, dims) for grp in gp.groups)
        assert SEL.objective_cost(snap, dims, group=True) == want


# ---------------------------------------------------------------------------
# Selection under the grouped objective
# ---------------------------------------------------------------------------

def test_grouped_and_global_objectives_disagree():
    """At single-block dims the two objectives rank layernorm_matmul's
    snapshots differently — the pinned witness that group=True changes
    what the pipeline compiles, not just the reported number."""
    g = AP.layernorm_matmul_program(32.0)
    snaps = fuse(g)
    sel_glob = SEL.select(g, DISAGREE_DIMS, snapshots=snaps)
    sel_grp = SEL.select(g, DISAGREE_DIMS, snapshots=snaps, group=True)
    assert sel_glob.snapshot_index != sel_grp.snapshot_index
    # each winner is optimal under its own objective...
    assert sel_glob.cost == min(sel_glob.costs)
    assert sel_grp.cost == min(sel_grp.costs)
    # ...and the grouped costs are the grouped objective, per snapshot
    for j, s in enumerate(snaps):
        assert sel_grp.costs[j] == SEL.objective_cost(
            s, DISAGREE_DIMS, group=True)
    # the grouped winner actually pays less than the global winner
    # would, under the residency-aware model of what runs
    grouped_cost_of_global_winner = SEL.objective_cost(
        snaps[sel_glob.snapshot_index], DISAGREE_DIMS, group=True)
    assert sel_grp.cost < grouped_cost_of_global_winner


def test_select_group_false_is_unchanged():
    """group=False (the default) still ranks by the paper's global
    objective — bit-identical costs to snapshot_cost."""
    g = AP.layernorm_matmul_program(32.0)
    snaps = fuse(g)
    sel = SEL.select(g, DISAGREE_DIMS, snapshots=snaps)
    assert sel.costs == tuple(
        SEL.snapshot_cost(s, DISAGREE_DIMS) for s in snaps)


def test_select_group_reuses_shared_plans():
    """The _plans write-back caches one region partition per snapshot
    across a sweep (the partition is dims-independent)."""
    g = AP.attention_program(0.125)
    snaps = fuse(g)
    shared: list = []
    a = SEL.select(g, {"M": 2, "D": 2, "N": 3, "L": 2}, snapshots=snaps,
                   group=True, _plans=shared)
    assert len(shared) == len(snaps)
    before = list(shared)
    b = SEL.select(g, {"M": 4, "D": 2, "N": 3, "L": 2}, snapshots=snaps,
                   group=True, _plans=shared)
    assert shared == before  # reused, not recomputed
    assert a.snapshot_index == b.snapshot_index  # same partition ranked


# ---------------------------------------------------------------------------
# Through the pipeline: disk cache round-trip
# ---------------------------------------------------------------------------

def test_grouped_selection_disk_cache_roundtrip(tmp_path, rng):
    """compile(backend='pallas', group=True) picks the grouped winner at
    the disagreement dims, and a fresh process-boundary cache reloads
    the same selection from disk with identical outputs."""
    M, K, N, bs = 1, 1, 2, 8
    X = rng.normal(size=(M * bs, K * bs))
    Y = rng.normal(size=(K * bs, N * bs))
    g = AP.layernorm_matmul_program(float(K * bs))
    dims = {"M": M, "K": K, "N": N}
    blocks = {"M": bs, "K": bs, "N": bs}
    inputs = {"X": X.astype(np.float32),
              "YT": np.ascontiguousarray(Y.T).astype(np.float32)}

    sel_grp = SEL.select(g, dims, group=True)
    c1 = pipeline.KernelCache(tmp_path)
    k1 = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          cache=c1)
    assert k1.cache_hit is None
    assert k1.snapshot_index == sel_grp.snapshot_index  # grouped winner
    assert k1.cost == sel_grp.cost
    out1 = np.asarray(k1(inputs)["Z"])

    c2 = pipeline.KernelCache(tmp_path)  # fresh in-memory maps
    k2 = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                          cache=c2)
    assert k2.cache_hit == "disk"
    assert k2.snapshot_index == k1.snapshot_index
    np.testing.assert_allclose(np.asarray(k2(inputs)["Z"]), out1,
                               rtol=1e-6, atol=1e-6)

    mu = X.mean(axis=1, keepdims=True)
    sd = np.sqrt((X ** 2).mean(axis=1, keepdims=True) - mu ** 2)
    np.testing.assert_allclose(out1, ((X - mu) / sd) @ Y,
                               rtol=2e-4, atol=2e-4)


def test_jax_backend_keeps_global_objective(tmp_path):
    """The jax backend has no region-group lowering, so its selection
    stays on the paper's global objective even with group=True."""
    g = AP.layernorm_matmul_program(32.0)
    sel_glob = SEL.select(g, DISAGREE_DIMS)
    k = pipeline.compile(g, DISAGREE_DIMS, backend="jax",
                         cache=pipeline.KernelCache(tmp_path))
    assert k.snapshot_index == sel_glob.snapshot_index


# ---------------------------------------------------------------------------
# Measured autotuning composes with the grouped objective
# ---------------------------------------------------------------------------

def test_measured_autotune_never_slower_with_group():
    """With group=True the analytic pruning ranks by the grouped
    objective, the grouped-analytic choice is among the timed finalists,
    and the measured winner can never be slower than it."""
    g = AP.layernorm_matmul_program(32.0)
    cands = {"M": [1, 2], "K": [1, 2], "N": [1, 2]}
    calls = []

    def measure(sel):
        calls.append(dict(sel.dims))
        return 1.0 / sel.cost  # anti-correlated with the analytic model

    best = SEL.autotune(g, cands, objective="measured", measure=measure,
                        top_k=4, group=True)
    assert best.measured_s is not None
    assert best.measured_s == min(t for _, t in best.timings)
    analytic = SEL.autotune(g, cands, group=True)
    # the grouped-analytic choice was timed, so measured <= analytic
    times = dict(best.timings)
    akey = tuple(sorted(analytic.dims.items()))
    assert akey in times
    assert best.measured_s <= times[akey]
    # and every analytic cost the sweep produced used the grouped
    # objective (spot-check the winner)
    assert analytic.cost == SEL.objective_cost(
        analytic.graph, analytic.dims, group=True)
