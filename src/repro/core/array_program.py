"""Array programs and their conversion to block programs (paper §2.2).

An array program is a DAG of standard array operators.  The conversion is a
lookup: each array operator expands to its predefined, *fully unfused* block
subgraph (paper Table 2), using global memory between every stage.

Conventions (paper): ``dot(a, b) = a @ b.T``, so the right-hand operand of
every matrix multiplication is supplied transposed (``KT``, ``YT``...), and
matrices are blocked row-major as lists of lists-of-blocks.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import ops as O
from repro.core.graph import GB, Graph, Ref, VType


@dataclass(frozen=True)
class AVal:
    """An array-program value: a reference into the growing block program,
    plus its blocked dims, e.g. ("M","K") for a matrix blocked both ways, or
    ("M",) for a per-row-block list of vectors."""

    ref: Ref
    dims: Tuple[str, ...]
    item: str = O.BLOCK


class ArrayProgramBuilder:
    """Builds the initial (unfused) block program for an array program."""

    def __init__(self):
        self.b = GB()

    # -- program boundary ---------------------------------------------------
    def input(self, name: str, dims: Sequence[str], item: str = O.BLOCK) -> AVal:
        ref = self.b.inp(name, VType(tuple(dims), item))
        return AVal(ref, tuple(dims), item)

    def output(self, name: str, val: AVal) -> None:
        self.b.out(name, val.ref)

    def build(self) -> Graph:
        g = self.b.g
        g.validate()
        return g

    # -- Table 2: array operators as unfused block subgraphs -----------------

    def elementwise(self, expr: str, *vals: AVal, **consts) -> AVal:
        """Apply an elementwise op to (M,N)-blocked matrices (or any same-
        shaped blocked values).  One map per blocked dim around a single
        elementwise functional operator."""
        dims = vals[0].dims
        assert all(v.dims == dims for v in vals)
        op = O.ew(expr, len(vals), **consts)

        def build_level(level: int) -> Graph:
            gb = GB()
            if level == len(dims):
                ins = [gb.inp(f"a{i}", VType((), v.item)) for i, v in enumerate(vals)]
                out = gb.func(op, *ins)
                gb.out("o", out)
                return gb.g
            inner = build_level(level + 1)
            gb2 = GB()
            ins = [gb2.inp(f"a{i}", VType(dims[level:], v.item))
                   for i, v in enumerate(vals)]
            outs = gb2.map(dims[level], inner, [(r, True) for r in ins])
            gb2.out("o", outs[0])
            return gb2.g

        inner = build_level(1) if dims else None
        if not dims:
            ref = self.b.func(op, *[v.ref for v in vals])
            return AVal(ref, (), vals[0].item)
        outs = self.b.map(dims[0], inner, [(v.ref, True) for v in vals])
        return AVal(outs[0], dims, vals[0].item)

    def matmul_t(self, a: AVal, bt: AVal, out_dim: str) -> AVal:
        """C = A @ B where A is blocked (M, K) and B is supplied transposed,
        blocked (N, K); C is blocked (M, N) with N == out_dim.

        Table 2 subgraph:  Map_M{ Map_N{ Map_K{dot} -> Reduce } } with the
        K-list of partial products materialized in global memory (unfused).
        """
        (m_dim, k_dim), (n_dim, k2) = a.dims, bt.dims
        assert k_dim == k2 and n_dim == out_dim, (a.dims, bt.dims, out_dim)

        gk = GB()
        ia = gk.inp("a", VType((), O.BLOCK))
        ib = gk.inp("b", VType((), O.BLOCK))
        gk.out("o", gk.func(O.DOT, ia, ib))

        gn = GB()
        arow = gn.inp("arow", VType((k_dim,), O.BLOCK))
        brow = gn.inp("brow", VType((k_dim,), O.BLOCK))
        parts = gn.map(k_dim, gk.g, [(arow, True), (brow, True)])
        gn.out("o", gn.reduce(parts[0]))

        gm = GB()
        arow_m = gm.inp("arow", VType((k_dim,), O.BLOCK))
        bt_m = gm.inp("bt", VType((n_dim, k_dim), O.BLOCK))
        outs = gm.map(n_dim, gn.g, [(arow_m, False), (bt_m, True)])
        gm.out("o", outs[0])

        top = self.b.map(m_dim, gm.g, [(a.ref, True), (bt.ref, False)])
        return AVal(top[0], (m_dim, n_dim))

    def _row_map(self, dim: str, inner: Graph,
                 inputs: Sequence[Tuple[AVal, bool]]) -> Ref:
        """Map over the leading (row-block) dim of the given values."""
        outs = self.b.map(dim, inner, [(v.ref, m) for v, m in inputs])
        return outs[0]

    def row_sums(self, x: AVal) -> AVal:
        """Per-block row sums: (M, K) blocks -> (M, K) vectors."""
        m_dim, k_dim = x.dims
        gk = GB()
        i = gk.inp("x", VType((), O.BLOCK))
        gk.out("o", gk.func(O.ROW_SUM, i))
        gm = GB()
        xr = gm.inp("x", VType((k_dim,), O.BLOCK))
        outs = gm.map(k_dim, gk.g, [(xr, True)])
        gm.out("o", outs[0])
        top = self.b.map(m_dim, gm.g, [(x.ref, True)])
        return AVal(top[0], x.dims, O.VECTOR)

    def reduce_rows(self, x: AVal, post_expr: str,
                    extra: Sequence[AVal] = (), **consts) -> AVal:
        """Reduce the inner list dim then apply an elementwise epilogue:
        (M, K)-list of items -> (M,)-list of items.

        ``extra`` are additional per-row-block items (dims (M,)) consumed as
        later elementwise args."""
        m_dim, k_dim = x.dims
        gm = GB()
        xs = gm.inp("xs", VType((k_dim,), x.item))
        extras = [gm.inp(f"e{i}", VType((), v.item)) for i, v in enumerate(extra)]
        red = gm.reduce(xs)
        out = gm.func(O.ew(post_expr, 1 + len(extra), **consts), red, *extras)
        gm.out("o", out)
        ins = [(x.ref, True)] + [(v.ref, True) for v in extra]
        top = self.b.map(m_dim, gm.g, ins)
        return AVal(top[0], (m_dim,), O.VECTOR if x.item == O.VECTOR else x.item)

    def row_apply(self, op: O.Op, x: AVal, c: AVal) -> AVal:
        """row_scale / row_shift of (M, K) blocks by per-row-block vectors
        c (dims (M,))."""
        m_dim, k_dim = x.dims
        gk = GB()
        xb = gk.inp("x", VType((), O.BLOCK))
        cv = gk.inp("c", VType((), c.item))
        gk.out("o", gk.func(op, xb, cv))
        gm = GB()
        xr = gm.inp("x", VType((k_dim,), O.BLOCK))
        cr = gm.inp("c", VType((), c.item))
        outs = gm.map(k_dim, gk.g, [(xr, True), (cr, False)])
        gm.out("o", outs[0])
        top = self.b.map(m_dim, gm.g, [(x.ref, True), (c.ref, True)])
        return AVal(top[0], x.dims)

    # -- composite standard operators ----------------------------------------

    def softmax_rows(self, x: AVal) -> AVal:
        """Row-wise softmax of an (M, N)-blocked matrix: four block
        operators (paper Example 1): exp map, row-sum map, reduce+reciprocal
        map, row-scale map."""
        e = self.elementwise("exp(a0)", x)
        s = self.row_sums(e)
        r = self.reduce_rows(s, "1/a0")
        return self.row_apply(O.ROW_SCALE, e, r)

    def layernorm_rows(self, x: AVal, kk: float) -> AVal:
        """Row-wise LayerNorm of an (M, K)-blocked matrix (paper Example 2).

        sigma(s1, s2) = sqrt(s2/k - (s1/k)^2); the program materializes the
        negated mean (t5 = -s1/k) and uses row_shift to subtract it."""
        s1 = self.row_sums(x)
        nmean = self.reduce_rows(s1, "-a0/KK", KK=kk)
        shifted = self.row_apply(O.ROW_SHIFT, x, nmean)
        sq = self.elementwise("a0*a0", x)
        s2 = self.row_sums(sq)
        istd = self.reduce_rows(s2, "(a0/KK - a1*a1)**(-0.5)",
                                extra=[nmean], KK=kk)
        return self.row_apply(O.ROW_SCALE, shifted, istd)

    def rmsnorm_rows(self, x: AVal, dd: float, eps: float = 0.0) -> AVal:
        """Row-wise RMSNorm of an (M, D)-blocked matrix (paper Example 3).

        Note: the paper's listing uses 1/sqrt(sum); real RMSNorm divides by
        the dim (mean).  We use the correct mean form — immaterial to
        fusion structure."""
        sq = self.elementwise("a0*a0", x)
        s = self.row_sums(sq)
        # float() so an np scalar eps neither bakes an uneval-able repr
        # into the expression nor perturbs the graph fingerprint
        irms = self.reduce_rows(s, f"1/sqrt(a0/DD + {float(eps)!r})", DD=dd)
        return self.row_apply(O.ROW_SCALE, x, irms)

    def causal_mask(self, s: AVal, qp: AVal, kp: AVal) -> AVal:
        """Causally mask an (M, N)-blocked score matrix.

        ``qp`` is an (M,)-list of per-row-block global position vectors,
        ``kp`` an (N,)-list of per-column-block position vectors.  Table-2
        style expansion: Map_M{ Map_N{ causal_mask } } with the row
        positions mapped over M (broadcast into N) and the column
        positions broadcast into M (mapped over N)."""
        m_dim, n_dim = s.dims
        assert qp.dims == (m_dim,) and kp.dims == (n_dim,), (qp.dims,
                                                             kp.dims)
        gn = GB()
        sb = gn.inp("s", VType((), O.BLOCK))
        qv = gn.inp("q", VType((), O.VECTOR))
        kv = gn.inp("k", VType((), O.VECTOR))
        gn.out("o", gn.func(O.CAUSAL_MASK, sb, qv, kv))
        gm = GB()
        srow = gm.inp("s", VType((n_dim,), O.BLOCK))
        qv_m = gm.inp("q", VType((), O.VECTOR))
        kl = gm.inp("k", VType((n_dim,), O.VECTOR))
        outs = gm.map(n_dim, gn.g, [(srow, True), (qv_m, False),
                                    (kl, True)])
        gm.out("o", outs[0])
        top = self.b.map(m_dim, gm.g, [(s.ref, True), (qp.ref, True),
                                       (kp.ref, False)])
        return AVal(top[0], s.dims)

    def swish(self, x: AVal) -> AVal:
        return self.elementwise("a0/(1+exp(-a0))", x)

    def hadamard(self, a: AVal, b: AVal) -> AVal:
        return self.elementwise("a0*a1", a, b)

    def scale_const(self, x: AVal, c: float) -> AVal:
        return self.elementwise("a0*C0", x, C0=c)


# ---------------------------------------------------------------------------
# The paper's three example programs
# ---------------------------------------------------------------------------

def attention_program(scale: float) -> Graph:
    """Paper Example 1: Attention = matmul, /sqrt(d), softmax, matmul.

    Inputs: Q blocked (M, D); K^T blocked (N, D); V^T blocked (L, N).
    Output: O blocked (M, L)."""
    ap = ArrayProgramBuilder()
    q = ap.input("Q", ("M", "D"))
    kt = ap.input("KT", ("N", "D"))
    vt = ap.input("VT", ("L", "N"))
    s = ap.matmul_t(q, kt, out_dim="N")
    s = ap.scale_const(s, scale)
    p = ap.softmax_rows(s)
    o = ap.matmul_t(p, vt, out_dim="L")
    ap.output("O", o)
    return ap.build()


def causal_attention_program(scale: float) -> Graph:
    """Causal (decoder) attention as a block program.

    Inputs: Q blocked (M, D); K^T blocked (N, D); V^T blocked (L, N);
    QP — (M,)-list of per-row-block global query-position vectors;
    KP — (N,)-list of per-column-block key-position vectors.
    Output: O blocked (M, L).

    Masking happens *before* the scale so Rule 9 still composes the scale
    into the exp (the flagship trace's elementwise fusion); masked scores
    stay ``<= scale * NEG_MASK`` and exp to exactly 0.  A one-token decode
    step is this same program with M = 1 block and QP = [write position].
    """
    assert scale > 0.0, "causal masking needs a positive logit scale"
    ap = ArrayProgramBuilder()
    q = ap.input("Q", ("M", "D"))
    kt = ap.input("KT", ("N", "D"))
    vt = ap.input("VT", ("L", "N"))
    qp = ap.input("QP", ("M",), O.VECTOR)
    kp = ap.input("KP", ("N",), O.VECTOR)
    s = ap.matmul_t(q, kt, out_dim="N")
    s = ap.causal_mask(s, qp, kp)
    s = ap.scale_const(s, scale)
    p = ap.softmax_rows(s)
    o = ap.matmul_t(p, vt, out_dim="L")
    ap.output("O", o)
    g = ap.build()
    g.causal_dims = {"N": "M"}
    return g


def gqa_attention_program(scale: float, causal: bool = False) -> Graph:
    """Grouped-query attention: the attention body wrapped in a map over
    the head-group dim H whose K/V (and position) ports are *broadcast* —
    one K/V block set shared by every query head in the group, which is
    exactly the head-group broadcast GQA buys.

    Inputs: Q blocked (H, M, D); K^T (N, D); V^T (L, N); plus QP/KP when
    ``causal``.  Output: O blocked (H, M, L)."""
    inner = (causal_attention_program(scale) if causal
             else attention_program(scale))
    gb = GB()
    q = gb.inp("Q", VType(("H", "M", "D"), O.BLOCK))
    kt = gb.inp("KT", VType(("N", "D"), O.BLOCK))
    vt = gb.inp("VT", VType(("L", "N"), O.BLOCK))
    ins = [(q, True), (kt, False), (vt, False)]
    if causal:
        qp = gb.inp("QP", VType(("M",), O.VECTOR))
        kp = gb.inp("KP", VType(("N",), O.VECTOR))
        ins += [(qp, False), (kp, False)]
    outs = gb.map("H", inner, ins)
    gb.out("O", outs[0])
    g = gb.g
    g.causal_dims = dict(inner.causal_dims)
    g.validate()
    return g


def layernorm_matmul_program(kk: float) -> Graph:
    """Paper Example 2: Z = LayerNorm_rows(X) @ Y.

    Inputs: X blocked (M, K); Y^T blocked (N, K).  Output: Z (M, N)."""
    ap = ArrayProgramBuilder()
    x = ap.input("X", ("M", "K"))
    yt = ap.input("YT", ("N", "K"))
    ln = ap.layernorm_rows(x, kk)
    z = ap.matmul_t(ln, yt, out_dim="N")
    ap.output("Z", z)
    return ap.build()


def rmsnorm_ffn_swiglu_program(dd: float, eps: float = 0.0) -> Graph:
    """Paper Example 3: O = (Swish(RMS(X) @ W) * (RMS(X) @ V)) @ U.

    Inputs: X (M, D); W^T (K, D); V^T (K, D); U^T (N, K).  Output: O (M, N).
    ``eps`` matches the model layers' ``rms_norm`` stabilizer (inside the
    sqrt); the paper's listing has none."""
    ap = ArrayProgramBuilder()
    x = ap.input("X", ("M", "D"))
    wt = ap.input("WT", ("K", "D"))
    vt = ap.input("VT", ("K", "D"))
    ut = ap.input("UT", ("N", "K"))
    xn = ap.rmsnorm_rows(x, dd, eps=eps)
    g = ap.swish(ap.matmul_t(xn, wt, out_dim="K"))
    u = ap.matmul_t(xn, vt, out_dim="K")
    h = ap.hadamard(g, u)
    o = ap.matmul_t(h, ut, out_dim="N")
    ap.output("O", o)
    return ap.build()
