"""Wall-clock kernel timing: the measurement half of the
predict -> run -> measure -> recalibrate loop.

* :func:`time_callable` — the robust harness every measurement goes
  through: warmup calls first (compilation, tracing), then median-of-K
  timed calls, each fenced with ``jax.block_until_ready`` so async
  dispatch cannot leak work across the stopwatch.
* :func:`region_times` — per-kernel timing of a compiled
  ``pipeline.CompiledKernel`` on the Pallas backend: each region of the
  ``ProgramPlan`` is timed standalone (inputs threaded exactly as the
  real execution threads them), so entry *i* pairs with entry *i* of
  ``CompiledKernel.region_costs`` — the (features, seconds) samples
  ``core/calibrate.py`` fits.
* :func:`synth_inputs` — synthetic merged inputs for a program at given
  dims/block extents (position vectors get ``arange``, data gets scaled
  normals), shared by the measured autotuner and the benchmarks.
* :func:`measured` — a process-wide measurement memo keyed by
  ``(fingerprint, dims, backend, device, ...)`` so the autotuner never
  times the same configuration twice.
* :func:`spearman` — rank agreement between predicted and measured
  orderings (the calibration acceptance metric).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blocks import merged_shape
from repro.core.graph import Graph

# names that carry global positions, not data (the attention programs'
# query/key position vectors) — synthetic inputs must keep them ordinal
POSITION_INPUTS = ("QP", "KP")


def _sync(out) -> None:
    """Block until ``out`` (any pytree of arrays) is actually computed;
    numpy leaves pass through untouched."""
    try:
        import jax
        jax.block_until_ready(out)
    except ImportError:  # pragma: no cover - jax is a hard dep in-repo
        pass


@dataclass(frozen=True)
class TimingResult:
    times_s: Tuple[float, ...]

    @property
    def median_s(self) -> float:
        return float(np.median(self.times_s))

    @property
    def best_s(self) -> float:
        return float(min(self.times_s))


def time_callable(fn: Callable, *args, warmup: int = 1, repeats: int = 5,
                  **kwargs) -> TimingResult:
    """Median-of-``repeats`` wall time of ``fn(*args, **kwargs)`` after
    ``warmup`` untimed calls; every call is fenced."""
    for _ in range(max(warmup, 0)):
        _sync(fn(*args, **kwargs))
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        _sync(fn(*args, **kwargs))
        times.append(time.perf_counter() - t0)
    return TimingResult(tuple(times))


# ---------------------------------------------------------------------------
# Synthetic inputs
# ---------------------------------------------------------------------------

def stack_dims(g: Graph) -> frozenset:
    """Dims that appear as leading stack axes of some program input —
    the Pallas backend requires block size 1 for them."""
    out = set()
    for nid in g.input_ids:
        vt = g.nodes[nid].vtype
        out.update(vt.dims[:vt.lead_dims])
    return frozenset(out)


def synth_blocks(g: Graph, dims: Dict[str, int],
                 item: int = 8) -> Dict[str, int]:
    """A valid per-dim block-extent map for ``g``: ``item`` everywhere,
    1 on stack dims (the Pallas constraint)."""
    sd = stack_dims(g)
    return {d: (1 if d in sd else item) for d in dims}


def synth_inputs(g: Graph, dims: Dict[str, int],
                 blocks: Optional[Dict[str, int]] = None, *,
                 item: int = 8, seed: int = 0) -> Dict[str, np.ndarray]:
    """Random merged input arrays for ``g`` at ``dims`` with per-dim
    block extents ``blocks`` (default: :func:`synth_blocks`).  Data
    inputs are normals scaled by the contraction width; position inputs
    get ``arange`` so causal masks stay meaningful."""
    rng = np.random.default_rng(seed)
    blocks = blocks if blocks is not None else synth_blocks(g, dims, item)
    out = {}
    for nid in g.input_ids:
        node = g.nodes[nid]
        vt = node.vtype
        ish = tuple(blocks.get(d, item) for d in vt.dims[vt.lead_dims:])
        shape = merged_shape(vt, ish, dims)
        if node.name in POSITION_INPUTS:
            out[node.name] = np.arange(shape[0], dtype=np.float32)
        else:
            out[node.name] = (rng.normal(size=shape)
                              / max(shape[-1], 1) ** 0.5
                              ).astype(np.float32)
    return out


# ---------------------------------------------------------------------------
# Per-region timing of a compiled plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RegionTime:
    label: str
    result: TimingResult

    @property
    def median_s(self) -> float:
        return self.result.median_s


def region_times(kern, inputs: Dict[str, Any], *, warmup: int = 1,
                 repeats: int = 5) -> Optional[List[RegionTime]]:
    """Wall time of each region kernel of a compiled Pallas
    ``CompiledKernel``, in plan order — entry *i* pairs with
    ``kern.region_costs[i]`` and ``kern.lowering_report.regions[i]``.

    The regions are executed in topological order with real
    intermediates threaded between them (exactly what ``kern(inputs)``
    does), but each region is warmed up and timed standalone.  Returns
    ``None`` for kernels that do not expose region runners (py/jax
    backends)."""
    raw = getattr(getattr(kern, "_fn", None), "raw_program", None)
    runners = getattr(raw, "region_runners", None)
    if runners is None:
        return None
    merged = [inputs[nm] for nm in kern.in_names]
    env: Dict[Tuple[int, int], Any] = dict(zip(raw.input_refs, merged))
    out: List[RegionTime] = []
    for spec, fn in runners:
        args = [env[r] for r in spec.in_refs]
        # the first warmup call doubles as the real execution whose
        # outputs thread into downstream regions — no extra call
        outs = fn(*args)
        _sync(outs)
        for ref, o in zip(spec.out_refs, outs):
            env[ref] = o
        res = time_callable(fn, *args, warmup=max(warmup - 1, 0),
                            repeats=repeats)
        out.append(RegionTime(spec.label, res))
    return out


# ---------------------------------------------------------------------------
# Measurement memo
# ---------------------------------------------------------------------------

_MEASUREMENTS: Dict[Tuple, float] = {}


def measured(key: Tuple, thunk: Callable[[], float]) -> float:
    """Process-wide memo: run ``thunk`` (seconds) once per ``key``.
    Keys embed everything the measurement depends on — graph
    fingerprint, dims, backend, device, problem extents — so re-sweeps
    and overlapping top-K sets never re-time a configuration."""
    if key not in _MEASUREMENTS:
        _MEASUREMENTS[key] = float(thunk())
    return _MEASUREMENTS[key]


def clear_measurements() -> None:
    """Drop the memo (tests)."""
    _MEASUREMENTS.clear()


def measurement_count() -> int:
    return len(_MEASUREMENTS)


# ---------------------------------------------------------------------------
# Rank agreement
# ---------------------------------------------------------------------------

def _ranks(v: Sequence[float]) -> np.ndarray:
    a = np.asarray(v, dtype=np.float64)
    order = np.argsort(a, kind="stable")
    ranks = np.empty(len(a), dtype=np.float64)
    ranks[order] = np.arange(len(a), dtype=np.float64)
    # average ties so equal values cannot fake agreement
    for val in np.unique(a):
        m = a == val
        if m.sum() > 1:
            ranks[m] = ranks[m].mean()
    return ranks


def spearman(pred: Sequence[float], meas: Sequence[float]) -> float:
    """Spearman rank correlation between a predicted and a measured
    ordering.  Fewer than two samples is vacuous agreement (1.0); one
    constant side against a varying one is no agreement (0.0)."""
    if len(pred) != len(meas):
        raise ValueError("length mismatch")
    if len(pred) < 2:
        return 1.0
    rp, rm = _ranks(pred), _ranks(meas)
    sp, sm = rp.std(), rm.std()
    if sp == 0.0 and sm == 0.0:
        return 1.0
    if sp == 0.0 or sm == 0.0:
        return 0.0
    return float(np.corrcoef(rp, rm)[0, 1])
