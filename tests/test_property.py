"""Property-based tests (hypothesis) on the system's invariants.

The central invariant is the paper's: *every substitution rule is
logic-preserving* — so the full fusion algorithm must preserve program
semantics for arbitrary programs built from the operator vocabulary, for
arbitrary block decompositions.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import array_program as AP
from repro.core import blocks as B
from repro.core import cost as C
from repro.core import ops as O
from repro.core.fusion import fuse
from repro.core.graph import internal_buffered_edges
from repro.core.interpreter import run

dims_st = st.tuples(st.integers(1, 3), st.integers(1, 3),
                    st.integers(1, 4), st.integers(1, 3))


def _random_chain_program(rng, n_ops: int):
    """A random array program: X(M,K) through a chain of row-wise norms,
    elementwise ops and matmuls (the paper's operator vocabulary)."""
    ap = AP.ArrayProgramBuilder()
    x = ap.input("X", ("M", "K"))
    weights = []
    val = x
    kinds = rng.integers(0, 4, size=n_ops)
    for i, kind in enumerate(kinds):
        if kind == 0:
            val = ap.elementwise("a0*a0+C0", val, C0=float(rng.normal()))
        elif kind == 1:
            val = ap.rmsnorm_rows(val, dd=8.0)
        elif kind == 2:
            val = ap.layernorm_rows(val, kk=8.0)
        else:
            name = f"W{i}"
            ap_in = ap.input(name, ("K", "K"))
            weights.append(name)
            val = ap.matmul_t(val, ap_in, out_dim="K")
    ap.output("O", val)
    return ap.build(), weights


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_ops=st.integers(1, 4))
def test_fusion_preserves_semantics_on_random_programs(seed, n_ops):
    rng = np.random.default_rng(seed)
    g, weights = _random_chain_program(rng, n_ops)
    M, K = 2, 2
    bs = 4
    X = rng.normal(size=(M * bs, K * bs))
    inputs = {"X": B.split(X, M, K)}
    for w in weights:
        inputs[w] = B.split(rng.normal(size=(K * bs, K * bs)) / 3.0, K, K)
    dims = {"M": M, "K": K}
    ref = B.merge(run(g, inputs, dims)["O"])
    for snap in fuse(g):
        got = B.merge(run(snap, inputs, dims)["O"])
        np.testing.assert_allclose(got, ref, rtol=1e-8, atol=1e-8)


@settings(max_examples=10, deadline=None)
@given(dims=dims_st, seed=st.integers(0, 1000))
def test_attention_fusion_invariant_to_block_decomposition(dims, seed):
    """The fused result must not depend on how matrices are split into
    blocks (the selection algorithm chooses shapes after fusion)."""
    M, D, N, L = dims
    rng = np.random.default_rng(seed)
    bs = 4
    Q = rng.normal(size=(M * bs, D * bs))
    K = rng.normal(size=(N * bs, D * bs))
    V = rng.normal(size=(N * bs, L * bs))
    g = AP.attention_program(0.3)
    snaps = fuse(g)
    inputs = {"Q": B.split(Q, M, D), "KT": B.split(K, N, D),
              "VT": B.split(V.T, L, N)}
    out = B.merge(run(snaps[-1], inputs, {"M": M, "D": D, "N": N, "L": L})
                  ["O"])
    S = (Q @ K.T) * 0.3
    P = np.exp(S)
    ref = (P / P.sum(1, keepdims=True)) @ V
    np.testing.assert_allclose(out, ref, rtol=1e-7, atol=1e-7)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_ops=st.integers(1, 4))
def test_fusion_never_increases_stores(seed, n_ops):
    """Fusion rules only remove buffered edges: the first no-extension
    snapshot can never store MORE than the unfused program."""
    rng = np.random.default_rng(seed)
    g, _ = _random_chain_program(rng, n_ops)
    dims = {"M": 2, "K": 3}
    before = C.traffic(g, dims)
    snap0 = fuse(g)[0]
    after = C.traffic(snap0, dims)
    assert sum(after.stores.values()) <= sum(before.stores.values())
    assert after.launches <= before.launches


@settings(max_examples=25, deadline=None)
@given(exprs=st.lists(st.sampled_from(["a0*2.0", "exp(a0)", "a0+1.5",
                                       "a0*a0", "1/(1+exp(-a0))"]),
                      min_size=2, max_size=5),
       seed=st.integers(0, 100))
def test_elementwise_composition_associative(exprs, seed):
    """Rule 9 composition: folding a chain of elementwise ops one at a time
    equals applying them sequentially."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(3, 4))
    composed = O.ew(exprs[0])
    for e in exprs[1:]:
        composed = O.compose_elementwise(composed, O.ew(e), 0)
    want = x
    for e in exprs:
        want = O.ew(e).apply(np, want)
    np.testing.assert_allclose(composed.apply(np, x), want,
                               rtol=1e-10, atol=1e-10)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(2, 12), d=st.integers(1, 3), fused=st.booleans())
def test_causal_traffic_strictly_below_noncausal(n, d, fused):
    """The mask-aware cost model: for more than one sequence block, the
    causal program moves strictly fewer bytes than the non-causal one
    (fully-masked tiles are never touched), fused or not."""
    from repro.core import selection as SEL

    dims = {"M": n, "D": d, "N": n, "L": d}
    gc = AP.causal_attention_program(0.125)
    gn = AP.attention_program(0.125)
    if fused:
        gc, gn = fuse(gc)[-1], fuse(gn)[-1]
    bc = C.traffic(gc, dims).bytes_moved(SEL.DEFAULT_ITEM_BYTES)
    bn = C.traffic(gn, dims).bytes_moved(SEL.DEFAULT_ITEM_BYTES)
    assert bc < bn


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 10), d=st.integers(1, 3))
def test_causal_traffic_monotone_in_seq_len(n, d):
    """Predicted causal traffic grows strictly with the number of
    sequence blocks (the discount never makes a longer sequence look
    cheaper)."""
    from repro.core import selection as SEL

    fused = fuse(AP.causal_attention_program(0.125))[-1]

    def cost(k):
        return C.traffic(fused, {"M": k, "D": d, "N": k, "L": d}
                         ).bytes_moved(SEL.DEFAULT_ITEM_BYTES)

    assert cost(n) < cost(n + 1)


# ---------------------------------------------------------------------------
# The compute-aware cost model
# ---------------------------------------------------------------------------

_PROGRAM_BUILDERS = {
    "layernorm_matmul": (lambda: AP.layernorm_matmul_program(32.0),
                         ("M", "K", "N")),
    "rmsnorm_ffn_swiglu": (lambda: AP.rmsnorm_ffn_swiglu_program(16.0),
                           ("M", "D", "K", "N")),
    "attention": (lambda: AP.attention_program(0.125),
                  ("M", "D", "N", "L")),
    "causal_attention": (lambda: AP.causal_attention_program(0.25),
                         ("M", "D", "N", "L")),
    "gqa_attention": (lambda: AP.gqa_attention_program(0.25, causal=True),
                      ("H", "M", "D", "N", "L")),
}
_SNAPSHOT_CACHE = {}


def _snapshots(name):
    if name not in _SNAPSHOT_CACHE:
        _SNAPSHOT_CACHE[name] = fuse(_PROGRAM_BUILDERS[name][0]())
    return _SNAPSHOT_CACHE[name]


@settings(max_examples=20, deadline=None)
@given(name=st.sampled_from(sorted(_PROGRAM_BUILDERS)),
       cls=st.sampled_from(C.WORK_CLASSES),
       delta=st.floats(1e-12, 1e-6),
       dim_seed=st.integers(0, 1000))
def test_cost_monotone_in_each_work_coefficient(name, cls, delta,
                                                dim_seed):
    """Raising any single work coefficient never makes a snapshot look
    cheaper — and strictly raises the cost of a snapshot that does work
    of that class (the compute term prices work, never discounts it)."""
    from dataclasses import replace

    from repro.core import calibrate as CAL
    from repro.core import selection as SEL

    rng = np.random.default_rng(dim_seed)
    _, dim_names = _PROGRAM_BUILDERS[name]
    dims = {d: int(rng.integers(1, 5)) for d in dim_names}
    snap = _snapshots(name)[0]
    bumped = replace(
        CAL.DEFAULT_PROFILE,
        work_coef={**CAL.DEFAULT_WORK_COEF, cls: delta})
    base = SEL.snapshot_cost(snap, dims)
    raised = SEL.snapshot_cost(snap, dims, profile=bumped)
    assert raised >= base
    if C.traffic(snap, dims).flops()[cls] > 0:
        assert raised > base


@settings(max_examples=20, deadline=None)
@given(name=st.sampled_from(sorted(_PROGRAM_BUILDERS)),
       dim_seed=st.integers(0, 1000))
def test_grouped_objective_never_exceeds_global(name, dim_seed):
    """The residency-aware grouped objective can only *uncharge* edges
    and merge launches: for every snapshot of every in-repo program, at
    any dims, sum(group_cost) <= snapshot_cost under the default
    profile."""
    from repro.core import selection as SEL

    rng = np.random.default_rng(dim_seed)
    _, dim_names = _PROGRAM_BUILDERS[name]
    dims = {d: int(rng.integers(1, 5)) for d in dim_names}
    for snap in _snapshots(name):
        grouped = SEL.objective_cost(snap, dims, group=True)
        glob = SEL.snapshot_cost(snap, dims)
        assert grouped <= glob


@settings(max_examples=20, deadline=None)
@given(name=st.sampled_from(sorted(_PROGRAM_BUILDERS)),
       dim_seed=st.integers(0, 1000),
       block=st.floats(0.1, 10.0), launch=st.floats(0.0, 1e6))
def test_zero_work_profile_is_pre_work_formula_exactly(name, dim_seed,
                                                       block, launch):
    """Any profile with all-zero work and instance coefficients prices a
    snapshot bit-identically to the pre-work-feature formula
    ``bytes_moved + launch_coef * launches`` — the new features are
    invisible until a fit turns them on."""
    from dataclasses import replace

    from repro.core import calibrate as CAL
    from repro.core import selection as SEL

    rng = np.random.default_rng(dim_seed)
    _, dim_names = _PROGRAM_BUILDERS[name]
    dims = {d: int(rng.integers(1, 5)) for d in dim_names}
    coef = {"block": block, "vector": block / 128.0,
            "scalar": block / 16384.0}
    prof = replace(CAL.DEFAULT_PROFILE, item_coef=coef,
                   launch_coef=launch)
    snap = _snapshots(name)[0]
    t = C.traffic(snap, dims)
    assert SEL.snapshot_cost(snap, dims, profile=prof) == (
        t.bytes_moved(coef) + launch * t.launches)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000),
       splits=st.tuples(st.integers(1, 4), st.integers(1, 4)))
def test_interpreter_block_split_invariance(seed, splits):
    """Interpreting any program is invariant to the block decomposition of
    its inputs (blocks are an implementation detail, paper §2.1)."""
    rng = np.random.default_rng(seed)
    M, K = splits
    X = rng.normal(size=(8, 12))
    g = AP.layernorm_matmul_program(12.0)
    Y = rng.normal(size=(12, 8))
    out = B.merge(run(g, {"X": B.split(X, M, K), "YT": B.split(Y.T, 2, K)},
                      {"M": M, "K": K, "N": 2})["Z"])
    mu = X.mean(1, keepdims=True)
    sd = np.sqrt((X ** 2).mean(1, keepdims=True) - mu ** 2)
    np.testing.assert_allclose(out, ((X - mu) / sd) @ Y, rtol=1e-8,
                               atol=1e-8)
