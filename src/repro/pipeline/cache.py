"""Two-level kernel cache for ``pipeline.compile``.

* **in-process** — compiled-callable objects keyed by the full compile key;
  a hit returns the existing jitted kernel with zero work.
* **on-disk** — the *compilation plan* (selected snapshot index, dims,
  costs) as JSON plus the selected snapshot graph itself pickled next to
  it.  A disk hit skips fusion, the autotune sweep, and snapshot
  selection; only backend lowering (fast) reruns.  Programs containing
  un-picklable ``MiscNode.fn`` closures degrade gracefully to plan-only
  entries (fusion reruns, selection doesn't).

Keys combine the graph fingerprint with every input that affects the
emitted kernel: backend, dims, block shapes, whether fusion ran, and the
``CODEGEN_VERSION`` salt.  The cache directory defaults to
``~/.cache/repro/kernels`` and is overridable via ``$REPRO_KERNEL_CACHE``
(tests point it at a tmpdir).

The on-disk level is a size-capped LRU: every hit touches the entry's
mtime, and after every write the oldest entries are evicted until the
directory fits ``max_disk_bytes`` (default 1 GiB, overridable via
``$REPRO_KERNEL_CACHE_MAX_BYTES``; ``0``/negative disables eviction) —
the cache no longer grows without bound.

Integrity: every on-disk artifact is checksummed — plan JSON rides in a
``{"schema", "sha256", "plan"}`` envelope, graph pickles carry a magic +
sha256 header — and verified on read.  A corrupt, truncated, or
wrong-schema entry is **quarantined** (moved to ``<cache>/quarantine/``
for triage, never silently deleted), counted in :class:`CacheStats`
(``corrupt_plans`` / ``corrupt_graphs`` / ``quarantined``), and logged
with the offending path; the compile then proceeds as a miss.  Writes
are crash-safe (unique temp file + fsync + atomic rename) and
concurrent writers are serialized with a best-effort ``flock`` on
``<cache>/.lock`` where the platform provides one.
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import time
import warnings
from dataclasses import asdict, dataclass, field, fields
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.core.graph import Graph

# v4: checksummed envelopes (plan JSON + graph pickle header) with
# quarantine on mismatch.  Old unversioned artifacts hash to different
# digests, so they are never read — just unreferenced bytes the LRU
# eviction eventually clears.
_SCHEMA_VERSION = 4

# magic prefixing every graph pickle: 8 bytes tag + 32 bytes sha256 of
# the payload that follows
_GRAPH_MAGIC = b"RPRGRPH1"

# Version salt for everything downstream of the graph fingerprint: fusion
# rules, the selection cost model, and the three backend code generators.
# Bump it whenever any of those change semantics so stale on-disk plans
# from an older build are never loaded (they would re-lower a snapshot
# selected — or shaped — by the old compiler).  v2: causal/GQA attention
# (mask-aware cost model, lead-dim packing).  v3: region-partitioned
# multi-kernel Pallas lowering (every snapshot lowers; the walk-back to
# the final snapshot is gone, so old pallas plans describe kernels this
# build would never emit).  v4: region-group megakernels (compatible
# regions share one pallas_call with VMEM-resident cross-region values;
# per-kernel costs are residency-aware and paired by kernel id).
# v5: compute-aware grouped selection (pallas snapshots rank by
# sum-of-group-costs under a schema-2 calibration profile with work
# coefficients; old plans may carry a differently-selected snapshot).
# v6: graph-level numerical stabilization (``numerics.stabilize``
# rewrites top-level-exp programs into significand/exponent pairs with
# rescaled serial carries; stabilized snapshots have different shapes,
# costs, and kernels than anything a v5 build selected).
CODEGEN_VERSION = 6

DEFAULT_MAX_DISK_BYTES = 1 << 30  # 1 GiB

# crash-recovery sweep thresholds (KernelCache.recover): a *.tmp file
# whose embedded writer pid is dead — or older than this — is an orphan
# from a crashed writer; a .lock nobody holds and older than this is
# stale.  Quarantine is capped at a byte budget, oldest-first.
STALE_TMP_AGE_S = 3600.0
STALE_LOCK_AGE_S = 3600.0
DEFAULT_QUARANTINE_MAX_BYTES = 64 << 20  # 64 MiB


def _tmp_writer_pid(name: str) -> Optional[int]:
    """The writer pid embedded in an ``{entry}.{pid}.tmp`` name, else
    ``None`` (a tmp file this cache's writers did not produce)."""
    parts = name.rsplit(".", 2)
    if len(parts) == 3 and parts[2] == "tmp":
        try:
            return int(parts[1])
        except ValueError:
            return None
    return None


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except (OSError, OverflowError):
        return True  # EPERM etc.: some process owns it — assume alive
    return True


def _norm(d: Optional[Dict[str, Any]]) -> Tuple:
    return tuple(sorted(d.items())) if d else ()


@dataclass(frozen=True)
class CacheKey:
    fingerprint: str
    backend: str
    dims: Tuple = ()
    blocks: Tuple = ()
    fused: bool = True
    opts: Tuple = ()  # backend/selection options that change the kernel
                      # (resolved interpret flag, jit, item_bytes, ...)

    @classmethod
    def make(cls, fingerprint: str, backend: str,
             dims: Optional[Dict[str, int]],
             blocks: Optional[Dict[str, int]], fused: bool,
             opts: Tuple = ()) -> "CacheKey":
        return cls(fingerprint, backend, _norm(dims), _norm(blocks), fused,
                   opts)

    def digest(self) -> str:
        # CODEGEN_VERSION is read at call time so tests (and hot-reloads)
        # that bump the module global invalidate every existing entry
        raw = json.dumps([_SCHEMA_VERSION, CODEGEN_VERSION,
                          self.fingerprint, self.backend,
                          self.dims, self.blocks, self.fused, self.opts])
        return hashlib.sha256(raw.encode()).hexdigest()[:32]


@dataclass
class CachePlan:
    """What selection decided — everything needed to re-lower without
    re-running fusion or the block-shape sweep."""

    snapshot_index: int
    dims: Dict[str, int]
    cost: float
    costs: Tuple[float, ...]
    initial_cost: float
    # per-kernel traffic attribution of the selected snapshot (pallas
    # backend: one entry per emitted kernel — a region-group megakernel
    # counts once), None for other backends
    region_costs: Optional[Tuple[float, ...]] = None
    # wall seconds of the winning config when the plan came from a
    # measured autotune sweep (optional key; absent in older entries)
    measured_s: Optional[float] = None
    # stable ids of the emitted kernels, aligned with region_costs — the
    # timing harness pairs measured kernel times with costs by id
    kernel_ids: Optional[Tuple[str, ...]] = None
    # grouped-lowering provenance: kernels launched per call and
    # cross-region values kept VMEM-resident
    launches: Optional[int] = None
    resident_edges: Optional[int] = None
    # True when the snapshots were rewritten by ``numerics.stabilize``
    # before selection (snapshot_index addresses the stabilized list)
    stabilized: bool = False

    def to_json(self) -> Dict[str, Any]:
        d = asdict(self)
        d["costs"] = list(self.costs)
        d["region_costs"] = (list(self.region_costs)
                             if self.region_costs is not None else None)
        d["kernel_ids"] = (list(self.kernel_ids)
                           if self.kernel_ids is not None else None)
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "CachePlan":
        rc = d.get("region_costs")
        ms = d.get("measured_s")
        kids = d.get("kernel_ids")
        launches = d.get("launches")
        resident = d.get("resident_edges")
        return cls(int(d["snapshot_index"]), dict(d["dims"]),
                   float(d["cost"]), tuple(d["costs"]),
                   float(d["initial_cost"]),
                   tuple(rc) if rc is not None else None,
                   float(ms) if ms is not None else None,
                   tuple(str(k) for k in kids) if kids is not None
                   else None,
                   int(launches) if launches is not None else None,
                   int(resident) if resident is not None else None,
                   bool(d.get("stabilized", False)))


@dataclass
class CacheStats:
    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0
    # -- integrity counters: every recovered-from error is named --------
    corrupt_plans: int = 0    # unreadable/bad-checksum/bad-schema plan JSON
    corrupt_graphs: int = 0   # unreadable/bad-checksum graph pickle
    quarantined: int = 0      # files moved to <cache>/quarantine/
    write_errors: int = 0     # failed plan/graph writes (entry skipped)
    evict_errors: int = 0     # failed unlinks during LRU eviction
    io_errors: int = 0        # failed stat/utime/scan (entry degraded)
    # -- startup crash-recovery sweep (KernelCache.recover) -------------
    recovered_tmp: int = 0         # orphaned *.pid.tmp from dead writers
    stale_locks: int = 0           # unheld, over-age .lock files removed
    quarantine_evicted: int = 0    # quarantine files over the byte budget

    @property
    def compiles(self) -> int:
        """Compile paths that did NOT hit the in-process kernel cache:
        fresh compilations (``misses``) plus disk-plan reloads
        (``disk_hits``).  Serving engines pin the steady-state growth of
        this counter to zero to prove no per-step recompiles."""
        return self.misses + self.disk_hits

    @property
    def hit_rate(self) -> float:
        hits = self.memory_hits + self.disk_hits
        total = hits + self.misses
        return hits / total if total else 1.0

    def snapshot(self) -> "CacheStats":
        return CacheStats(**{f.name: getattr(self, f.name)
                             for f in fields(self)})

    def delta(self, since: "CacheStats") -> "CacheStats":
        """Counter growth since a ``snapshot()``."""
        return CacheStats(**{
            f.name: getattr(self, f.name) - getattr(since, f.name)
            for f in fields(self)})


def _plan_envelope(plan: CachePlan) -> str:
    payload = json.dumps(plan.to_json(), sort_keys=True)
    digest = hashlib.sha256(payload.encode()).hexdigest()
    return json.dumps({"schema": _SCHEMA_VERSION, "sha256": digest,
                       "plan": json.loads(payload)})


def _graph_blob(graph: Graph) -> bytes:
    payload = pickle.dumps(graph)
    return _GRAPH_MAGIC + hashlib.sha256(payload).digest() + payload


class CacheIntegrityError(ValueError):
    """An on-disk entry failed its schema or checksum guard."""


def _read_plan(path: Path) -> CachePlan:
    """Parse + verify a plan envelope; raises on any integrity failure
    (missing file raises FileNotFoundError, a plain miss)."""
    blob = path.read_bytes()
    try:
        env = json.loads(blob.decode("utf-8"))
    except (ValueError, UnicodeDecodeError) as e:
        raise CacheIntegrityError(f"unparseable JSON ({e})") from None
    if not isinstance(env, dict) or "plan" not in env:
        raise CacheIntegrityError("not a plan envelope")
    if env.get("schema") != _SCHEMA_VERSION:
        raise CacheIntegrityError(
            f"schema {env.get('schema')!r} != {_SCHEMA_VERSION}")
    payload = json.dumps(env["plan"], sort_keys=True)
    digest = hashlib.sha256(payload.encode()).hexdigest()
    if digest != env.get("sha256"):
        raise CacheIntegrityError("checksum mismatch (corrupt/truncated)")
    try:
        return CachePlan.from_json(env["plan"])
    except (KeyError, TypeError, ValueError) as e:
        raise CacheIntegrityError(f"malformed plan ({e})") from None


def _read_graph(path: Path) -> Graph:
    blob = path.read_bytes()
    head = len(_GRAPH_MAGIC) + 32
    if len(blob) < head or not blob.startswith(_GRAPH_MAGIC):
        raise CacheIntegrityError("graph pickle missing integrity header")
    digest, payload = blob[len(_GRAPH_MAGIC):head], blob[head:]
    if hashlib.sha256(payload).digest() != digest:
        raise CacheIntegrityError(
            "graph checksum mismatch (corrupt/truncated)")
    return pickle.loads(payload)


class KernelCache:
    def __init__(self, root: Optional[os.PathLike] = None,
                 disk: bool = True,
                 max_disk_bytes: Optional[int] = None):
        if root is None:
            # shared with core/calibrate.py: calibration profiles live
            # under <root>/calibration/, next to the plans they tune
            from repro.core.calibrate import default_cache_root
            root = default_cache_root()
        if max_disk_bytes is None:
            max_disk_bytes = int(os.environ.get(
                "REPRO_KERNEL_CACHE_MAX_BYTES", DEFAULT_MAX_DISK_BYTES))
        self.root = Path(root)
        self.disk = disk
        self.max_disk_bytes = max_disk_bytes
        self._kernels: Dict[CacheKey, Any] = {}
        self.stats = CacheStats()
        self._health = None
        self.recover()

    @property
    def health(self):
        """This cache's :class:`resilience.HealthLedger` — breaker state
        for compile rungs, persisted under ``<root>/health/`` (memory-
        only for ``disk=False`` caches).  Built lazily and performs zero
        I/O until a rung actually fails."""
        if self._health is None:
            from repro import resilience as RZ
            self._health = RZ.HealthLedger(
                self.root / "health" if self.disk else None)
        return self._health

    # -- startup crash recovery --------------------------------------------
    def recover(self) -> None:
        """Crash-recovery sweep, run once per cache construction:

        * remove orphaned ``*.{pid}.tmp`` files left by writers that
          died between open and rename (dead pid, or over-age as the
          cross-host fallback where the pid namespace differs);
        * remove a stale ``.lock`` that no live process holds (flock
          acquirable) once it is over-age;
        * cap ``<root>/quarantine/`` at ``$REPRO_QUARANTINE_MAX_BYTES``
          (oldest-first) so triage copies cannot grow without bound.

        Every action is counted (``recovered_tmp`` / ``stale_locks`` /
        ``quarantine_evicted``) and warned — never silent."""
        if not self.disk:
            return
        try:
            if not self.root.is_dir():
                return
        except OSError:
            return
        now = time.time()
        for d in (self.root, self.root / "health"):
            try:
                tmps = sorted(d.glob("*.tmp"))
            except OSError:
                continue
            for tmp in tmps:
                pid = _tmp_writer_pid(tmp.name)
                if pid is not None and pid != os.getpid() \
                        and not _pid_alive(pid):
                    orphan = True
                else:
                    # our own pid, a live writer, or an unparseable name:
                    # only reclaim once clearly abandoned by age
                    try:
                        orphan = now - tmp.stat().st_mtime > STALE_TMP_AGE_S
                    except OSError:
                        continue
                if not orphan:
                    continue
                try:
                    tmp.unlink()
                except OSError:
                    continue
                self.stats.recovered_tmp += 1
                warnings.warn(
                    f"kernel cache: recovered orphaned tmp file {tmp} "
                    f"(writer pid {pid} is gone)", RuntimeWarning,
                    stacklevel=2)
        self._sweep_stale_lock(now)
        self._cap_quarantine()

    def _sweep_stale_lock(self, now: float) -> None:
        lock = self.root / ".lock"
        try:
            age = now - lock.stat().st_mtime
        except OSError:
            return
        if age <= STALE_LOCK_AGE_S:
            return
        try:
            import fcntl
            fd = os.open(str(lock), os.O_RDWR)
        except (ImportError, OSError):
            return
        try:
            try:
                # acquirable => no live writer holds it => genuinely stale
                fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
            except OSError:
                return  # held by a live process: not stale
            try:
                lock.unlink()
            except OSError:
                return
            self.stats.stale_locks += 1
            warnings.warn(
                f"kernel cache: removed stale lock {lock} "
                f"(unheld, {age:.0f}s old)", RuntimeWarning, stacklevel=3)
        finally:
            os.close(fd)

    def _cap_quarantine(self) -> int:
        budget = int(os.environ.get("REPRO_QUARANTINE_MAX_BYTES",
                                    DEFAULT_QUARANTINE_MAX_BYTES))
        if budget < 0:
            return 0  # negative budget disables the cap
        try:
            files = [(p, p.stat()) for p in self.quarantine_dir.iterdir()
                     if p.is_file()]
        except OSError:
            return 0
        total = sum(st.st_size for _, st in files)
        evicted = 0
        for p, st in sorted(files, key=lambda e: e[1].st_mtime):
            if total <= budget:
                break
            try:
                p.unlink()
            except OSError:
                continue
            total -= st.st_size
            evicted += 1
        if evicted:
            self.stats.quarantine_evicted += evicted
            warnings.warn(
                f"kernel cache: evicted {evicted} oldest quarantine "
                f"file(s) over the {budget}-byte budget", RuntimeWarning,
                stacklevel=3)
        return evicted

    # -- in-process level ---------------------------------------------------
    def get_kernel(self, key: CacheKey):
        k = self._kernels.get(key)
        if k is not None:
            self.stats.memory_hits += 1
        return k

    def put_kernel(self, key: CacheKey, kernel) -> None:
        self._kernels[key] = kernel

    # -- on-disk level ------------------------------------------------------
    def _paths(self, key: CacheKey) -> Tuple[Path, Path]:
        d = key.digest()
        return self.root / f"{d}.json", self.root / f"{d}.graph.pkl"

    @property
    def quarantine_dir(self) -> Path:
        return self.root / "quarantine"

    def quarantine(self, path: Path, reason: str) -> None:
        """Move a corrupt artifact aside for triage (never silently
        delete it) and count it; falls back to unlink if the move
        itself fails."""
        qdir = self.quarantine_dir
        try:
            qdir.mkdir(parents=True, exist_ok=True)
            path.replace(qdir / path.name)
        except OSError:
            try:
                path.unlink()
            except OSError as e:
                self.stats.io_errors += 1
                warnings.warn(
                    f"kernel cache: could not quarantine OR remove "
                    f"corrupt entry {path} ({e}); it will be re-read",
                    RuntimeWarning, stacklevel=3)
                return
        self.stats.quarantined += 1
        warnings.warn(
            f"kernel cache: quarantined corrupt entry {path} -> "
            f"{qdir / path.name} ({reason})", RuntimeWarning, stacklevel=3)
        self._cap_quarantine()  # keep triage copies under the byte budget

    def get_plan(self, key: CacheKey
                 ) -> Tuple[Optional[CachePlan], Optional[Graph]]:
        """Returns (plan, selected_graph); graph may be None (plan-only).
        A corrupt/truncated/stale-schema entry is quarantined, counted,
        and treated as a miss — never silently swallowed."""
        if not self.disk:
            return None, None
        pj, pg = self._paths(key)
        # fault injection (tests/chaos CI): genuinely garble the on-disk
        # entry so the REAL integrity machinery below detects it
        from repro import resilience as RZ
        spec = RZ.fire("cache:get_plan")
        if spec is not None and spec.kind == "corrupt" and pj.exists():
            blob = pj.read_bytes()
            pj.write_bytes(blob[:max(len(blob) // 2, 1)] + b"\xff{corrupt")
        try:
            plan = _read_plan(pj)
        except FileNotFoundError:
            return None, None
        except CacheIntegrityError as e:
            self.stats.corrupt_plans += 1
            warnings.warn(f"kernel cache: corrupt plan {pj}: {e}",
                          RuntimeWarning, stacklevel=2)
            self.quarantine(pj, str(e))
            if pg.exists():  # its paired graph describes a dead plan
                self.quarantine(pg, "paired with corrupt plan")
            return None, None
        except OSError as e:
            self.stats.io_errors += 1
            warnings.warn(f"kernel cache: unreadable plan {pj}: {e}",
                          RuntimeWarning, stacklevel=2)
            return None, None
        graph: Optional[Graph] = None
        try:
            graph = _read_graph(pg)
        except FileNotFoundError:
            graph = None  # plan-only entry: expected, not an error
        except (CacheIntegrityError, pickle.PickleError, AttributeError,
                ImportError, EOFError, IndexError) as e:
            self.stats.corrupt_graphs += 1
            warnings.warn(f"kernel cache: corrupt graph {pg}: {e} "
                          "(degrading to plan-only entry)",
                          RuntimeWarning, stacklevel=2)
            self.quarantine(pg, str(e))
        except OSError as e:
            self.stats.io_errors += 1
            warnings.warn(f"kernel cache: unreadable graph {pg}: {e}",
                          RuntimeWarning, stacklevel=2)
        for path in (pj, pg):  # LRU touch: a hit is recent use
            try:
                os.utime(path)
            except OSError:
                self.stats.io_errors += 1  # missing graph lands here; fine
        self.stats.disk_hits += 1
        return plan, graph

    def _lock(self):
        """Best-effort inter-process write lock (<root>/.lock).  Returns
        a context manager; a no-op where flock is unavailable."""
        root = self.root

        class _Lock:
            def __enter__(self):
                self.fd = None
                try:
                    import fcntl
                    root.mkdir(parents=True, exist_ok=True)
                    self.fd = os.open(str(root / ".lock"),
                                      os.O_CREAT | os.O_RDWR)
                    fcntl.flock(self.fd, fcntl.LOCK_EX)
                except (ImportError, OSError):
                    if self.fd is not None:
                        os.close(self.fd)
                        self.fd = None
                return self

            def __exit__(self, *exc):
                if self.fd is not None:
                    try:
                        import fcntl
                        fcntl.flock(self.fd, fcntl.LOCK_UN)
                    except (ImportError, OSError):
                        pass
                    os.close(self.fd)
                return False

        return _Lock()

    @staticmethod
    def _atomic_write(path: Path, data: bytes) -> None:
        """Crash-safe write: unique temp file (no cross-process tmp-name
        collisions) + fsync + atomic rename."""
        tmp = path.parent / f"{path.name}.{os.getpid()}.tmp"
        fd = os.open(str(tmp), os.O_CREAT | os.O_WRONLY | os.O_TRUNC)
        try:
            os.write(fd, data)
            os.fsync(fd)
        finally:
            os.close(fd)
        tmp.replace(path)

    def put_plan(self, key: CacheKey, plan: CachePlan,
                 graph: Optional[Graph]) -> None:
        # a fresh plan is a compile-path miss whether or not it persists
        # (disk=False caches still feed the serving recompile counters)
        self.stats.misses += 1
        if not self.disk:
            return
        pj, pg = self._paths(key)
        with self._lock():
            try:
                self.root.mkdir(parents=True, exist_ok=True)
                self._atomic_write(pj, _plan_envelope(plan).encode())
            except OSError as e:
                self.stats.write_errors += 1
                warnings.warn(f"kernel cache: failed to write plan {pj}: "
                              f"{e} (entry not cached)",
                              RuntimeWarning, stacklevel=2)
                return
            if graph is not None:
                try:
                    self._atomic_write(pg, _graph_blob(graph))
                except (OSError, pickle.PickleError, TypeError,
                        AttributeError) as e:
                    # plan-only entry: fusion reruns on a disk hit.
                    # Un-picklable MiscNode closures land here routinely,
                    # so count + warn but keep the plan
                    self.stats.write_errors += 1
                    warnings.warn(
                        f"kernel cache: failed to write graph {pg}: {e} "
                        "(plan-only entry; fusion reruns on hit)",
                        RuntimeWarning, stacklevel=2)
            self.evict()

    # -- eviction -----------------------------------------------------------
    def disk_entries(self) -> List[Tuple[str, float, int]]:
        """(digest, last-use mtime, total bytes) per on-disk entry."""
        out = []
        try:
            plans = sorted(self.root.glob("*.json"))
        except OSError as e:
            self.stats.io_errors += 1
            warnings.warn(f"kernel cache: cannot scan {self.root}: {e}",
                          RuntimeWarning, stacklevel=2)
            return []
        for pj in plans:
            digest = pj.name[:-len(".json")]
            mtime, size = 0.0, 0
            for path in (pj, self.root / f"{digest}.graph.pkl"):
                try:
                    st = path.stat()
                except OSError:
                    continue  # unpaired graph / racing eviction: normal
                mtime = max(mtime, st.st_mtime)
                size += st.st_size
            out.append((digest, mtime, size))
        return out

    def evict(self) -> int:
        """Delete least-recently-used on-disk entries until the cache
        fits ``max_disk_bytes``.  Returns the number of entries evicted;
        a non-positive cap disables eviction."""
        if not self.disk or self.max_disk_bytes <= 0:
            return 0
        entries = self.disk_entries()
        total = sum(size for _, _, size in entries)
        evicted = 0
        for digest, _, size in sorted(entries, key=lambda e: e[1]):
            if total <= self.max_disk_bytes:
                break
            for path in (self.root / f"{digest}.json",
                         self.root / f"{digest}.graph.pkl"):
                try:
                    path.unlink()
                except FileNotFoundError:
                    pass  # plan-only entry / concurrent eviction
                except OSError as e:
                    self.stats.evict_errors += 1
                    warnings.warn(
                        f"kernel cache: failed to evict {path}: {e}",
                        RuntimeWarning, stacklevel=2)
            total -= size
            evicted += 1
        return evicted

    def clear_memory(self) -> None:
        self._kernels.clear()


_DEFAULT: Optional[KernelCache] = None


def default_cache() -> KernelCache:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = KernelCache()
    return _DEFAULT


def reset_default_cache() -> None:
    """Drop the process-wide cache object (tests)."""
    global _DEFAULT
    _DEFAULT = None
