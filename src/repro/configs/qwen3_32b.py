"""qwen3-32b [dense]: qk_norm, GQA.  [hf:Qwen/Qwen3-32B family]"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=25600,
    vocab=151936,
    rope_theta=1e6,
    qk_norm=True,
)
