"""Auto-emitted Pallas kernels from fusion-derived block programs:
array program -> Table-2 expansion -> the 9 rules -> emit() -> pallas_call.

This closes the loop the paper opens: the fusion algorithm's output is not
just analyzed but *executed as a TPU kernel* (interpret mode on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import array_program as AP
from repro.core.codegen_pallas import emit
from repro.core.fusion import fuse


def test_attention_kernel_autogen(rng):
    dims = {"M": 2, "D": 2, "N": 4, "L": 2}
    blocks = {"M": 8, "D": 16, "N": 8, "L": 16}
    fused = fuse(AP.attention_program(scale=0.125))[-1]
    f = emit(fused, dims, blocks, interpret=True)
    Q = rng.normal(size=(16, 32)).astype(np.float32) * 0.5
    K = rng.normal(size=(32, 32)).astype(np.float32) * 0.5
    V = rng.normal(size=(32, 32)).astype(np.float32)
    out = f(jnp.asarray(Q), jnp.asarray(K), jnp.asarray(V.T))
    S = (Q @ K.T) * 0.125
    P = np.exp(S)
    ref = (P / P.sum(1, keepdims=True)) @ V
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5, rtol=2e-5)


def test_layernorm_matmul_kernel_autogen(rng):
    dims = {"M": 2, "K": 4, "N": 2}
    blocks = {"M": 8, "K": 8, "N": 16}
    KK = dims["K"] * blocks["K"]
    fused = fuse(AP.layernorm_matmul_program(float(KK)))[-1]
    f = emit(fused, dims, blocks, interpret=True)
    X = rng.normal(size=(16, KK)).astype(np.float32)
    Y = rng.normal(size=(KK, 32)).astype(np.float32)
    out = f(jnp.asarray(X), jnp.asarray(Y.T))
    mu = X.mean(1, keepdims=True)
    sd = np.sqrt((X ** 2).mean(1, keepdims=True) - mu ** 2)
    ref = ((X - mu) / sd) @ Y
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)


def test_rmsnorm_swiglu_kernel_autogen(rng):
    dims = {"M": 2, "D": 2, "K": 4, "N": 2}
    blocks = {"M": 8, "D": 16, "K": 8, "N": 8}
    DD = dims["D"] * blocks["D"]
    fused = fuse(AP.rmsnorm_ffn_swiglu_program(float(DD)))[-1]
    f = emit(fused, dims, blocks, interpret=True)
    X = rng.normal(size=(16, DD)).astype(np.float32)
    W = (rng.normal(size=(DD, 32)) / np.sqrt(DD)).astype(np.float32)
    V = (rng.normal(size=(DD, 32)) / np.sqrt(DD)).astype(np.float32)
    U = (rng.normal(size=(32, 16)) / np.sqrt(32)).astype(np.float32)
    out = f(jnp.asarray(X), jnp.asarray(W.T), jnp.asarray(V.T),
            jnp.asarray(U.T))
    xn = X / np.sqrt((X ** 2).mean(1, keepdims=True))
    gsw = xn @ W
    ref = ((gsw / (1 + np.exp(-gsw))) * (xn @ V)) @ U
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)
