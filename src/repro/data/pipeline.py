"""Deterministic, restart-safe synthetic LM data pipeline.

Batches are a pure function of (seed, step): after a failure/restart the
pipeline replays exactly, which is what makes checkpoint-resume bitwise
reproducible (tested in test_train_integration.py).  Tokens follow a
skewed (zipf-ish) distribution with short-range structure so the loss
actually decreases — good enough to validate optimization end to end.

On a multi-host pod each process feeds its addressable shard of the batch
(``host_slice``); under single-process SPMD (this container and the
dry-run) the full batch is produced and jit moves shards to devices.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, jax.Array]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s = self.global_batch, self.seq_len
        # zipf-ish marginals + markov-ish structure: next token depends on
        # previous token half the time
        base = rng.zipf(1.5, size=(b, s + 1)) % self.vocab
        prev = np.roll(base, 1, axis=1)
        mix = rng.random((b, s + 1)) < 0.5
        toks = np.where(mix, (prev * 7 + 3) % self.vocab, base)
        toks = toks.astype(np.int32)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:])}

    def host_slice(self, step: int, process_index: int,
                   process_count: int) -> Dict[str, jax.Array]:
        full = self.batch(step)
        per = self.global_batch // process_count
        sl = slice(process_index * per, (process_index + 1) * per)
        return {k: v[sl] for k, v in full.items()}
