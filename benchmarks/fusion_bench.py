"""One benchmark per paper example (the paper's results are its three
worked examples): global-memory traffic before/after fusion, kernel-launch
counts, work replication across snapshots, and fusion-algorithm runtime.

``run_pipeline`` additionally *executes* each example through
``pipeline.compile`` on the jax backend — fused vs unfused wall time
(speedup) next to the cost model's predicted traffic, from the same
driver the model layers use — and closes the calibration loop: each
Pallas region kernel of the selected snapshot is timed standalone
(``core/timing.region_times``), the per-region wall times are paired
with the cost model's per-region traffic attribution (rank agreement is
reported as ``region_spearman``), and a measured
``calibrate.CalibrationProfile`` is fitted from all collected
(features, seconds) samples and saved to the cache dir (the
``calibration_profile`` summary row).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import array_program as AP
from repro.core import cost as C
from repro.core.fusion import FusionTrace, fuse

# representative block sizes (bytes): 128x128 f32 blocks, 128 f32 vectors
ITEM_BYTES = {"block": 128 * 128 * 4, "vector": 128 * 4, "scalar": 4}

# the five in-repo example programs
EXAMPLES = {
    "attention": (lambda: AP.attention_program(0.125),
                  {"M": 8, "D": 4, "N": 16, "L": 4}),
    # decoder prefill: M == N tile the same sequence; the mask-aware cost
    # model skips fully-masked tiles, so predicted traffic is ~(N+1)/2N
    # of the non-causal program's
    "causal_attention": (lambda: AP.causal_attention_program(0.125),
                         {"M": 16, "D": 4, "N": 16, "L": 4}),
    # grouped-query decoder attention: head-group dim H is a stack axis
    "gqa_attention": (lambda: AP.gqa_attention_program(0.125, causal=True),
                      {"H": 2, "M": 8, "D": 4, "N": 8, "L": 4}),
    "layernorm_matmul": (lambda: AP.layernorm_matmul_program(512.0),
                         {"M": 8, "K": 16, "N": 8}),
    "rmsnorm_ffn_swiglu": (lambda: AP.rmsnorm_ffn_swiglu_program(512.0),
                           {"M": 8, "D": 8, "K": 16, "N": 8}),
}

# the tiny fixed configuration CI's bench job runs (block size 8,
# 2 repeats): small enough for an ubuntu runner, same programs, and the
# derived values the regression gate compares (predicted traffic
# reduction, pallas region/fallback counts) are deterministic
CI_EXAMPLES = {
    # L (the key-block grid dim of the softmax+PV region) is kept well
    # above the other extents so the two attention regions' grid-cell
    # counts are decisively asymmetric: at L == 2 their measured times
    # tie within runner noise and the pinned region_spearman flips sign
    # run-to-run
    "attention": (lambda: AP.attention_program(0.125),
                  {"M": 2, "D": 2, "N": 4, "L": 8}),
    "causal_attention": (lambda: AP.causal_attention_program(0.125),
                         {"M": 4, "D": 2, "N": 4, "L": 8}),
    "gqa_attention": (lambda: AP.gqa_attention_program(0.25, causal=True),
                      {"H": 2, "M": 2, "D": 2, "N": 2, "L": 8}),
    "layernorm_matmul": (lambda: AP.layernorm_matmul_program(64.0),
                         {"M": 2, "K": 4, "N": 2}),
    "rmsnorm_ffn_swiglu": (lambda: AP.rmsnorm_ffn_swiglu_program(64.0),
                           {"M": 2, "D": 2, "K": 4, "N": 2}),
}

# (examples, wall repeats, block size): the tiny ci preset needs MANY
# repeats and non-trivial block extents — sub-ms calls are
# dispatch-noise dominated, and the fused/unfused speedup ratio is now
# a (generously, in aggregate) gated key
PRESETS = {"full": (EXAMPLES, 7, 16), "ci": (CI_EXAMPLES, 30, 16)}


def bench_example(name: str) -> List[Dict]:
    build, dims = EXAMPLES[name]
    g = build()
    t0 = time.perf_counter()
    trace = FusionTrace()
    snaps = fuse(g, trace)
    fuse_us = (time.perf_counter() - t0) * 1e6

    t_init = C.traffic(g, dims)
    rows = []
    init_bytes = t_init.bytes_moved(ITEM_BYTES)
    for i, s in enumerate(snaps):
        t = C.traffic(s, dims)
        rows.append({
            "name": f"fusion_{name}_snap{i}",
            "us_per_call": fuse_us,
            "derived": (
                f"traffic_bytes={t.bytes_moved(ITEM_BYTES)};"
                f"traffic_reduction={init_bytes / max(t.bytes_moved(ITEM_BYTES), 1):.2f}x;"
                f"stores={sum(t.stores.values())};"
                f"loads={sum(t.loads.values())};"
                f"launches={t_init.launches}->{t.launches};"
                f"work_factor={sum(t.work.values()) / max(sum(t_init.work.values()), 1):.2f};"
                f"rule_applications={len(trace.steps)}"
            ),
        })
    return rows


def bench_pipeline_example(name: str, repeats: int = 5, bs: int = 16,
                           examples: Dict = None,
                           samples: Optional[List[Dict]] = None,
                           lowering_reports: Optional[Dict] = None
                           ) -> List[Dict]:
    """Fused vs unfused wall time through ``pipeline.compile`` (jax
    backend), with the cost model's predicted traffic side by side, plus
    the Pallas lowering of the selected snapshot: the grouped megakernel
    schedule (``launches``/``resident_edges``/``grouped_cost`` — the CI
    gate pins launches and fallbacks) next to the per-region breakdown.
    Both the grouped kernels and the ungrouped per-region kernels are
    timed standalone and paired with their cost attributions *by kernel
    id* (``group_spearman``/``region_spearman`` are the rank
    agreements); every raw (traffic features, seconds) pair is appended
    to ``samples`` for the profile fit, and the lowering report is
    recorded in ``lowering_reports`` (the CI artifact)."""
    from repro import pipeline
    from repro.core import calibrate as CAL
    from repro.core import timing as T

    build, dims = (examples or EXAMPLES)[name]
    g = build()
    blocks = T.synth_blocks(g, dims, item=bs)
    inputs = T.synth_inputs(g, dims, blocks, seed=0)
    cache = pipeline.KernelCache(disk=False)

    def timed(kern) -> float:
        # median, not best-of: the gated speedup ratio must be robust
        # to scheduler noise on shared runners
        return T.time_callable(kern, inputs, warmup=1,
                               repeats=repeats).median_s * 1e6

    jopt = pipeline.CompileOptions(backend="jax", blocks=blocks)
    kf = pipeline.compile(g, dims, options=jopt, cache=cache)
    # the unfused baseline is jitted PER OPERATOR (launch per top-level
    # op, intermediates materialized between launches) — the paper's
    # actual baseline.  Whole-program jit here would hand the unfused
    # graph to XLA, which fuses it itself, and "speedup" would compare
    # our fusion against XLA's instead of against no fusion (that made
    # the pinned ratio dip below 1.0x on several rows).
    ku = pipeline.compile(
        g, dims, options=jopt.replace(fused=False, jit="per-op"),
        cache=cache)
    fused_us, unfused_us = timed(kf), timed(ku)
    # the second compile must be an in-process cache hit
    rehit = pipeline.compile(g, dims, options=jopt,
                             cache=cache).cache_hit
    # Pallas lowering of the SAME selected snapshot: the grouped
    # megakernel schedule (what actually runs) and, for calibration
    # sample diversity, the ungrouped per-region schedule
    popt = pipeline.CompileOptions(backend="pallas", blocks=blocks,
                                   interpret=True)
    kp = pipeline.compile(g, dims, options=popt, cache=cache)
    kpr = pipeline.compile(g, dims, options=popt.replace(group=False),
                           cache=cache)
    rep = kp.lowering_report
    if lowering_reports is not None:
        lowering_reports[name] = {
            "launches": rep.launches,
            "resident_edges": rep.resident_edges,
            "regions": rep.n_regions,
            "fallbacks": rep.fallbacks,
            "kernel_ids": list(kp.kernel_ids or ()),
            "summary": rep.summary(),
        }
    extra = ""
    # per-row rank agreement is computed AFTER the profile fit (the
    # calibrated model is what selection/autotune actually rank with),
    # so each row only collects its (features, seconds) pairs here;
    # ``run_pipeline`` injects {group,region}_spearman post-fit
    pairs: Dict[str, List] = {"group": [], "region": []}
    # kernels run in interpret mode off-TPU (hundreds of ms): a handful
    # of repeats is enough and keeps the bench under a minute
    t_reps = min(5, max(2, repeats // 2))
    gts = T.region_times(kp, inputs, warmup=1, repeats=t_reps)
    gpaired = T.pair_region_times(kp, gts or [])
    if gpaired:
        extra += ("kernel_times_us="
                  + "/".join(f"{s * 1e6:.0f}" for _, _, s in gpaired)
                  + ";")
        gfp = T.pair_region_features(
            gts or [], CAL.group_features(kp.graph, dims, blocks) or ())
        pairs["group"] = [(f, s) for _, f, s in gfp]
        if samples is not None:
            for gid, f, s in gfp:
                samples.append({"program": name, "kernel": gid,
                                "features": f, "seconds": s})
    rts = T.region_times(kpr, inputs, warmup=1, repeats=t_reps)
    rpaired = T.pair_region_times(kpr, rts or [])
    feats = CAL.region_features(kpr.graph, dims)
    if rpaired:
        extra += ("region_times_us="
                  + "/".join(f"{s * 1e6:.0f}" for _, _, s in rpaired)
                  + ";")
        if feats and len(feats) == len(rpaired):
            pairs["region"] = [(f, s) for f, (_, _, s)
                               in zip(feats, rpaired)]
            if samples is not None:
                for f, (gid, _, s) in zip(feats, rpaired):
                    samples.append({"program": name, "kernel": gid,
                                    "features": f, "seconds": s})
    return [{
        "name": f"pipeline_{name}",
        "us_per_call": fused_us,
        "_pairs": pairs,
        "derived": (
            f"unfused_us={unfused_us:.1f};"
            f"speedup={unfused_us / max(fused_us, 1e-9):.2f}x;"
            f"pred_cost_fused={kf.cost:.3g};"
            f"pred_cost_unfused={kf.initial_cost:.3g};"
            f"pred_traffic_reduction={kf.predicted_traffic_reduction:.2f}x;"
            f"snapshot={kf.snapshot_index};recompile_hit={rehit};"
            f"pallas_regions={rep.n_regions};"
            f"pallas_fallbacks={rep.fallbacks};"
            f"launches={rep.launches};"
            f"resident_edges={rep.resident_edges};"
            + (f"grouped_cost={kp.grouped_cost:.3g};"
               if kp.grouped_cost is not None else "")
            + extra
        ).rstrip(";"),
    }]


def _calibration_row(samples: List[Dict],
                     profile_out: Optional[str] = None) -> Dict:
    """Fit a measured profile from every collected (features, seconds)
    region sample, persist it (cache dir + optional explicit path), and
    summarize the fit — including the pooled predicted-vs-measured rank
    agreement of the *calibrated* model, the calibration acceptance
    metric.  Returns ``(summary row, fitted profile)`` so the caller
    can score per-row rank agreement under the same profile."""
    import json

    from repro.core import calibrate as CAL
    from repro.core import timing as T

    dev = CAL.device_kind().replace(",", "-").replace(";", "-")
    prof = CAL.fit_profile([s["features"] for s in samples],
                           [s["seconds"] for s in samples],
                           backend="pallas", device_kind=dev)
    pred = [prof.predict(s["features"]) for s in samples]
    meas = [s["seconds"] for s in samples]
    pooled = T.spearman(pred, meas)
    path = CAL.save_profile(prof)
    if profile_out:
        with open(profile_out, "w") as f:
            json.dump(prof.to_json(), f, indent=2)
    coefs = ";".join(f"{k}_coef={prof.item_coef[k]:.3g}"
                     for k in sorted(prof.item_coef))
    work = ";".join(f"work_{k}_coef={prof.work_coef[k]:.3g}"
                    for k in sorted(prof.work_coef))
    row = {
        "name": "calibration_profile",
        "us_per_call": float(np.median(meas)) * 1e6,
        "derived": (
            f"backend={prof.backend};device={dev};"
            f"n_samples={prof.n_samples};residual={prof.residual:.3f};"
            f"pooled_spearman={pooled:.2f};{coefs};{work};"
            f"launch_coef={prof.launch_coef:.3g};saved={path}"
        ),
    }
    return row, prof


def run_pipeline(preset: str = "full",
                 profile_out: Optional[str] = None,
                 lowering_out: Optional[str] = None) -> List[Dict]:
    from repro.core import calibrate as CAL
    from repro.core import timing as T

    examples, repeats, bs = PRESETS[preset]
    rows: List[Dict] = []
    samples: List[Dict] = []
    reports: Dict[str, Dict] = {}
    for name in examples:
        rows.extend(bench_pipeline_example(name, repeats=repeats, bs=bs,
                                           examples=examples,
                                           samples=samples,
                                           lowering_reports=reports))
    prof = CAL.DEFAULT_PROFILE
    if samples:
        cal_row, prof = _calibration_row(samples, profile_out)
    # per-row rank agreement under the CALIBRATED model (the one the
    # measured autotune path actually ranks with): predicted cost of
    # each kernel's feature row vs its measured seconds
    for row in rows:
        pairs = row.pop("_pairs", None)
        if not pairs:
            continue
        for kind in ("group", "region"):
            ps = pairs.get(kind) or []
            if ps:
                sp = T.spearman([prof.predict(f) for f, _ in ps],
                                [s for _, s in ps])
                row["derived"] += f";{kind}_spearman={sp:.2f}"
    if samples:
        rows.append(cal_row)
    if lowering_out:
        import json
        with open(lowering_out, "w") as f:
            json.dump({"preset": preset, "programs": reports}, f,
                      indent=2)
            f.write("\n")
    return rows


def run() -> List[Dict]:
    """Traffic-model rows only (the original entry point); executing
    pipeline rows are a separate section: ``run_pipeline``."""
    rows = []
    for name in EXAMPLES:
        rows.extend(bench_example(name))
    return rows
