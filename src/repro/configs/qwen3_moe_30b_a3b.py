"""qwen3-moe-30b-a3b [moe]: 128 experts top-8, qk_norm GQA.
[hf:Qwen/Qwen3-30B-A3B]"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=6144,             # unused (no dense layers)
    vocab=151936,
    rope_theta=1e6,
    qk_norm=True,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    n_shared_experts=0,
    n_dense_layers=0,
)
