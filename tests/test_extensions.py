"""Beyond-paper extensions: candidate selection stand-in, int8 gradient
compression with error feedback, distributed flash-decode (the appendix's
significand-exponent combine across chips)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import array_program as AP
from repro.core.selection import autotune, select


def test_selection_picks_cheapest_snapshot():
    g = AP.rmsnorm_ffn_swiglu_program(64.0)
    dims = {"M": 4, "D": 4, "K": 8, "N": 4}
    sel = select(g, dims)
    assert sel.cost == min(sel.costs)
    assert len(sel.costs) == 3  # paper Example 3 produces 3 snapshots


def test_autotune_degenerate_counts_kill_replication():
    """The paper's epilogue: with N=1 (or K=1) the Rule-6 replication
    disappears, so the autotuner should never pay more than the N>1
    configs at equal block budget."""
    g = AP.attention_program(0.125)
    best = autotune(g, {"M": [4], "D": [1, 2], "N": [4], "L": [1, 4]})
    assert best.dims["L"] == 1  # L=1 removes the L-map replication


def test_int8_roundtrip_error_small():
    from repro.optim.compression import compress_roundtrip_error
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    assert compress_roundtrip_error(x) < 0.01


def test_compressed_psum_with_error_feedback():
    """Across multiple devices (forced host platform), the compressed mean
    matches the exact mean closely, and error feedback pushes the *running
    average* of the compressed stream toward exactness."""
    if jax.device_count() < 4:
        pytest.skip("needs multi-device (run in the dryrun env)")
    from jax.sharding import Mesh
    from repro.optim.compression import compressed_psum_mean
    mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4,), ("data",))
    rng = np.random.default_rng(0)
    g_true = []
    errors = None
    acc_exact = jnp.zeros((4, 256))
    acc_comp = jnp.zeros((4, 256))
    for step in range(8):
        grads = {"w": jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)}
        exact = grads["w"].mean(axis=0, keepdims=True)
        synced, errors = compressed_psum_mean(grads, mesh, ("data",),
                                              errors)
        acc_exact += jnp.broadcast_to(exact, (4, 256))
        acc_comp += synced["w"]
        rel = float(jnp.linalg.norm(synced["w"][0] - exact[0])
                    / jnp.linalg.norm(exact[0]))
        assert rel < 0.05
    drift = float(jnp.linalg.norm(acc_comp - acc_exact)
                  / jnp.linalg.norm(acc_exact))
    assert drift < 0.02  # error feedback keeps accumulated bias tiny


def test_distributed_flash_decode_matches_single_device():
    if jax.device_count() < 4:
        pytest.skip("needs multi-device (run in the dryrun env)")
    from jax.sharding import Mesh
    from repro.kernels.ref import attention_ref
    from repro.runtime.collectives import distributed_decode_attention
    mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4,), ("data",))
    rng = np.random.default_rng(0)
    b, h, hkv, s, dh = 2, 4, 2, 64, 32
    pos = 45  # cache filled through position 45
    q = jnp.asarray(rng.normal(size=(b, h, 1, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, dh)), jnp.float32)
    out = distributed_decode_attention(q, k, v, pos, mesh)
    ref = attention_ref(q, k[:, :, :pos + 1], v[:, :, :pos + 1])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
