"""Continuous-batching serving example: an open-loop request trace
through the slot scheduler + ragged pipeline decode (reduced configs on
CPU).  Attention-family archs only — padded bucket prefill is exact
under causal masking; SSM/hybrid state scans would carry pad state.

    PYTHONPATH=src python examples/serve_lm.py --arch smollm-135m
    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v3-671b
"""

import argparse

from repro.launch import serve as S

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--sampling", default="greedy",
                    choices=("greedy", "categorical"))
    args = ap.parse_args()
    S.main(["--arch", args.arch, "--n-requests", str(args.n_requests),
            "--sampling", args.sampling])
