"""``repro.pipeline`` — the end-to-end fusion pipeline.

``compile(graph, dims, backend=...)`` drives the whole paper loop —
fusion algorithm -> snapshot/block-shape selection (traffic cost model)
-> backend codegen — and memoizes the result in a two-level kernel cache
(in-process callables + on-disk compilation plans).  Model layers and
benchmarks execute through this driver; it is the substrate later
scaling work (sharding, batching, serving) compiles through.
"""

from repro.pipeline.cache import (CODEGEN_VERSION, CacheKey, CachePlan,
                                  CacheStats, KernelCache, default_cache,
                                  reset_default_cache)
from repro.pipeline.driver import BACKENDS, CompiledKernel, compile

__all__ = [
    "BACKENDS", "CODEGEN_VERSION", "CacheKey", "CachePlan", "CacheStats",
    "CompiledKernel", "KernelCache", "compile", "default_cache",
    "reset_default_cache",
]
