"""Decoder-only LM assembly for the architecture zoo.

A model is a sequence of *stages*; each stage is a stack of identical
*super-layers* consumed with ``jax.lax.scan`` (so deepseek's 61 layers or
jamba's 72 don't blow up the HLO).  A super-layer is a list of sub-layers
(jamba: 7 mamba + 1 attention per period, alternating MoE).

Sub-layer kinds:  mixer in {attn, mla, mamba, none},
                  mlp   in {swiglu, moe, none}.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.common import (ModelConfig, ParamBuilder, rms_norm,
                                 softmax_xent, stack_layers, stack_specs)


def _remat(fn, cfg):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)
from repro.runtime.sharding import constrain


@dataclass(frozen=True)
class SubLayer:
    mixer: str          # attn | mla | mamba | none
    mlp: str            # swiglu | moe | none
    d_ff: int = 0


@dataclass(frozen=True)
class Stage:
    n: int              # number of stacked super-layers
    subs: Tuple[SubLayer, ...]


def plan_stages(cfg: ModelConfig) -> List[Stage]:
    mixer = "mla" if cfg.use_mla else "attn"
    if cfg.family in ("dense", "vlm"):
        return [Stage(cfg.n_layers, (SubLayer(mixer, "swiglu", cfg.d_ff),))]
    if cfg.family == "moe":
        stages = []
        if cfg.n_dense_layers:
            stages.append(Stage(cfg.n_dense_layers,
                                (SubLayer(mixer, "swiglu", cfg.d_ff),)))
        stages.append(Stage(cfg.n_layers - cfg.n_dense_layers,
                            (SubLayer(mixer, "moe"),)))
        return stages
    if cfg.family == "ssm":
        return [Stage(cfg.n_layers, (SubLayer("mamba", "none"),))]
    if cfg.family == "hybrid":
        period = cfg.attn_period
        assert cfg.n_layers % period == 0
        subs = []
        for j in range(period):
            mix = "attn" if j == period // 2 else "mamba"
            mlp = "moe" if (j % cfg.moe_period == cfg.moe_period - 1) \
                else "swiglu"
            subs.append(SubLayer(mix, mlp, cfg.d_ff))
        return [Stage(cfg.n_layers // period, tuple(subs))]
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# sub-layer init / apply / prefill / decode
# ---------------------------------------------------------------------------

def _init_sublayer(pb: ParamBuilder, cfg: ModelConfig, spec: SubLayer):
    d = cfg.d_model
    if spec.mixer != "none":
        pb.ones("ln1", (d,), (None,))
        sub = pb.sub("mixer")
        if spec.mixer == "attn":
            L.init_attention(sub, cfg)
        elif spec.mixer == "mla":
            L.init_mla(sub, cfg)
        elif spec.mixer == "mamba":
            L.init_mamba(sub, cfg)
    if spec.mlp != "none":
        pb.ones("ln2", (d,), (None,))
        sub = pb.sub("mlp")
        if spec.mlp == "swiglu":
            L.init_swiglu(sub, cfg, spec.d_ff)
        elif spec.mlp == "moe":
            L.init_moe(sub, cfg)


def _apply_sublayer(p, x, cfg: ModelConfig, spec: SubLayer, causal=True):
    if spec.mixer == "attn":
        x = x + L.attention_apply(p["mixer"], rms_norm(x, p["ln1"],
                                                       cfg.norm_eps),
                                  cfg, causal=causal)
    elif spec.mixer == "mla":
        x = x + L.mla_apply(p["mixer"], rms_norm(x, p["ln1"], cfg.norm_eps),
                            cfg, causal=causal)
    elif spec.mixer == "mamba":
        x = x + L.mamba_apply(p["mixer"], x, p["ln1"], cfg)
    if spec.mlp == "swiglu":
        x = x + L.rmsnorm_swiglu_apply(p["mlp"], x, p["ln2"], cfg)
    elif spec.mlp == "moe":
        x = x + L.moe_apply(p["mlp"], x, p["ln2"], cfg)
    return x


def _sub_cache_init(cfg, spec: SubLayer, batch, max_len, dtype):
    if spec.mixer == "attn":
        return L.attention_init_cache(cfg, batch, max_len, dtype)
    if spec.mixer == "mla":
        return L.mla_init_cache(cfg, batch, max_len, dtype)
    if spec.mixer == "mamba":
        return L.mamba_init_cache(cfg, batch, dtype)
    return {}


def _sub_cache_specs(cfg, spec: SubLayer):
    if spec.mixer == "attn":
        return L.attention_cache_specs(cfg)
    if spec.mixer == "mla":
        return L.mla_cache_specs(cfg)
    if spec.mixer == "mamba":
        return L.mamba_cache_specs(cfg)
    return {}


def _decode_sublayer(p, x, cache, pos, cfg, spec: SubLayer):
    if spec.mixer == "attn":
        y, cache = L.attention_decode(
            p["mixer"], rms_norm(x, p["ln1"], cfg.norm_eps), cache, pos, cfg)
        x = x + y
    elif spec.mixer == "mla":
        y, cache = L.mla_decode(
            p["mixer"], rms_norm(x, p["ln1"], cfg.norm_eps), cache, pos, cfg)
        x = x + y
    elif spec.mixer == "mamba":
        y, cache = L.mamba_decode(p["mixer"], x, p["ln1"], cache, cfg)
        x = x + y
    if spec.mlp == "swiglu":
        x = x + L.rmsnorm_swiglu_apply(p["mlp"], x, p["ln2"], cfg)
    elif spec.mlp == "moe":
        x = x + L.moe_apply(p["mlp"], x, p["ln2"], cfg)
    return x, cache


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.stages = plan_stages(cfg)

    # -- params ---------------------------------------------------------------
    def init_params(self, key: jax.Array):
        cfg = self.cfg
        pb = ParamBuilder(key, cfg.dtype)
        pb.dense("embed", (cfg.vocab, cfg.d_model), ("tensor", "fsdp"),
                 scale=0.02)
        for si, stage in enumerate(self.stages):
            reps_p, reps_s = [], None
            for _ in range(stage.n):
                spb = ParamBuilder(pb._split(), cfg.dtype)
                for j, spec in enumerate(stage.subs):
                    b = spb.sub(f"sub{j}")
                    _init_sublayer(b, cfg, spec)
                reps_p.append(spb.params)
                reps_s = spb.specs
            pb.params[f"stage{si}"] = stack_layers(reps_p)
            pb.specs[f"stage{si}"] = stack_specs(reps_s)
        pb.ones("ln_f", (cfg.d_model,), (None,))
        if not cfg.tie_embeddings:
            pb.dense("head", (cfg.d_model, cfg.vocab), ("fsdp", "tensor"),
                     scale=0.02)
        return pb.build()

    # -- forward ----------------------------------------------------------------
    def _embed(self, params, tokens, vision_embeds=None):
        x = params["embed"][tokens].astype(self.cfg.dtype)
        if self.cfg.family == "vlm" and vision_embeds is not None:
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
        return constrain(x, "batch", None, None)

    def _logits(self, params, x):
        x = rms_norm(x, params["ln_f"], self.cfg.norm_eps)
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["head"])
        logits = x @ head
        return constrain(logits, "batch", None, "tensor")

    def forward(self, params, tokens, vision_embeds=None):
        cfg = self.cfg
        x = self._embed(params, tokens, vision_embeds)

        for si, stage in enumerate(self.stages):
            def body(x, lp, stage=stage):
                for j, spec in enumerate(stage.subs):
                    x = _apply_sublayer(lp[f"sub{j}"], x, cfg, spec)
                return x, None

            fn = _remat(body, cfg)
            x, _ = jax.lax.scan(fn, x, params[f"stage{si}"],
                                unroll=stage.n if cfg.unroll_scans else 1)
        return self._logits(params, x)

    def loss(self, params, tokens, labels, vision_embeds=None):
        logits = self.forward(params, tokens, vision_embeds)
        if self.cfg.family == "vlm" and vision_embeds is not None:
            logits = logits[:, vision_embeds.shape[1]:]
        return softmax_xent(logits, labels)

    # -- caches -------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        caches = {}
        for si, stage in enumerate(self.stages):
            def one(_):
                return {f"sub{j}": _sub_cache_init(cfg, spec, batch, max_len,
                                                   cfg.dtype)
                        for j, spec in enumerate(stage.subs)}
            caches[f"stage{si}"] = stack_layers(
                [one(i) for i in range(stage.n)])
        return caches

    def cache_specs(self):
        caches = {}
        for si, stage in enumerate(self.stages):
            spec = {f"sub{j}": _sub_cache_specs(self.cfg, s)
                    for j, s in enumerate(stage.subs)}
            caches[f"stage{si}"] = stack_specs(spec)
        return caches

    # -- decode ---------------------------------------------------------------------
    def decode_step(self, params, caches, tokens, pos):
        """tokens: (B, 1) next input token; pos: filled cache length —
        a scalar (lockstep batch) or a (B,) int vector (ragged
        continuous-batching step, each sequence at its own position)."""
        cfg = self.cfg
        x = self._embed(params, tokens)

        new_caches = {}
        for si, stage in enumerate(self.stages):
            def body(x, inp, stage=stage):
                lp, cache = inp
                new = {}
                for j, spec in enumerate(stage.subs):
                    x, new[f"sub{j}"] = _decode_sublayer(
                        lp[f"sub{j}"], x, cache[f"sub{j}"], pos, cfg, spec)
                return x, new

            x, new_caches[f"stage{si}"] = jax.lax.scan(
                body, x, (params[f"stage{si}"], caches[f"stage{si}"]),
                unroll=stage.n if cfg.unroll_scans else 1)
        return self._logits(params, x), new_caches

    def prefill(self, params, tokens, max_len: Optional[int] = None,
                vision_embeds=None):
        """Run the prompt, returning logits and a cache filled to len(prompt)
        (padded to ``max_len`` for subsequent decode steps)."""
        cfg = self.cfg
        x = self._embed(params, tokens, vision_embeds)
        s = x.shape[1]
        max_len = max_len or s

        caches = {}
        for si, stage in enumerate(self.stages):
            def body(x, lp, stage=stage):
                cache = {}
                for j, spec in enumerate(stage.subs):
                    p = lp[f"sub{j}"]
                    if spec.mixer == "attn":
                        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
                        pos = jnp.arange(s) if cfg.rope_theta > 0 else None
                        q, k, v = L._qkv(p["mixer"], xn, cfg, pos)
                        if cfg.attn_impl == "pipeline":
                            y = L._attention_pipeline(
                                q, k, v, 1.0 / cfg.d_head ** 0.5, cfg,
                                causal=True)
                        else:
                            from repro.kernels import ops as K
                            y = K.flash_attention(q, k, v, causal=True,
                                                  impl=cfg.attn_impl,
                                                  unroll=cfg.unroll_scans)
                        b = x.shape[0]
                        y = y.transpose(0, 2, 1, 3).reshape(
                            b, s, cfg.n_heads * cfg.d_head)
                        x = x + constrain(y @ p["mixer"]["wo"],
                                          "batch", None, None)
                        pad = max_len - s
                        cache[f"sub{j}"] = {
                            "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad),
                                             (0, 0))).astype(cfg.dtype),
                            "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad),
                                             (0, 0))).astype(cfg.dtype),
                        }
                    elif spec.mixer == "mla":
                        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
                        x = x + L.mla_apply(p["mixer"], xn, cfg)
                        ckv, krope = L._mla_kv_compressed(
                            p["mixer"], xn, cfg, jnp.arange(s))
                        pad = max_len - s
                        cache[f"sub{j}"] = {
                            "ckv": jnp.pad(ckv, ((0, 0), (0, pad),
                                                 (0, 0))).astype(cfg.dtype),
                            "krope": jnp.pad(krope,
                                             ((0, 0), (0, pad),
                                              (0, 0))).astype(cfg.dtype),
                        }
                    elif spec.mixer == "mamba":
                        y, st = L.mamba_prefill(p["mixer"], x, p["ln1"], cfg)
                        x = x + y
                        cache[f"sub{j}"] = st
                    if spec.mlp == "swiglu":
                        x = x + L.rmsnorm_swiglu_apply(p["mlp"], x, p["ln2"],
                                                       cfg)
                    elif spec.mlp == "moe":
                        x = x + L.moe_apply(p["mlp"], x, p["ln2"], cfg)
                return x, cache

            x, caches[f"stage{si}"] = jax.lax.scan(
                body, x, params[f"stage{si}"],
                unroll=stage.n if cfg.unroll_scans else 1)
        return self._logits(params, x), caches


def build_model(cfg: ModelConfig) -> LM:
    if cfg.family == "encdec":
        from repro.models.encdec import EncDec
        return EncDec(cfg)
    return LM(cfg)
