"""Shared fixtures: the paper's three example programs with concrete data.

NOTE: do NOT set XLA_FLAGS/device-count here — smoke tests and benches must
see the real single-device CPU; only launch/dryrun.py forces 512 devices.
"""

import numpy as np
import pytest

from repro.core import array_program as AP
from repro.core import blocks as B


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


class ExampleCase:
    def __init__(self, graph, inputs, dims, ref, out_name):
        self.graph = graph
        self.inputs = inputs
        self.dims = dims
        self.ref = ref
        self.out_name = out_name


def make_attention_case(rng, M=3, D=2, N=4, L=2, bm=8, bd=16, bn=8, bl=16,
                        logit_scale=1.0):
    d_model = D * bd
    Q = rng.normal(size=(M * bm, d_model)) * logit_scale
    K = rng.normal(size=(N * bn, d_model)) * logit_scale
    V = rng.normal(size=(N * bn, L * bl))
    scale = 1.0 / np.sqrt(d_model)
    S = (Q @ K.T) * scale
    Sm = S - S.max(axis=1, keepdims=True)
    P = np.exp(Sm) / np.exp(Sm).sum(axis=1, keepdims=True)
    ref = P @ V
    g = AP.attention_program(scale)
    inputs = {"Q": B.split(Q, M, D), "KT": B.split(K, N, D),
              "VT": B.split(V.T, L, N)}
    return ExampleCase(g, inputs, {"M": M, "D": D, "N": N, "L": L}, ref, "O")


def make_layernorm_case(rng, M=3, K=4, N=2, bm=8, bk=8, bn=16):
    KK = K * bk
    X = rng.normal(size=(M * bm, KK))
    Y = rng.normal(size=(KK, N * bn))
    mu = X.mean(axis=1, keepdims=True)
    sd = np.sqrt((X ** 2).mean(axis=1, keepdims=True) - mu ** 2)
    ref = ((X - mu) / sd) @ Y
    g = AP.layernorm_matmul_program(float(KK))
    inputs = {"X": B.split(X, M, K), "YT": B.split(Y.T, N, K)}
    return ExampleCase(g, inputs, {"M": M, "K": K, "N": N}, ref, "Z")


def make_swiglu_case(rng, M=2, D=3, K=4, N=2, b=8):
    DD = D * b
    X = rng.normal(size=(M * b, DD))
    W = rng.normal(size=(DD, K * b)) / np.sqrt(DD)
    V = rng.normal(size=(DD, K * b)) / np.sqrt(DD)
    U = rng.normal(size=(K * b, N * b)) / np.sqrt(K * b)
    xn = X / np.sqrt((X ** 2).mean(axis=1, keepdims=True))
    gsw = xn @ W
    sw = gsw / (1 + np.exp(-gsw))
    ref = (sw * (xn @ V)) @ U
    g = AP.rmsnorm_ffn_swiglu_program(float(DD))
    inputs = {"X": B.split(X, M, D), "WT": B.split(W.T, K, D),
              "VT": B.split(V.T, K, D), "UT": B.split(U.T, N, K)}
    return ExampleCase(g, inputs, {"M": M, "D": D, "K": K, "N": N}, ref, "O")


@pytest.fixture()
def attention_case(rng):
    return make_attention_case(rng)


@pytest.fixture()
def layernorm_case(rng):
    return make_layernorm_case(rng)


@pytest.fixture()
def swiglu_case(rng):
    return make_swiglu_case(rng)
