"""Model configuration + shared layers for the 10-architecture zoo.

Pure-JAX functional style: parameters are nested dicts of arrays; every
parameter tree has a parallel *spec tree* of logical sharding axes
(see ``runtime/sharding.py``).  Layer stacks are stored with a leading
layer dim and consumed with ``jax.lax.scan`` so the HLO stays compact for
the 61-72-layer assigned architectures.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.runtime.sharding import constrain


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"       # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    max_seq: int = 8192
    rope_theta: float = 10000.0
    qkv_bias: bool = False       # qwen2
    qk_norm: bool = False        # qwen3
    norm: str = "rms"            # rms | ln  (whisper uses ln)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    n_dense_layers: int = 0      # leading dense layers (deepseek: 3)
    capacity_factor: float = 1.25
    moe_period: int = 1          # apply MoE every k-th layer (jamba: 2)
    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_period: int = 0         # hybrid: every k-th layer is attention
                                 # (jamba: 8 -> 1 attn : 7 mamba)
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 1500
    # --- vlm ---
    n_vision_tokens: int = 0
    # --- runtime ---
    dtype: Any = jnp.bfloat16
    attn_impl: str = "xla"       # xla | ref | pallas | interpret | pipeline
    mlp_impl: str = "fused_ref"  # fused_ref | pallas | interpret | unfused
                                 # | pipeline
    pipeline_backend: str = "jax"  # codegen backend for the *pipeline*
                                 # impls: py | jax | pallas (the fusion-
                                 # derived kernels from repro.pipeline)
    pipeline_options: Any = None  # Optional[pipeline.CompileOptions]:
                                 # full compile-option override for the
                                 # pipeline impls; when set, its backend
                                 # field wins over pipeline_backend.
                                 # Hashable, so the config stays usable
                                 # as a cache key.
    remat: bool = True
    remat_policy: str = "full"   # full | dots  (dots: save matmul outputs,
                                 # no recompute of the big dots in backward)
    unroll_scans: bool = False   # dry-run: unroll kv/ssd chunk scans so
                                 # cost_analysis counts every iteration
    attn_p_half: bool = False    # half-precision softmax probs for the PV
                                 # dot (flash-kernel MXU convention)
    moe_impl: str = "dense"      # dense | shard_map (EP dispatch path)
    logical_batch: Tuple[str, ...] = ("batch", None, None)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_attn_layer(self, i: int) -> bool:
        if self.family not in ("ssm", "hybrid"):
            return True
        if self.family == "ssm":
            return False
        # jamba: one attention layer per attn_period block (at index p//2)
        return i % self.attn_period == (self.attn_period // 2)

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        return i >= self.n_dense_layers and (i % self.moe_period ==
                                             self.moe_period - 1)


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.attn_period == 0
                     else cfg.attn_period),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        d_head=32,
        d_ff=256,
        vocab=512,
        max_seq=256,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        capacity_factor=8.0,  # no drops at smoke-test scale (exactness)
        moe_d_ff=64 if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        n_dense_layers=min(cfg.n_dense_layers, 1),
        q_lora_rank=64 if cfg.q_lora_rank else 0,
        kv_lora_rank=32 if cfg.use_mla else 0,
        qk_nope_dim=32 if cfg.use_mla else 0,
        qk_rope_dim=16 if cfg.use_mla else 0,
        v_head_dim=32 if cfg.use_mla else 0,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=32,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        enc_seq=64 if cfg.n_enc_layers else 0,
        n_vision_tokens=min(cfg.n_vision_tokens, 16),
        dtype=jnp.float32,
        remat=False,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


# ---------------------------------------------------------------------------
# parameter init helpers — every creator returns (array, logical_axes)
# ---------------------------------------------------------------------------

Param = Tuple[jax.Array, Tuple[Optional[str], ...]]


class ParamBuilder:
    def __init__(self, key: jax.Array, dtype):
        self.key = key
        self.dtype = dtype
        self.params: Dict[str, Any] = {}
        self.specs: Dict[str, Any] = {}

    def _split(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def dense(self, name, shape, axes, scale=None):
        fan_in = shape[0] if len(shape) >= 2 else 1
        scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
        w = jax.random.normal(self._split(), shape, self.dtype) * scale
        self.params[name] = w
        self.specs[name] = axes
        return w

    def zeros(self, name, shape, axes):
        self.params[name] = jnp.zeros(shape, self.dtype)
        self.specs[name] = axes
        return self.params[name]

    def ones(self, name, shape, axes):
        self.params[name] = jnp.ones(shape, self.dtype)
        self.specs[name] = axes
        return self.params[name]

    def sub(self, name):
        b = ParamBuilder(self._split(), self.dtype)
        self.params[name] = b.params
        self.specs[name] = b.specs
        return b

    def build(self):
        return self.params, self.specs


def stack_layers(trees: List[Dict]) -> Dict:
    """Stack a list of identical param trees along a new leading layer dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stack_specs(spec: Dict) -> Dict:
    """Prepend the (replicated) layer axis to every spec tuple."""
    return jax.tree.map(
        lambda axes: (None,) + tuple(axes),
        spec,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


# ---------------------------------------------------------------------------
# normalization / rope
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps):
    xf = x.astype(jnp.float32)
    irms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * irms * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, gamma, beta, eps):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = xf.var(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)
            + beta.astype(jnp.float32)).astype(x.dtype)


def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, Dh) with Dh even; positions: (S,) or broadcastable."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (d/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross entropy, f32 accumulation.

    The gold logit is extracted with a one-hot reduction rather than
    ``take_along_axis`` so that vocab-sharded logits stay sharded (a gather
    over the tensor-parallel vocab dim would force XLA to all-gather the
    full logits — measured at +13GB/device on the 256-chip dry-run)."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, lf.shape[-1], dtype=lf.dtype)
    gold = jnp.sum(lf * onehot, axis=-1)
    return (logz - gold).mean()
