"""The resilience layer: degradation-ladder compiles under injected
faults (matrix over every in-repo program, outputs pinned to the
interpreter oracle), kernel-cache integrity (checksums, quarantine,
named counters), serving-engine fault isolation (poison eviction with
co-batched oracle match, watchdog demotion, bounded admission,
deadlines), and the deterministic FaultPlan machinery itself."""

import json
import pickle

import numpy as np
import pytest

from repro import configs, pipeline
from repro import resilience as RZ
from repro.pipeline import cache as C

from test_lowering_coverage import PROGRAMS, _merged_inputs


@pytest.fixture()
def fresh_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_CACHE", str(tmp_path))
    pipeline.reset_default_cache()
    yield
    pipeline.reset_default_cache()


@pytest.fixture(autouse=True)
def _no_env_plan(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT_PLAN", raising=False)
    RZ.install(None)
    yield
    RZ.install(None)


def _mem_cache():
    return pipeline.KernelCache(disk=False)


# ---------------------------------------------------------------------------
# ladder primitives
# ---------------------------------------------------------------------------

def test_ladder_order_and_rungs_from():
    assert RZ.LADDER == ("grouped", "ungrouped", "jax", "interpreter")
    assert RZ.start_rung("pallas", True) == "grouped"
    assert RZ.start_rung("pallas", False) == "ungrouped"
    assert RZ.start_rung("jax", True) == "jax"
    assert RZ.start_rung("py", True) == "interpreter"
    assert RZ.rungs_from("grouped", "interpreter") == RZ.LADDER
    assert RZ.rungs_from("ungrouped", "jax") == ("ungrouped", "jax")
    # a max_rung ABOVE the start permits no demotion at all
    assert RZ.rungs_from("jax", "grouped") == ("jax",)
    with pytest.raises(ValueError):
        RZ.rung_index("warp-speed")


def test_policy_is_frozen_hashable_and_keyed():
    p = RZ.ResiliencePolicy(max_rung="jax", retries=2)
    assert hash(p) != hash(RZ.DEFAULT_POLICY)
    assert p.key() == ("jax", None, 2, 0.05, 3, 60.0, 3600.0)
    with pytest.raises(ValueError):
        RZ.ResiliencePolicy(max_rung="nope")
    # non-default policies land in the cache-key opts; the default stays
    # byte-identical to pre-resilience builds
    base = pipeline.CompileOptions(backend="jax")
    keyed = pipeline.CompileOptions(backend="jax", resilience=p)
    assert base.cache_opts(stabilized=False, autotuned=False) == \
        pipeline.CompileOptions(
            backend="jax",
            resilience=RZ.ResiliencePolicy()).cache_opts(
                stabilized=False, autotuned=False)
    assert ("resilience", p.key()) in keyed.cache_opts(
        stabilized=False, autotuned=False)
    assert base != keyed


# ---------------------------------------------------------------------------
# the fault-injection matrix: every program x injected compile faults,
# output pinned to the interpreter oracle, report names the served rung
# ---------------------------------------------------------------------------

_FAULT_MATRIX = [
    # (faulted sites, expected served rung, expected demotions)
    (("compile:grouped",), "ungrouped", 1),
    (("compile:grouped", "compile:ungrouped"), "jax", 2),
    (("compile:grouped", "compile:ungrouped", "compile:jax"),
     "interpreter", 3),
]


@pytest.mark.parametrize("name", sorted(PROGRAMS))
@pytest.mark.parametrize("sites,rung,demotions", _FAULT_MATRIX)
def test_ladder_matrix_matches_interpreter_oracle(name, sites, rung,
                                                  demotions):
    build, dims, blocks = PROGRAMS[name]
    g = build()
    oracle = pipeline.compile(g, dims, backend="py", cache=_mem_cache())
    inputs = _merged_inputs(g, dims, blocks,
                            np.random.default_rng(0))
    expect = oracle(dict(inputs))

    plan = RZ.FaultPlan([RZ.FaultSpec(site=s) for s in sites])
    with RZ.faults(plan), pytest.warns(RuntimeWarning,
                                       match="compile ladder"):
        kern = pipeline.compile(g, dims, backend="pallas", blocks=blocks,
                                cache=_mem_cache())
    rr = kern.resilience_report
    assert rr is not None and rr.rung == rung == kern.rung
    assert rr.requested == "grouped"
    assert rr.demotions == demotions
    assert len(rr.errors) == len(sites)
    assert all("InjectedFault" in e for e in rr.errors)
    assert plan.fired_count() == len(sites)
    got = kern(dict(inputs))
    for nm in expect:
        np.testing.assert_allclose(np.asarray(got[nm]),
                                   np.asarray(expect[nm]),
                                   rtol=2e-4, atol=2e-4)


def test_happy_path_report_is_one_ok_attempt(fresh_cache):
    build, dims, blocks = PROGRAMS["layernorm_matmul"]
    before = RZ.METRICS.snapshot()
    kern = pipeline.compile(build(), dims, backend="pallas",
                            blocks=blocks, cache=_mem_cache())
    rr = kern.resilience_report
    assert rr.rung == rr.requested == "grouped"
    assert rr.demotions == 0 and rr.errors == []
    assert [a.ok for a in rr.attempts] == [True]
    d = RZ.METRICS.delta(before)
    assert d.demotions == 0 and d.faults_fired == 0
    # and the report is JSON-serializable provenance
    js = json.loads(json.dumps(rr.to_json()))
    assert js["demotions"] == 0 and js["rung"] == "grouped"


def test_retry_recovers_at_same_rung():
    """A transient failure with retries budget: second try at the SAME
    rung succeeds — no demotion recorded."""
    build, dims, blocks = PROGRAMS["layernorm_matmul"]
    plan = RZ.FaultPlan([RZ.FaultSpec(site="compile:grouped",
                                      indices=(0,))])
    opts = pipeline.CompileOptions(
        backend="pallas", blocks=blocks,
        resilience=RZ.ResiliencePolicy(retries=1, backoff_s=0.0))
    with RZ.faults(plan):
        kern = pipeline.compile(build(), dims, options=opts,
                                cache=_mem_cache())
    rr = kern.resilience_report
    assert rr.rung == "grouped" and rr.demotions == 0
    assert [(a.ok, a.retry) for a in rr.attempts] == [(False, 0),
                                                      (True, 1)]


def test_slow_compile_times_out_and_demotes():
    build, dims, blocks = PROGRAMS["layernorm_matmul"]
    plan = RZ.FaultPlan([RZ.FaultSpec(site="compile:grouped",
                                      kind="sleep", sleep_s=5.0)])
    opts = pipeline.CompileOptions(
        backend="pallas", blocks=blocks,
        resilience=RZ.ResiliencePolicy(attempt_timeout_s=0.2))
    with RZ.faults(plan), pytest.warns(RuntimeWarning,
                                       match="compile ladder"):
        kern = pipeline.compile(build(), dims, options=opts,
                                cache=_mem_cache())
    rr = kern.resilience_report
    assert rr.attempts[0].timed_out and not rr.attempts[0].ok
    assert rr.rung == "ungrouped"


def test_bounded_max_rung_exhaustion_raises_ladder_error():
    build, dims, blocks = PROGRAMS["layernorm_matmul"]
    plan = RZ.FaultPlan([RZ.FaultSpec(site="compile:grouped"),
                         RZ.FaultSpec(site="compile:ungrouped")])
    opts = pipeline.CompileOptions(
        backend="pallas", blocks=blocks,
        resilience=RZ.ResiliencePolicy(max_rung="ungrouped"))
    before = RZ.METRICS.snapshot()
    with RZ.faults(plan), pytest.warns(RuntimeWarning), \
            pytest.raises(RZ.LadderError) as ei:
        pipeline.compile(build(), dims, options=opts, cache=_mem_cache())
    rep = ei.value.report
    assert [a.rung for a in rep.attempts] == ["grouped", "ungrouped"]
    assert RZ.METRICS.delta(before).ladder_failures == 1


def test_config_errors_raise_instead_of_demoting():
    """User mistakes (pallas without blocks) are not failures to survive:
    they raise before any rung runs."""
    build, dims, _ = PROGRAMS["layernorm_matmul"]
    with pytest.raises(ValueError, match="blocks"):
        pipeline.compile(build(), dims, backend="pallas",
                         cache=_mem_cache())


# ---------------------------------------------------------------------------
# fault plan machinery
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic_and_roundtrips():
    spec = RZ.FaultSpec(site="compile:grouped", indices=(1, 3),
                        kind="raise", message="boom")
    plan = RZ.FaultPlan([spec], seed=7)
    fired = [plan.fire("compile:grouped") is not None for _ in range(5)]
    assert fired == [False, True, False, True, False]
    assert plan.calls("compile:grouped") == 5
    assert plan.fired_count() == 2
    assert plan.expected_count("compile:") == 2

    plan2 = RZ.FaultPlan.from_json(
        json.loads(json.dumps(plan.to_json())))
    assert plan2.seed == 7 and plan2.specs == (spec,)
    fired2 = [plan2.fire("compile:grouped") is not None for _ in range(5)]
    assert fired2 == fired  # same plan, same schedule, every run

    plan.reset()
    assert plan.calls("compile:grouped") == 0 and plan.fired_count() == 0
    with pytest.raises(ValueError, match="fault kind"):
        RZ.FaultSpec(site="x", kind="explode")


def test_env_var_activates_plan(monkeypatch):
    raw = json.dumps({"seed": 1, "faults": [
        {"site": "compile:grouped", "indices": [0]}]})
    monkeypatch.setenv("REPRO_FAULT_PLAN", raw)
    plan = RZ.active()
    assert plan is not None and plan.seed == 1
    assert RZ.active() is plan  # cached per env value: counters survive
    with pytest.raises(RZ.InjectedFault):
        RZ.check("compile:grouped")


def test_run_with_timeout_does_not_block_on_hung_worker():
    import time as _t
    t0 = _t.perf_counter()
    with pytest.raises(RZ.AttemptTimeout):
        RZ.run_with_timeout(lambda: _t.sleep(10), 0.1)
    assert _t.perf_counter() - t0 < 5.0  # returned without joining


# ---------------------------------------------------------------------------
# cache integrity: checksums, quarantine, named counters, atomic writes
# ---------------------------------------------------------------------------

def _kc(tmp_path):
    return C.KernelCache(root=tmp_path)


def _seed_entry(kc, with_graph=True):
    from repro.core import array_program as AP
    key = C.CacheKey.make("fp-test", "jax", {"M": 2}, None, True)
    plan = C.CachePlan(0, {"M": 2}, 1.0, (1.0, 2.0), 2.0)
    kc.put_plan(key, plan,
                AP.layernorm_matmul_program(32.0) if with_graph else None)
    return key, plan


def test_plan_roundtrip_and_checksum_envelope(tmp_path):
    kc = _kc(tmp_path)
    key, plan = _seed_entry(kc)
    got, graph = kc.get_plan(key)
    assert got == plan and graph is not None
    env = json.loads((tmp_path / f"{key.digest()}.json").read_text())
    assert env["schema"] == C._SCHEMA_VERSION
    assert len(env["sha256"]) == 64
    # no stray temp files after the atomic write
    assert not list(tmp_path.glob("*.tmp"))


def test_missing_entry_is_a_plain_miss_no_counters(tmp_path):
    kc = _kc(tmp_path)
    key = C.CacheKey.make("nope", "jax", {"M": 2}, None, True)
    assert kc.get_plan(key) == (None, None)
    st = kc.stats
    assert (st.corrupt_plans, st.corrupt_graphs, st.quarantined,
            st.io_errors) == (0, 0, 0, 0)


@pytest.mark.parametrize("mutate,reason", [
    (lambda b: b[: len(b) // 2], "truncated"),
    (lambda b: b"\xffgarbage" + b[8:], "garbled bytes"),
    (lambda b: b.replace(b'"snapshot_index": 0',
                         b'"snapshot_index": 9'), "checksum mismatch"),
    (lambda b: json.dumps({"schema": 3, "sha256": "0" * 64,
                           "plan": {}}).encode(), "stale schema"),
])
def test_corrupt_plan_quarantined_counted_warned(tmp_path, mutate, reason):
    import re
    kc = _kc(tmp_path)
    key, _ = _seed_entry(kc)
    pj = tmp_path / f"{key.digest()}.json"
    pj.write_bytes(mutate(pj.read_bytes()))
    # the satellite contract: the warning names the offending path
    with pytest.warns(RuntimeWarning, match=re.escape(str(pj))):
        assert kc.get_plan(key) == (None, None), reason
    assert kc.stats.corrupt_plans == 1
    # plan AND its paired graph move aside for triage (never deleted)
    assert kc.stats.quarantined == 2
    qdir = tmp_path / "quarantine"
    assert sorted(p.name for p in qdir.iterdir()) == sorted(
        [pj.name, f"{key.digest()}.graph.pkl"])
    # the entry is gone from the hot path: next read is a plain miss
    assert kc.get_plan(key) == (None, None)
    assert kc.stats.corrupt_plans == 1


def test_corrupt_graph_degrades_to_plan_only(tmp_path):
    kc = _kc(tmp_path)
    key, plan = _seed_entry(kc)
    pg = tmp_path / f"{key.digest()}.graph.pkl"
    blob = pg.read_bytes()
    pg.write_bytes(blob[:-10])  # truncate the pickle payload
    with pytest.warns(RuntimeWarning, match="corrupt graph"):
        got, graph = kc.get_plan(key)
    assert got == plan and graph is None  # plan survives, graph gone
    assert kc.stats.corrupt_graphs == 1 and kc.stats.quarantined == 1
    assert kc.stats.disk_hits == 1


def test_graph_missing_magic_header_rejected(tmp_path):
    kc = _kc(tmp_path)
    key, plan = _seed_entry(kc)
    pg = tmp_path / f"{key.digest()}.graph.pkl"
    # a legacy headerless pickle must not be trusted
    pg.write_bytes(pickle.dumps({"not": "a graph"}))
    with pytest.warns(RuntimeWarning, match="integrity header"):
        got, graph = kc.get_plan(key)
    assert got == plan and graph is None
    assert kc.stats.corrupt_graphs == 1


def test_write_failure_counts_and_warns(tmp_path):
    blocker = tmp_path / "blocked"
    blocker.write_text("a regular file where the cache dir should be")
    kc = C.KernelCache(root=blocker / "sub")
    from repro.core import array_program as AP
    key = C.CacheKey.make("fp", "jax", {"M": 2}, None, True)
    with pytest.warns(RuntimeWarning, match="failed to write plan"):
        kc.put_plan(key, C.CachePlan(0, {"M": 2}, 1.0, (1.0,), 2.0),
                    AP.layernorm_matmul_program(32.0))
    assert kc.stats.write_errors == 1
    assert kc.stats.misses == 1  # still counted as a compile-path miss


def test_unpicklable_graph_is_plan_only_with_counter(tmp_path):
    from repro.core import array_program as AP
    g = AP.layernorm_matmul_program(32.0)
    g._poison = lambda x: x  # closures don't pickle
    kc = _kc(tmp_path)
    key = C.CacheKey.make("fp-unpick", "jax", {"M": 2}, None, True)
    with pytest.warns(RuntimeWarning, match="plan-only"):
        kc.put_plan(key, C.CachePlan(0, {"M": 2}, 1.0, (1.0,), 2.0), g)
    assert kc.stats.write_errors == 1
    got, graph = kc.get_plan(key)
    assert got is not None and graph is None


def test_cache_stats_snapshot_delta_cover_all_counters(tmp_path):
    st = C.CacheStats(memory_hits=3, disk_hits=1, misses=2,
                      corrupt_plans=4, quarantined=5)
    snap = st.snapshot()
    st.quarantined += 2
    st.io_errors += 1
    d = st.delta(snap)
    assert (d.quarantined, d.io_errors, d.corrupt_plans) == (2, 1, 0)
    assert d.memory_hits == 0


def test_injected_cache_corruption_drives_real_machinery(fresh_cache):
    """The chaos-CI path: a 'corrupt' fault garbles the REAL on-disk
    entry; detection, quarantine, and recompile all run for real."""
    from repro.core import array_program as AP
    g = AP.layernorm_matmul_program(32.0)
    dims = {"M": 2, "K": 4, "N": 2}
    k1 = pipeline.compile(g, dims, backend="jax")
    assert k1.cache_hit is None
    pipeline.reset_default_cache()
    plan = RZ.FaultPlan([RZ.FaultSpec(site="cache:get_plan",
                                      kind="corrupt")])
    with RZ.faults(plan), pytest.warns(RuntimeWarning,
                                       match="corrupt plan"):
        k2 = pipeline.compile(g, dims, backend="jax")
    assert k2.cache_hit is None  # quarantined -> honest miss
    st = pipeline.default_cache().stats
    assert st.corrupt_plans == 1 and st.quarantined >= 1
    # the rewritten entry serves the next compile from disk again
    pipeline.reset_default_cache()
    assert pipeline.compile(g, dims, backend="jax").cache_hit == "disk"


# ---------------------------------------------------------------------------
# serving isolation: poison eviction, watchdog, admission bounds, deadlines
# ---------------------------------------------------------------------------

def _tiny_cfg(backend="jax", **overrides):
    mc = configs.get_reduced_config(
        "smollm-135m", n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
        d_head=32, d_ff=128, vocab=128, **overrides)
    return configs.with_pipeline(
        mc, options=pipeline.CompileOptions(backend=backend))


def _oracle(engine, req):
    """Per-sequence sequential greedy decode — no batching, no padding."""
    import jax
    import jax.numpy as jnp
    m, params = engine.model, engine.params
    decode = jax.jit(m.decode_step)
    prompt = jnp.asarray(req.prompt)[None, :]
    lg, cache = m.prefill(params, prompt, max_len=engine.max_len)
    tok = int(jnp.argmax(lg[0, -1]))
    toks = [tok]
    pos = len(req.prompt)
    for _ in range(req.max_new_tokens - 1):
        lg, cache = decode(params, cache, jnp.asarray([[tok]]),
                           jnp.asarray(pos))
        tok = int(jnp.argmax(lg[0, -1]))
        toks.append(tok)
        pos += 1
    return toks


def test_poison_request_evicted_cobatched_match_oracle(fresh_cache):
    """The isolation acceptance: one NaN-logits request is evicted with
    a structured failure record while every co-batched sequence's tokens
    exactly match the sequential-decode oracle."""
    from repro.launch.engine import Engine, synth_trace
    engine = Engine(_tiny_cfg("jax"), max_batch=3, max_len=48,
                    prompt_buckets=(8, 16), sampling="greedy", seed=0)
    trace = synth_trace(6, seed=3, arrival_rate=1.5, prompt_lens=(3, 14),
                        gen_lens=(3, 6), vocab=engine.cfg.vocab)
    plan = RZ.FaultPlan([RZ.FaultSpec(site="serve:logits", indices=(1,),
                                      kind="nan")])
    with RZ.faults(plan):
        report = engine.run(trace)
    assert report.n_poisoned == 1
    bad = [f for f in report.failures
           if f["reason"] == "nonfinite_logits"]
    assert len(bad) == 1 and "rid" in bad[0] and "step" in bad[0]
    poisoned_rid = bad[0]["rid"]
    assert report.n_completed == len(trace) - 1
    for req in trace:
        if req.rid == poisoned_rid:
            continue  # evicted with partial tokens; the rest are exact
        assert report.tokens[req.rid] == _oracle(engine, req), (
            f"co-batched request {req.rid} diverged after the poison "
            "eviction")


def test_watchdog_demotes_decode_and_keeps_serving(fresh_cache):
    """A decode-step crash mid-run demotes the kernel one rung and the
    run completes; tokens still match the oracle on the ORIGINAL impl
    (the demoted backend computes the same function)."""
    from repro.launch.engine import Engine, synth_trace
    engine = Engine(_tiny_cfg("pallas"), max_batch=2, max_len=32,
                    prompt_buckets=(8,), sampling="greedy", seed=0)
    oracle_engine = Engine(_tiny_cfg("pallas"), max_batch=2, max_len=32,
                           prompt_buckets=(8,), sampling="greedy", seed=0)
    trace = synth_trace(4, seed=1, arrival_rate=1.0, prompt_lens=(2, 7),
                        gen_lens=(3, 5), vocab=engine.cfg.vocab)
    plan = RZ.FaultPlan([RZ.FaultSpec(site="serve:decode", indices=(1,))])
    with RZ.faults(plan), pytest.warns(RuntimeWarning,
                                       match="serve watchdog"):
        report = engine.run(trace)
    assert report.n_completed == len(trace)
    assert engine.watchdog_demotions == 1
    assert report.degradations >= 1
    demos = [f for f in report.failures
             if f["reason"] == "decode_demotion"]
    assert len(demos) == 1 and demos[0]["to"] == "pipeline-jax"
    # strict_no_recompile stayed armed: the demotion compiles were
    # explained, and nothing else compiled
    assert report.decode_recompiles == 0
    for req in trace:
        assert report.tokens[req.rid] == _oracle(oracle_engine, req)


def test_bounded_admission_rejects_with_record(fresh_cache):
    from repro.launch.engine import Engine, Request
    engine = Engine(_tiny_cfg("jax"), max_batch=1, max_len=32,
                    prompt_buckets=(8,), sampling="greedy", seed=0,
                    max_queue=1)
    trace = [Request(rid=i, prompt=(1, 2, 3), max_new_tokens=3,
                     arrival_step=0) for i in range(5)]
    report = engine.run(trace)
    overflows = [f for f in report.failures
                 if f["reason"] == "queue_full"]
    assert report.n_rejected == len(overflows) > 0
    assert report.max_queue_depth <= 1
    assert report.n_completed == len(trace) - report.n_rejected


def test_deadline_evicts_queued_and_active(fresh_cache):
    from repro.launch.engine import Engine, Request
    engine = Engine(_tiny_cfg("jax"), max_batch=1, max_len=48,
                    prompt_buckets=(8,), sampling="greedy", seed=0)
    trace = [
        # hogs the only slot for a while
        Request(rid=0, prompt=(1, 2, 3), max_new_tokens=12,
                arrival_step=0),
        # active eviction: admitted but cut off mid-generation
        Request(rid=1, prompt=(4, 5, 6), max_new_tokens=12,
                arrival_step=0, deadline_step=14),
        # queued eviction: expires while waiting behind the others
        Request(rid=2, prompt=(7, 8), max_new_tokens=4,
                arrival_step=0, deadline_step=2),
    ]
    report = engine.run(trace)
    assert report.n_deadline_evicted == 2
    reasons = sorted(f["reason"] for f in report.failures)
    assert reasons == ["deadline", "deadline_queued"]
    assert report.n_completed == 1
    assert 0 < len(report.tokens[1]) < 12  # partial output recorded


def test_clean_serve_run_has_zero_resilience_counters(fresh_cache):
    from repro.launch.engine import Engine, synth_trace
    engine = Engine(_tiny_cfg("jax"), max_batch=2, max_len=32,
                    prompt_buckets=(8,), sampling="greedy", seed=0)
    trace = synth_trace(3, seed=0, arrival_rate=1.0, prompt_lens=(2, 6),
                        gen_lens=(2, 4), vocab=engine.cfg.vocab)
    report = engine.run(trace)
    assert report.degradations == 0
    assert report.quarantined == 0
    assert report.n_poisoned == 0
    assert report.n_deadline_evicted == 0
    assert report.failures == []
    # the new counters serialize with the report
    d = json.loads(json.dumps(report.to_json()))
    assert d["degradations"] == 0 and d["failures"] == []
