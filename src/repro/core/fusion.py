"""The fusion algorithm (paper §4).

``fuse_no_extend`` applies rules in the paper's priority order
``8 -> 4 -> 5 -> 9 -> 3 -> 1 -> 2`` on one graph level until fixpoint;
``bfs_fuse_no_extend`` walks the hierarchy breadth-first;
``bfs_extend`` finds the first Rule-6 opportunity anywhere;
``fuse`` interleaves them, snapshotting after every no-extend fixpoint so
the candidate-selection algorithm can pick among partially/fully fused
variants (the paper's contract)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.graph import Graph, MapNode
from repro.core.rules import RULES_PRIORITY, Rule6


@dataclass
class FusionTrace:
    """Sequence of (rule_name, level_path) applications, for inspection and
    for tests that compare against the paper's worked examples."""

    steps: List[Tuple[str, str]] = field(default_factory=list)

    def count(self, rule_name: str) -> int:
        return sum(1 for r, _ in self.steps if r == rule_name)


_MAX_STEPS = 10_000


def _inner_graphs(g: Graph) -> List[Graph]:
    return [g.nodes[n].inner for n in sorted(g.op_nodes())
            if isinstance(g.nodes[n], MapNode)]


def fuse_no_extend(g: Graph, trace: Optional[FusionTrace] = None,
                   path: str = "/") -> bool:
    """Apply all rules except Rule 6 on one level until fixpoint."""
    changed_any = False
    for _ in range(_MAX_STEPS):
        for rule in RULES_PRIORITY:
            m = rule.match(g)
            if m is not None:
                rule.apply(g, m)
                if trace is not None:
                    trace.steps.append((rule.name, path))
                changed_any = True
                break
        else:
            return changed_any
    raise RuntimeError("fusion did not converge (rule ping-pong?)")


def bfs_fuse_no_extend(g: Graph, trace: Optional[FusionTrace] = None) -> Graph:
    queue: List[Tuple[Graph, str]] = [(g, "/")]
    while queue:
        cur, path = queue.pop(0)
        fuse_no_extend(cur, trace, path)
        for i, inner in enumerate(_inner_graphs(cur)):
            queue.append((inner, f"{path}{i}/"))
    return g


def bfs_extend(g: Graph, trace: Optional[FusionTrace] = None) -> bool:
    """Apply Rule 6 at the first (BFS) level where it matches."""
    queue: List[Tuple[Graph, str]] = [(g, "/")]
    while queue:
        cur, path = queue.pop(0)
        m = Rule6.match(cur)
        if m is not None:
            Rule6.apply(cur, m)
            if trace is not None:
                trace.steps.append((Rule6.name, path))
            return True
        for i, inner in enumerate(_inner_graphs(cur)):
            queue.append((inner, f"{path}{i}/"))
    return False


def fuse(g: Graph, trace: Optional[FusionTrace] = None,
         max_extensions: int = 16) -> List[Graph]:
    """Run the full algorithm; returns the snapshot list (paper §4.3).

    The last snapshot is the most aggressively fused program.  Snapshots are
    independent clones — the input graph is not mutated."""
    work = g.clone()
    bfs_fuse_no_extend(work, trace)
    snapshots = [work.clone()]
    for _ in range(max_extensions):
        if not bfs_extend(work, trace):
            break
        bfs_fuse_no_extend(work, trace)
        snapshots.append(work.clone())
    return snapshots
