"""Traffic cost model (the fusion objective made explicit).

Counts, symbolically from the hierarchy, exactly the ``load``/``store``
instructions that the paper's listings contain:

* a *store* for every item written into a buffered (list-typed) value.
  Lists materialize at the map out-port that wraps a locally-produced item
  (one ``store`` per iteration); outer ports that merely re-wrap an
  already-global list are views, not extra traffic.
* a *load* whenever a global item is brought into a local temp — once per
  consuming loop iteration, shared between consumers at that level
  (``t1 = load(X[m,d])`` serves every use of ``t1``); a reduce over a
  global list loads each item.

Also counts functional-operator applications (work; Rule 6 replicates work)
and top-level operator count (kernel launches before candidate selection
splits the program).

Causal masking (``Graph.causal_dims`` maps a key-block dim to its
query-block dim): a fully-masked tile is never loaded, computed, or
stored — a map over a masked key dim nested inside its query dim iterates
only the non-fully-masked tiles, so its trip count drops from ``N`` to
the average ``sum_m ceil((m+1)*N/M) / M`` (``(N+1)/2`` when the two dims
tile the sequence identically).  This is exactly the traffic win causal
fusion buys, and it is what makes the cost model prefer the causal
program's snapshots for decoder workloads.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, field
from math import prod
from typing import Dict, List, Sequence, Tuple

from repro.core.graph import (FuncNode, Graph, InputNode, MapNode, MiscNode,
                              OutputNode, ReduceNode, VType)


@dataclass
class Traffic:
    loads: Counter = field(default_factory=Counter)    # item kind -> count
    stores: Counter = field(default_factory=Counter)
    work: Counter = field(default_factory=Counter)     # op name -> count
    launches: int = 0

    def total_items(self) -> int:
        return sum(self.loads.values()) + sum(self.stores.values())

    def bytes_moved(self, item_bytes: Dict[str, int]) -> int:
        return (sum(item_bytes.get(k, 0) * v for k, v in self.loads.items())
                + sum(item_bytes.get(k, 0) * v for k, v in self.stores.items()))


def _causal_trips(q_count: int, k_count: int) -> float:
    """Expected non-fully-masked key-block count per query block, assuming
    both dims tile the same sequence uniformly.  Equals ``(k+1)/2`` when
    ``q_count == k_count``; always ``<= k_count``."""
    tot = 0
    for m in range(q_count):
        tot += min(k_count, -(-((m + 1) * k_count) // q_count))
    return tot / q_count


def _eff_count(dim: str, sizes: Dict[str, int], causal: Dict[str, str],
               enclosing: frozenset):
    """Trip count of ``dim``, discounted when it is causally masked
    against an enclosing query dim (masked tiles are skipped)."""
    q_dim = causal.get(dim)
    if q_dim is not None and q_dim in enclosing:
        return _causal_trips(sizes[q_dim], sizes[dim])
    return sizes[dim]


def _n_items(dims: Tuple[str, ...], sizes: Dict[str, int],
             causal: Dict[str, str] = {},
             enclosing: frozenset = frozenset()):
    return prod(_eff_count(d, sizes, causal, enclosing) for d in dims)


def _walk(g: Graph, in_types: Sequence[VType], in_global: Sequence[bool],
          mult: float, sizes: Dict[str, int], t: Traffic, top: bool,
          causal: Dict[str, str] = {},
          enclosing: frozenset = frozenset()) -> None:
    types = g.infer_types(in_types)
    glob: Dict[Tuple[int, int], bool] = {}
    for nid, gl in zip(g.input_ids, in_global):
        glob[(nid, 0)] = gl
    order = g.topo()

    for nid in order:
        node = g.nodes[nid]
        if isinstance(node, (InputNode, OutputNode)):
            continue
        for p in range(node.n_out()):
            glob[(nid, p)] = types[(nid, p)].is_list

    # loads of global items into local temps; reduce loads over global lists
    for nid in order:
        node = g.nodes[nid]
        if isinstance(node, OutputNode):
            continue
        for p in range(node.n_out()):
            vt = types[(nid, p)]
            cons = [e for e in g.out_edges(nid, p)
                    if not isinstance(g.nodes[e.dst], OutputNode)]
            if glob[(nid, p)] and not vt.is_list and cons:
                t.loads[vt.item] += mult
                glob[(nid, p)] = False  # now in a local temp
            if vt.is_list:
                for e in cons:
                    if isinstance(g.nodes[e.dst], ReduceNode):
                        t.loads[vt.item] += mult * _n_items(
                            vt.dims, sizes, causal, enclosing)

    if top:  # item-typed program outputs get a single store
        for oid in g.output_ids:
            e = g.in_edge(oid, 0)
            vt = types[(e.src, e.sp)]
            if not vt.is_list:
                t.stores[vt.item] += mult

    # work + stores-at-materialization + recursion into maps
    for nid in order:
        node = g.nodes[nid]
        if isinstance(node, FuncNode):
            t.work[node.op.name] += mult
        elif isinstance(node, ReduceNode):
            e = g.in_edge(nid, 0)
            vt = types[(e.src, e.sp)]
            t.work["reduce_add"] += mult * max(
                _n_items(vt.dims, sizes, causal, enclosing) - 1, 0)
        elif isinstance(node, MapNode):
            dim_n = _eff_count(node.dim, sizes, causal, enclosing)
            inner_types: List[VType] = []
            inner_glob: List[bool] = []
            for p in range(node.n_in()):
                e = g.in_edge(nid, p)
                vt = types[(e.src, e.sp)]
                src_glob = glob[(e.src, e.sp)]
                if node.mapped[p]:
                    inner_types.append(vt.strip())
                    inner_glob.append(src_glob)
                else:
                    inner_types.append(vt)
                    inner_glob.append(src_glob)
            inner_tmap = node.inner.infer_types(inner_types)
            for p, oid in enumerate(node.inner.output_ids):
                ie = node.inner.in_edge(oid, 0)
                ivt = inner_tmap[(ie.src, ie.sp)]
                consumed = bool(g.out_edges(nid, p))
                if node.reduced[p] is None and not ivt.is_list and consumed:
                    # the list materializes here: one store per iteration
                    t.stores[ivt.item] += mult * dim_n
            _walk(node.inner, inner_types, inner_glob, mult * dim_n, sizes, t,
                  top=False, causal=causal,
                  enclosing=enclosing | {node.dim})


def traffic(g: Graph, sizes: Dict[str, int]) -> Traffic:
    t = Traffic()
    in_types = [g.nodes[nid].vtype for nid in g.input_ids]
    causal = dict(getattr(g, "causal_dims", None) or {})
    _walk(g, in_types, [True] * len(in_types), 1, sizes, t, top=True,
          causal=causal)
    t.launches = len(g.op_nodes())
    return t


def traffic_bytes(g: Graph, sizes: Dict[str, int],
                  item_bytes: Dict[str, int]) -> int:
    return traffic(g, sizes).bytes_moved(item_bytes)
