"""Quickstart: rediscover Flash Attention with the Blockbuster fusion
algorithm (paper Example 1), end to end in ~2 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import array_program as AP
from repro.core import blocks as B
from repro.core import cost as C
from repro.core.codegen_py import render
from repro.core.fusion import FusionTrace, fuse
from repro.core.graph import internal_buffered_edges
from repro.core.interpreter import run
from repro.core.numerics import run_stabilized

# 1. the array program: Attention = Q@K^T -> /sqrt(d) -> softmax -> @V
dims = {"M": 4, "D": 2, "N": 8, "L": 2}
d_model = 64
graph = AP.attention_program(scale=1.0 / np.sqrt(d_model))

print("=" * 72)
print("INITIAL block program (paper Table 2 expansion, fully unfused):")
print("=" * 72)
print(render(graph))

# 2. run the fusion algorithm (rules applied in priority 8->4->5->9->3->1->2)
trace = FusionTrace()
snapshots = fuse(graph, trace)
print()
print(f"fusion applied {len(trace.steps)} rules "
      f"(the paper's Example 1 trace has 17 steps):")
for rule, path in trace.steps:
    print(f"  {path:8s} {rule}")

print()
print("=" * 72)
print("FINAL fused program == Flash Attention (paper Example 1 epilogue):")
print("=" * 72)
print(render(snapshots[-1]))
assert internal_buffered_edges(snapshots[-1]) == [], "fully fused!"

# 3. the objective: global-memory traffic collapse
t0, t1 = C.traffic(graph, dims), C.traffic(snapshots[-1], dims)
print()
print(f"kernel launches : {t0.launches} -> {t1.launches}")
print(f"block stores    : {sum(t0.stores.values())} -> "
      f"{sum(t1.stores.values())}")
print(f"block loads     : {sum(t0.loads.values())} -> "
      f"{sum(t1.loads.values())}")

# 4. logic preservation: interpret both against dense numpy
rng = np.random.default_rng(0)
Q = rng.normal(size=(4 * 8, d_model))
K = rng.normal(size=(8 * 8, d_model))
V = rng.normal(size=(8 * 8, 2 * 16))
inputs = {"Q": B.split(Q, 4, 2), "KT": B.split(K, 8, 2),
          "VT": B.split(V.T, 2, 8)}
S = (Q @ K.T) / np.sqrt(d_model)
P = np.exp(S - S.max(1, keepdims=True))
ref = (P / P.sum(1, keepdims=True)) @ V

out = B.merge(run_stabilized(snapshots[-1], inputs, dims)["O"])
print(f"max |fused - numpy| = {np.abs(out - ref).max():.2e}  "
      "(with the appendix's significand-exponent safety)")

# 5. the end-to-end pipeline: one call drives fuse -> selection (traffic
#    cost model) -> codegen and returns a cached, executing kernel that
#    takes plain dense arrays.  Swap backend="jax" for "py" (interpreter
#    oracle) or "pallas" (one mega-kernel; interpret-mode off-TPU).
from repro import pipeline

kern = pipeline.compile(graph, dims, backend="jax")
fused_out = np.asarray(kern({"Q": Q, "KT": K, "VT": V.T})["O"])
print()
print(f"pipeline.compile: backend={kern.backend} "
      f"snapshot={kern.snapshot_index} "
      f"predicted traffic x{kern.predicted_traffic_reduction:.2f} "
      f"max |kernel - numpy| = {np.abs(fused_out - ref).max():.2e}")
again = pipeline.compile(graph, dims, backend="jax")
print(f"second compile: cache_hit={again.cache_hit!r} "
      "(in-process; plans also persist on disk across processes)")

# 6. the decoder path: causal attention as a block program.  The mask is
#    a block-level operator fed by global query/key *position vectors*
#    (ordinary kernel inputs), so the same compiled kernel serves any
#    decode position.  The cost model knows fully-masked tiles are
#    skipped: predicted traffic is ~half the non-causal program's.
# queries and keys tile the SAME sequence (M == N block counts), which
# is what the mask-aware cost model assumes when it skips masked tiles
cdims = {"M": dims["N"], "D": dims["D"], "N": dims["N"], "L": dims["L"]}
seq = K.shape[0]
causal_graph = AP.causal_attention_program(scale=1.0 / np.sqrt(d_model))
ckern = pipeline.compile(causal_graph, cdims, backend="jax")
pos = np.arange(seq, dtype=np.float32)
Qc = np.concatenate([Q, Q], axis=0)[:seq]  # pad queries to the kv length
causal_out = np.asarray(ckern({"Q": Qc, "KT": K, "VT": V.T,
                               "QP": pos, "KP": pos})["O"])
Sc = (Qc @ K.T) / np.sqrt(d_model)
Sc = np.where(pos[:, None] >= pos[None, :], Sc, -np.inf)
Pc = np.exp(Sc - Sc.max(1, keepdims=True))
causal_ref = (Pc / Pc.sum(1, keepdims=True)) @ V
print()
print(f"causal pipeline.compile: snapshot={ckern.snapshot_index} "
      f"predicted traffic x{ckern.predicted_traffic_reduction:.2f} "
      f"max |kernel - numpy| = "
      f"{np.abs(causal_out - causal_ref).max():.2e}")
nc = C.traffic(fuse(AP.attention_program(1.0 / np.sqrt(d_model)))[-1],
               cdims).total_items()
cc = C.traffic(ckern.graph, cdims).total_items()
print(f"mask-aware cost model: causal moves {cc:.0f} items vs "
      f"{nc} non-causal at equal shapes (fully-masked tiles are free)")

# 7. multi-region Pallas lowering: EVERY snapshot lowers, not just the
#    fully fused one, and programs may have several outputs.  Here the
#    program returns both LayerNorm(X) @ Y and the normalized rows —
#    the partitioner (core/regions.py) splits the selected snapshot
#    into spine regions, emits one multi-output pallas_call per region,
#    and threads the intermediates; lowering_report proves no region
#    fell back off Pallas.
KK = 32.0
apb = AP.ArrayProgramBuilder()
x_in = apb.input("X", ("M", "K"))
yt_in = apb.input("YT", ("N", "K"))
ln = apb.layernorm_rows(x_in, KK)
z = apb.matmul_t(ln, yt_in, out_dim="N")
apb.output("Z", z)
apb.output("XN", ln)
multi = apb.build()

mdims = {"M": 2, "K": 4, "N": 2}
mblocks = {"M": 8, "K": 8, "N": 8}
mkern = pipeline.compile(multi, mdims, backend="pallas", blocks=mblocks)
X = rng.normal(size=(16, 32)).astype(np.float32)
Y = rng.normal(size=(32, 16)).astype(np.float32)
mout = mkern({"X": X, "YT": Y.T})
mu = X.mean(1, keepdims=True)
sd = np.sqrt((X ** 2).mean(1, keepdims=True) - mu ** 2)
xn_ref = (X - mu) / sd
print()
print(f"multi-output pallas: {mkern.lowering_report.summary()}")
print(f"  per-region predicted traffic: "
      + ", ".join(f"{c:.3g}" for c in mkern.region_costs))
print(f"  max |Z - numpy|  = "
      f"{np.abs(np.asarray(mout['Z']) - xn_ref @ Y).max():.2e}")
print(f"  max |XN - numpy| = "
      f"{np.abs(np.asarray(mout['XN']) - xn_ref).max():.2e}")
assert mkern.lowering_report.fallbacks == 0

# 8. measured autotuning: let selection optimize for TIME, not bytes.
#    The (calibrated) analytic traffic model prunes the block-count
#    sweep; only the top-K survivors are compiled and timed (warmup +
#    median-of-K, fenced); the wall-clock winner is what lowers and
#    caches.  The analytic choice is always among the timed candidates,
#    so the measured pick is never slower than it.  The pruning model's
#    coefficients come from the CalibrationProfile saved for this
#    (backend, device) in the kernel cache dir, if one exists —
#    `benchmarks/run.py --only pipeline` fits one for the *pallas*
#    backend from per-region kernel timings; other backends keep the
#    default constants until calibrated.
mkern2 = pipeline.compile(graph, backend="jax",
                          dim_candidates={"M": [2, 4], "D": [1, 2],
                                          "N": [4, 8], "L": [1, 2]},
                          autotune="measured", top_k=3)
print()
print(f"measured autotune: dims={mkern2.dims} "
      f"wall={mkern2.measured_s * 1e6:.0f}us "
      f"(predicted traffic x{mkern2.predicted_traffic_reduction:.2f})")
if mkern2.autotune_timings:  # None on a disk-plan hit: nothing re-timed
    for dkey, secs in mkern2.autotune_timings:
        print(f"  candidate {dict(dkey)}: {secs * 1e6:.0f}us")
else:
    print(f"  (cache_hit={mkern2.cache_hit!r}: the measured winner "
          "re-loaded without re-timing)")

# 9. region-group megakernels: the Pallas backend packs compatible
#    regions of the selected snapshot into one multi-stage pallas_call,
#    so cross-region intermediates stay VMEM-resident instead of
#    round-tripping through HBM.  Reading the lowering report:
#      - lowering_report.n_regions   how the snapshot partitioned
#      - lowering_report.launches    kernels actually launched per call
#                                    (groups; < n_regions == regions
#                                    sharing kernels)
#      - lowering_report.resident_edges  cross-region values that never
#                                    touched global memory
#      - region_costs / kernel_ids   residency-aware predicted cost per
#                                    *kernel*, paired by id (a
#                                    megakernel serving 3 regions is
#                                    one entry)
#    Example 3 is the paper's mega-kernel claim: rmsnorm -> two matmuls
#    + swish/hadamard -> matmul partitions into three regions on grids
#    (M,), (M,K), (M,N) that all share the M spine -> ONE kernel.
swiglu = AP.rmsnorm_ffn_swiglu_program(512.0)
sdims = {"M": 4, "D": 4, "K": 8, "N": 4}
sblocks = {"M": 16, "D": 16, "K": 16, "N": 16}
skern = pipeline.compile(swiglu, sdims, backend="pallas", blocks=sblocks)
srep = skern.lowering_report
print()
print(f"grouped pallas lowering: {srep.summary()}")
print(f"  {srep.n_regions} regions -> {srep.launches} launch(es), "
      f"{srep.resident_edges} VMEM-resident edges")
print(f"  predicted cost: snapshot (all edges global) {skern.cost:.3g} "
      f"-> grouped (resident edges free) {skern.grouped_cost:.3g}")
for gid, c in zip(skern.kernel_ids, skern.region_costs):
    print(f"  kernel {gid}: predicted {c:.3g}")
assert srep.fallbacks == 0 and srep.launches == 1
# group=False keeps the one-kernel-per-region schedule (spilled
# intermediates are donated via input_output_aliases); the grouped and
# ungrouped lowerings are differentially tested equal in CI
ukern = pipeline.compile(swiglu, sdims, backend="pallas", blocks=sblocks,
                         group=False)
print(f"  ungrouped for comparison: {ukern.lowering_report.launches} "
      "launches")

# 10. the compute-aware calibration profile: selection's cost model is
#     a CalibrationProfile — per-item-kind traffic coefficients plus,
#     since schema 2, per-op-class WORK coefficients (matmul /
#     elementwise / reduce FLOPs at the representative block extent), a
#     per-grid-cell instance coefficient, and per-dtype item scales
#     (bf16 blocks move half the bytes of f32, int8/fp8 a quarter).
#     The DEFAULT profile keeps every new coefficient at zero, so it
#     prices exactly the paper's bytes+launches objective —
#     bit-identical to the pre-compute-aware model.  A measured fit
#     (benchmarks/run.py --only pipeline fits one from per-kernel wall
#     times) turns the new terms on; with group=True (the pallas
#     default) selection then ranks snapshots by the SUM of grouped,
#     residency-aware kernel costs — the cost of what actually runs.
from dataclasses import replace

from repro.core import calibrate as CAL
from repro.core import selection as SEL

t_fused = C.traffic(snapshots[-1], dims)
base_cost = SEL.snapshot_cost(snapshots[-1], dims)
assert base_cost == (t_fused.bytes_moved(CAL.DEFAULT_ITEM_BYTES)
                     + CAL.KERNEL_LAUNCH_COST * t_fused.launches)
# units are arbitrary (selection only ranks): these price one matmul
# FLOP at ~1/100 the cost of moving one byte
compute_aware = replace(
    CAL.DEFAULT_PROFILE,
    work_coef={"matmul": 1e-2, "elementwise": 1e-3, "reduce": 1e-3},
    instance_coef=1e3)
print()
print("compute-aware profile (schema %d):" % CAL.PROFILE_SCHEMA)
print(f"  flops per class  : "
      + ", ".join(f"{k}={v:.3g}" for k, v in t_fused.flops().items()))
print(f"  traffic-only cost: {base_cost:.4g}")
print(f"  +work/instances  : "
      f"{SEL.snapshot_cost(snapshots[-1], dims, profile=compute_aware):.4g}")
print(f"  bf16 item coefs  : scaled x"
      f"{compute_aware.dtype_scale['bf16']} via item_coef_for('bf16')")
# grouped vs global objective on the same snapshot (what select ranks
# by under group=True):
print(f"  objective: global {SEL.objective_cost(snapshots[-1], dims):.4g}"
      f" vs grouped "
      f"{SEL.objective_cost(snapshots[-1], dims, group=True):.4g}")
# re-fit + re-pin loop: PYTHONPATH=src:. python benchmarks/run.py
#   --only pipeline --preset ci --json BENCH_ci.json   (fits + saves a
#   profile under the kernel cache; writes per-row region_spearman)
# then python benchmarks/check_regression.py --pin BENCH_ci.json \
#   benchmarks/baseline.json pins the gated keys, including the rank
#   agreement the compute-aware features bought.

# 11. numerical safety: the appendix's online-softmax pass, compiled.
#     pipeline.compile stabilizes softmax-bearing programs BY DEFAULT
#     (stabilize=None auto-detects a block-valued top-level exp via
#     numerics.needs_stabilization): numerics.stabilize rewrites the
#     exp producer into row_max / row_shift / exp(shifted) and threads
#     the exponent alongside the significand, so the fused serial spine
#     carries a running "max" with its accumulators retagged "+@k"
#     (rescale-on-new-max).  That IS Flash Attention's online softmax,
#     derived from the paper's fused program — and it lowers on every
#     backend, still as ONE Pallas launch with zero fallbacks.
#     The flag is part of the cache key (stabilized and raw kernels
#     never alias) and of the on-disk CachePlan; pass stabilize=False
#     to opt out (e.g. to reproduce the raw paper listings), or
#     stabilize=True to force it on an exp-free program (a no-op
#     rewrite there).  Exp-free programs (layernorm, swiglu) skip the
#     pass automatically: same graphs, same cache keys as before.
import warnings

huge = {"Q": (Q * 2000).astype(np.float32),   # |logit| ~ 1e4
        "KT": K.T.astype(np.float32),
        "VT": V.T.astype(np.float32)}
safe = pipeline.compile(graph, dims, backend="jax")
assert safe.stabilized           # auto-detected, no opt-in needed
out = np.asarray(safe(huge)["O"])
raw = pipeline.compile(graph, dims, backend="jax", stabilize=False)
with warnings.catch_warnings():
    warnings.simplefilter("ignore")      # overflow in exp, by design
    out_raw = np.asarray(raw(huge)["O"])
print()
print("numerical safety at |logit| ~ 1e4:")
print(f"  stabilized (default): finite={bool(np.isfinite(out).all())}")
print(f"  stabilize=False     : finite={bool(np.isfinite(out_raw).all())}")
assert np.isfinite(out).all() and not np.isfinite(out_raw).all()

# 12. the serving surface: pipeline.compile's knobs live in ONE frozen,
#     hashable CompileOptions dataclass (options=...), and the serving
#     engine (launch/engine.py) drives continuous-batching decode
#     through the compiled megakernels.  Migration note: the old flat
#     kwargs (pipeline.compile(g, dims, backend=..., blocks=...,
#     interpret=...)) still work — they are collected into a
#     CompileOptions internally and produce byte-identical cache keys —
#     but options= is the primary API: it can be stored on a
#     ModelConfig (configs.with_pipeline(cfg, options=o)), used as an
#     lru_cache/dict key, and .replace()'d per call site.  Passing both
#     forms at once is a TypeError.
opts = pipeline.CompileOptions(backend="jax", blocks={"M": 8})
k_opts = pipeline.compile(graph, dims, options=opts)
k_kw = pipeline.compile(graph, dims, backend="jax", blocks={"M": 8})
assert k_opts.key == k_kw.key          # the kwargs shim aliases exactly
assert opts == opts.replace()          # frozen + hashable
print()
print(f"CompileOptions: {opts.backend} blocks={opts.blocks_dict} "
      f"hash={hash(opts) & 0xffff:#x} (kwargs shim aliases: "
      f"{k_opts.key == k_kw.key})")

#     The serving engine: an open-loop arrival trace through a
#     slot-based scheduler.  Prompts prefill padded to a shape bucket
#     (exact under causal masking — pad keys sit at future positions);
#     every active sequence then advances one token per tick through a
#     SINGLE ragged decode step whose per-sequence cache positions are
#     kernel *data* (the §6 position vectors), so the same compiled
#     kernels serve every step: warmup compiles one prefill pipeline
#     per bucket plus the full-batch decode, and the run loop pins
#     steady-state recompiles to zero via kernel-cache stats.
#     benchmarks/serve_bench.py gates tokens/sec and the zero-recompile
#     pin in CI; python -m repro.launch.serve --backend pallas runs the
#     full CLI.
from repro import configs
from repro.launch.engine import Engine, synth_trace

serve_cfg = configs.with_pipeline(
    configs.get_reduced_config("smollm-135m", n_layers=2, d_model=64,
                               n_heads=2, n_kv_heads=2, d_head=32,
                               d_ff=128, vocab=256),
    options=pipeline.CompileOptions(backend="jax"))
engine = Engine(serve_cfg, max_batch=2, max_len=32, prompt_buckets=(8,),
                sampling="greedy", seed=0)
trace = synth_trace(4, seed=0, arrival_rate=1.0, prompt_lens=(3, 8),
                    gen_lens=(2, 4), vocab=serve_cfg.vocab)
report = engine.run(trace)
print(f"serving: {report.n_completed}/{report.n_requests} requests in "
      f"{report.steps} steps, {report.decode_tokens} tokens, "
      f"occupancy {report.mean_occupancy:.2f}, "
      f"recompiles after warmup = {report.decode_recompiles}")
assert report.n_completed == len(trace)
assert report.decode_recompiles == 0   # positions are data, not shape

# 13. what happens when things fail: every compile now runs on a
#     degradation LADDER — grouped megakernel -> ungrouped per-region
#     pallas -> jax -> interpreter.  When a rung raises (or exceeds
#     ResiliencePolicy.attempt_timeout_s), pipeline.compile demotes one
#     rung and keeps going; the kernel you get back carries the full
#     provenance in .resilience_report.  The default policy costs the
#     happy path nothing (no timeout thread, no retries — one `try`
#     around the lowering call that already existed), and demotion
#     never swallows YOUR mistakes: configuration errors (pallas
#     without blocks) still raise ValueError before any rung runs.
#
#     Triage runbook, in the order things break:
#       * kernel.resilience_report.summary() — which rung served the
#         compile and every failed attempt (rung, retry, elapsed,
#         error); demotions > 0 in production is a backend bug to
#         file, not a crash to page on.
#       * pipeline.default_cache().stats — corrupt_plans /
#         corrupt_graphs / quarantined / write_errors name every
#         recovered cache error; the corrupt bytes sit untouched in
#         <cache>/quarantine/ for inspection (entries are checksummed
#         envelopes, verified on every read, written atomically).
#       * ServeReport.failures — one structured record per poisoned /
#         deadline-evicted / rejected request and per watchdog decode
#         demotion; report.degradations + report.quarantined roll the
#         run's counters up (both pinned to ZERO on the clean path by
#         benchmarks/check_regression.py, and chaos-tested in the CI
#         `chaos` job via a seeded resilience.FaultPlan —
#         $REPRO_FAULT_PLAN drives the same machinery from the shell).
from repro import resilience as RZ

outage = RZ.FaultPlan([RZ.FaultSpec(site="compile:grouped",
                                    kind="raise",
                                    message="demo outage")])
with RZ.faults(outage), warnings.catch_warnings():
    warnings.simplefilter("ignore")  # the demotion warns; demo hides it
    k_demoted = pipeline.compile(multi, mdims, backend="pallas",
                                 blocks=mblocks,
                                 cache=pipeline.KernelCache(disk=False))
print()
print("resilience: injected a grouped-rung failure ->")
print(f"  {k_demoted.resilience_report.summary()}")
z_demoted = np.asarray(k_demoted({"X": X, "YT": Y.T})["Z"])
np.testing.assert_allclose(z_demoted, xn_ref @ Y, rtol=2e-4, atol=2e-4)
print(f"  demoted kernel output matches the reference: True "
      f"(served by rung {k_demoted.rung!r})")
assert k_demoted.rung == "ungrouped"
assert k_demoted.resilience_report.demotions == 1

# a bounded policy turns exhaustion into a typed, report-carrying error
strict = pipeline.CompileOptions(
    backend="pallas", blocks=mblocks,
    resilience=RZ.ResiliencePolicy(max_rung="ungrouped", retries=1,
                                   backoff_s=0.0))
both_down = RZ.FaultPlan([
    RZ.FaultSpec(site="compile:grouped", indices=(0, 1)),
    RZ.FaultSpec(site="compile:ungrouped", indices=(0, 1))])
with RZ.faults(both_down), warnings.catch_warnings():
    warnings.simplefilter("ignore")
    try:
        pipeline.compile(multi, mdims, options=strict,
                         cache=pipeline.KernelCache(disk=False))
        raise AssertionError("bounded ladder should have exhausted")
    except RZ.LadderError as e:
        print(f"  bounded ladder exhausted as designed: "
              f"{len(e.report.attempts)} attempts, "
              f"last rung {e.report.attempts[-1].rung!r} "
              f"(retries included)")

# 14. self-healing: failures in 13 are not forever.  Every compile
#     failure is also recorded in a per-(graph, rung) HEALTH LEDGER — a
#     circuit breaker persisted as checksummed envelopes under
#     <cache>/health/, shared across processes and restarts.  After
#     breaker_threshold consecutive failures a rung OPENS and later
#     compiles of the same graph skip it instantly (no timeout burned,
#     no recompile attempted); after an exponential cool-down
#     (breaker_cooldown_s, doubling per trip up to
#     breaker_cooldown_max_s) the next compile becomes a HALF-OPEN
#     PROBE that re-tries the rung for real — success closes the
#     breaker and deletes the entry, failure re-opens it at doubled
#     cool-down.  The serving engine runs the same lifecycle on its
#     decode ladder: after `repromote_after` clean ticks on a demoted
#     rung, a probe re-compiles the original OFF the hot path, checks
#     its logits are finite, and swaps it back mid-run
#     (ServeReport.repromotions / probes / probe_failures; the CI
#     `chaos` job's heal step pins the full demote -> failed probe ->
#     doubled cool-down -> re-promotion arc against a seeded plan).
#
#     Triage knobs, in the order you reach for them:
#       * ResiliencePolicy(breaker_threshold=...) — consecutive
#         failures before a rung opens; 0 disables the breaker.
#       * breaker_cooldown_s / breaker_cooldown_max_s — the probe
#         cadence (doubles per trip, capped).
#       * cache.health.entries() — every open/half-open rung on disk:
#         failures, trips, cool-down, last error (the triage view).
#       * cache.health.stats — reads/writes/skipped_open/probes; ALL
#         ZERO on the happy path (no ledger I/O until a rung fails —
#         <cache>/health/ is not even created).
#       * Engine(repromote_after=N) / serve --repromote-after N — clean
#         decode ticks before a re-promotion probe; 0/None disables.
#     The cache also self-repairs at startup: KernelCache() sweeps
#     orphaned *.tmp files from crashed writers, removes stale unheld
#     .lock files, and caps <cache>/quarantine/ at a byte budget
#     ($REPRO_QUARANTINE_MAX_BYTES), counting every action in
#     CacheStats.recovered_tmp / stale_locks / quarantine_evicted.
hcache = pipeline.KernelCache(disk=False)  # in-memory demo ledger
flaky = RZ.FaultPlan([RZ.FaultSpec(site="compile:jax", indices=(0, 1))])
jax_opts = pipeline.CompileOptions(
    backend="jax",
    resilience=RZ.ResiliencePolicy(breaker_threshold=2,
                                   breaker_cooldown_s=3600.0))
with RZ.faults(flaky), warnings.catch_warnings():
    warnings.simplefilter("ignore")
    pipeline.compile(multi, mdims, options=jax_opts, cache=hcache)
    pipeline.compile(multi, {**mdims, "M": 8}, options=jax_opts,
                     cache=hcache)     # second failure -> breaker opens
    k_skip = pipeline.compile(multi, {**mdims, "M": 16},
                              options=jax_opts, cache=hcache)
print()
print("self-healing: tripped the jax-rung breaker ->")
print(f"  {k_skip.resilience_report.summary()}")
assert k_skip.resilience_report.skipped_open == 1   # skipped, not run
# fast-forward the ledger's (injectable) clock past the cool-down: the
# NEXT compile becomes a half-open probe, and with the fault plan
# exhausted it succeeds and heals the rung
hcache.health.clock = lambda: float("inf")
k_heal = pipeline.compile(multi, {**mdims, "M": 32}, options=jax_opts,
                          cache=hcache)
assert k_heal.rung == "jax" and k_heal.resilience_report.probes == 1
print(f"  probe healed the rung: served by {k_heal.rung!r}, "
      f"breaker {hcache.health.state(multi.fingerprint(), 'jax')!r}")
