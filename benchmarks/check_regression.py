"""CI benchmark-regression gate.

    python benchmarks/check_regression.py BENCH_ci.json benchmarks/baseline.json

Compares a fresh ``run.py --only pipeline --preset ci --json BENCH_ci.json``
run against the committed baseline and exits non-zero if

  * any pipeline row's **predicted traffic reduction** regresses more
    than 10% below the baseline (the fusion objective got worse for the
    same program/config),
  * any **Pallas region falls back** off the Pallas backend in ANY row,
    baseline-listed or new (``pallas_fallbacks != 0`` — the selected
    snapshot must lower),
  * any pinned row's **kernel launch count** grows (``launches`` — the
    grouped megakernel schedule split apart, paying launches and HBM
    round-trips the baseline avoided),
  * any pinned row's **calibrated region rank agreement**
    (``region_spearman`` — predicted vs measured per-kernel times under
    the fitted profile) drops more than 0.5 below the baseline (the
    compute-aware cost model re-learned a rank inversion),
  * the **wall-clock fused-vs-unfused speedup** — the geometric mean of
    the per-row ratios — collapses by more than ``WALL_TOLERANCE``
    (1.5x) below the baseline's.  Generous on purpose: absolute wall
    times are never compared across machines, only the same-machine
    fused/unfused *ratio*; it is aggregated over every program so
    single-row scheduler noise averages out; and only a >1.5x collapse
    fails so shared-runner noise cannot,
  * the same geomean speedup falls below **1.0x** in absolute terms —
    fusion slower than the launch-per-operator baseline is wrong no
    matter what the pin says (the baseline is per-op jitted, so this is
    fusion vs genuinely-no-fusion, not vs XLA's own fusion), or
  * a baseline row is missing from the fresh run.

``serve_*`` rows (from ``benchmarks/serve_bench.py``) are gated too:
the deterministic scheduler counters (completed/rejected/stalled
requests, warmup compile count) are pinned **exactly** — the synthetic
trace is seeded, so any drift is a scheduler behaviour change — while
**decode recompiles** and **Pallas fallbacks** must be zero on every
current serve row, pinned or not (one persistent megakernel per shape
bucket is the whole point of the serving tentpole), and so must the
resilience and self-healing counters (``degradations``,
``quarantined``, ``repromotions``, ``probes``, ``probe_failures`` —
the clean path never demotes, never probes, never heals).  Throughput
(``tokens_per_s``) gets the same generous same-machine treatment as the
speedup ratio: only a >1.5x collapse below the pin fails.

Absolute wall-clock columns are never gated — CI runners are too noisy;
the tightly-gated quantities are deterministic functions of the cost
model and the lowering, and the only timing key gated (the speedup
ratio) gets the generous threshold above.

Re-pin the baseline with

    python benchmarks/check_regression.py --pin BENCH_ci.json benchmarks/baseline.json

which writes ONLY the gated keys (predicted traffic reduction, region
and fallback counts, speedup ratio) so baseline diffs show real
changes, not machine-local wall-clock noise.
"""

from __future__ import annotations

import json
import sys

TOLERANCE = 0.10  # fail when reduction drops >10% below baseline
WALL_TOLERANCE = 1.5  # fail when speedup collapses >1.5x below baseline
SPEARMAN_TOLERANCE = 0.5  # fail when region rank agreement drops by more
GATED_KEYS = ("pred_traffic_reduction", "pallas_regions",
              "pallas_fallbacks", "launches", "resident_edges", "speedup",
              "region_spearman")
# serving rows: exact pins for the deterministic scheduler counters,
# ratio-gated throughput, and the zero-recompile / zero-fallback pins.
# degradations/quarantined are the resilience counters, and
# repromotions/probes/probe_failures the self-healing counters: all
# pinned at zero on the clean path (neither the fault machinery nor the
# health ledger may cost the happy path)
GATED_SERVE_KEYS = ("tokens_per_s", "completed", "rejected", "stalled",
                    "warmup_compiles", "decode_recompiles",
                    "pallas_fallbacks", "degradations", "quarantined",
                    "repromotions", "probes", "probe_failures")
SERVE_EXACT_KEYS = ("completed", "rejected", "stalled", "warmup_compiles",
                    "degradations", "quarantined", "repromotions",
                    "probes", "probe_failures")


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def _rows(path: str, prefix: str = "pipeline_") -> dict:
    with open(path) as f:
        data = json.load(f)
    rows = data["rows"] if isinstance(data, dict) else data
    return {r["name"]: _parse_derived(r["derived"]) for r in rows
            if r["name"].startswith(prefix)}


def _reduction(derived: dict) -> float:
    return float(derived["pred_traffic_reduction"].rstrip("x"))


def _pin(current_path: str, baseline_path: str) -> int:
    """Write a gated-keys-only baseline from a fresh run."""
    with open(current_path) as f:
        data = json.load(f)
    rows = data["rows"] if isinstance(data, dict) else data
    pinned = []
    for r in rows:
        if r["name"].startswith("pipeline_"):
            keys = GATED_KEYS
        elif r["name"].startswith("serve_"):
            keys = GATED_SERVE_KEYS
        else:
            continue
        derived = _parse_derived(r["derived"])
        kept = ";".join(f"{k}={derived[k]}" for k in keys if k in derived)
        pinned.append({"name": r["name"], "derived": kept})
    with open(baseline_path, "w") as f:
        json.dump({"preset": data.get("preset", "ci"), "rows": pinned}, f,
                  indent=2)
        f.write("\n")
    print(f"pinned {len(pinned)} row(s) -> {baseline_path}")
    return 0


def main(argv) -> int:
    if len(argv) == 4 and argv[1] == "--pin":
        return _pin(argv[2], argv[3])
    if len(argv) != 3:
        print(__doc__)
        return 2
    current, baseline = _rows(argv[1]), _rows(argv[2])
    failures, improved = [], []
    print(f"{'benchmark':32s} {'base':>8s} {'now':>8s}  verdict")
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run")
            continue
        base_red, cur_red = _reduction(base), _reduction(cur)
        floor = base_red * (1.0 - TOLERANCE)
        verdict = "ok"
        if cur_red < floor:
            verdict = "REGRESSED"
            failures.append(
                f"{name}: predicted traffic reduction {cur_red:.2f}x < "
                f"{floor:.2f}x (baseline {base_red:.2f}x - {TOLERANCE:.0%})")
        elif cur_red > base_red * (1.0 + TOLERANCE):
            verdict = "improved (re-pin baseline?)"
            improved.append(name)
        # region count is pinned too: MORE regions for the same program
        # is a partitioning regression; fewer is an improvement worth
        # re-pinning
        base_rg, cur_rg = base.get("pallas_regions"), cur.get(
            "pallas_regions")
        if base_rg is not None and cur_rg is not None:
            if int(cur_rg) > int(base_rg):
                verdict = "MORE REGIONS"
                failures.append(
                    f"{name}: selected snapshot now partitions into "
                    f"{cur_rg} regions (baseline {base_rg})")
            elif int(cur_rg) < int(base_rg) and verdict == "ok":
                verdict = "improved (re-pin baseline?)"
                improved.append(name)
        # launch count: the grouped megakernel schedule must not split
        # apart (every extra launch pays a cross-kernel HBM round-trip)
        base_l, cur_l = base.get("launches"), cur.get("launches")
        if base_l is not None and cur_l is not None:
            if int(cur_l) > int(base_l):
                verdict = "MORE LAUNCHES"
                failures.append(
                    f"{name}: grouped lowering now launches {cur_l} "
                    f"kernels (baseline {base_l})")
            elif int(cur_l) < int(base_l) and verdict == "ok":
                verdict = "improved (re-pin baseline?)"
                improved.append(name)
        # calibrated region rank agreement: the per-row Spearman of
        # predicted vs measured per-kernel times must not collapse (a
        # drop > SPEARMAN_TOLERANCE below the pin means the cost model
        # re-learned a rank inversion the baseline had fixed); measured
        # per-kernel seconds are noisy on shared runners, so only a
        # large drop fails
        base_sp, cur_sp = base.get("region_spearman"), cur.get(
            "region_spearman")
        if base_sp is not None and cur_sp is not None:
            if float(cur_sp) < float(base_sp) - SPEARMAN_TOLERANCE:
                verdict = "RANK INVERTED"
                failures.append(
                    f"{name}: region_spearman {float(cur_sp):.2f} < "
                    f"{float(base_sp):.2f} - {SPEARMAN_TOLERANCE} "
                    "(predicted-vs-measured region ranking collapsed)")
        print(f"{name:32s} {base_red:7.2f}x {cur_red:7.2f}x  {verdict}")
    # wall-clock gate: the same-machine fused/unfused speedup ratio,
    # aggregated (geometric mean) over every row both runs share so
    # single-row scheduler noise averages out, with a deliberately
    # generous threshold for shared runners
    shared = [(float(baseline[n]["speedup"].rstrip("x")),
               float(current[n]["speedup"].rstrip("x")))
              for n in sorted(set(baseline) & set(current))
              if "speedup" in baseline[n] and "speedup" in current[n]]
    if shared:
        import math
        base_geo = math.exp(sum(math.log(max(b, 1e-9))
                                for b, _ in shared) / len(shared))
        cur_geo = math.exp(sum(math.log(max(c, 1e-9))
                               for _, c in shared) / len(shared))
        floor = base_geo / WALL_TOLERANCE
        print(f"{'wall-clock (geomean speedup)':32s} {base_geo:7.2f}x "
              f"{cur_geo:7.2f}x  "
              f"{'ok' if cur_geo >= floor else 'WALL REGRESSED'}")
        if cur_geo < floor:
            failures.append(
                f"wall-clock: geomean fused-vs-unfused speedup "
                f"{cur_geo:.2f}x < {floor:.2f}x (baseline "
                f"{base_geo:.2f}x / {WALL_TOLERANCE})")
        # absolute floor, independent of the pin: fused code slower
        # than the launch-per-operator baseline is a regression even if
        # an old baseline was pinned that low
        if cur_geo < 1.0:
            failures.append(
                f"wall-clock: geomean fused-vs-unfused speedup "
                f"{cur_geo:.2f}x < 1.00x — fusion is slower than the "
                "per-op unfused baseline")
    # the fallback gate covers EVERY current row, including programs not
    # yet pinned into the baseline — a new benchmark may not sneak a
    # non-lowering snapshot past the gate
    for name, cur in sorted(current.items()):
        fb = cur.get("pallas_fallbacks")
        if fb is not None and fb != "0":
            failures.append(f"{name}: {fb} Pallas region(s) fell back to "
                            "the jax backend")
    # -- serving rows (benchmarks/serve_bench.py) ---------------------------
    cur_srv, base_srv = _rows(argv[1], "serve_"), _rows(argv[2], "serve_")
    for name, base in sorted(base_srv.items()):
        cur = cur_srv.get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run")
            continue
        verdict = "ok"
        base_tps = float(base["tokens_per_s"])
        cur_tps = float(cur["tokens_per_s"])
        floor = base_tps / WALL_TOLERANCE
        if cur_tps < floor:
            verdict = "THROUGHPUT COLLAPSED"
            failures.append(
                f"{name}: {cur_tps:.0f} tokens/s < {floor:.0f} (baseline "
                f"{base_tps:.0f} / {WALL_TOLERANCE})")
        # the trace is seeded: scheduler counters are deterministic and
        # pinned exactly — any drift is a behaviour change, not noise
        for k in SERVE_EXACT_KEYS:
            if k in base and k in cur and base[k] != cur[k]:
                verdict = "SCHEDULER DRIFT"
                failures.append(f"{name}: {k}={cur[k]} (baseline pinned "
                                f"{base[k]})")
        print(f"{name:32s} {base_tps:7.0f}t {cur_tps:7.0f}t  {verdict}")
    # zero-recompile / zero-fallback pins cover EVERY current serve row,
    # baseline-listed or new — a steady-state decode step that compiles
    # (or a region that falls off the megakernel path) always fails
    for name, cur in sorted(cur_srv.items()):
        for k in ("decode_recompiles", "pallas_fallbacks",
                  "degradations", "quarantined", "repromotions",
                  "probes", "probe_failures"):
            v = cur.get(k)
            if v is not None and v != "0":
                failures.append(f"{name}: {k}={v} (must be 0)")
    extra = sorted(set(current) - set(baseline))
    if extra:
        print("note: rows not in baseline (traffic unchecked, fallbacks "
              f"still gated): {', '.join(extra)}")
    if failures:
        print("\nBENCH GATE FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench gate passed"
          + (f" ({len(improved)} row(s) improved)" if improved else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
