"""jamba-1.5-large-398b [hybrid]: Mamba + attention 1:7 interleave (one
attention layer per 8), MoE 16 experts top-2 on every other layer.  The
mamba layers use the Mamba-2 SSD form (one SSM implementation across the
zoo; noted in DESIGN.md).  [arXiv:2403.19887; hf]"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab=65536,
    rope_theta=0.0,        # jamba uses no positional encoding
    attn_period=8,
    n_experts=16,
    top_k=2,
    moe_d_ff=24576,
    moe_period=2,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
)
