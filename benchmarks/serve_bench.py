"""Serving-loop benchmark: replay a fixed synthetic open-loop trace
through the continuous-batching engine (``launch/engine.py``) and emit
the gated numbers — tokens/sec, p50/p99 per-token latency, occupancy,
and the zero-recompile / zero-fallback pins.

    PYTHONPATH=src:. python benchmarks/serve_bench.py --preset ci \
        --json SERVE_ci.json --report serve_report.json

Row format matches ``benchmarks/run.py`` (``name,us_per_call,derived``)
so ``check_regression.py`` gates ``serve_*`` rows the same way it gates
``pipeline_*`` rows: tokens/sec may not collapse >1.5x below the pinned
baseline, and any steady-state decode recompile or Pallas fallback
fails outright.  Determinstic keys (completed/rejected counts, compile
counts) are pinned exactly.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.launch.serve import ServeConfig, run

PRESETS = {
    # tiny fixed trace for CI runners: small slot count, short prompts
    "ci": ServeConfig(arch="smollm-135m", backend="pallas", max_batch=2,
                      max_len=64, prompt_buckets=(8, 16), n_requests=8,
                      arrival_rate=1.0, prompt_lens=(4, 14),
                      gen_lens=(3, 8), seed=0, keep_per_step=False),
    # the trajectory pin at repo root (BENCH_serve.json)
    "full": ServeConfig(arch="smollm-135m", backend="pallas", max_batch=4,
                        max_len=96, prompt_buckets=(8, 16, 32),
                        n_requests=32, arrival_rate=1.0,
                        prompt_lens=(4, 30), gen_lens=(6, 16), seed=0,
                        keep_per_step=False),
}


def bench(preset: str) -> dict:
    cfg = PRESETS[preset]
    report = run(cfg)
    total_tokens = report.prefill_tokens + report.decode_tokens
    us_per_token = (report.wall_s * 1e6 / max(report.decode_tokens, 1))
    derived = ";".join([
        f"tokens_per_s={report.tokens_per_s:.1f}",
        f"decode_tokens_per_s={report.decode_tokens_per_s:.1f}",
        f"p50_ms={report.p50_token_ms:.2f}",
        f"p99_ms={report.p99_token_ms:.2f}",
        f"mean_occupancy={report.mean_occupancy:.2f}",
        f"max_queue_depth={report.max_queue_depth}",
        f"steps={report.steps}",
        f"total_tokens={total_tokens}",
        f"completed={report.n_completed}",
        f"rejected={report.n_rejected}",
        f"stalled={report.n_evicted_stalled}",
        f"warmup_compiles={report.warmup_compiles}",
        f"decode_recompiles={report.decode_recompiles}",
        f"pallas_fallbacks={report.pallas_fallbacks}",
        f"cache_hit_rate={report.cache_hit_rate:.3f}",
    ])
    row = {"name": f"serve_{cfg.arch}_{preset}",
           "us_per_call": us_per_token, "derived": derived}
    return {"row": row, "report": report}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=sorted(PRESETS))
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the gate-format rows file")
    ap.add_argument("--report", default=None,
                    help="write the full ServeReport JSON")
    args = ap.parse_args(argv)

    out = bench(args.preset)
    row, report = out["row"], out["report"]
    print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"preset": args.preset, "rows": [row]}, f, indent=2)
            f.write("\n")
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report.to_json(), f, indent=1)
    return 1 if (report.decode_recompiles or report.pallas_fallbacks) else 0


if __name__ == "__main__":
    sys.exit(main())
