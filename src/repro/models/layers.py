"""Layer implementations shared across the 10-architecture zoo.

Every layer is a pair of functions:
  * ``init_<layer>(pb, cfg)``          — adds params+specs to a ParamBuilder
  * ``<layer>_apply(p, x, cfg, ...)``  — forward (full sequence)
  * ``<layer>_decode(p, x, cache, ...)`` — one-token step with cache

The paper's fused kernels are wired in here: attention uses the fused
flash kernel (Example 1), gated MLPs use Flash-RMSNorm+FFN-SwiGLU
(Example 3), whisper's LayerNorm+fc1 uses Flash-LayerNorm+Matmul
(Example 2).  ``cfg.attn_impl`` / ``cfg.mlp_impl`` select Pallas vs the
XLA-level fused lowering (dry-run / CPU).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.models.common import (ModelConfig, ParamBuilder, apply_rope,
                                 layer_norm, rms_norm)
from repro.runtime.sharding import constrain


# ---------------------------------------------------------------------------
# Fusion-pipeline execution path (repro.pipeline): layers compile their
# block program through fuse -> select -> codegen and run the resulting
# cached kernel.  Selected by ``cfg.attn_impl``/``cfg.mlp_impl`` ==
# "pipeline"; ``cfg.pipeline_backend`` picks the codegen backend.
# ---------------------------------------------------------------------------

def _n_blocks(size: int, target: int = 128) -> int:
    """Smallest block count that divides ``size`` evenly with blocks at
    most ``target`` wide.  When only pathologically thin blocks would
    qualify (no divisor yields a block within target/4..target — e.g. a
    prime size), keep the dim whole instead of shattering it."""
    cnt = max(1, -(-size // target))
    while size % cnt:
        cnt += 1
    if cnt > 1 and (size // cnt) * 4 < target:
        return 1
    return cnt


def _pipeline_dims_blocks(sizes):
    dims = {d: _n_blocks(s) for d, s in sizes.items()}
    blocks = {d: sizes[d] // n for d, n in dims.items()}
    return dims, blocks


def _pipeline_options(src):
    """Resolve a :class:`pipeline.CompileOptions` from a ModelConfig, a
    bare backend string (back-compat), or an options instance."""
    from repro import pipeline as PL
    if isinstance(src, PL.CompileOptions):
        return src
    if isinstance(src, str):
        return PL.CompileOptions(backend=src)
    if src.pipeline_options is not None:
        return src.pipeline_options
    return PL.CompileOptions(backend=src.pipeline_backend)


@functools.lru_cache(maxsize=256)
def _attention_kernel(s: int, dh: int, sk: int, dv: int, group: int,
                      causal: bool, scale: float, options):
    """One compiled kernel per (shape, group, causal, scale, options); the
    lru_cache skips graph reconstruction + fingerprinting on every forward
    call (CompileOptions is hashable, so it keys directly).  Query
    positions are kernel *data* (QP/KP inputs), so a decode step at any
    cache position — scalar or a ragged per-sequence position vector —
    reuses the same compiled kernel."""
    from repro import pipeline as PL
    from repro.core import array_program as AP
    dims, blocks = _pipeline_dims_blocks(
        {"M": s, "D": dh, "N": sk, "L": dv})
    if group > 1:
        g = AP.gqa_attention_program(scale, causal=causal)
        dims["H"] = group
        blocks["H"] = 1  # the head-group dim is a stack axis
    elif causal:
        g = AP.causal_attention_program(scale)
    else:
        g = AP.attention_program(scale)
    return PL.compile(g, dims, options=options.replace(blocks=blocks))


@functools.lru_cache(maxsize=256)
def _swiglu_kernel(t: int, d: int, d_ff: int, eps: float, options):
    from repro import pipeline as PL
    from repro.core import array_program as AP
    dims, blocks = _pipeline_dims_blocks(
        {"M": t, "D": d, "K": d_ff, "N": d})
    return PL.compile(
        AP.rmsnorm_ffn_swiglu_program(float(d), eps=eps), dims,
        options=options.replace(blocks=blocks))


def _attention_pipeline(q, k, v, scale: float, options, *,
                        causal: bool = False, q_offset=0) -> jax.Array:
    """Attention through the fused pipeline — causal or not, MHA or GQA.

    One compiled kernel per (shape, group, causal, options), vmapped over
    batch and kv heads.  GQA runs the head-group block program (Q blocked
    (H, M, D); K/V broadcast across the group).  Causal masking takes the
    global query/key positions as kernel inputs, so decode (``q`` is one
    token at cache position ``q_offset``) is the same program with M = 1
    and needs no recompile as the position advances.  ``q_offset`` may be
    a scalar (every sequence at the same position) or a ``(b,)`` vector
    (ragged continuous-batching decode: each sequence at its own cache
    position) — the ragged case maps the per-sequence position vector
    into the kernel's QP input, same compiled kernel either way."""
    opts = _pipeline_options(options)
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[3]
    group = hq // hkv
    kern = _attention_kernel(sq, dh, skv, dv, group, causal, scale, opts)
    kp = jnp.arange(skv, dtype=jnp.float32)

    def one(qh, kh, vh, qp):
        feed = {"Q": qh.astype(jnp.float32),
                "KT": kh.astype(jnp.float32),
                "VT": vh.astype(jnp.float32).T}
        if causal:
            feed["QP"], feed["KP"] = qp, kp
        return kern(feed)["O"]

    off = jnp.asarray(q_offset, dtype=jnp.float32)
    qp = off[..., None] + jnp.arange(sq, dtype=jnp.float32)
    # heads share the position vector; the batch axis maps it only when
    # q_offset is ragged (per-sequence)
    inner = jax.vmap(one, in_axes=(0, 0, 0, None))
    outer = jax.vmap(inner, in_axes=(0, 0, 0, 0 if off.ndim == 1 else None))
    if group > 1:
        qg = q.reshape(b, hkv, group, sq, dh)
        o = outer(qg, k, v, qp)                    # (b, hkv, group, sq, dv)
        o = o.reshape(b, hq, sq, dv)
    else:
        o = outer(q, k, v, qp)
    return o.astype(q.dtype)


def _swiglu_pipeline(x2, wg, wu, wd, gamma, cfg: ModelConfig) -> jax.Array:
    """RMSNorm+FFN-SwiGLU through the fused pipeline.  The norm gain is
    folded into W/V columns (RMS(x)*g @ W == RMS(x) @ diag(g)W), so the
    paper's gain-free Example-3 program applies unchanged."""
    t, d = x2.shape
    d_ff = wg.shape[1]
    kern = _swiglu_kernel(t, d, d_ff, float(cfg.norm_eps),
                          _pipeline_options(cfg))
    gf = gamma.astype(jnp.float32)[:, None]
    out = kern({"X": x2.astype(jnp.float32),
                "WT": (gf * wg.astype(jnp.float32)).T,
                "VT": (gf * wu.astype(jnp.float32)).T,
                "UT": wd.astype(jnp.float32).T})["O"]
    return out.astype(x2.dtype)


# ---------------------------------------------------------------------------
# GQA attention (qwen2/llama3/qwen3/internvl/jamba/whisper-self)
# ---------------------------------------------------------------------------

def init_attention(pb: ParamBuilder, cfg: ModelConfig) -> None:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    pb.dense("wq", (d, h * dh), ("fsdp", "tensor"))
    pb.dense("wk", (d, hkv * dh), ("fsdp", "tensor"))
    pb.dense("wv", (d, hkv * dh), ("fsdp", "tensor"))
    pb.dense("wo", (h * dh, d), ("tensor", "fsdp"))
    if cfg.qkv_bias:
        pb.zeros("bq", (h * dh,), ("tensor",))
        pb.zeros("bk", (hkv * dh,), ("tensor",))
        pb.zeros("bv", (hkv * dh,), ("tensor",))
    if cfg.qk_norm:
        pb.ones("q_norm", (dh,), (None,))
        pb.ones("k_norm", (dh,), (None,))


def _qkv(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "tensor", None, None)
    k = constrain(k, "batch", "tensor", None, None)
    v = constrain(v, "batch", "tensor", None, None)
    return q, k, v


def attention_apply(p, x, cfg: ModelConfig, *, causal=True,
                    positions=None) -> jax.Array:
    b, s, _ = x.shape
    if positions is None and cfg.rope_theta > 0:
        positions = jnp.arange(s)
    q, k, v = _qkv(p, x, cfg, positions)
    if cfg.attn_impl == "pipeline":
        # fusion-derived flash kernel via the pipeline driver — causal
        # (decoder prefill) and GQA included; no XLA fallback.  Two
        # hand-kernel knobs do not apply here: attn_p_half/unroll_scans
        # belong to kernels/flash_attention.py.  The driver stabilizes
        # softmax-bearing programs by default (numerics.stabilize: the
        # online-softmax rewrite, compiled on every backend), so the
        # generated kernel is finite at any logit magnitude.
        o = _attention_pipeline(q, k, v, 1.0 / cfg.d_head ** 0.5,
                                cfg, causal=causal)
    else:
        o = K.flash_attention(q, k, v, causal=causal, impl=cfg.attn_impl,
                              unroll=cfg.unroll_scans,
                              p_half=cfg.attn_p_half)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.d_head)
    return constrain(o @ p["wo"], "batch", None, None)


def attention_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hkv, dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((batch, hkv, max_len, dh), dtype),
        "v": jnp.zeros((batch, hkv, max_len, dh), dtype),
    }


def attention_cache_specs(cfg: ModelConfig):
    return {"k": ("batch", "tensor", "kv_seq", None),
            "v": ("batch", "tensor", "kv_seq", None)}


def attention_decode(p, x, cache, pos, cfg: ModelConfig):
    """One-token decode: insert k/v at ``pos``, attend over the cache.

    ``pos`` is either a scalar (every sequence at the same position — the
    classic lockstep batch) or a ``(b,)`` int vector (ragged
    continuous-batching step: each sequence writes its k/v at its own
    cache position and masks its own causal frontier).  Both run the same
    compiled kernels — positions are data, not shape."""
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    ragged = pos.ndim == 1
    if cfg.rope_theta > 0:
        # (b,1,1) broadcasts per-sequence angles through apply_rope's
        # (..., S, Dh) convention; scalar keeps the shared (1,) vector
        positions = pos[:, None, None] if ragged else pos.reshape(1)
    else:
        positions = None
    q, k, v = _qkv(p, x, cfg, positions)
    if ragged:
        def put(buf, val, pv):  # per sequence: (hkv, max_len, dh) at pv
            return jax.lax.dynamic_update_slice(buf, val, (0, pv, 0))
        ck = jax.vmap(put)(cache["k"], k.astype(cache["k"].dtype), pos)
        cv = jax.vmap(put)(cache["v"], v.astype(cache["v"].dtype), pos)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, pos, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, pos, 0))
    # mask positions beyond pos via the causal path with explicit offset
    if cfg.attn_impl == "pipeline":
        o = _attention_pipeline(q, ck, cv, 1.0 / cfg.d_head ** 0.5,
                                cfg, causal=True, q_offset=pos)
    else:
        o = K.flash_attention(q, ck, cv, causal=True, q_offset=pos,
                              impl=cfg.attn_impl,
                              unroll=cfg.unroll_scans)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.d_head)
    return constrain(o @ p["wo"], "batch", None, None), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v3): low-rank q/kv compression, decoupled RoPE,
# compressed-KV cache with the absorbed decode form.
# ---------------------------------------------------------------------------

def init_mla(pb: ParamBuilder, cfg: ModelConfig) -> None:
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        pb.dense("wq_a", (d, cfg.q_lora_rank), ("fsdp", None))
        pb.ones("q_norm", (cfg.q_lora_rank,), (None,))
        pb.dense("wq_b", (cfg.q_lora_rank, h * qd), (None, "tensor"))
    else:
        pb.dense("wq", (d, h * qd), ("fsdp", "tensor"))
    pb.dense("wkv_a", (d, cfg.kv_lora_rank + cfg.qk_rope_dim), ("fsdp", None))
    pb.ones("kv_norm", (cfg.kv_lora_rank,), (None,))
    pb.dense("wkv_b",
             (cfg.kv_lora_rank, h * (cfg.qk_nope_dim + cfg.v_head_dim)),
             (None, "tensor"))
    pb.dense("wo", (h * cfg.v_head_dim, d), ("tensor", "fsdp"))


def _mla_q(p, x, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        ql = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
        q = ql @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, h, qd).transpose(0, 2, 1, 3)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_compressed(p, x, cfg: ModelConfig, positions):
    ckv, k_rope = jnp.split(x @ p["wkv_a"], [cfg.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)[:, 0]
    return ckv, k_rope  # (B,S,r), (B,S,rope)


def mla_apply(p, x, cfg: ModelConfig, *, causal=True,
              positions=None) -> jax.Array:
    b, s, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    ckv, k_rope = _mla_kv_compressed(p, x, cfg, positions)
    kv = (ckv @ p["wkv_b"]).reshape(
        b, s, h, cfg.qk_nope_dim + cfg.v_head_dim).transpose(0, 2, 1, 3)
    k_nope, v = jnp.split(kv, [cfg.qk_nope_dim], axis=-1)
    k_rope_h = jnp.broadcast_to(k_rope[:, None],
                                (b, h, s, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_h], -1)
    q = constrain(q, "batch", "tensor", None, None)
    k = constrain(k, "batch", "tensor", None, None)
    scale = 1.0 / (cfg.qk_nope_dim + cfg.qk_rope_dim) ** 0.5
    impl = cfg.attn_impl if cfg.attn_impl in ("xla", "ref") else "xla"
    o = K.flash_attention(q, k, v, scale=scale, causal=causal, impl=impl,
                          unroll=cfg.unroll_scans)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * cfg.v_head_dim)
    return constrain(o @ p["wo"], "batch", None, None)


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_cache_specs(cfg: ModelConfig):
    return {"ckv": ("batch", "kv_seq", None),
            "krope": ("batch", "kv_seq", None)}


def mla_decode(p, x, cache, pos, cfg: ModelConfig):
    """Absorbed decode: attention runs against the *compressed* cache
    (this is MLA's serving trick; the per-token cache is r+rope wide).

    Like ``attention_decode``, ``pos`` is a scalar or a ``(b,)`` vector
    (ragged continuous-batching step)."""
    b = x.shape[0]
    h = cfg.n_heads
    pos = jnp.asarray(pos, jnp.int32)
    ragged = pos.ndim == 1
    positions = pos[:, None, None] if ragged else pos.reshape(1)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)       # (b,h,1,*)
    ckv_t, krope_t = _mla_kv_compressed(p, x, cfg, positions)
    if ragged:
        def put(buf, val, pv):  # per sequence: (max_len, width) at pv
            return jax.lax.dynamic_update_slice(buf, val, (pv, 0))
        ckv = jax.vmap(put)(cache["ckv"],
                            ckv_t.astype(cache["ckv"].dtype), pos)
        krope = jax.vmap(put)(cache["krope"],
                              krope_t.astype(cache["krope"].dtype), pos)
    else:
        ckv = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_t.astype(cache["ckv"].dtype), (0, pos, 0))
        krope = jax.lax.dynamic_update_slice(
            cache["krope"], krope_t.astype(cache["krope"].dtype),
            (0, pos, 0))

    wkv_b = p["wkv_b"].reshape(cfg.kv_lora_rank, h,
                               cfg.qk_nope_dim + cfg.v_head_dim)
    w_uk = wkv_b[:, :, :cfg.qk_nope_dim]                 # (r,h,nope)
    w_uv = wkv_b[:, :, cfg.qk_nope_dim:]                 # (r,h,v)
    q_abs = jnp.einsum("bhqn,rhn->bhqr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))         # (b,h,1,r)
    scale = 1.0 / (cfg.qk_nope_dim + cfg.qk_rope_dim) ** 0.5
    s = (jnp.einsum("bhqr,bsr->bhqs", q_abs, ckv.astype(jnp.float32))
         + jnp.einsum("bhqe,bse->bhqs", q_rope.astype(jnp.float32),
                      krope.astype(jnp.float32))) * scale
    cols = jnp.arange(ckv.shape[1])[None, None, None, :]
    frontier = pos[:, None, None, None] if ragged else pos
    s = jnp.where(cols <= frontier, s, -1e30)
    m = s.max(-1, keepdims=True)
    pr = jnp.exp(s - m)
    pr = pr / pr.sum(-1, keepdims=True)
    ctx = jnp.einsum("bhqs,bsr->bhqr", pr, ckv.astype(jnp.float32))
    o = jnp.einsum("bhqr,rhv->bhqv", ctx, w_uv.astype(jnp.float32))
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * cfg.v_head_dim)
    o = o.astype(x.dtype)
    return (constrain(o @ p["wo"], "batch", None, None),
            {"ckv": ckv, "krope": krope})


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU) — fused with the preceding RMSNorm (paper Example 3)
# ---------------------------------------------------------------------------

def init_swiglu(pb: ParamBuilder, cfg: ModelConfig, d_ff: int,
                prefix: str = "") -> None:
    d = cfg.d_model
    pb.dense(prefix + "w_gate", (d, d_ff), ("fsdp", "tensor"))
    pb.dense(prefix + "w_up", (d, d_ff), ("fsdp", "tensor"))
    pb.dense(prefix + "w_down", (d_ff, d), ("tensor", "fsdp"))


def rmsnorm_swiglu_apply(p, x, gamma, cfg: ModelConfig,
                         prefix: str = "") -> jax.Array:
    """O = (swish(RMS_g(x) @ Wg) * (RMS_g(x) @ Wu)) @ Wd, fused."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    if cfg.mlp_impl == "unfused":
        xn = rms_norm(x2, gamma, cfg.norm_eps)
        h = R.swish(xn @ p[prefix + "w_gate"]) * (xn @ p[prefix + "w_up"])
        out = h @ p[prefix + "w_down"]
    elif cfg.mlp_impl == "pipeline":
        out = _swiglu_pipeline(x2, p[prefix + "w_gate"],
                               p[prefix + "w_up"], p[prefix + "w_down"],
                               gamma, cfg)
    else:
        impl = {"fused_ref": "ref", "pallas": "pallas",
                "interpret": "interpret"}[cfg.mlp_impl]
        out = K.rmsnorm_swiglu(x2, p[prefix + "w_gate"], p[prefix + "w_up"],
                               p[prefix + "w_down"], gamma,
                               eps=cfg.norm_eps, impl=impl)
    return constrain(out.reshape(b, s, d), "batch", None, None)


# ---------------------------------------------------------------------------
# MoE (qwen3-moe / deepseek-v3 / jamba): top-k routing with capacity,
# scatter dispatch into per-expert buffers, EP over the 'expert' axis.
# ---------------------------------------------------------------------------

def init_moe(pb: ParamBuilder, cfg: ModelConfig) -> None:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    pb.dense("router", (d, e), (None, None), scale=0.02)
    pb.dense("we_gate", (e, d, f), ("expert", "fsdp", None))
    pb.dense("we_up", (e, d, f), ("expert", "fsdp", None))
    pb.dense("we_down", (e, f, d), ("expert", None, "fsdp"))
    if cfg.n_shared_experts:
        init_swiglu(pb, cfg, cfg.moe_d_ff * cfg.n_shared_experts, "shared_")


def moe_apply(p, x, gamma, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d).  RMSNorm -> router -> top-k experts (+ shared).

    With an active mesh the dispatch/combine run through the shard_map
    path (shard-local scatter, deterministic shardings): GSPMD's generic
    scatter partitioning replicates the (E, C, d) buffer and all-reduces
    it — measured 13TB/chip/step on deepseek-v3 train_4k (§Perf)."""
    from repro.runtime.sharding import active_mesh
    mesh = active_mesh()
    if (cfg.moe_impl == "shard_map" and mesh is not None
            and "data" in mesh.axis_names and "model" in mesh.axis_names):
        return _moe_apply_sharded(p, x, gamma, cfg, mesh)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xn = rms_norm(x, gamma, cfg.norm_eps).reshape(b * s, d)
    t = b * s

    logits = (xn.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)             # (T,k)
    top_w = top_w / top_w.sum(-1, keepdims=True)

    import math
    capacity = int(min(t, max(1, math.ceil(t * k * cfg.capacity_factor / e))))
    onehot = jax.nn.one_hot(top_ids, e, dtype=jnp.int32)  # (T,k,E)
    flat = onehot.reshape(t * k, e)
    # position of each assignment within its expert.  NOTE: jnp.cumsum
    # lowers to reduce-window (cost = elements x window -> quadratic in
    # tokens; measured 1.1e15 flops/chip on the 256-chip mesh);
    # associative_scan is the log-depth prefix sum.
    pos_in_expert = jax.lax.associative_scan(jnp.add, flat, axis=0) - flat
    pos = (pos_in_expert * flat).sum(-1).reshape(t, k)    # (T,k)
    keep = pos < capacity

    # dropped assignments scatter a zero contribution into slot 0 of their
    # expert (keeps the buffer evenly shardable over the expert axis)
    slot = top_ids * capacity + jnp.minimum(pos, capacity - 1)  # (T,k)
    updates = jnp.repeat(xn, k, axis=0) * keep.reshape(-1, 1).astype(xn.dtype)
    buf = jnp.zeros((e * capacity, d), xn.dtype)
    buf = buf.at[slot.reshape(-1)].add(updates)
    eb = buf.reshape(e, capacity, d)
    # shard experts over the model axis (EP) AND capacity over the data
    # axes — otherwise every data-parallel replica runs the full global
    # expert batch (measured 16x flop replication on the 256-chip mesh)
    eb = constrain(eb, "expert", "capacity", None)

    h = constrain(jnp.einsum("ecd,edf->ecf", eb, p["we_gate"]),
                  "expert", "capacity", None)
    u = constrain(jnp.einsum("ecd,edf->ecf", eb, p["we_up"]),
                  "expert", "capacity", None)
    h = R.swish(h) * u
    eo = jnp.einsum("ecf,efd->ecd", h, p["we_down"])
    eo = constrain(eo, "expert", "capacity", None)

    flat_out = eo.reshape(e * capacity, d)
    routed = flat_out[slot]                                # (T,k,d)
    routed = constrain(routed, "batch", None, None)
    w = (top_w * keep).astype(routed.dtype)
    out = jnp.einsum("tkd,tk->td", routed, w)

    if cfg.n_shared_experts:
        xs = xn
        hsh = R.swish(xs @ p["shared_w_gate"]) * (xs @ p["shared_w_up"])
        out = out + hsh @ p["shared_w_down"]
    return constrain(out.reshape(b, s, d).astype(x.dtype),
                     "batch", None, None)


def _moe_apply_sharded(p, x, gamma, cfg: ModelConfig, mesh) -> jax.Array:
    """EP MoE with shard_map dispatch/combine (capacity enforced per data
    shard — standard local-capacity semantics).

      1. routing: token-sharded top-k (plain SPMD ops);
      2. dispatch: per-data-shard local scatter into (E, C_local, d) —
         zero collectives, deterministic sharding;
      3. experts: the (E, C, d) buffer resharded to (expert->model,
         capacity->data) with one cheap all-to-all; einsums fully sharded;
      4. combine: per-(model,data) shard masked local gather of its own
         experts' rows + psum over model (bf16 partials).
    """
    import math
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xn = rms_norm(x, gamma, cfg.norm_eps).reshape(t, d)
    xn = constrain(xn, "batch", None)

    logits = (xn.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)
    top_w = (top_w / top_w.sum(-1, keepdims=True)).astype(xn.dtype)

    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_shards = math.prod(mesh.shape[a] for a in dax)
    t_local = t // n_shards
    cap_local = int(min(t_local,
                        max(1, math.ceil(t_local * k
                                         * cfg.capacity_factor / e))))
    e_local = e // mesh.shape["model"]

    def local_dispatch(xn_l, ids_l):
        onehot = jax.nn.one_hot(ids_l, e, dtype=jnp.int32)
        flat = onehot.reshape(-1, e)
        pos = jax.lax.associative_scan(jnp.add, flat, axis=0) - flat
        pos_tk = (pos * flat).sum(-1).reshape(-1, k)
        keep = pos_tk < cap_local
        slot = ids_l * cap_local + jnp.minimum(pos_tk, cap_local - 1)
        upd = jnp.repeat(xn_l, k, axis=0) * keep.reshape(-1, 1).astype(
            xn_l.dtype)
        buf = jnp.zeros((e * cap_local, d), xn_l.dtype)
        buf = buf.at[slot.reshape(-1)].add(upd)
        return buf.reshape(e, cap_local, d), slot, keep

    eb, slot, keep = shard_map(
        local_dispatch, mesh=mesh,
        in_specs=(P(dax), P(dax)),
        out_specs=(P(None, dax, None), P(dax), P(dax)),
        check_rep=False,
    )(xn, top_ids)
    eb = constrain(eb, "expert", "capacity", None)

    h = constrain(jnp.einsum("ecd,edf->ecf", eb, p["we_gate"]),
                  "expert", "capacity", None)
    u = constrain(jnp.einsum("ecd,edf->ecf", eb, p["we_up"]),
                  "expert", "capacity", None)
    eo = jnp.einsum("ecf,efd->ecd", R.swish(h) * u, p["we_down"])
    eo = constrain(eo, "expert", "capacity", None)

    def local_combine(eo_l, slot_l, keep_l, w_l):
        # eo_l: (e_local, cap_local, d) — this (model,data) shard's slice;
        # gather only rows of the LOCAL experts, psum partials over model
        midx = jax.lax.axis_index("model")
        e_base = midx * e_local
        flat = eo_l.reshape(e_local * cap_local, d)
        exp_id = slot_l // cap_local
        local = (exp_id >= e_base) & (exp_id < e_base + e_local) & keep_l
        local_slot = jnp.where(local, slot_l - e_base * cap_local, 0)
        routed = flat[local_slot] * local[..., None].astype(flat.dtype)
        out = jnp.einsum("tkd,tk->td", routed, w_l.astype(routed.dtype))
        return jax.lax.psum(out.astype(jnp.bfloat16), "model")

    out = shard_map(
        local_combine, mesh=mesh,
        in_specs=(P("model", dax, None), P(dax), P(dax), P(dax)),
        out_specs=P(dax),
        check_rep=False,
    )(eo, slot, keep, top_w)

    if cfg.n_shared_experts:
        out = out.astype(xn.dtype) + (
            R.swish(xn @ p["shared_w_gate"])
            * (xn @ p["shared_w_up"])) @ p["shared_w_down"]
    return constrain(out.reshape(b, s, d).astype(x.dtype),
                     "batch", None, None)


def moe_ref(p, x, gamma, cfg: ModelConfig) -> jax.Array:
    """Dense per-expert loop oracle (tests only; no capacity drops)."""
    b, s, d = x.shape
    xn = rms_norm(x, gamma, cfg.norm_eps).reshape(b * s, d)
    logits = xn.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_w, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    out = jnp.zeros_like(xn)
    for e_i in range(cfg.n_experts):
        he = R.swish(xn @ p["we_gate"][e_i]) * (xn @ p["we_up"][e_i])
        oe = he @ p["we_down"][e_i]
        wsel = jnp.where(top_ids == e_i, top_w, 0.0).sum(-1)
        out = out + oe * wsel[:, None].astype(oe.dtype)
    if cfg.n_shared_experts:
        out = out + (R.swish(xn @ p["shared_w_gate"])
                     * (xn @ p["shared_w_up"])) @ p["shared_w_down"]
    return out.reshape(b, s, d).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba2 (SSD, chunked) — attention-free; matmul-dominant form for the MXU
# ---------------------------------------------------------------------------

def init_mamba(pb: ParamBuilder, cfg: ModelConfig) -> None:
    d, di, n, hd = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = cfg.n_ssm_heads
    conv_ch = di + 2 * n
    pb.dense("w_in", (d, 2 * di + 2 * n + nh), ("fsdp", "tensor"))
    pb.dense("conv_w", (cfg.ssm_conv, conv_ch), (None, "tensor"), scale=0.5)
    pb.zeros("conv_b", (conv_ch,), ("tensor",))
    pb.zeros("A_log", (nh,), ("tensor",))
    pb.zeros("dt_bias", (nh,), ("tensor",))
    pb.zeros("D", (nh,), ("tensor",))
    pb.ones("ssm_norm", (di,), ("tensor",))
    pb.dense("w_out", (di, d), ("tensor", "fsdp"))


def _mamba_proj(p, x, cfg: ModelConfig):
    di, n, nh = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    zxbcdt = x @ p["w_in"]
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt  # xbc holds conv channels (x_in, B, C)


def _causal_conv(xbc, p, cfg: ModelConfig):
    """Depthwise causal conv, width cfg.ssm_conv (silu activation)."""
    w = p["conv_w"]                                     # (W, C)
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(width))
    return jax.nn.silu(out + p["conv_b"])


def _ssd_chunked(xh, dt, A, B, C, cfg: ModelConfig, h0=None):
    """SSD forward (Mamba-2).  xh: (b,s,nh,hd); dt: (b,s,nh);
    B, C: (b,s,n).  Returns y (b,s,nh,hd) and final state (b,nh,hd,n)."""
    b, s, nh, hd = xh.shape
    n = B.shape[-1]
    q = min(cfg.ssm_chunk, s)
    pad = (-s) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = (s + pad) // q
    xh = xh.reshape(b, L, q, nh, hd).astype(jnp.float32)
    dt = dt.reshape(b, L, q, nh).astype(jnp.float32)
    Bc = B.reshape(b, L, q, n).astype(jnp.float32)
    Cc = C.reshape(b, L, q, n).astype(jnp.float32)

    dA = dt * A[None, None, None, :]                     # (b,L,q,nh) <= 0
    cs = jnp.cumsum(dA, axis=2)
    seg = cs[:, :, :, None, :] - jnp.swapaxes(cs[:, :, :, None, :], 2, 3)
    iota = jnp.arange(q)
    causal = (iota[:, None] >= iota[None, :])[None, None, :, :, None]
    decay = jnp.where(causal, jnp.exp(seg), 0.0)         # (b,L,q,q,nh)

    # intra-chunk (the diagonal blocks): y = (C B^T . decay . dt) x
    cb = jnp.einsum("blqn,blkn->blqk", Cc, Bc)           # (b,L,q,q)
    att = cb[..., None] * decay * dt[:, :, None, :, :]   # (b,L,q,k,nh)
    y_diag = jnp.einsum("blqkh,blkhd->blqhd", att, xh)

    # chunk states: h_c = sum_j exp(cs_end - cs_j) dt_j B_j x_j
    last = cs[:, :, -1:, :]                              # (b,L,1,nh)
    w_end = jnp.exp(last - cs) * dt                      # (b,L,q,nh)
    states = jnp.einsum("blqn,blqh,blqhd->blhdn", Bc, w_end, xh)

    # inter-chunk recurrence over L
    chunk_decay = jnp.exp(last[:, :, 0, :])              # (b,L,nh)

    def scan_fn(h, inp):
        st, dec = inp
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, n), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(states, 1, 0),
                      jnp.moveaxis(chunk_decay, 1, 0)),
                      unroll=L if cfg.unroll_scans else 1)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (b,L,nh,hd,n)

    y_inter = jnp.einsum("blqn,blqh,blhdn->blqhd", Cc, jnp.exp(cs), h_prevs)
    y = (y_diag + y_inter).reshape(b, L * q, nh, hd)[:, :s]
    return y, h_final


def mamba_apply(p, x, gamma, cfg: ModelConfig):
    """Pre-norm Mamba2 block (returns residual delta)."""
    b, s, d = x.shape
    nh, hd, n, di = (cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                     cfg.d_inner)
    xn = rms_norm(x, gamma, cfg.norm_eps)
    z, xbc, dt = _mamba_proj(p, xn, cfg)
    xbc = _causal_conv(xbc, p, cfg)
    xin, B, C = jnp.split(xbc, [di, di + n], axis=-1)
    xh = xin.reshape(b, s, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = _ssd_chunked(xh, dt, A, B, C, cfg)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None,
                                                                :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    return constrain(y @ p["w_out"], "batch", None, None)


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "h": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim, n),
                       jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
    }


def mamba_cache_specs(cfg: ModelConfig):
    return {"h": ("batch", "tensor", None, None),
            "conv": ("batch", None, "tensor")}


def mamba_prefill(p, x, gamma, cfg: ModelConfig):
    """Full-sequence SSD that also returns the decode cache (final SSM state
    + the raw conv window)."""
    b, s, d = x.shape
    nh, hd, n, di = (cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                     cfg.d_inner)
    xn = rms_norm(x, gamma, cfg.norm_eps)
    z, xbc_raw, dt = _mamba_proj(p, xn, cfg)
    xbc = _causal_conv(xbc_raw, p, cfg)
    xin, B, C = jnp.split(xbc, [di, di + n], axis=-1)
    xh = xin.reshape(b, s, nh, hd)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_final = _ssd_chunked(xh, dtv, A, B, C, cfg)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None,
                                                                :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    w = cfg.ssm_conv - 1
    window = jnp.pad(xbc_raw, ((0, 0), (max(w - s, 0), 0), (0, 0)))[:, -w:]
    cache = {"h": h_final, "conv": window.astype(cfg.dtype)}
    return constrain(y @ p["w_out"], "batch", None, None), cache


def mamba_decode(p, x, gamma, cache, cfg: ModelConfig):
    """One-token SSM step: O(1) state update (no KV cache)."""
    b = x.shape[0]
    nh, hd, n, di = (cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                     cfg.d_inner)
    xn = rms_norm(x, gamma, cfg.norm_eps)
    z, xbc, dt = _mamba_proj(p, xn, cfg)                  # x: (b,1,d)
    window = jnp.concatenate([cache["conv"], xbc], axis=1)
    w = p["conv_w"]
    conv = jax.nn.silu((window * w[None]).sum(axis=1, keepdims=True)
                       + p["conv_b"])
    xin, B, C = jnp.split(conv, [di, di + n], axis=-1)
    xh = xin.reshape(b, nh, hd).astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (b,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * A[None])                        # (b,nh)
    Bv = B[:, 0].astype(jnp.float32)                      # (b,n)
    Cv = C[:, 0].astype(jnp.float32)
    h = cache["h"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhd,bn->bhdn", dtv, xh, Bv)
    y = jnp.einsum("bhdn,bn->bhd", h, Cv)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    new_cache = {"h": h, "conv": window[:, 1:]}
    return constrain(y @ p["w_out"], "batch", None, None), new_cache
