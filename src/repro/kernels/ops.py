"""Public fused-kernel API with implementation dispatch + training support.

``impl``:
  * ``"pallas"``    — the TPU kernel (real hardware).
  * ``"interpret"`` — the same kernel body executed by the Pallas
                      interpreter on CPU (correctness validation).
  * ``"ref"``       — the pure-jnp oracle (also the lowering used by the
                      multi-pod dry-run on CPU host devices: XLA sees the
                      same HLO-level math the kernel fuses on TPU).
  * ``None``        — auto: pallas on TPU backends, ref elsewhere.

All three entry points are differentiable: forward runs the fused
implementation, backward is the VJP of the reference (recompute — the
standard Flash-Attention-style backward strategy).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.layernorm_matmul import layernorm_matmul_pallas
from repro.kernels.rmsnorm_swiglu import rmsnorm_swiglu_pallas


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _with_ref_vjp(fused_fn, ref_fn):
    @jax.custom_vjp
    def f(*args):
        return fused_fn(*args)

    def fwd(*args):
        return fused_fn(*args), args

    def bwd(args, ct):
        _, vjp = jax.vjp(ref_fn, *args)
        return vjp(ct)

    f.defvjp(fwd, bwd)
    return f


# ---------------------------------------------------------------------------
# Flash attention (paper Example 1)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: Optional[float] = None, causal: bool = False,
                    q_offset: int = 0, impl: Optional[str] = None,
                    block_q: int = 128, block_kv: int = 512,
                    unroll: bool = False, p_half: bool = False) -> jax.Array:
    impl = impl or default_impl()
    ref_fn = functools.partial(R.attention_ref, scale=scale, causal=causal,
                               q_offset=q_offset)
    if impl == "ref":
        fused = ref_fn
    elif impl == "xla":
        # flash semantics in pure XLA (scan over KV chunks); the scalable
        # non-Pallas lowering used by the dry-run and CPU training
        fused = functools.partial(R.attention_xla_flash, scale=scale,
                                  causal=causal, q_offset=q_offset,
                                  block_kv=block_kv, unroll=unroll,
                                  p_half=p_half)
    else:
        fused = functools.partial(
            flash_attention_pallas, scale=scale, causal=causal,
            q_offset=q_offset, block_q=block_q, block_kv=block_kv,
            interpret=(impl == "interpret"))
    return _with_ref_vjp(fused, ref_fn)(q, k, v)


# ---------------------------------------------------------------------------
# Flash-LayerNorm+Matmul (paper Example 2)
# ---------------------------------------------------------------------------

def layernorm_matmul(x: jax.Array, y: jax.Array, gamma: jax.Array,
                     beta: jax.Array, *, eps: float = 1e-5,
                     impl: Optional[str] = None, block_m: int = 128,
                     block_n: int = 128, block_k: int = 512) -> jax.Array:
    impl = impl or default_impl()
    ref_fn = functools.partial(R.layernorm_matmul_ref, eps=eps)
    if impl == "ref":
        fused = ref_fn
    else:
        fused = functools.partial(
            layernorm_matmul_pallas, eps=eps, block_m=block_m,
            block_n=block_n, block_k=block_k,
            interpret=(impl == "interpret"))
    return _with_ref_vjp(fused, ref_fn)(x, y, gamma, beta)


# ---------------------------------------------------------------------------
# Flash-RMSNorm+FFN-SwiGLU (paper Example 3)
# ---------------------------------------------------------------------------

def rmsnorm_swiglu(x: jax.Array, w: jax.Array, v: jax.Array, u: jax.Array,
                   gamma: jax.Array, *, eps: float = 1e-6,
                   impl: Optional[str] = None, block_m: int = 128,
                   block_k: int = 512) -> jax.Array:
    impl = impl or default_impl()
    ref_fn = functools.partial(R.rmsnorm_swiglu_ref, eps=eps)
    if impl == "ref":
        fused = ref_fn
    else:
        fused = functools.partial(
            rmsnorm_swiglu_pallas, eps=eps, block_m=block_m,
            block_k=block_k, interpret=(impl == "interpret"))
    return _with_ref_vjp(fused, ref_fn)(x, w, v, u, gamma)
