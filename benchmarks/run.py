"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  * fusion_*    — the paper's three worked examples: traffic collapse,
                  launch counts, work replication, rule applications;
  * kernel_*    — fused vs naive kernel wall times (host backend);
  * roofline_*  — per (arch x shape x mesh) bound times from the dry-run
                  artifact (if dryrun_results.json exists).
"""

from __future__ import annotations


def main() -> None:
    from benchmarks import fusion_bench, kernel_bench, roofline

    rows = []
    rows += fusion_bench.run()
    rows += kernel_bench.run()
    rows += roofline.run()

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
