"""Golden fusion-trace regressions.

The paper's two flagship results — Flash Attention rediscovered
(Example 1) and the RMSNorm+FFN-SwiGLU mega-kernel (Example 3) — are
pinned as *exact ordered rule sequences*, not just counts: a rule-priority
regression that still converges to a fused program (but via a different,
possibly costlier route) fails loudly here instead of silently producing
worse snapshots downstream of ``pipeline.compile``.
"""

from collections import Counter

from repro.core import array_program as AP
from repro.core import ops as O
from repro.core.fusion import FusionTrace, fuse
from repro.core.graph import FuncNode, Graph, MapNode, internal_buffered_edges

# Example 1: the paper's 17-step Flash Attention derivation.
GOLDEN_ATTENTION_TRACE = [
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule4_swap_scale_dot",
    "rule3_fuse_map_reduction",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule3_fuse_map_reduction",
    "rule9_fuse_consecutive_elementwise",
    "rule3_fuse_map_reduction",
    "rule6_extend_map",
    "rule1_fuse_consecutive_maps",
]

# Causal attention: the decoder-side flash rediscovery.  Two extra Rule-1
# steps absorb the mask's Map_M{Map_N{causal_mask}} into the score chain;
# the rest replays the Example-1 derivation (the mask rides inside the
# maps, so the serial N-spine still forms and Rule 9 still folds the
# scale into the exp).
GOLDEN_CAUSAL_ATTENTION_TRACE = [
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule4_swap_scale_dot",
    "rule3_fuse_map_reduction",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule3_fuse_map_reduction",
    "rule9_fuse_consecutive_elementwise",
    "rule3_fuse_map_reduction",
    "rule6_extend_map",
    "rule1_fuse_consecutive_maps",
]

# Example 3: the SwiGLU mega-kernel (27 steps: Rule-8 duplication, two
# linearity swaps, two sibling fusions, two map extensions).
GOLDEN_SWIGLU_TRACE = [
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule8_duplicate_mapped_scale",
    "rule4_swap_scale_dot",
    "rule4_swap_scale_dot",
    "rule3_fuse_map_reduction",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule1_fuse_consecutive_maps",
    "rule3_fuse_map_reduction",
    "rule9_fuse_consecutive_elementwise",
    "rule3_fuse_map_reduction",
    "rule3_fuse_map_reduction",
    "rule2_fuse_sibling_maps",
    "rule6_extend_map",
    "rule1_fuse_consecutive_maps",
    "rule6_extend_map",
    "rule2_fuse_sibling_maps",
]


def _trace(graph):
    t = FusionTrace()
    fuse(graph, t)
    return [r for r, _ in t.steps]


def test_flash_attention_golden_trace():
    got = _trace(AP.attention_program(0.125))
    assert len(got) == 17, got  # the paper's step count
    assert got == GOLDEN_ATTENTION_TRACE, got


def test_swiglu_megakernel_golden_trace():
    got = _trace(AP.rmsnorm_ffn_swiglu_program(512.0))
    assert got == GOLDEN_SWIGLU_TRACE, got


def _serial_map(g: Graph):
    """Descend the single-map spine to the serial (accumulated) map."""
    cur = g
    while True:
        (mid,) = [n for n in cur.op_nodes()
                  if isinstance(cur.nodes[n], MapNode)]
        node = cur.nodes[mid]
        if node.serial:
            return node
        cur = node.inner


def _has_causal_mask(g: Graph) -> bool:
    for node in g.nodes.values():
        if isinstance(node, FuncNode) and isinstance(node.op,
                                                     O.CausalMask):
            return True
        if isinstance(node, MapNode) and _has_causal_mask(node.inner):
            return True
    return False


def test_causal_attention_golden_trace():
    got = _trace(AP.causal_attention_program(0.125))
    assert got == GOLDEN_CAUSAL_ATTENTION_TRACE, got


def test_causal_mask_fuses_into_serial_map():
    """The mask must ride inside the serial N-map of the flash spine —
    not split the spine into separate kernels (the fused program is
    buffer-free and the masked score feeds the in-loop exp directly)."""
    final = fuse(AP.causal_attention_program(0.125))[-1]
    assert internal_buffered_edges(final) == []
    smap = _serial_map(final)
    assert smap.dim == "N"
    assert _has_causal_mask(smap.inner)

    # the same holds under the GQA head-group wrap
    gqa = fuse(AP.gqa_attention_program(0.125, causal=True))[-1]
    assert internal_buffered_edges(gqa) == []
    smap = _serial_map(gqa)
    assert smap.dim == "N" and _has_causal_mask(smap.inner)


def test_gqa_trace_matches_inner_program():
    """The H wrap adds no fusion steps of its own: the GQA trace is the
    inner attention trace replayed one level deeper."""
    assert _trace(AP.gqa_attention_program(0.125)) == \
        _trace(AP.attention_program(0.125))
    assert _trace(AP.gqa_attention_program(0.125, causal=True)) == \
        _trace(AP.causal_attention_program(0.125))


def test_golden_rule_counts():
    """Counts, separately from order, for a friendlier failure signal."""
    att = Counter(_trace(AP.attention_program(0.125)))
    assert att == Counter({"rule1_fuse_consecutive_maps": 11,
                           "rule4_swap_scale_dot": 1,
                           "rule3_fuse_map_reduction": 3,
                           "rule9_fuse_consecutive_elementwise": 1,
                           "rule6_extend_map": 1})
    swi = Counter(_trace(AP.rmsnorm_ffn_swiglu_program(512.0)))
    assert swi == Counter({"rule1_fuse_consecutive_maps": 15,
                           "rule8_duplicate_mapped_scale": 1,
                           "rule4_swap_scale_dot": 2,
                           "rule3_fuse_map_reduction": 4,
                           "rule9_fuse_consecutive_elementwise": 1,
                           "rule2_fuse_sibling_maps": 2,
                           "rule6_extend_map": 2})


def test_golden_trace_independent_of_constants():
    """The trace depends on program *structure* only, never on the baked
    scale constants (selection owns shapes; fusion owns structure)."""
    assert _trace(AP.attention_program(0.125)) == \
        _trace(AP.attention_program(0.99))
    assert _trace(AP.causal_attention_program(0.125)) == \
        _trace(AP.causal_attention_program(0.99))
    assert _trace(AP.rmsnorm_ffn_swiglu_program(512.0)) == \
        _trace(AP.rmsnorm_ffn_swiglu_program(64.0, eps=1e-6))
