"""Appendix: numerical safety via significand-exponent pairs."""

import warnings

import numpy as np
import pytest

from repro.core import ops as O
from repro.core.blocks import merge
from repro.core.fusion import fuse
from repro.core.interpreter import run
from repro.core.numerics import (SEPair, _top_level_exp, pair_add,
                                 run_stabilized, stabilized_apply)
from conftest import make_attention_case


def test_top_level_exp_detection():
    assert _top_level_exp("exp(a0)")
    assert _top_level_exp("exp((a0*0.125))")
    assert not _top_level_exp("a0/(1+exp(-a0))")
    assert not _top_level_exp("exp(a0)+a1")


def test_pair_add_matches_plain():
    rng = np.random.default_rng(0)
    a = SEPair(rng.normal(size=(4, 8)), rng.normal(size=4))
    b = SEPair(rng.normal(size=(4, 8)), rng.normal(size=4))
    got = pair_add(np, a, b).materialize(np)
    want = a.materialize(np) + b.materialize(np)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_stabilized_equals_naive_in_safe_range(attention_case):
    snaps = fuse(attention_case.graph)
    naive = merge(run(snaps[-1], attention_case.inputs,
                      attention_case.dims)["O"])
    stab = merge(run_stabilized(snaps[-1], attention_case.inputs,
                                attention_case.dims)["O"])
    np.testing.assert_allclose(stab, naive, rtol=1e-10, atol=1e-12)


def test_stabilized_survives_huge_logits(rng):
    """The paper's headline appendix claim: the fused kernel plus the
    safety pass = numerically safe Flash Attention (online softmax)."""
    case = make_attention_case(rng, logit_scale=40.0)
    snaps = fuse(case.graph)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        naive = merge(run(snaps[-1], case.inputs, case.dims)["O"])
    assert not np.isfinite(naive).all()
    stab = merge(run_stabilized(snaps[-1], case.inputs, case.dims)["O"])
    assert np.isfinite(stab).all()
    np.testing.assert_allclose(stab, case.ref, rtol=1e-9, atol=1e-9)


def test_stabilized_on_every_snapshot(rng):
    """The pass composes with *any* fusion level (it is representation-only,
    independent of the graph structure)."""
    case = make_attention_case(rng, logit_scale=40.0)
    for s in fuse(case.graph):
        stab = merge(run_stabilized(s, case.inputs, case.dims)["O"])
        np.testing.assert_allclose(stab, case.ref, rtol=1e-9, atol=1e-9)


def test_stabilized_causal_survives_huge_logits(rng):
    """Online *causal* softmax: fully-masked tiles produce pairs with an
    exponent of ~scale*NEG_MASK that must vanish under pair_add, and the
    masked entries of partial tiles must not poison the running max."""
    from repro.core import array_program as AP
    from repro.core import blocks as B

    M = N = 4
    D = L = 2
    b = 8
    seq = M * b
    Q = rng.normal(size=(seq, D * b)) * 30
    K = rng.normal(size=(seq, D * b)) * 30
    V = rng.normal(size=(seq, L * b))
    pos = np.arange(seq, dtype=np.float64)
    scale = 1.0 / np.sqrt(D * b)
    s = np.where(pos[:, None] >= pos[None, :], Q @ K.T, -np.inf) * scale
    p = np.exp(s - s.max(1, keepdims=True))
    ref = (p / p.sum(1, keepdims=True)) @ V
    assert (s.max() > 709), "logits must overflow naive float64 exp"

    inputs = {"Q": B.split(Q, M, D), "KT": B.split(K, N, D),
              "VT": B.split(V.T, L, N), "QP": B.split_rows(pos, M),
              "KP": B.split_rows(pos, N)}
    dims = {"M": M, "D": D, "N": N, "L": L}
    for snap in fuse(AP.causal_attention_program(scale)):
        stab = merge(run_stabilized(snap, inputs, dims)["O"])
        assert np.isfinite(stab).all()
        np.testing.assert_allclose(stab, ref, rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# Expression matching: whitespace- and commutativity-robust (regression:
# the rules used to compare raw expr strings, so "a1+a0" or "1 / a0"
# silently fell off the pair algebra and materialized early)
# ---------------------------------------------------------------------------

def _pair(rng, rows=4, cols=8):
    return SEPair(rng.normal(size=(rows, cols)), rng.normal(size=rows))


@pytest.mark.parametrize("expr", ["a0+a1", "a1+a0", "a0 +a1", " a0 + a1 "])
def test_add_matching_is_canonical(expr, rng):
    a, b = _pair(rng), _pair(rng)
    got = stabilized_apply(O.ew(expr, 2), np, a, b)
    assert isinstance(got, SEPair), expr
    np.testing.assert_allclose(got.materialize(np),
                               a.materialize(np) + b.materialize(np),
                               rtol=1e-12)


@pytest.mark.parametrize("expr", ["a0*a1", "a1*a0", "a0 * a1"])
def test_mul_matching_is_canonical(expr, rng):
    a, b = _pair(rng), _pair(rng)
    got = stabilized_apply(O.ew(expr, 2), np, a, b)
    assert isinstance(got, SEPair), expr
    np.testing.assert_allclose(got.materialize(np),
                               a.materialize(np) * b.materialize(np),
                               rtol=1e-12)


@pytest.mark.parametrize("expr", ["1/a0", "1 / a0", " 1/a0 "])
def test_recip_matching_ignores_whitespace(expr, rng):
    a = _pair(rng)
    got = stabilized_apply(O.ew(expr), np, a)
    assert isinstance(got, SEPair), expr
    np.testing.assert_allclose(got.materialize(np),
                               1.0 / a.materialize(np), rtol=1e-12)


def test_canon_expr_only_swaps_flat_commutative():
    from repro.core.numerics import _canon_expr
    assert _canon_expr("a1+a0") == "a0+a1"
    assert _canon_expr("a1*a0") == "a0*a1"
    assert _canon_expr("a0-a1") == "a0-a1"          # not commutative
    assert _canon_expr("a1+a0*a2") == "a1+a0*a2"    # not a flat 2-op
    assert _canon_expr("exp( a0 )") == "exp(a0)"


# ---------------------------------------------------------------------------
# Uniform rank rule: 1-D significands keep per-element exponents
# (regression: the old rowmax collapsed rank-1 values to one scalar max,
# so a vector pair's exponent lost its per-row resolution)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(6,), (4, 8), (3, 5, 7), ()])
def test_pair_add_mixed_plain_any_rank(shape, rng):
    """pair_add(pair, plain) at every rank: the plain side is wrapped
    with a zero exponent of the pair's row shape and the result matches
    dense addition."""
    from repro.core.numerics import _rowmax
    s = rng.normal(size=shape)
    t = rng.normal(size=np.shape(_rowmax(np, s)))
    pair = SEPair(s, t)
    plain = rng.normal(size=shape)
    got = pair_add(np, pair, plain)
    assert np.shape(got.t) == np.shape(t)
    np.testing.assert_allclose(got.materialize(np),
                               pair.materialize(np) + plain, rtol=1e-12)
    # and symmetrically
    got2 = pair_add(np, plain, pair)
    np.testing.assert_allclose(got2.materialize(np),
                               got.materialize(np), rtol=1e-12)


def test_vector_exp_keeps_per_element_exponent(rng):
    """exp over a 1-D value: each element is its own row, so the
    exponent is the argument itself and the significand is all-ones —
    no cross-element max contaminates the pair."""
    v = rng.normal(size=8) * 500.0   # overflows naive float64 exp pairs
    got = stabilized_apply(O.ew("exp(a0)"), np, v)
    assert isinstance(got, SEPair)
    np.testing.assert_allclose(np.asarray(got.s), np.ones(8))
    np.testing.assert_allclose(np.asarray(got.t), v)


def test_rowmax_reduces_all_trailing_axes(rng):
    from repro.core.numerics import _rowmax
    a = rng.normal(size=(3, 4, 5))
    np.testing.assert_allclose(_rowmax(np, a), a.max(axis=(1, 2)))
    b = rng.normal(size=(6,))
    np.testing.assert_allclose(_rowmax(np, b), b)
    assert np.shape(_rowmax(np, 3.0)) == ()
