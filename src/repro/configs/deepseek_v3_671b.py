"""deepseek-v3-671b [moe]: MLA, 1 shared + 256 routed top-8 experts, 3
leading dense layers.  MTP (multi-token prediction) is a training-objective
variant orthogonal to the fusion technique and is not modeled (noted in
DESIGN.md).  [arXiv:2412.19437; hf]"""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=18432,            # the 3 dense layers
    vocab=129280,
    rope_theta=1e4,
    # MoE
    n_experts=256,
    top_k=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    n_dense_layers=3,
    # MLA
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)
